"""Version-compat shims for the jax API surface this repo relies on.

``shard_map`` moved from ``jax.experimental.shard_map`` (kwarg
``check_rep``) to top-level ``jax.shard_map`` (kwarg ``check_vma``); this
repo supports both so the sharded selection/MoE/GNN paths run on the
container's pinned jax as well as newer releases.
"""
from __future__ import annotations

import jax

try:
    _shard_map_new = jax.shard_map          # newer jax: top-level API
except AttributeError:
    _shard_map_new = None
    from jax.experimental.shard_map import shard_map as _shard_map_old


def shard_map(f, *, mesh, in_specs, out_specs):
    """`jax.shard_map` with replication checking off, on any jax version."""
    if _shard_map_new is not None:
        return _shard_map_new(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
    return _shard_map_old(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
