"""Fixed-shape graph container for JAX.

The graph is stored as an edge list sorted two ways (by src = CSR order, by
dst = CSC order) plus offset arrays, all as dense jnp arrays so every kernel
is shape-stable under jit.  IMM's reverse BFS traverses *in*-edges (CSC view),
GNN message passing traverses src→dst (CSR/edge view).

Edge weights:
  * IC model: ``prob[e]`` — independent activation probability of edge e.
  * LT model: ``lt_weight[e]`` — incoming weight; per-dst weights sum to <= 1.
    ``lt_cum[e]`` is the within-dst-segment cumulative weight so a single
    uniform draw r selects an in-neighbor by searchsorted (or "none" when
    r > total weight), which is exactly the LT RRR random walk of Tang'15.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Graph:
    n: int
    m: int
    # CSR (sorted by src): out-edges
    src_offsets: jnp.ndarray  # (n+1,) int32
    out_dst: jnp.ndarray      # (m,) int32 — dst of each out-edge
    # CSC (sorted by dst): in-edges
    dst_offsets: jnp.ndarray  # (n+1,) int32
    in_src: jnp.ndarray       # (m,) int32 — src of each in-edge
    in_prob: jnp.ndarray      # (m,) float32 — IC prob, CSC order
    in_lt_cum: jnp.ndarray    # (m,) float32 — LT cumulative weight, CSC order
    in_lt_total: jnp.ndarray  # (n,) float32 — per-node total LT weight
    # edge view (CSC order) for message passing / vectorized IC steps
    edge_src: jnp.ndarray     # (m,) int32 (== in_src)
    edge_dst: jnp.ndarray     # (m,) int32

    def in_degree(self):
        return self.dst_offsets[1:] - self.dst_offsets[:-1]

    def out_degree(self):
        return self.src_offsets[1:] - self.src_offsets[:-1]

    def max_in_degree(self) -> int:
        return int(np.max(np.asarray(self.in_degree()))) if self.m else 0


def _offsets_from_sorted(keys: np.ndarray, n: int) -> np.ndarray:
    counts = np.bincount(keys, minlength=n)
    return np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)


def wc_edge_probs(dst, n: int) -> np.ndarray:
    """Weighted-cascade probabilities ``p(u->v) = 1/indeg(v)`` for edges
    with destinations ``dst`` — the single definition shared by
    `build_graph`'s ``weighted_ic="wc"`` option and the WC diffusion
    model (``repro.core.sampler``).  Zero-indegree is clamped to 1."""
    dst = np.asarray(dst)
    indeg = np.bincount(dst, minlength=n).astype(np.float64)
    return 1.0 / np.maximum(indeg[dst], 1.0)


def build_graph(src, dst, n: int, *, ic_prob=None, seed: int = 0,
                weighted_ic: str = "uniform", lt_weight=None) -> Graph:
    """Build a Graph from numpy edge arrays.

    ic_prob: explicit per-edge IC probabilities (aligned with (src,dst)), or
    None → generated: "uniform" U(0,1) per the paper's setup, or "wc" (weighted
    cascade, 1/in_degree).  LT weights are normalized per-dst so they sum to
    <= 1 (paper: "probabilities of either activating a neighbor or activating
    none sum to one").

    lt_weight: explicit per-edge LT weights (aligned with (src, dst)), or
    None → generated from ``seed`` as above.  Explicit weights are taken
    verbatim (callers keep per-dst sums <= 1) — the streaming delta path
    uses this to rebuild a mutated graph while every untouched dst keeps a
    bit-identical LT segment, so RRR walks through unmutated vertices
    re-sample identically.
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    m = src.shape[0]
    rng = np.random.default_rng(seed)

    if ic_prob is None:
        if weighted_ic == "wc":
            ic_prob = wc_edge_probs(dst, n)
        else:
            ic_prob = rng.uniform(0.0, 1.0, size=m)
    ic_prob = np.asarray(ic_prob, dtype=np.float32)

    # CSR order
    order_src = np.argsort(src, kind="stable")
    src_offsets = _offsets_from_sorted(src[order_src], n)
    out_dst = dst[order_src]

    # CSC order
    order_dst = np.argsort(dst, kind="stable")
    dst_sorted = dst[order_dst]
    dst_offsets = _offsets_from_sorted(dst_sorted, n)
    in_src = src[order_dst]
    in_prob = ic_prob[order_dst]

    if lt_weight is None:
        # LT weights: raw U(0,1) normalized per dst (indeg draw totals ~<=1).
        raw = rng.uniform(0.0, 1.0, size=m).astype(np.float64)
        indeg = (dst_offsets[1:] - dst_offsets[:-1]).astype(np.int64)
        # per-dst sum of raw
        seg_sum = np.zeros(n, dtype=np.float64)
        np.add.at(seg_sum, dst_sorted, raw)
        # scale so the per-node total weight is total0 = U(0,1) * (indeg>0)
        total0 = rng.uniform(0.3, 1.0, size=n)
        total0 = np.where(indeg > 0, total0, 0.0)
        scale = np.where(seg_sum > 0, total0 / np.maximum(seg_sum, 1e-30), 0.0)
        w = raw * scale[dst_sorted]
    else:
        w = np.asarray(lt_weight, dtype=np.float64)[order_dst]
    # within-segment cumulative sums
    cum = np.cumsum(w)
    seg_start_cum = np.concatenate([[0.0], cum])[dst_offsets[:-1]]
    lt_cum = cum - seg_start_cum[dst_sorted] if m else np.zeros(0)
    lt_total = np.zeros(n, dtype=np.float64)
    np.add.at(lt_total, dst_sorted, w)

    return Graph(
        n=n,
        m=m,
        src_offsets=jnp.asarray(src_offsets),
        out_dst=jnp.asarray(out_dst),
        dst_offsets=jnp.asarray(dst_offsets),
        in_src=jnp.asarray(in_src),
        in_prob=jnp.asarray(in_prob),
        in_lt_cum=jnp.asarray(lt_cum, dtype=jnp.float32),
        in_lt_total=jnp.asarray(lt_total, dtype=jnp.float32),
        edge_src=jnp.asarray(in_src),
        edge_dst=jnp.asarray(dst_sorted),
    )


def edge_arrays(g: Graph):
    """Host (src, dst, ic_prob, lt_weight) arrays in CSC order — the
    inverse of `build_graph`'s preprocessing, used by the streaming delta
    path to rebuild a mutated graph.

    The per-edge LT weight is recovered from the within-segment
    cumulative sums (``w[e] = lt_cum[e] - lt_cum[e-1]`` inside each dst
    segment, exact float64 differences of float32 values), so a rebuild
    reproduces ``in_lt_cum`` bit-for-bit.  ``in_lt_total`` of a rebuilt
    graph may differ from the original's by one float32 ulp (the forward
    pass summed pre-rounding float64 weights); the round trip is
    **idempotent** after one application, which is why `repro.stream`
    canonicalizes a graph through this path before streaming from it.
    """
    src = np.asarray(g.in_src)
    dst = np.asarray(g.edge_dst)
    prob = np.asarray(g.in_prob)
    lt_cum = np.asarray(g.in_lt_cum, dtype=np.float64)
    dst_offsets = np.asarray(g.dst_offsets)
    w = lt_cum.copy()
    seg_starts = dst_offsets[:-1][dst_offsets[:-1] < g.m]
    interior = np.ones(g.m, bool)
    interior[seg_starts] = False
    w[interior] = lt_cum[interior] - lt_cum[np.flatnonzero(interior) - 1]
    return src, dst, prob, w


def dense_ic_matrix(g: Graph, probs=None) -> jnp.ndarray:
    """Dense (n, n) matrix P with P[u, v] = activation prob of edge u->v.

    ``probs`` overrides the per-edge marginals (CSC order, aligned with
    ``in_src``/``edge_dst``) — diffusion models other than IC supply
    theirs here; None uses the graph's IC probabilities.  Used by the
    dense (bitmap) sampling branch; only valid for small n.
    """
    P = np.zeros((g.n, g.n), dtype=np.float32)
    P[np.asarray(g.in_src), np.asarray(g.edge_dst)] = np.asarray(
        g.in_prob if probs is None else probs, dtype=np.float32)
    return jnp.asarray(P)
