"""Uniform fan-out neighbor sampler (GraphSAGE minibatch training).

Pure-JAX, shape-stable: for each seed node, samples ``fanout`` in-neighbors
uniformly with replacement from the CSC adjacency (standard GraphSAGE
estimator).  Zero-degree nodes sample the sentinel ``n`` (masked downstream).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def neighbor_sampler(key, dst_offsets, in_src, seeds, fanout: int):
    """seeds: (B,) int32 → (B, fanout) sampled neighbor ids (sentinel n for
    isolated nodes)."""
    n = dst_offsets.shape[0] - 1
    start = dst_offsets[seeds]
    deg = dst_offsets[seeds + 1] - start
    u = jax.random.uniform(key, (seeds.shape[0], fanout))
    pick = start[:, None] + jnp.floor(u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
    nbrs = in_src[jnp.clip(pick, 0, in_src.shape[0] - 1)]
    return jnp.where(deg[:, None] > 0, nbrs, n)


def sample_blocks(key, dst_offsets, in_src, seeds, fanouts):
    """Multi-hop sampling: returns list of (frontier, nbrs) per hop, where
    hop i samples fanouts[i] neighbors for every node in the previous
    frontier. frontier_0 = seeds."""
    blocks = []
    frontier = seeds
    for i, f in enumerate(fanouts):
        key, sub = jax.random.split(key)
        nbrs = neighbor_sampler(sub, dst_offsets, in_src, frontier, f)
        blocks.append((frontier, nbrs))
        frontier = nbrs.reshape(-1)
    return blocks
