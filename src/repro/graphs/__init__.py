from repro.graphs.csr import Graph, build_graph
from repro.graphs.generators import rmat_graph, erdos_graph, star_graph, path_graph
from repro.graphs.datasets import SNAP_STATS, synthetic_snap, scaled_snap
from repro.graphs.partition import (
    VertexPartition,
    balance_report,
    balanced_vertex_partition,
    partition_edges_by_dst,
    resolve_partition,
    vertex_partition,
)
from repro.graphs.sampler import neighbor_sampler

__all__ = [
    "Graph",
    "build_graph",
    "rmat_graph",
    "erdos_graph",
    "star_graph",
    "path_graph",
    "SNAP_STATS",
    "synthetic_snap",
    "scaled_snap",
    "VertexPartition",
    "balance_report",
    "balanced_vertex_partition",
    "partition_edges_by_dst",
    "resolve_partition",
    "vertex_partition",
    "neighbor_sampler",
]
