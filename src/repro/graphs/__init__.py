from repro.graphs.csr import Graph, build_graph
from repro.graphs.generators import rmat_graph, erdos_graph, star_graph, path_graph
from repro.graphs.datasets import SNAP_STATS, synthetic_snap, scaled_snap
from repro.graphs.partition import partition_edges_by_dst
from repro.graphs.sampler import neighbor_sampler

__all__ = [
    "Graph",
    "build_graph",
    "rmat_graph",
    "erdos_graph",
    "star_graph",
    "path_graph",
    "SNAP_STATS",
    "synthetic_snap",
    "scaled_snap",
    "partition_edges_by_dst",
    "neighbor_sampler",
]
