"""Host-side vertex/edge partitioners — the NUMA-placement analogue
(DESIGN §2 C2).

Full-graph GNN training shards nodes into contiguous blocks across the mesh's
data axis.  Edges are sorted so every shard's edge slab targets only its own
dst block; the per-slab ``segment_sum`` then needs no cross-device scatter
(only the src-feature all-gather), mirroring EfficientIMM's "RRRsets local,
counters reduced" layout.  Slabs are padded to equal length (SPMD shape
stability); padding edges point at the dropped sentinel dst.

`VertexPartition` is the one definition of the *vertex-axis* block layout
the 2D influence pipeline shares: the `ShardedStore` arena columns, the
samplers' column-sharded activation tables, sharded selection's
local<->global vertex id mapping, and the streaming reverse-touch queries
all agree on the same contiguous blocks, so no layer ever reindexes
another's output.

Two layouts live behind the one abstraction:

* **equal** (``bounds is None``): vertex ``u`` lives in block
  ``u // block`` at local id ``u % block`` — pure arithmetic, traceable.
* **balanced** (``bounds`` set): blocks are still contiguous ascending
  runs of global ids, but the boundaries are *data-dependent* — chosen by
  `balanced_vertex_partition` so per-shard dst-edge counts are near-equal
  on skewed (power-law) graphs.  Every tile is padded to the width of the
  largest block (``block = max(sizes)``), so SPMD shapes stay uniform;
  pad columns hold no vertex and stay all-zero everywhere.

Because both layouts keep blocks contiguous and ascending, any consumer
that resolves "first global id with the max value" per shard and then
takes the first shard with the global max gets exactly the unsharded
first-argmax answer — which is why selection stays seed-for-seed
identical when the boundaries move.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class VertexPartition:
    """Contiguous block partition of ``n`` vertices over ``shards``
    vertex shards.  ``block`` is the padded tile width (the largest block
    size); ``n_pad = shards * block`` is the SPMD-padded column count
    (pad columns hold no vertex and stay all-zero everywhere).

    ``bounds`` is ``None`` for the equal-block layout (vertex ``u`` lives
    in block ``u // block``), or a tuple of ``shards + 1`` ascending
    start offsets (``bounds[0] == 0``, ``bounds[-1] == n``) for an
    edge-balanced layout with data-dependent boundaries.
    """
    n: int
    shards: int
    block: int      # padded tile width (max vertices in any shard)
    n_pad: int      # shards * block — the padded global column count
    bounds: tuple = None   # None (equal) or (shards+1,) ascending starts

    # -- layout queries ----------------------------------------------------
    @property
    def starts(self) -> np.ndarray:
        """(shards + 1,) int32 block start offsets in global-id space
        (``starts[s] .. starts[s+1]`` is shard s's vertex range)."""
        if self.bounds is None:
            return np.minimum(
                np.arange(self.shards + 1, dtype=np.int64) * self.block,
                self.n).astype(np.int32)
        return np.asarray(self.bounds, dtype=np.int32)

    @property
    def sizes(self) -> np.ndarray:
        """(shards,) int32 live vertex count per shard (≤ ``block``)."""
        return np.diff(self.starts).astype(np.int32)

    def block_of(self, u):
        if self.bounds is None:
            return u // self.block
        return np.searchsorted(self.starts, u, side="right") - 1

    def local_id(self, u):
        if self.bounds is None:
            return u - (u // self.block) * self.block
        return u - self.starts[self.block_of(u)]

    def padded_col(self, u):
        """Padded column index of vertex ``u`` in the (n_pad,) layout."""
        return self.block_of(u) * self.block + self.local_id(u)

    # -- host-side gather maps (layout <-> global order) -------------------
    def source_cols(self) -> np.ndarray:
        """(n_pad,) int32: global vertex id backing each padded column,
        or the sentinel ``n`` for pad columns (gather with a masked
        source to build the layout from a global-order array)."""
        starts, sizes = self.starts, self.sizes
        cols = np.full(self.n_pad, self.n, dtype=np.int32)
        for s in range(self.shards):
            c = int(sizes[s])
            cols[s * self.block: s * self.block + c] = np.arange(
                starts[s], starts[s] + c, dtype=np.int32)
        return cols

    def padded_cols(self) -> np.ndarray:
        """(n,) int32: padded column of each vertex (inverse of
        `source_cols` restricted to live columns; gather with it to put a
        layout array back in global vertex order)."""
        starts, sizes = self.starts, self.sizes
        out = np.empty(self.n, dtype=np.int32)
        for s in range(self.shards):
            c = int(sizes[s])
            out[starts[s]: starts[s] + c] = s * self.block + np.arange(
                c, dtype=np.int32)
        return out

    @property
    def is_equal(self) -> bool:
        return self.bounds is None


def vertex_partition(n: int, shards: int) -> VertexPartition:
    """The canonical equal-block vertex-axis layout for ``n`` vertices
    over ``shards`` shards (shards=1 degenerates to the unsharded layout:
    block == n_pad == n)."""
    shards = max(int(shards), 1)
    block = -(-int(n) // shards)
    return VertexPartition(int(n), shards, block, shards * block)


def balanced_vertex_partition(n: int, shards: int, dst=None,
                              weights=None) -> VertexPartition:
    """Edge-balanced contiguous layout: block boundaries are placed at
    the quantiles of the cumulative per-vertex weight (dst-degree + 1 by
    default), so each shard owns a near-equal share of the edges that
    `partition_edges_by_dst` / the store's column tiles will route to it.

    Blocks remain contiguous ascending global-id runs — only the
    boundaries are data-dependent — so every consumer of
    `VertexPartition` (store tiles, selection's id mapping, reverse
    touch) works unchanged.  The ``+ 1`` vertex term keeps isolated
    vertices weighted, so blocks stay non-degenerate on sparse graphs.
    """
    shards = max(int(shards), 1)
    n = int(n)
    if weights is None:
        deg = np.zeros(n, dtype=np.int64)
        if dst is not None and len(np.asarray(dst)):
            deg = np.bincount(
                np.asarray(dst, dtype=np.int64), minlength=n)[:n]
        weights = deg + 1
    w = np.asarray(weights, dtype=np.float64)
    if w.shape != (n,):
        raise ValueError(f"weights must be shape ({n},), got {w.shape}")
    cum = np.cumsum(w)
    total = cum[-1] if n else 0.0
    targets = total * np.arange(1, shards, dtype=np.float64) / shards
    cuts = np.searchsorted(cum, targets, side="left") + 1
    starts = np.concatenate([[0], np.minimum(cuts, n), [n]])
    starts = np.maximum.accumulate(starts).astype(np.int64)
    sizes = np.diff(starts)
    block = int(sizes.max()) if shards else n
    block = max(block, 1)
    return VertexPartition(n, shards, block, shards * block,
                           bounds=tuple(int(s) for s in starts))


def resolve_partition(spec, n: int, shards: int, dst=None) -> VertexPartition:
    """Resolve a partition request to a concrete `VertexPartition`:
    ``None``/``"equal"`` -> equal blocks, ``"balanced"`` -> edge-balanced
    (needs ``dst``), or pass a `VertexPartition` through (validated)."""
    if isinstance(spec, VertexPartition):
        if spec.n != int(n) or spec.shards != int(shards):
            raise ValueError(
                f"partition is for n={spec.n} shards={spec.shards}, "
                f"need n={n} shards={shards}")
        return spec
    if spec is None or spec == "equal":
        return vertex_partition(n, shards)
    if spec == "balanced":
        return balanced_vertex_partition(n, shards, dst=dst)
    raise ValueError(f"unknown partition spec {spec!r}")


def partition_edges_by_dst(src, dst, n_nodes: int, n_shards: int,
                           partition: VertexPartition = None):
    """Returns (src_slabs, dst_slabs, node_block) with shapes
    (n_shards, slab_len) int32; node_block is the padded tile width
    (``partition.block``, ceil(n/n_shards) for the default equal layout).

    dst ids in slab s are LOCAL to block s (0..node_block-1); padding edges
    carry local dst == node_block (dropped by segment_sum with
    num_segments=node_block).
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    part = (partition if partition is not None
            else vertex_partition(n_nodes, n_shards))
    if part.shards != n_shards:
        raise ValueError(
            f"partition has {part.shards} shards, expected {n_shards}")
    node_block = part.block
    block_starts = part.starts
    shard_of = np.asarray(part.block_of(dst), dtype=np.int64)
    order = np.argsort(shard_of, kind="stable")
    src_s, dst_s, shard_s = src[order], dst[order], shard_of[order]
    counts = np.bincount(shard_s, minlength=n_shards)
    slab_len = int(counts.max()) if len(counts) else 1
    src_slabs = np.full((n_shards, slab_len), 0, dtype=np.int32)
    dst_slabs = np.full((n_shards, slab_len), node_block, dtype=np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for s in range(n_shards):
        c = counts[s]
        sl = slice(starts[s], starts[s] + c)
        src_slabs[s, :c] = src_s[sl]
        dst_slabs[s, :c] = dst_s[sl] - block_starts[s]
    return src_slabs, dst_slabs, node_block


def balance_report(dst, n_nodes: int, n_shards: int,
                   partition: VertexPartition = None) -> dict:
    """Imbalance stats (max/mean dst-edges per shard) for a layout —
    the quantity `balanced_vertex_partition` minimizes and BENCH_5
    reports per mesh row."""
    part = (partition if partition is not None
            else vertex_partition(n_nodes, n_shards))
    dst = np.asarray(dst, dtype=np.int64)
    counts = np.bincount(np.asarray(part.block_of(dst), dtype=np.int64),
                         minlength=n_shards)
    mean = counts.mean() if counts.size else 0.0
    return {
        "max_edges": int(counts.max()) if counts.size else 0,
        "mean_edges": float(mean),
        "imbalance": float(counts.max() / max(mean, 1e-9))
        if counts.size else 1.0,
    }
