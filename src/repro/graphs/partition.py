"""Host-side vertex/edge partitioners — the NUMA-placement analogue
(DESIGN §2 C2).

Full-graph GNN training shards nodes into contiguous blocks across the mesh's
data axis.  Edges are sorted so every shard's edge slab targets only its own
dst block; the per-slab ``segment_sum`` then needs no cross-device scatter
(only the src-feature all-gather), mirroring EfficientIMM's "RRRsets local,
counters reduced" layout.  Slabs are padded to equal length (SPMD shape
stability); padding edges point at the dropped sentinel dst.

`VertexPartition` is the one definition of the *vertex-axis* block layout
the 2D influence pipeline shares: the `ShardedStore` arena columns, the
samplers' column-sharded activation tables, sharded selection's
local<->global vertex id mapping, and the streaming reverse-touch queries
all agree on the same contiguous equal blocks (vertex ``u`` lives in block
``u // block``), so no layer ever reindexes another's output.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class VertexPartition:
    """Contiguous equal-block partition of ``n`` vertices over ``shards``
    vertex shards.  ``n_pad = shards * block`` is the SPMD-padded column
    count (pad columns hold no vertex and stay all-zero everywhere);
    vertex ``u`` lives in block ``u // block`` at local id ``u % block``.
    """
    n: int
    shards: int
    block: int      # vertices per shard (ceil(n / shards))
    n_pad: int      # shards * block — the padded global column count

    def local_id(self, u):
        return u - (u // self.block) * self.block

    def block_of(self, u):
        return u // self.block


def vertex_partition(n: int, shards: int) -> VertexPartition:
    """The canonical vertex-axis block layout for ``n`` vertices over
    ``shards`` shards (shards=1 degenerates to the unsharded layout:
    block == n_pad == n)."""
    shards = max(int(shards), 1)
    block = -(-int(n) // shards)
    return VertexPartition(int(n), shards, block, shards * block)


def partition_edges_by_dst(src, dst, n_nodes: int, n_shards: int):
    """Returns (src_slabs, dst_slabs, node_block) with shapes
    (n_shards, slab_len) int32; node_block = ceil(n/n_shards).

    dst ids in slab s are LOCAL to block s (0..node_block-1); padding edges
    carry local dst == node_block (dropped by segment_sum with
    num_segments=node_block).
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    node_block = -(-n_nodes // n_shards)
    shard_of = dst // node_block
    order = np.argsort(shard_of, kind="stable")
    src_s, dst_s, shard_s = src[order], dst[order], shard_of[order]
    counts = np.bincount(shard_s, minlength=n_shards)
    slab_len = int(counts.max()) if len(counts) else 1
    src_slabs = np.full((n_shards, slab_len), 0, dtype=np.int32)
    dst_slabs = np.full((n_shards, slab_len), node_block, dtype=np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for s in range(n_shards):
        c = counts[s]
        sl = slice(starts[s], starts[s] + c)
        src_slabs[s, :c] = src_s[sl]
        dst_slabs[s, :c] = dst_s[sl] - s * node_block
    return src_slabs, dst_slabs, node_block


def balance_report(dst, n_nodes: int, n_shards: int) -> dict:
    """Imbalance stats for EXPERIMENTS (max/mean edges per shard)."""
    node_block = -(-n_nodes // n_shards)
    counts = np.bincount(np.asarray(dst) // node_block, minlength=n_shards)
    mean = counts.mean() if counts.size else 0.0
    return {
        "max_edges": int(counts.max()),
        "mean_edges": float(mean),
        "imbalance": float(counts.max() / max(mean, 1e-9)),
    }
