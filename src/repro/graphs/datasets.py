"""SNAP dataset registry (paper Table I) + hermetic synthetic stand-ins.

Offline container: real SNAP downloads are unavailable, so ``synthetic_snap``
generates an R-MAT graph matching each dataset's |V|, |E| and directedness.
``scaled_snap`` shrinks both by ``scale`` while preserving density — used by
the CPU benchmarks so every paper table/figure runs in seconds.
"""
from __future__ import annotations

from repro.graphs.generators import rmat_graph

# name: (nodes, edges, directed)  — paper Table I
SNAP_STATS = {
    "com-Amazon":  (334_863, 925_872, False),
    "com-YouTube": (1_134_890, 2_987_624, False),
    "com-DBLP":    (317_080, 1_049_866, False),
    "com-LJ":      (3_997_962, 34_681_189, False),
    "soc-Pokec":   (1_632_803, 30_622_564, True),
    "as-Skitter":  (1_696_415, 11_095_298, False),
    "web-Google":  (875_713, 5_105_039, True),
    "Twitter7":    (41_652_230, 1_468_365_182, True),
}


def synthetic_snap(name: str, *, seed: int = 0, **kw):
    n, m, directed = SNAP_STATS[name]
    return rmat_graph(n, m, seed=seed, directed=directed, **kw)


def scaled_snap(name: str, scale: float, *, seed: int = 0, **kw):
    """Density-preserving shrink for CPU benchmarking."""
    n, m, directed = SNAP_STATS[name]
    ns = max(int(n * scale), 64)
    ms = max(int(m * scale), 4 * ns)
    return rmat_graph(ns, ms, seed=seed, directed=directed, **kw)
