"""Synthetic graph generators.

``rmat_graph`` produces power-law graphs with the skewed degree distributions
and large SCCs that drive the paper's observations (Table I: IC RRRsets cover
>50% of most social graphs).  Used as hermetic stand-ins for SNAP datasets.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.csr import Graph, build_graph


def rmat_graph(n: int, m: int, *, seed: int = 0, a=0.57, b=0.19, c=0.19,
               directed: bool = True, **kw) -> Graph:
    """Recursive-matrix (Kronecker) generator, R-MAT parameters a,b,c,d."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(n, 2))))
    n_pow = 1 << scale
    d = 1.0 - a - b - c
    probs = np.array([a, b, c, d])
    # vectorized: for each edge, sample `scale` quadrant choices
    quad = rng.choice(4, size=(m, scale), p=probs)
    row_bits = (quad == 2) | (quad == 3)
    col_bits = (quad == 1) | (quad == 3)
    weights = (1 << np.arange(scale - 1, -1, -1)).astype(np.int64)
    src = (row_bits @ weights) % n
    dst = (col_bits @ weights) % n
    # drop self loops, dedupe
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if not directed:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    eid = src.astype(np.int64) * n + dst.astype(np.int64)
    _, uniq = np.unique(eid, return_index=True)
    src, dst = src[uniq], dst[uniq]
    return build_graph(src, dst, n, seed=seed, **kw)


def erdos_graph(n: int, m: int, *, seed: int = 0, **kw) -> Graph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=2 * m)
    dst = rng.integers(0, n, size=2 * m)
    keep = src != dst
    src, dst = src[keep][:m], dst[keep][:m]
    eid = src.astype(np.int64) * n + dst.astype(np.int64)
    _, uniq = np.unique(eid, return_index=True)
    return build_graph(src[uniq], dst[uniq], n, seed=seed, **kw)


def star_graph(n: int, *, p: float = 0.5, seed: int = 0) -> Graph:
    """Hub 0 -> spokes 1..n-1, every edge with IC prob p (closed-form tests)."""
    src = np.zeros(n - 1, dtype=np.int32)
    dst = np.arange(1, n, dtype=np.int32)
    prob = np.full(n - 1, p, dtype=np.float32)
    return build_graph(src, dst, n, ic_prob=prob, seed=seed)


def path_graph(n: int, *, p: float = 1.0, seed: int = 0) -> Graph:
    """0 -> 1 -> ... -> n-1 with fixed edge prob (closed-form tests)."""
    src = np.arange(0, n - 1, dtype=np.int32)
    dst = np.arange(1, n, dtype=np.int32)
    prob = np.full(n - 1, p, dtype=np.float32)
    return build_graph(src, dst, n, ic_prob=prob, seed=seed)
