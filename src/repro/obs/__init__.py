"""IMTrace — phase-timed spans + a metrics registry for every tier.

The repo-wide observability switchboard.  Instrumented code (engine,
store, stream, serve, launch, benchmarks) calls the module-level helpers
unconditionally:

    from repro import obs

    with obs.span("sample", tier="engine"):
        ...
    obs.counter("store.rows_written").add(B)
    obs.gauge("store.bytes_per_device").set(tile_bytes)
    obs.histogram("serve.latency_ms", tenant=name).observe(ms)

and this module routes them to a live `MetricsRegistry` + `Tracer` when
observability is **enabled**, or to shared no-op singletons when it is
**disabled** (the default).

**Overhead contract** (the reason the switch exists):

  * *Disabled* (default): every helper is one module-global flag check
    returning a pre-built singleton — no allocation, no lock, no
    string formatting; ``span`` returns a reusable null context
    manager.  Nothing is recorded anywhere.
  * *Enabled*: records are host-side only — a ``perf_counter_ns`` pair
    per span, one locked increment per metric.  Nothing in this package
    is ever called inside ``jax.jit`` / ``shard_map`` / Pallas kernels,
    so tracing can never alter a compiled computation, add a device
    sync, or touch a PRNG stream.
  * *Either way*: seed-for-seed results are bitwise identical with obs
    on and off (gated by ``tests/force_obs_check.py`` on a forced
    8-device 2x4 mesh and ``tests/test_obs.py`` single-device).

``enable(jax_annotations=True)`` additionally bridges every span into a
``jax.profiler.TraceAnnotation`` so a device profile captured alongside
carries the same phase names as the host spans.

Snapshots: ``obs.snapshot()`` / ``obs.write_metrics(path)`` export the
registry (consumed by ``benchmarks/_emit.py`` and the ``--metrics-out``
launch flags); ``obs.chrome_trace()`` / ``obs.write_trace(path)`` export
the span timeline as Chrome trace-event JSON loadable in Perfetto
(``--trace-out``).  See docs/observability.md for the metric catalog
and span-phase names.
"""
from __future__ import annotations

import contextlib

from repro.obs.metrics import (                           # noqa: F401
    Counter, Gauge, Histogram, LATENCY_BUCKETS_MS, MetricsRegistry,
    SIZE_BUCKETS, series_key,
)
from repro.obs.tracer import PHASES, Span, Tracer         # noqa: F401

_enabled = False
_registry: MetricsRegistry = MetricsRegistry()
_tracer: Tracer = Tracer()

#: Reusable null context manager handed out by `span` when disabled
#: (contextlib.nullcontext is reentrant and reusable by contract).
_NULL_SPAN = contextlib.nullcontext()


class _NoopInstrument:
    """Shared do-nothing stand-in for every instrument kind."""

    __slots__ = ()

    def add(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    value = 0
    max = 0.0
    count = 0
    sum = 0.0

    def percentile(self, p: float) -> float:
        return 0.0


_NOOP = _NoopInstrument()


# ------------------------------------------------------------- switch ----

def enable(*, registry: MetricsRegistry = None, tracer: Tracer = None,
           jax_annotations: bool = False) -> None:
    """Turn observability on (idempotent).

    Fresh ``registry``/``tracer`` objects replace the current ones when
    given; otherwise new empty ones are installed on the first enable
    and kept across enable/disable cycles (so a disable/enable pair
    does not silently wipe collected data — call `reset` for that).
    ``jax_annotations`` rebuilds the tracer with the device bridge.
    """
    global _enabled, _registry, _tracer
    if registry is not None:
        _registry = registry
    if tracer is not None:
        _tracer = tracer
    elif jax_annotations and _tracer._annotate is None:
        _tracer = Tracer(jax_annotations=True)
    _enabled = True


def disable() -> None:
    """Turn observability off: helpers return no-op singletons again.
    Already-collected data stays readable via `snapshot`/`chrome_trace`."""
    global _enabled
    _enabled = False


def reset() -> None:
    """Disable and drop all collected data (test isolation)."""
    global _enabled, _registry, _tracer
    _enabled = False
    _registry = MetricsRegistry()
    _tracer = Tracer()


def enabled() -> bool:
    return _enabled


# -------------------------------------------------------------- access ----

def get_metrics() -> MetricsRegistry:
    """The live registry (whatever the switch state — callers that hold
    it record unconditionally; prefer the module helpers)."""
    return _registry


def get_tracer() -> Tracer:
    """The live tracer (see `get_metrics` caveat)."""
    return _tracer


def counter(name: str, **labels):
    """`Counter` for ``(name, labels)`` — the shared no-op when disabled."""
    return _registry.counter(name, **labels) if _enabled else _NOOP


def gauge(name: str, **labels):
    """`Gauge` for ``(name, labels)`` — the shared no-op when disabled."""
    return _registry.gauge(name, **labels) if _enabled else _NOOP


def histogram(name: str, buckets=None, **labels):
    """`Histogram` for ``(name, labels)`` — the shared no-op when
    disabled.  ``buckets`` (ascending upper bounds) applies on first
    creation; defaults to `LATENCY_BUCKETS_MS`."""
    if not _enabled:
        return _NOOP
    return _registry.histogram(name, buckets=buckets, **labels)


def span(name: str, *, tier: str = "", **args):
    """Context manager timing one phase — a reusable null context when
    disabled.  ``tier`` tags the Chrome-trace event category."""
    return _tracer.span(name, tier=tier, **args) if _enabled else _NULL_SPAN


# -------------------------------------------------------------- export ----

def snapshot() -> dict:
    """The metrics registry snapshot (see `MetricsRegistry.snapshot`)."""
    return _registry.snapshot()


def chrome_trace() -> dict:
    """The span timeline as a Chrome trace-event dict."""
    return _tracer.chrome_trace()


def write_metrics(path: str) -> str:
    """Dump the registry snapshot as JSON; returns ``path``."""
    return _registry.write(path)


def write_trace(path: str) -> str:
    """Dump the Chrome trace as JSON; returns ``path``."""
    return _tracer.write(path)
