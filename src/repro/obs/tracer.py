"""Span-based phase tracing with Chrome trace-event export.

EFFICIENTIMM's wins came from *attributing* time to phases; this tracer
makes the same attribution a first-class runtime artifact instead of a
per-benchmark hand-rolled timer.  A span is one timed phase:

    with tracer.span("sample", tier="engine", sampler="IC/dense"):
        visited, counter, _ = sample(key)

Spans nest naturally (a ``store.write`` span inside an ``extend`` span
inside a ``run`` span), are tracked per thread (a `threading.local`
stack gives each span its depth and parent), and are recorded
host-side only on ``__exit__`` — one ``perf_counter_ns`` pair and one
locked list append per span, nothing inside ``jax.jit``.

Export is the Chrome trace-event format (``ph: "X"`` complete events
with microsecond ``ts``/``dur``), the JSON Perfetto and
``chrome://tracing`` load directly: `chrome_trace()` returns the dict,
`write(path)` dumps it.  Events carry ``cat`` = the instrumented tier
(``engine`` / ``store`` / ``stream`` / ``serve`` / ``bench``), so trace
consumers (and the CI gate ``scripts/check_obs.py``) can assert
per-tier coverage, and ``args`` carries the span's labels plus its
nesting ``depth`` and ``parent`` span name.

The optional **device bridge** (``jax_annotations=True``) additionally
enters a ``jax.profiler.TraceAnnotation(name)`` for every span, so when
a JAX device profile is captured alongside, the device timeline carries
the same phase names as the host spans and the two line up in Perfetto.
The bridge changes nothing about what executes — annotations are
metadata on the trace, never on the computation.
"""
from __future__ import annotations

import json
import threading
import time

#: Phase names the instrumented tiers emit (a catalog, not a closed
#: set — user spans may use any name).  See docs/observability.md.
PHASES = (
    "run", "round", "extend", "sample", "store.write", "count",
    "select", "influence", "collective", "compute", "delta",
    "refresh", "admission", "cache", "serve.batch", "replica.sync",
    "flush",
)


class Span:
    """One in-flight phase; a context manager handed out by `Tracer.span`."""

    __slots__ = ("tracer", "name", "tier", "args", "t0", "depth",
                 "parent", "_ann")

    def __init__(self, tracer: "Tracer", name: str, tier: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.tier = tier
        self.args = args
        self.t0 = 0
        self.depth = 0
        self.parent = ""
        self._ann = None

    def __enter__(self) -> "Span":
        stack = self.tracer._stack()
        self.depth = len(stack)
        self.parent = stack[-1].name if stack else ""
        stack.append(self)
        if self.tracer._annotate is not None:
            self._ann = self.tracer._annotate(self.name)
            self._ann.__enter__()
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        if self._ann is not None:
            self._ann.__exit__(*exc)
            self._ann = None
        stack = self.tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self.tracer._record(self, t1)
        return False


class Tracer:
    """Collects completed spans; exports Chrome trace-event JSON.

    ``max_events`` bounds memory on indefinite serving runs: past it the
    oldest events are dropped (the count is reported in ``dropped``).
    """

    def __init__(self, *, jax_annotations: bool = False,
                 max_events: int = 1 << 20):
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._local = threading.local()
        self._epoch_ns = time.perf_counter_ns()
        self.max_events = int(max_events)
        self.dropped = 0
        self._annotate = None
        if jax_annotations:
            try:
                from jax.profiler import TraceAnnotation
                self._annotate = TraceAnnotation
            except Exception:            # profiler unavailable: host-only
                self._annotate = None

    # ------------------------------------------------------------ record

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def span(self, name: str, *, tier: str = "", **args) -> Span:
        """A context manager timing one phase (see module docstring)."""
        return Span(self, name, tier, args)

    def _record(self, span: Span, t1_ns: int) -> None:
        ev = {
            "name": span.name,
            "cat": span.tier or "user",
            "ph": "X",
            "ts": (span.t0 - self._epoch_ns) / 1e3,      # microseconds
            "dur": (t1_ns - span.t0) / 1e3,
            "pid": 0,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": {**span.args, "depth": span.depth,
                     "parent": span.parent},
        }
        with self._lock:
            self._events.append(ev)
            if len(self._events) > self.max_events:
                drop = len(self._events) - self.max_events
                del self._events[:drop]
                self.dropped += drop

    # ------------------------------------------------------------ export

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self, name: str = None, tier: str = None) -> list[dict]:
        """Completed span events (copies), optionally filtered."""
        with self._lock:
            evs = list(self._events)
        if name is not None:
            evs = [e for e in evs if e["name"] == name]
        if tier is not None:
            evs = [e for e in evs if e["cat"] == tier]
        return evs

    def durations_s(self, name: str, tier: str = None) -> list[float]:
        """Every completed ``name`` span's duration in seconds, in
        completion order — the registry-snapshot analogue of a hand
        timer list (BENCH emitters consume this)."""
        return [e["dur"] / 1e6 for e in self.events(name, tier)]

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0

    def chrome_trace(self) -> dict:
        """The trace as a Chrome trace-event dict: load the written JSON
        in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``."""
        with self._lock:
            events = list(self._events)
            dropped = self.dropped
        meta = [{
            "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
            "args": {"name": "repro-imtrace"},
        }]
        return {"traceEvents": meta + events,
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": dropped}}

    def write(self, path: str) -> str:
        """Dump `chrome_trace` as JSON; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1)
        return path
