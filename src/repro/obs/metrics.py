"""Thread-safe metrics registry: counters, gauges, fixed-bucket histograms.

Every number this repo used to scatter across ad-hoc ``time.time()``
pairs, benchmark-local dicts, and ``stats()`` methods flows through one
registry so BENCH rows, serving dashboards, and CI gates share a single
source of truth.  Three instrument kinds, all **host-side** (nothing
here ever runs under ``jax.jit`` or touches a device buffer — recording
a metric can never change a traced computation, a PRNG stream, or a
compiled artifact):

  * `Counter`   — monotonically increasing int (``add``).
  * `Gauge`     — last-written float (``set``), with the running max
    kept alongside (arena occupancy peaks matter as much as the final
    value).
  * `Histogram` — fixed ascending bucket upper bounds; ``observe``
    increments exactly one bucket.  Quantiles (`percentile`) are
    *bucket-resolution*: the reported p50/p99 is the smallest bucket
    upper bound covering that rank, so a value stream that lands on
    bucket boundaries yields **exact** quantiles (the property the tests
    pin), and any stream's true quantile is <= the reported one by at
    most one bucket width.  Exact ``count``/``sum``/``min``/``max`` ride
    along; observations above the last bound land in a ``+Inf``
    overflow bucket whose reported quantile is the exact observed max.

Instruments are identified by ``(name, labels)`` — labels are a small
``str -> str`` mapping (e.g. ``tenant="campaign7"``) rendered into
snapshot keys as ``name{k=v,...}`` with sorted keys.  Re-requesting the
same identity returns the same instrument, so instrumented code can call
``registry.counter("serve.cache_hits", tenant=t)`` on every event
without holding references.

Concurrency: the registry guards its instrument table with one lock and
every instrument guards its state with its own, so recording from many
serving threads (IMServe worker pools) is safe and exact — no torn
bucket counts, no lost increments.  Records are a few hundred
nanoseconds; the disabled-mode fast path in `repro.obs` avoids even
that (see the package docstring's overhead contract).

``snapshot()`` returns a plain JSON-serializable dict (the schema
``scripts/check_obs.py`` validates); ``write(path)`` dumps it.
"""
from __future__ import annotations

import json
import math
import threading

#: Default latency buckets (milliseconds): sub-ms serving paths up
#: through multi-second repair slices, roughly x2.5 per step.
LATENCY_BUCKETS_MS = (
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0,
)

#: Default size buckets (dimensionless counts: rows, bytes, queue
#: depths): powers of two so arena/batch quantities land on boundaries.
SIZE_BUCKETS = tuple(float(1 << i) for i in range(0, 31, 2))


def series_key(name: str, labels: dict) -> str:
    """Canonical snapshot key: ``name`` or ``name{k=v,...}`` (sorted)."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic counter.  ``add`` is thread-safe; negative increments
    are rejected (a counter that can go down is a gauge)."""

    __slots__ = ("key", "_lock", "_value")

    def __init__(self, key: str):
        self.key = key
        self._lock = threading.Lock()
        self._value = 0

    def add(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.key!r}: add({n}) is negative")
        with self._lock:
            self._value += int(n)

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-value gauge with a running max."""

    __slots__ = ("key", "_lock", "_value", "_max", "_written")

    def __init__(self, key: str):
        self.key = key
        self._lock = threading.Lock()
        self._value = 0.0
        self._max = -math.inf
        self._written = False

    def set(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._value = v
            self._max = v if v > self._max else self._max
            self._written = True

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    @property
    def max(self) -> float:
        with self._lock:
            return self._max if self._written else 0.0


class Histogram:
    """Fixed-bucket histogram with bucket-resolution quantiles.

    ``buckets`` is an ascending tuple of inclusive upper bounds; an
    observation lands in the first bucket whose bound is >= the value,
    or in the implicit ``+Inf`` overflow bucket past the last bound.
    """

    __slots__ = ("key", "buckets", "_lock", "_counts", "_count", "_sum",
                 "_min", "_max")

    def __init__(self, key: str, buckets=LATENCY_BUCKETS_MS):
        buckets = tuple(float(b) for b in buckets)
        if not buckets:
            raise ValueError(f"histogram {key!r}: needs >= 1 bucket")
        if any(b2 <= b1 for b1, b2 in zip(buckets, buckets[1:])):
            raise ValueError(
                f"histogram {key!r}: bucket bounds must be strictly "
                f"ascending, got {buckets}")
        self.key = key
        self.buckets = buckets
        self._lock = threading.Lock()
        self._counts = [0] * (len(buckets) + 1)   # +1: overflow (+Inf)
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def _bucket_of(self, v: float) -> int:
        lo, hi = 0, len(self.buckets)     # hi == overflow
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.buckets[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def observe(self, v: float) -> None:
        v = float(v)
        i = self._bucket_of(v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            self._min = v if v < self._min else self._min
            self._max = v if v > self._max else self._max

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, p: float) -> float:
        """Bucket-resolution p-th percentile (p in [0, 100]).

        The smallest bucket upper bound whose cumulative count reaches
        rank ``ceil(p/100 * count)`` — exact whenever observations sit
        on bucket boundaries; the overflow bucket reports the exact
        observed max.  0.0 on an empty histogram.
        """
        if not 0.0 <= p <= 100.0:
            raise ValueError(f"percentile wants p in [0, 100], got {p}")
        with self._lock:
            if self._count == 0:
                return 0.0
            rank = max(1, math.ceil(p / 100.0 * self._count))
            cum = 0
            for i, c in enumerate(self._counts):
                cum += c
                if cum >= rank:
                    return (self.buckets[i] if i < len(self.buckets)
                            else self._max)
            return self._max            # unreachable; defensive

    def to_dict(self) -> dict:
        with self._lock:
            counts = list(self._counts)
            count, total = self._count, self._sum
            mn = self._min if self._count else 0.0
            mx = self._max if self._count else 0.0
        d = {"count": count, "sum": total, "min": mn, "max": mx,
             "p50": self.percentile(50.0), "p99": self.percentile(99.0),
             "buckets": [[b, c] for b, c in zip(self.buckets, counts)]}
        d["buckets"].append(["+Inf", counts[-1]])
        return d


class MetricsRegistry:
    """Process-wide instrument table: get-or-create by (name, labels).

    One registry serves every tier; snapshot export keeps the three
    instrument kinds in separate maps so consumers never need to guess
    a key's type.  Asking for an existing name with a different kind
    (or a histogram with different buckets) is a bug and raises.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, object] = {}

    def _get(self, cls, name: str, labels: dict, **kw):
        key = series_key(name, labels)
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(key, **kw)
                self._instruments[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {key!r} is a {type(inst).__name__}, "
                    f"requested as {cls.__name__}")
            elif kw.get("buckets") and inst.buckets != tuple(
                    float(b) for b in kw["buckets"]):
                raise ValueError(
                    f"histogram {key!r} already registered with buckets "
                    f"{inst.buckets}")
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, buckets=None, **labels) -> Histogram:
        kw = {"buckets": buckets} if buckets is not None else {}
        return self._get(Histogram, name, labels, **kw)

    def __len__(self) -> int:
        with self._lock:
            return len(self._instruments)

    def snapshot(self) -> dict:
        """JSON-serializable registry snapshot:
        ``{"counters": {key: int}, "gauges": {key: {value, max}},
        "histograms": {key: {count, sum, min, max, p50, p99, buckets}}}``.
        """
        with self._lock:
            items = sorted(self._instruments.items())
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for key, inst in items:
            if isinstance(inst, Counter):
                out["counters"][key] = inst.value
            elif isinstance(inst, Gauge):
                out["gauges"][key] = {"value": inst.value, "max": inst.max}
            else:
                out["histograms"][key] = inst.to_dict()
        return out

    def write(self, path: str) -> str:
        """Dump `snapshot` as JSON; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)
        return path
