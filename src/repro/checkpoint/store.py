"""Sharded checkpointing with atomic commit, rolling retention, auto-resume.

Design (multi-thousand-node ready, filesystem-backed here):
  * every pytree leaf is saved as one npz entry keyed by its tree path —
    restore works across *any* mesh shape because leaves are saved
    un-sharded logical arrays; the restoring job re-applies its own
    shardings (elastic up/down-scale of the data axis);
  * writes go to ``<dir>/step_<n>.tmp`` then ``os.replace`` → crash-safe
    (a half-written checkpoint is never visible under its final name);
  * a ``latest`` pointer file is written after the rename; restart reads it
    and falls back to scanning if the pointer is stale/corrupt;
  * rolling retention keeps the newest ``keep`` checkpoints;
  * on a real multi-host pod only process 0 writes (guarded by
    ``jax.process_index()``), all hosts read.

The pytree may contain jnp/np arrays, python/np scalars, and nested
dict/list/tuple. Dataclass configs are NOT stored — they belong to code.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import numpy as np
import jax


_SEP = "/"


def _flatten(tree, prefix=""):
    """-> dict[path, leaf] with deterministic ordering + structure spec."""
    out = {}
    if isinstance(tree, dict):
        spec = {"__kind__": "dict", "keys": sorted(tree.keys())}
        children = {}
        for k in sorted(tree.keys()):
            sub_spec, sub_leaves = _flatten(tree[k], f"{prefix}{k}{_SEP}")
            children[k] = sub_spec
            out.update(sub_leaves)
        spec["children"] = children
        return spec, out
    if isinstance(tree, (list, tuple)):
        kind = "list" if isinstance(tree, list) else "tuple"
        spec = {"__kind__": kind, "n": len(tree)}
        children = []
        for i, v in enumerate(tree):
            sub_spec, sub_leaves = _flatten(v, f"{prefix}{i}{_SEP}")
            children.append(sub_spec)
            out.update(sub_leaves)
        spec["children"] = children
        return spec, out
    # leaf
    key = prefix[:-1] if prefix.endswith(_SEP) else prefix
    out[key] = np.asarray(tree)
    return {"__kind__": "leaf", "key": key}, out


def _unflatten(spec, leaves):
    kind = spec["__kind__"]
    if kind == "leaf":
        return leaves[spec["key"]]
    if kind == "dict":
        return {k: _unflatten(spec["children"][k], leaves)
                for k in spec["keys"]}
    children = [_unflatten(c, leaves) for c in spec["children"]]
    return children if kind == "list" else tuple(children)


def clone_tree(tree):
    """An independent host copy of a snapshot pytree.

    Replica fan-out hands one ``snapshot_tree`` to many engines; each
    restore must own its leaves — the primary keeps mutating (and its
    stores donate device buffers on every write), so replicas may not
    hold references into its state.  Flattening already converts every
    leaf to host numpy; the per-leaf ``np.array`` copy makes the clone
    independent of the source tree as well."""
    spec, leaves = _flatten(tree)
    return _unflatten(spec, {k: np.array(v) for k, v in leaves.items()})


def tree_bytes(tree) -> int:
    """Total host bytes of a snapshot pytree's leaves — what one replica
    fan-out ships (serve-tier accounting)."""
    _, leaves = _flatten(tree)
    return sum(int(v.nbytes) for v in leaves.values())


def _is_writer() -> bool:
    try:
        return jax.process_index() == 0
    except Exception:
        return True


def _write_npz(directory: str, fname: str, tree) -> str:
    """Atomic npz write of a flattened pytree to ``<directory>/<fname>``."""
    os.makedirs(directory, exist_ok=True)
    spec, leaves = _flatten(tree)
    # device -> host transfer happens here (np.asarray in _flatten)
    dest = os.path.join(directory, fname)
    # NOTE: np.savez appends ".npz" when missing — keep the suffix on the
    # temp name so the atomic rename moves the real payload.
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp.npz")
    os.close(fd)
    try:
        np.savez(tmp, __spec__=json.dumps(spec), **leaves)
        os.replace(tmp, dest)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return dest


def _read_npz(path: str):
    with np.load(path, allow_pickle=False) as z:
        spec = json.loads(str(z["__spec__"]))
        leaves = {k: z[k] for k in z.files if k != "__spec__"}
    return _unflatten(spec, leaves)


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3):
    """Atomic write of ``tree`` at ``step``; prunes to ``keep`` newest."""
    if not _is_writer():
        return None
    fname = _write_npz(directory, f"step_{step:010d}.npz", tree)
    with open(os.path.join(directory, "latest.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(directory, "latest.tmp"),
               os.path.join(directory, "latest"))
    _prune(directory, keep)
    return fname


def save_named(directory: str, name: str, tree):
    """Atomic write of ``tree`` under a stable name (no step counter, no
    retention) — single-slot snapshots like `InfluenceEngine.snapshot` that
    are overwritten in place rather than rolled."""
    if not _is_writer():
        return None
    if _SEP in name or name.startswith("step_"):
        raise ValueError(f"invalid snapshot name {name!r}")
    return _write_npz(directory, f"{name}.npz", tree)


def load_named(directory: str, name: str):
    """Read a `save_named` snapshot; returns None when absent."""
    path = os.path.join(directory, f"{name}.npz")
    if not os.path.exists(path):
        return None
    return _read_npz(path)


def _list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for f in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)\.npz", f)
        if m:
            steps.append(int(m.group(1)))
    return sorted(steps)


def _prune(directory: str, keep: int):
    steps = _list_steps(directory)
    for s in steps[:-keep] if keep > 0 else []:
        try:
            os.remove(os.path.join(directory, f"step_{s:010d}.npz"))
        except OSError:
            pass


def latest_step(directory: str):
    """Newest complete checkpoint step, or None."""
    ptr = os.path.join(directory, "latest")
    steps = _list_steps(directory)
    if not steps:
        return None
    if os.path.exists(ptr):
        try:
            with open(ptr) as f:
                s = int(f.read().strip())
            if s in steps:
                return s
        except (ValueError, OSError):
            pass
    return steps[-1]


def load_checkpoint(directory: str, step: int | None = None):
    """-> (step, tree of np arrays) or (None, None) if nothing to restore.

    Leaves come back as host numpy; callers ``jax.device_put`` with their own
    shardings (this is what makes restore mesh-elastic).
    """
    step = latest_step(directory) if step is None else step
    if step is None:
        return None, None
    return step, _read_npz(os.path.join(directory, f"step_{step:010d}.npz"))


class CheckpointManager:
    """Rolling save/restore driver used by the runtime loop.

    save_every steps; keep newest ``keep``; ``restore_or_init`` returns
    (step, tree) resuming from the newest checkpoint else (0, init_fn()).
    """

    def __init__(self, directory: str, *, save_every: int = 100,
                 keep: int = 3):
        self.directory = directory
        self.save_every = save_every
        self.keep = keep

    def maybe_save(self, step: int, tree):
        if step % self.save_every == 0 and step > 0:
            return save_checkpoint(self.directory, step, tree, keep=self.keep)
        return None

    def save(self, step: int, tree):
        return save_checkpoint(self.directory, step, tree, keep=self.keep)

    def restore_or_init(self, init_fn):
        step, tree = load_checkpoint(self.directory)
        if step is None:
            return 0, init_fn()
        return step, tree

    def wipe(self):
        if os.path.isdir(self.directory):
            shutil.rmtree(self.directory)
