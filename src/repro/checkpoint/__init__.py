from repro.checkpoint.store import (
    save_checkpoint,
    load_checkpoint,
    save_named,
    load_named,
    CheckpointManager,
)

__all__ = [
    "save_checkpoint", "load_checkpoint", "save_named", "load_named",
    "CheckpointManager",
]
