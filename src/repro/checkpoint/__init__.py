from repro.checkpoint.store import (
    save_checkpoint,
    load_checkpoint,
    CheckpointManager,
)

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]
