"""Real spherical-harmonic machinery for eSCN/Equiformer-v2.

``wigner_d_stack`` builds the real Wigner rotation matrices D^l(R) for
l = 0..l_max from a batch of 3x3 rotations via the Ivanic-Ruedenberg
recursion (J. Phys. Chem. 1996, 100, 6342, with the 1998 erratum) — the same
algorithm e3nn uses for real spherical harmonics.  Everything is vectorized
over the edge batch and unrolled over (l, m, m') at trace time
(sum_l (2l+1)^2 = 455 small ops for l_max=6).

Conventions: real SH order m = -l..l; the l=1 basis is (Y, Z, X) so that
D^1 is the permuted rotation matrix itself.

Properties tested: homomorphism D(R1 R2) = D(R1) D(R2), orthogonality, and
D^1 == permuted R.
"""
from __future__ import annotations

import math
from functools import lru_cache

import numpy as np
import jax.numpy as jnp


def rotation_to_align_z(vec, eps: float = 1e-9):
    """Batch of rotations R with R @ v_hat = z_hat.

    Stable half-angle form R = I + K + K^2/(1+c) with K = skew(v x z) — no
    division by sin(angle), so near-aligned edges stay well-conditioned
    (only v ~ -z needs a branch: 180-degree flip about x).
    vec: (..., 3) -> (..., 3, 3).
    """
    v = vec / (jnp.linalg.norm(vec, axis=-1, keepdims=True) + eps)
    c = v[..., 2]                                    # cos(angle) = v . z
    # w = v x z = (vy, -vx, 0)
    wx, wy = v[..., 1], -v[..., 0]
    zero = jnp.zeros_like(wx)
    K = jnp.stack([
        jnp.stack([zero, zero, wy], -1),
        jnp.stack([zero, zero, -wx], -1),
        jnp.stack([-wy, wx, zero], -1),
    ], -2)
    eye = jnp.eye(3)
    cc = c[..., None, None]
    K2 = K @ K
    # two branches, both with denominator >= 1:
    #   c >= 0: align v -> z directly
    #   c <  0: align v -> -z (w' = -w, c' = -c), then flip about x
    r_pos = eye + K + K2 / jnp.maximum(1.0 + cc, eps)
    flip = jnp.diag(jnp.array([1.0, -1.0, -1.0]))
    r_neg = flip @ (eye - K + K2 / jnp.maximum(1.0 - cc, eps))
    return jnp.where(cc >= 0, r_pos, r_neg)


def _perm_l1(R):
    """Real-SH l=1 rotation in (Y, Z, X) order from the 3x3 rotation.

    r[i, j] with i, j in {-1, 0, 1} maps (y, z, x): r[m, m'] =
    R[axis(m), axis(m')] with axis(-1)=1(y), axis(0)=2(z), axis(1)=0(x).
    """
    axes = [1, 2, 0]
    rows = [[R[..., axes[i], axes[j]] for j in range(3)] for i in range(3)]
    return jnp.stack([jnp.stack(r, -1) for r in rows], -2)


@lru_cache(maxsize=None)
def _uvw(l: int, mu: int, mp: int):
    """Scalar u, v, w coefficients of the recursion (host-side)."""
    if abs(mp) < l:
        denom = (l + mp) * (l - mp)
    else:
        denom = (2 * l) * (2 * l - 1)
    u = math.sqrt((l + mu) * (l - mu) / denom)
    d0 = 1.0 if mu == 0 else 0.0
    v = 0.5 * math.sqrt((1 + d0) * (l + abs(mu) - 1) * (l + abs(mu)) / denom) \
        * (1 - 2 * d0)
    w = -0.5 * math.sqrt((l - abs(mu) - 1) * (l - abs(mu)) / denom) * (1 - d0)
    return u, v, w


def _wigner_next(l: int, r1, Rprev):
    """D^l from D^1 (r1, indexed m,m' in -1..1) and D^{l-1} (Rprev)."""

    def r(i, j):
        return r1[..., i + 1, j + 1]

    def prev(mu, mp):
        # Rprev has indices -(l-1)..(l-1)
        return Rprev[..., mu + l - 1, mp + l - 1]

    def P(i, mu, mp):
        if mp == l:
            return r(i, 1) * prev(mu, l - 1) - r(i, -1) * prev(mu, -l + 1)
        if mp == -l:
            return r(i, 1) * prev(mu, -l + 1) + r(i, -1) * prev(mu, l - 1)
        return r(i, 0) * prev(mu, mp)

    rows = []
    for mu in range(-l, l + 1):
        row = []
        for mp in range(-l, l + 1):
            u, v, w = _uvw(l, mu, mp)
            total = 0.0
            if u != 0.0:
                total = total + u * P(0, mu, mp)
            if v != 0.0:
                if mu == 0:
                    V = P(1, 1, mp) + P(-1, -1, mp)
                elif mu > 0:
                    d1 = 1.0 if mu == 1 else 0.0
                    V = P(1, mu - 1, mp) * math.sqrt(1 + d1) \
                        - P(-1, -mu + 1, mp) * (1 - d1)
                else:
                    dm1 = 1.0 if mu == -1 else 0.0
                    V = P(1, mu + 1, mp) * (1 - dm1) \
                        + P(-1, -mu - 1, mp) * math.sqrt(1 + dm1)
                total = total + v * V
            if w != 0.0:
                if mu > 0:
                    W = P(1, mu + 1, mp) + P(-1, -mu - 1, mp)
                elif mu < 0:
                    W = P(1, mu - 1, mp) - P(-1, -mu + 1, mp)
                else:
                    W = 0.0
                total = total + w * W
            row.append(total)
        rows.append(jnp.stack(row, -1))
    return jnp.stack(rows, -2)


def wigner_d_stack(R, l_max: int):
    """R: (..., 3, 3) -> list of (..., 2l+1, 2l+1) for l = 0..l_max."""
    batch = R.shape[:-2]
    mats = [jnp.ones(batch + (1, 1))]
    if l_max >= 1:
        r1 = _perm_l1(R)
        mats.append(r1)
        prev = r1
        for l in range(2, l_max + 1):
            prev = _wigner_next(l, r1, prev)
            mats.append(prev)
    return mats


def sph_harm_from_wigner(vec, l_max: int):
    """Real SH of directions via the m=0 column of D(R_align)^T.

    Y_l(v) = D^l(R)^T Y_l(z), and Y_l(z) is nonzero only at m=0 with value
    sqrt((2l+1)/(4 pi)).  Returns (..., (l_max+1)^2).
    """
    R = rotation_to_align_z(vec)
    mats = wigner_d_stack(R, l_max)
    outs = []
    for l, D in enumerate(mats):
        norm = math.sqrt((2 * l + 1) / (4 * math.pi))
        outs.append(D[..., l, :] * norm)   # m=0 row (center index l)
    return jnp.concatenate(outs, axis=-1)


def num_sph(l_max: int) -> int:
    return (l_max + 1) ** 2


def l_slices(l_max: int):
    """[(start, end, l)] index ranges of each l block in flattened order."""
    out, start = [], 0
    for l in range(l_max + 1):
        out.append((start, start + 2 * l + 1, l))
        start += 2 * l + 1
    return out
