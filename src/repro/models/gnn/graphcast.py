"""GraphCast-style encode-process-decode mesh GNN (Lam et al. 2022).

Faithful processor: per layer, edge update MLP([e, h_src, h_dst]) + residual,
sum-aggregate to nodes, node update MLP([h, agg]) + residual, LayerNorm after
each MLP (the MeshGraphNet/GraphCast recipe).  GraphCast's icosahedral
multi-mesh refinement (mesh_refinement=6) defines *which* graph the processor
runs on; on the assigned generic graph shapes the processor runs on the given
edge list — noted in DESIGN §4.  n_vars=227 input/output channels as in the
weather configuration.

Layers are stacked + scanned with remat (61M-edge ogb_products cell).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.compat import shard_map

from repro.models.common import mlp_init, mlp_apply, layer_norm, shard_rows
from repro.sparse.segment import segment_sum


@dataclasses.dataclass(frozen=True)
class GraphCastConfig:
    name: str = "graphcast"
    n_layers: int = 16
    d_hidden: int = 512
    mesh_refinement: int = 6
    aggregator: str = "sum"
    n_vars: int = 227
    d_edge_in: int = 4           # edge geometric features
    remat: bool = True
    # checkpoint every ``remat_group`` layers (2-level scan): the saved
    # (h, e) carries shrink n_layers/remat_group-fold at the cost of
    # recomputing one group in bwd — the knob the 61.8M-edge ogb cell needs
    remat_group: int = 1
    dtype: str = "float32"       # latent dtype (bf16 for huge cells)
    # mesh axes pinning the node/edge latents (launch/steps.py sets these;
    # without them GSPMD replicates the (E, d) edge latent carry)
    node_axes: tuple = ()
    edge_axes: tuple = ()


def init_graphcast(key, cfg: GraphCastConfig):
    d = cfg.d_hidden
    k1, k2, k3, k4, key = jax.random.split(key, 5)
    enc_node = mlp_init(k1, [cfg.n_vars, d, d])
    enc_edge = mlp_init(k2, [cfg.d_edge_in, d, d])
    dec = mlp_init(k3, [d, d, cfg.n_vars])

    def layer_init(k):
        ka, kb = jax.random.split(k)
        return {
            "edge_mlp": mlp_init(ka, [3 * d, d, d]),
            "node_mlp": mlp_init(kb, [2 * d, d, d]),
            "ln_e": jnp.ones((d,)), "ln_e_b": jnp.zeros((d,)),
            "ln_n": jnp.ones((d,)), "ln_n_b": jnp.zeros((d,)),
        }

    layers = jax.vmap(layer_init)(jax.random.split(k4, cfg.n_layers))
    return {"enc_node": enc_node, "enc_edge": enc_edge, "dec": dec,
            "layers": layers}


def _processor_layer(carry, p, *, edge_src, edge_dst, n_nodes, cfg):
    h, e = carry
    msg_in = jnp.concatenate(
        [e, jnp.take(h, edge_src, axis=0), jnp.take(h, edge_dst, axis=0)],
        axis=-1)
    e_new = mlp_apply(p["edge_mlp"], msg_in).astype(e.dtype)
    e = shard_rows(
        e + layer_norm(e_new, p["ln_e"], p["ln_e_b"]).astype(e.dtype),
        cfg.edge_axes)
    agg = segment_sum(e, edge_dst, n_nodes)
    h_new = mlp_apply(p["node_mlp"],
                      jnp.concatenate([h, agg], axis=-1)).astype(h.dtype)
    h = shard_rows(
        h + layer_norm(h_new, p["ln_n"], p["ln_n_b"]).astype(h.dtype),
        cfg.node_axes)
    return (h, e), None


def forward_edges(params, cfg: GraphCastConfig, node_feats, edge_feats,
                  edge_src, edge_dst, n_nodes: int):
    """node_feats (N, n_vars), edge_feats (E, d_edge_in) -> (N, n_vars)."""
    dt = jnp.dtype(cfg.dtype)
    h = shard_rows(mlp_apply(params["enc_node"], node_feats).astype(dt),
                   cfg.node_axes)
    e = shard_rows(mlp_apply(params["enc_edge"], edge_feats).astype(dt),
                   cfg.edge_axes)
    body = partial(_processor_layer, edge_src=edge_src, edge_dst=edge_dst,
                   n_nodes=n_nodes, cfg=cfg)
    g = max(int(cfg.remat_group), 1)
    if g > 1:
        assert cfg.n_layers % g == 0, (cfg.n_layers, g)
        stacked = jax.tree.map(
            lambda x: x.reshape((cfg.n_layers // g, g) + x.shape[1:]),
            params["layers"])

        def group_body(carry, pg):
            return jax.lax.scan(body, carry, pg)

        if cfg.remat:
            group_body = jax.checkpoint(group_body)
        (h, e), _ = jax.lax.scan(group_body, (h, e), stacked)
    else:
        if cfg.remat:
            body = jax.checkpoint(body)
        (h, e), _ = jax.lax.scan(body, (h, e), params["layers"])
    return mlp_apply(params["dec"], h.astype(jnp.float32))


def loss_edges(params, cfg: GraphCastConfig, node_feats, edge_feats,
               edge_src, edge_dst, targets, n_nodes: int):
    pred = forward_edges(params, cfg, node_feats, edge_feats, edge_src,
                         edge_dst, n_nodes)
    return jnp.mean(jnp.square(pred - targets))


# ---------------------------------------- dst-partitioned (production) ----

def forward_edges_dst_partitioned(params, cfg: GraphCastConfig, node_feats,
                                  edge_feats, edge_src, edge_dst_local,
                                  n_nodes: int, *, mesh):
    """Explicit shard_map processor honoring the paper's C2 layout:

      * nodes block-partitioned over the data axes (NUMA-node analogue),
      * edges pre-partitioned by DST block (graphs/partition.py) so every
        device's segment_sum writes only its local node block; the model
        axis splits each slab 16-way and partial aggregates ``psum`` over
        it (the EfficientIMM partial-counter pattern),
      * per-layer ``all_gather`` of the node latents over the data axes
        replaces the random cross-device gathers GSPMD would emit.

    edge_dst_local: dst ids LOCAL to the owning block (sentinel n_block
    drops). Returns per-node predictions sharded like node_feats.
    """
    from jax.sharding import PartitionSpec as P

    dp = tuple(cfg.node_axes)
    tp = "model"
    dt = jnp.dtype(cfg.dtype)
    g = max(int(cfg.remat_group), 1)

    def local_fn(enc_n, enc_e, dec, layers, nf, ef, es, ed):
        n_block = nf.shape[0]
        h = mlp_apply(enc_n, nf).astype(dt)              # (N_loc, d)
        e = mlp_apply(enc_e, ef).astype(dt)              # (E_loc, d)

        def layer_body(carry, p):
            h, e = carry
            h_full = jax.lax.all_gather(h, dp, axis=0, tiled=True)
            msg_in = jnp.concatenate(
                [e, jnp.take(h_full, es, axis=0, mode="clip"),
                 jnp.take(h, jnp.clip(ed, 0, n_block - 1), axis=0)],
                axis=-1)
            e_new = mlp_apply(p["edge_mlp"], msg_in).astype(dt)
            e = e + layer_norm(e_new, p["ln_e"], p["ln_e_b"]).astype(dt)
            agg = segment_sum(e, ed, n_block)
            agg = jax.lax.psum(agg, tp)                  # model partials
            h_new = mlp_apply(
                p["node_mlp"],
                jnp.concatenate([h, agg.astype(dt)], axis=-1)).astype(dt)
            h = h + layer_norm(h_new, p["ln_n"], p["ln_n_b"]).astype(dt)
            return (h, e), None

        if g > 1:
            stacked = jax.tree.map(
                lambda x: x.reshape((cfg.n_layers // g, g) + x.shape[1:]),
                layers)

            def group_body(carry, pg):
                return jax.lax.scan(layer_body, carry, pg)

            body = jax.checkpoint(group_body) if cfg.remat else group_body
            (h, e), _ = jax.lax.scan(body, (h, e), stacked)
        else:
            body = jax.checkpoint(layer_body) if cfg.remat else layer_body
            (h, e), _ = jax.lax.scan(body, (h, e), layers)
        return mlp_apply(dec, h.astype(jnp.float32))

    rep = jax.tree.map(lambda x: P(*([None] * x.ndim)), params)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(rep["enc_node"], rep["enc_edge"], rep["dec"],
                  rep["layers"],
                  P(dp, None), P((*dp, tp), None), P((*dp, tp)),
                  P((*dp, tp))),
        out_specs=P(dp, None))
    return fn(params["enc_node"], params["enc_edge"], params["dec"],
              params["layers"], node_feats, edge_feats, edge_src,
              edge_dst_local)


def loss_edges_dst_partitioned(params, cfg, node_feats, edge_feats,
                               edge_src, edge_dst_local, targets,
                               n_nodes: int, *, mesh):
    pred = forward_edges_dst_partitioned(
        params, cfg, node_feats, edge_feats, edge_src, edge_dst_local,
        n_nodes, mesh=mesh)
    return jnp.mean(jnp.square(pred - targets))
