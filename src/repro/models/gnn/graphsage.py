"""GraphSAGE (Hamilton et al. 2017): mean aggregator, 2 layers, minibatch
fan-out sampling (sample_sizes 25-10 in the assigned config).

Two apply modes:
  * ``forward_blocks`` — the native minibatch form over sampled neighbor
    blocks (what the reddit ``minibatch_lg`` cell lowers);
  * ``forward_edges`` — full-graph form over an edge list (full_graph_sm /
    ogb_products cells).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.gnn.mpnn import aggregate


@dataclasses.dataclass(frozen=True)
class SageConfig:
    name: str = "graphsage"
    n_layers: int = 2
    d_hidden: int = 128
    d_feat: int = 602
    n_classes: int = 41
    aggregator: str = "mean"
    sample_sizes: tuple = (25, 10)


def init_sage(key, cfg: SageConfig):
    dims = [cfg.d_feat] + [cfg.d_hidden] * cfg.n_layers
    layers = []
    for i in range(cfg.n_layers):
        k1, k2, key = jax.random.split(key, 3)
        layers.append({
            "w_self": dense_init(k1, dims[i], dims[i + 1]),
            "w_nbr": dense_init(k2, dims[i], dims[i + 1]),
            "b": jnp.zeros((dims[i + 1],)),
        })
    kout, _ = jax.random.split(key)
    return {
        "layers": layers,
        "w_out": dense_init(kout, cfg.d_hidden, cfg.n_classes),
    }


def _sage_layer(p, h_self, h_nbr_mean):
    return jax.nn.relu(h_self @ p["w_self"] + h_nbr_mean @ p["w_nbr"] + p["b"])


def forward_blocks(params, cfg: SageConfig, x_seed, x_n1, x_n2):
    """x_seed (B, F); x_n1 (B, f1, F); x_n2 (B*f1, f2, F) -> logits (B, C)."""
    B, f1, F = x_n1.shape
    l1, l2 = params["layers"][0], params["layers"][1]
    # layer-1 embeddings for seeds and their level-1 neighbors
    h1_seed = _sage_layer(l1, x_seed, x_n1.mean(axis=1))
    h1_n1 = _sage_layer(l1, x_n1.reshape(B * f1, F), x_n2.mean(axis=1))
    # layer-2 for seeds
    h2 = _sage_layer(l2, h1_seed, h1_n1.reshape(B, f1, -1).mean(axis=1))
    return h2 @ params["w_out"]


def forward_edges(params, cfg: SageConfig, node_feats, edge_src, edge_dst,
                  n_nodes: int):
    """Full-graph mode: logits for every node."""
    h = node_feats
    for p in params["layers"]:
        msgs = jnp.take(h, edge_src, axis=0)
        agg = aggregate(msgs, edge_dst, n_nodes, cfg.aggregator)
        h = _sage_layer(p, h, agg)
    return h @ params["w_out"]


def loss_blocks(params, cfg: SageConfig, x_seed, x_n1, x_n2, labels):
    logits = forward_blocks(params, cfg, x_seed, x_n1, x_n2)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def loss_edges(params, cfg: SageConfig, node_feats, edge_src, edge_dst,
               labels, n_nodes: int):
    logits = forward_edges(params, cfg, node_feats, edge_src, edge_dst, n_nodes)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
