from repro.models.gnn import mpnn, graphsage, graphcast, egnn, irreps

__all__ = ["mpnn", "graphsage", "graphcast", "egnn", "irreps"]
