"""Shared message-passing utilities.

All GNN aggregation reduces to gather(src) -> reduce-by-dst — the same
primitive as the EfficientIMM counter update (DESIGN §4).  Two modes:

  * flat edge list (full-graph training; optionally pre-partitioned by dst
    block via graphs.partition for the sharded path)
  * per-device edge slabs inside shard_map: local segment_sum into the
    device's dst block after an all-gather of src features (the IMM
    partial-counter + psum pattern).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.segment import segment_sum, segment_mean, segment_max


def gather_src(h, edge_src):
    return jnp.take(h, edge_src, axis=0)


def aggregate(messages, edge_dst, n_nodes: int, op: str = "sum"):
    if op == "sum":
        return segment_sum(messages, edge_dst, n_nodes)
    if op == "mean":
        return segment_mean(messages, edge_dst, n_nodes)
    if op == "max":
        out = segment_max(messages, edge_dst, n_nodes)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(op)


def sharded_aggregate(h_global, msg_fn, src_slab, dst_slab, node_block: int,
                      *, axis_name: str, op: str = "sum"):
    """Inside shard_map: this device owns edge slab (src, local dst) and the
    dst node block; h_global is the all-gathered node feature table."""
    msgs = msg_fn(jnp.take(h_global, src_slab, axis=0))
    return aggregate(msgs, dst_slab, node_block, op)
