"""E(n)-equivariant GNN (Satorras et al. 2021), the exact EGNN layer:

    m_ij  = phi_e(h_i, h_j, ||x_i - x_j||^2)
    x_i'  = x_i + C * sum_j (x_i - x_j) * phi_x(m_ij)
    h_i'  = phi_h(h_i, sum_j m_ij)

Equivariance is property-tested (tests/test_gnn.py): rotating + translating
the inputs rotates/translates x' and leaves h' invariant.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import mlp_init, mlp_apply
from repro.sparse.segment import segment_sum, segment_mean


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_feat: int = 16
    coord_agg: str = "mean"      # paper uses C = 1/(n-1); mean is the stable form


def init_egnn(key, cfg: EGNNConfig):
    d = cfg.d_hidden
    k_in, k_out, key = jax.random.split(key, 3)
    layers = []
    for _ in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        layers.append({
            "phi_e": mlp_init(k1, [2 * d + 1, d, d]),
            "phi_x": mlp_init(k2, [d, d, 1]),
            "phi_h": mlp_init(k3, [2 * d, d, d]),
        })
    return {
        "embed": mlp_init(k_in, [cfg.d_feat, d]),
        "layers": layers,
        "readout": mlp_init(k_out, [d, d, 1]),
    }


def forward_edges(params, cfg: EGNNConfig, node_feats, pos, edge_src,
                  edge_dst, n_nodes: int):
    """-> (h (N, d), pos' (N, 3), energy ())."""
    h = mlp_apply(params["embed"], node_feats)
    x = pos
    for p in params["layers"]:
        xi, xj = jnp.take(x, edge_dst, axis=0), jnp.take(x, edge_src, axis=0)
        diff = xi - xj
        dist2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        hi = jnp.take(h, edge_dst, axis=0)
        hj = jnp.take(h, edge_src, axis=0)
        m = mlp_apply(p["phi_e"], jnp.concatenate([hi, hj, dist2], -1),
                      final_act=True)
        coef = mlp_apply(p["phi_x"], m)                      # (E, 1)
        agg_fn = segment_mean if cfg.coord_agg == "mean" else segment_sum
        x = x + agg_fn(diff * coef, edge_dst, n_nodes)
        m_agg = segment_sum(m, edge_dst, n_nodes)
        h = h + mlp_apply(p["phi_h"], jnp.concatenate([h, m_agg], -1))
    energy = mlp_apply(params["readout"], h).sum()
    return h, x, energy


def loss_edges(params, cfg: EGNNConfig, node_feats, pos, edge_src, edge_dst,
               target_pos, n_nodes: int):
    _, x, _ = forward_edges(params, cfg, node_feats, pos, edge_src, edge_dst,
                            n_nodes)
    return jnp.mean(jnp.square(x - target_pos))
