"""Equiformer-v2-style equivariant graph attention via eSCN SO(2) convs
(Liao et al. 2023 / Passaro & Zitnick 2023).

Node features are real-SH irrep stacks X: (N, S, C) with S = (l_max+1)^2.
Per layer, per edge:
  1. per-l linear mix of src/dst features,
  2. rotate into the edge-aligned frame (exact Wigner D from irreps.py),
  3. SO(2) convolution truncated at m_max (the eSCN O(L^6) -> O(L^3) trick),
     with radial-basis gating,
  4. rotate back, attention weights from the invariant (l=0) channel,
     aggregate, per-l node update + invariant-gated FFN.

Attention normalization uses soft-capped logits (``logit_cap * tanh``)
followed by a plain exp-sum — mathematically identical to segment-softmax
(the cap bounds the exponent) but computable in ONE pass over edges.  That
single-pass form enables **edge chunking**: with ``edge_src/edge_dst`` given
as (n_chunks, chunk) the layer scans edge blocks, accumulating the weighted
message numerator and the attention denominator into node buffers — the per
-edge (chunk, S, C) irrep tensors never exist all at once.  This is the TPU
analogue of how eSCN codebases block their edge loop, and it is what makes
the 61.8M-edge ``ogb_products`` cell memory-feasible (DESIGN §4).

Simplification vs the released model (documented in DESIGN §4): the SO(2)
weights are static parameters modulated by a radial MLP gate instead of fully
edge-generated weights; macro compute/memory structure (rotations + per-m
mixing) is preserved.  Equivariance is property-tested end-to-end.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import (mlp_init, mlp_apply, dense_init,
                                 shard_rows, shard_latent)
from repro.models.gnn.irreps import (
    rotation_to_align_z, wigner_d_stack, sph_harm_from_wigner, l_slices,
    num_sph,
)
from repro.sparse.segment import segment_sum


@dataclasses.dataclass(frozen=True)
class EquiformerConfig:
    name: str = "equiformer_v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    d_feat: int = 16
    n_rbf: int = 8
    n_out: int = 1
    cutoff: float = 5.0
    logit_cap: float = 10.0      # tanh soft cap -> single-pass attention
    dtype: str = "float32"       # irrep feature dtype (bf16 for huge cells)
    remat: bool = True
    # mesh axes pinning the (N, S, C) irrep stacks and aggregation buffers
    # (launch/steps.py sets these; GSPMD otherwise replicates the carry);
    # channel_axis additionally shards the C axis ("model") so carries,
    # remat stacks and gather psums shrink tp-fold
    node_axes: tuple = ()
    channel_axis: str = ""


def _m_index_sets(l_max: int, m_max: int):
    """For each m in 0..m_max: flat indices of (l, +m) and (l, -m), l >= m."""
    sl = l_slices(l_max)
    sets = []
    for m in range(m_max + 1):
        plus = [s + l + m for s, e, l in sl if l >= m]
        minus = [s + l - m for s, e, l in sl if l >= m]
        sets.append((jnp.array(plus), jnp.array(minus)))
    return sets


def init_equiformer(key, cfg: EquiformerConfig):
    C, L = cfg.d_hidden, cfg.l_max
    n_l = L + 1
    k_embed, k_out, k_layers = jax.random.split(key, 3)

    def layer_init(k):
        ks = jax.random.split(k, 10)
        p = {
            # per-l channel mixers for src/dst/aggregate/update
            "w_src": jax.vmap(lambda kk: dense_init(kk, C, C))(
                jax.random.split(ks[0], n_l)),
            "w_dst": jax.vmap(lambda kk: dense_init(kk, C, C))(
                jax.random.split(ks[1], n_l)),
            "w_upd": jax.vmap(lambda kk: dense_init(kk, C, C))(
                jax.random.split(ks[2], n_l)),
            "attn_mlp": mlp_init(ks[3], [C + cfg.n_rbf, C, cfg.n_heads]),
            "rad_mlp": mlp_init(ks[4], [cfg.n_rbf, C, n_l]),
            "gate_mlp": mlp_init(ks[5], [C, C, n_l * C]),
            "ffn0": mlp_init(ks[6], [C, 2 * C, C]),
        }
        # SO(2) conv weights per m
        for m in range(cfg.m_max + 1):
            n_lm = L + 1 - m
            kA, kB = jax.random.split(ks[7 + min(m, 2)], 2)
            scale = 1.0 / jnp.sqrt(n_lm * C)
            p[f"so2_A{m}"] = (jax.random.normal(kA, (n_lm * C, n_lm * C))
                              * scale)
            if m > 0:
                p[f"so2_B{m}"] = (jax.random.normal(kB, (n_lm * C, n_lm * C))
                                  * scale)
        return p

    layers = jax.vmap(layer_init)(jax.random.split(k_layers, cfg.n_layers))
    return {
        "embed": mlp_init(k_embed, [cfg.d_feat, C]),
        "out": mlp_init(k_out, [C, C, cfg.n_out]),
        "layers": layers,
    }


def _per_l_linear(w_stack, X, l_max: int):
    """w_stack (n_l, C, C); X (..., S, C) -> per-l block matmul."""
    outs = []
    for s, e, l in l_slices(l_max):
        outs.append(jnp.einsum("...mc,cd->...md",
                               X[..., s:e, :], w_stack[l].astype(X.dtype)))
    return jnp.concatenate(outs, axis=-2)


def _rotate(D, X, l_max: int, transpose: bool = False):
    """Apply block-diagonal Wigner stack to (..., S, C)."""
    outs = []
    for (s, e, l), Dl in zip(l_slices(l_max), D):
        eq = "...ji,...jc->...ic" if transpose else "...ij,...jc->...ic"
        outs.append(jnp.einsum(eq, Dl.astype(X.dtype), X[..., s:e, :]))
    return jnp.concatenate(outs, axis=-2)


def _rbf(dist, n_rbf: int, cutoff: float):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * jnp.square(dist[..., None] - centers))


def _so2_conv(p, Z, cfg: EquiformerConfig, m_sets, rad_gate):
    """Z: (E, S, C) aligned features -> (E, S, C), |m|>m_max zeroed.

    rad_gate: (E, n_l) radial modulation applied per output l block.
    """
    E = Z.shape[0]
    C, L = cfg.d_hidden, cfg.l_max
    out = jnp.zeros_like(Z)
    for m, (ip, im) in enumerate(m_sets):
        n_lm = ip.shape[0]
        xp = Z[:, ip, :].reshape(E, n_lm * C)
        A = p[f"so2_A{m}"].astype(Z.dtype)
        if m == 0:
            y = xp @ A
            out = out.at[:, ip, :].set(y.reshape(E, n_lm, C))
        else:
            xm = Z[:, im, :].reshape(E, n_lm * C)
            B = p[f"so2_B{m}"].astype(Z.dtype)
            yp = xp @ A - xm @ B
            ym = xp @ B + xm @ A
            out = out.at[:, ip, :].set(yp.reshape(E, n_lm, C))
            out = out.at[:, im, :].set(ym.reshape(E, n_lm, C))
    # radial gating per l block
    gated = []
    for s, e, l in l_slices(L):
        gated.append(out[:, s:e, :] * rad_gate[:, None, l:l + 1].astype(Z.dtype))
    return jnp.concatenate(gated, axis=1)


def _edge_block(p, cfg: EquiformerConfig, X, pos, es, ed, m_sets):
    """Messages + attention weights for one block of edges.

    Returns (weighted messages (e, S, C), weights (e, heads), dst ids).
    Zero-length/padding edges get weight 0 (their dst may be the sentinel
    n_nodes, dropped by segment_sum).
    """
    L = cfg.l_max
    evec = jnp.take(pos, ed, axis=0, mode="clip") \
        - jnp.take(pos, es, axis=0, mode="clip")
    dist = jnp.linalg.norm(evec, axis=-1)
    valid = dist > 1e-6
    R = rotation_to_align_z(evec)
    D = wigner_d_stack(R, L)
    rbf = _rbf(dist, cfg.n_rbf, cfg.cutoff)

    Xs = jnp.take(X, es, axis=0, mode="clip")
    Xd = jnp.take(X, ed, axis=0, mode="clip")
    msg = _per_l_linear(p["w_src"], Xs, L) + _per_l_linear(p["w_dst"], Xd, L)
    Z = _rotate(D, msg, L)                                # edge-aligned
    rad_gate = mlp_apply(p["rad_mlp"], rbf)               # (e, n_l)
    Zc = _so2_conv(p, Z, cfg, m_sets, rad_gate)
    msg_out = _rotate(D, Zc, L, transpose=True)           # back to global

    # soft-capped attention logits -> single-pass exp weights
    inv = jnp.concatenate([Zc[:, 0, :],
                           rbf.astype(Zc.dtype)], axis=-1)
    logits = mlp_apply(p["attn_mlp"], inv).astype(jnp.float32)
    cap = cfg.logit_cap
    logits = cap * jnp.tanh(logits / cap)
    w = jnp.exp(logits) * valid[:, None]                  # (e, heads)

    e_, S, C = msg_out.shape
    mh = msg_out.reshape(e_, S, cfg.n_heads, C // cfg.n_heads)
    num = (mh * w[:, None, :, None].astype(mh.dtype)).reshape(e_, S, C)
    return num, w, ed


def forward_edges(params, cfg: EquiformerConfig, node_feats, pos, edge_src,
                  edge_dst, n_nodes: int):
    """-> (invariant node embeddings (N, C), per-node outputs (N, n_out)).

    edge_src/edge_dst: (E,) flat, or (n_chunks, chunk) for the chunked
    aggregation path (huge graphs; see module docstring).
    """
    C, L, S = cfg.d_hidden, cfg.l_max, num_sph(cfg.l_max)
    H = cfg.n_heads
    m_sets = _m_index_sets(cfg.l_max, cfg.m_max)
    dt = jnp.dtype(cfg.dtype)
    chunked = edge_src.ndim == 2

    # init: l=0 from node features; higher l seeded by neighbor geometry
    h0 = mlp_apply(params["embed"], node_feats).astype(dt)    # (N, C)

    def seed_block(es, ed):
        evec = jnp.take(pos, ed, axis=0, mode="clip") \
            - jnp.take(pos, es, axis=0, mode="clip")
        valid = jnp.linalg.norm(evec, axis=-1) > 1e-6
        sh = sph_harm_from_wigner(evec, L) * valid[:, None]   # (e, S)
        src_h = jnp.take(h0, es, axis=0, mode="clip")
        return segment_sum(
            (sh[:, :, None] * src_h[:, None, :]).astype(dt), ed, n_nodes)

    X = jnp.zeros((n_nodes, S, C), dt)
    X = X.at[:, 0, :].set(h0)
    if chunked:
        geo = jax.lax.scan(
            lambda acc, ee: (shard_latent(acc + seed_block(*ee),
                                          cfg.node_axes, cfg.channel_axis),
                             None),
            shard_latent(jnp.zeros((n_nodes, S, C), dt), cfg.node_axes,
                         cfg.channel_axis),
            (edge_src, edge_dst))[0]
    else:
        geo = seed_block(edge_src, edge_dst)
    X = shard_latent(X + geo / jnp.sqrt(S).astype(dt), cfg.node_axes,
                     cfg.channel_axis)

    def aggregate(p, X):
        if chunked:
            def chunk_fn(carry, ee):
                num_acc, den_acc = carry
                num, w, ed = _edge_block(p, cfg, X, pos, ee[0], ee[1], m_sets)
                num_acc = shard_latent(
                    num_acc + segment_sum(num, ed, n_nodes),
                    cfg.node_axes, cfg.channel_axis)
                den_acc = shard_rows(den_acc + segment_sum(w, ed, n_nodes),
                                     cfg.node_axes)
                return (num_acc, den_acc), None
            (num, den), _ = jax.lax.scan(
                chunk_fn,
                (shard_latent(jnp.zeros((n_nodes, S, C), dt),
                              cfg.node_axes, cfg.channel_axis),
                 shard_rows(jnp.zeros((n_nodes, H), jnp.float32),
                            cfg.node_axes)),
                (edge_src, edge_dst))
        else:
            num_e, w, ed = _edge_block(p, cfg, X, pos, edge_src, edge_dst,
                                       m_sets)
            num = segment_sum(num_e, ed, n_nodes)
            den = segment_sum(w, ed, n_nodes)
        den = jnp.maximum(den, 1e-9)
        numh = num.reshape(n_nodes, S, H, C // H)
        agg = (numh / den[:, None, :, None].astype(dt)
               ).reshape(n_nodes, S, C)
        return agg

    def layer(X, p):
        agg = aggregate(p, X)
        X = shard_latent(X + _per_l_linear(p["w_upd"], agg, L),
                         cfg.node_axes, cfg.channel_axis)

        # invariant-gated equivariant FFN
        inv_n = X[:, 0, :]
        gates = jax.nn.sigmoid(
            mlp_apply(p["gate_mlp"], inv_n).astype(jnp.float32)
        ).reshape(n_nodes, L + 1, C).astype(dt)
        ffn = []
        for s, e, l in l_slices(L):
            if l == 0:
                ffn.append((mlp_apply(p["ffn0"], inv_n)
                            * gates[:, 0, :])[:, None, :])
            else:
                ffn.append(X[:, s:e, :] * gates[:, l:l + 1, :])
        X = shard_latent(X + jnp.concatenate(ffn, axis=1).astype(X.dtype),
                         cfg.node_axes, cfg.channel_axis)
        return X, None

    body = layer
    if cfg.remat:
        body = jax.checkpoint(layer)
    X, _ = jax.lax.scan(body, X, params["layers"])

    inv = X[:, 0, :].astype(jnp.float32)
    return inv, mlp_apply(params["out"], inv)


def loss_edges(params, cfg: EquiformerConfig, node_feats, pos, edge_src,
               edge_dst, targets, n_nodes: int):
    _, out = forward_edges(params, cfg, node_feats, pos, edge_src, edge_dst,
                           n_nodes)
    return jnp.mean(jnp.square(out - targets))
