"""Shared model building blocks (plain-pytree params, no framework dep)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.square(xf - mu).mean(axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def dense_init(key, fan_in: int, fan_out: int, dtype=jnp.float32, scale=None):
    s = scale if scale is not None else (1.0 / jnp.sqrt(fan_in))
    return (jax.random.normal(key, (fan_in, fan_out)) * s).astype(dtype)


def mlp_init(key, dims, dtype=jnp.float32):
    """dims = [in, hidden, ..., out] -> {"w0","b0","w1","b1",...}"""
    params = {}
    keys = jax.random.split(key, len(dims) - 1)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"w{i}"] = dense_init(keys[i], a, b, dtype)
        params[f"b{i}"] = jnp.zeros((b,), dtype)
    return params


def mlp_apply(params, x, act=jax.nn.silu, final_act=False):
    n = len([k for k in params if k.startswith("w")])
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1 or final_act:
            x = act(x)
    return x


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


def shard_rows(x, axes):
    """Pin the leading axis of ``x`` to the given mesh axes (no-op when
    ``axes`` is empty).  GSPMD under-constrains scan carries — production
    layers pin node/edge latents at layer boundaries (DESIGN §3)."""
    if not axes:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(tuple(axes), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def shard_latent(x, row_axes, channel_axis=""):
    """Pin (rows, [mid...], channels) latents: rows over ``row_axes``,
    the LAST axis over ``channel_axis`` (no-op for empty axes)."""
    if not row_axes and not channel_axis:
        return x
    from jax.sharding import PartitionSpec as P
    rows = tuple(row_axes) or None
    ch = channel_axis or None
    spec = P(rows, *([None] * (x.ndim - 2)), ch)
    return jax.lax.with_sharding_constraint(x, spec)
