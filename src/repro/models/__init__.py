from repro.models import common, attention, transformer, moe
from repro.models.transformer import LMConfig, init_lm, lm_loss, lm_forward, decode_step, init_kv_cache

__all__ = [
    "common", "attention", "transformer", "moe",
    "LMConfig", "init_lm", "lm_loss", "lm_forward", "decode_step",
    "init_kv_cache",
]
