"""shard_map MoE FFN with explicit all-to-all dispatch (production path).

The GSPMD gather/scatter formulation in ``transformer._moe_ffn`` leaves the
partitioner to infer dispatch-buffer shardings; at grok scale its choices
replicate (E, C, d)-sized cotangents and psum at dispatch-buffer size.  This
module pins the WHOLE dispatch/compute/combine pipeline per device:

  * routing + capacity are LOCAL (per-device capacity C_loc = cf*k*T_loc/E —
    the standard expert-parallel formulation; the global-capacity GSPMD path
    remains the reference/small-scale implementation),
  * 'ep'  (experts over "model", moonshot 64e): token blocks move to their
    expert's shard via ``lax.all_to_all`` — wire per layer = the (E, C_loc,
    d) dispatch buffer itself, ~100x less than the psum-at-(E,C,d) pattern,
  * 'tpe' (TP-in-expert over "model", grok 8e): every device runs all
    experts on its own tokens over its ff shard; the FSDP-stored d/ff axes
    are re-gathered per layer (``lax.all_gather`` over the data axes) and
    the down-projection partial sums reduce over "model" (``lax.psum``).

The launcher (launch/steps.py) sets ``MESH`` before tracing; model code
stays mesh-agnostic otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from jax.sharding import PartitionSpec as P


# set by the launcher before tracing (shard_map needs the concrete mesh)
MESH = None


def _local_dispatch(xf, router, E: int, k: int, cap_factor: float):
    """Local top-k routing + sort-based slotting.

    xf: (T_loc, d) -> (xe (E, C_loc, d), slot_token, slot_gate, probs,
    flat_eid)."""
    T, d = xf.shape
    C = max(int(cap_factor * k * T / E), 1)
    logits = xf.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    flat_eid = gate_idx.reshape(-1)
    order = jnp.argsort(flat_eid, stable=True)
    sorted_eid = flat_eid[order]
    seg_start = jnp.searchsorted(sorted_eid,
                                 jnp.arange(E, dtype=sorted_eid.dtype))
    pos_sorted = (jnp.arange(T * k, dtype=jnp.int32)
                  - seg_start[sorted_eid].astype(jnp.int32))
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < C
    flat_slot = jnp.where(keep, flat_eid * C + pos, E * C)

    token_ids = jnp.broadcast_to(
        jnp.arange(T)[:, None], (T, k)).reshape(-1)
    slot_token = jnp.zeros((E * C,), jnp.int32).at[flat_slot].set(
        token_ids, mode="drop")
    slot_valid = jnp.zeros((E * C,), jnp.bool_).at[flat_slot].set(
        True, mode="drop")
    slot_gate = jnp.zeros((E * C,), jnp.float32).at[flat_slot].set(
        (gate_vals.reshape(-1) * keep), mode="drop")

    xe = jnp.where(slot_valid[:, None], xf[slot_token], 0.0)
    return (xe.reshape(E, C, d), slot_token, slot_gate, probs, flat_eid)


def _local_combine(ye, slot_token, slot_gate, T: int, d: int):
    E, C, _ = ye.shape
    weighted = (ye * slot_gate.reshape(E, C)[..., None].astype(ye.dtype)
                ).reshape(E * C, d)
    return jnp.zeros((T, d), jnp.float32).at[slot_token].add(
        weighted.astype(jnp.float32), mode="drop")


def _aux_loss(flat_eid, probs, E: int, axes):
    T = probs.shape[0]
    density = jax.ops.segment_sum(
        jnp.ones_like(flat_eid, jnp.float32), flat_eid, E)
    density = jax.lax.psum(density, axes)
    pmean = jax.lax.psum(probs.sum(0), axes)
    t_tot = jax.lax.psum(jnp.float32(T), axes)
    return E * jnp.sum((density / t_tot) * (pmean / t_tot))


def moe_ffn_sharded(p, x, cfg):
    """x: (B, S, d) sharded P(dp, "model", None) -> (y, aux).

    Requires MESH set and cfg.moe_shard_axes/moe_partition configured.
    p holds one layer's slices: router (d, E), w_gate_up (E, d, 2ff),
    w_down (E, ff, d) with the launch/shardings.py storage layout.
    """
    assert MESH is not None, "launch layer must set moe_sharded.MESH"
    dp = tuple(cfg.moe_shard_axes)
    tp = "model"
    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    ep = cfg.moe_partition == "ep"
    tp_size = MESH.shape[tp]
    all_axes = dp + (tp,)

    if ep:
        wgu_spec, wdn_spec = P(tp, dp, None), P(tp, dp, None)
    else:
        wgu_spec, wdn_spec = P(None, dp, tp), P(None, tp, dp)

    def local_fn(router, wgu, wdn, x_loc):
        B_, S_, d = x_loc.shape
        if ep:
            xf = x_loc.reshape(-1, d)
        else:
            # 'tpe' reduces ff partials over "model" — every model shard
            # must therefore dispatch the SAME tokens: re-gather the
            # seq-sharded activations first. (The ungathered variant
            # psum-mixed partials of DIFFERENT tokens — caught by the
            # useful-flops-ratio check, EXPERIMENTS §Perf.)
            x_all = jax.lax.all_gather(x_loc, tp, axis=1, tiled=True)
            xf = x_all.reshape(-1, d)                # (B_loc*S_full, d)
        T_loc = xf.shape[0]
        xe, slot_token, slot_gate, probs, flat_eid = _local_dispatch(
            xf, router, E, k, cf)

        if ep:
            # tokens -> expert shards (all-to-all over the model axis),
            # FSDP d re-gather over the data axes. Correct under seq
            # sharding: slots return to their source shard afterwards.
            xe = jax.lax.all_to_all(xe, tp, split_axis=0, concat_axis=1,
                                    tiled=True)      # (E/tp, C*tp, d)
            wgu_full = jax.lax.all_gather(wgu, dp, axis=1, tiled=True)
            wdn_full = jax.lax.all_gather(wdn, dp, axis=1, tiled=True)
            gu = jnp.einsum("ecd,edf->ecf", xe, wgu_full)
            g, u = jnp.split(gu, 2, axis=-1)
            ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wdn_full)
            ye = jax.lax.all_to_all(ye, tp, split_axis=1, concat_axis=0,
                                    tiled=True)      # (E, C, d)
        else:
            # all experts on the gathered tokens over the local ff shard;
            # FSDP-stored axes re-gathered per layer
            wgu_full = jax.lax.all_gather(wgu, dp, axis=1, tiled=True)
            wdn_full = jax.lax.all_gather(wdn, dp, axis=2, tiled=True)
            gu = jnp.einsum("ecd,edf->ecf", xe, wgu_full)
            g, u = jnp.split(gu, 2, axis=-1)
            ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wdn_full)
            ye = jax.lax.psum(ye, tp)                # reduce ff partials

        y = _local_combine(ye, slot_token, slot_gate, T_loc, d)
        aux = _aux_loss(flat_eid, probs, E, all_axes)
        if not ep:
            # slice this shard's sequence block back out
            y = y.reshape(B_, S_ * tp_size, d)
            start = jax.lax.axis_index(tp) * S_
            y = jax.lax.dynamic_slice_in_dim(y, start, S_, axis=1)
            return y.astype(x_loc.dtype), aux
        return y.reshape(B_, S_, d).astype(x_loc.dtype), aux

    fn = shard_map(
        local_fn, mesh=MESH,
        in_specs=(P(None, None), wgu_spec, wdn_spec, P(dp, tp, None)),
        out_specs=(P(dp, tp, None), P()))
    return fn(p["router"], p["w_gate_up"], p["w_down"], x)
