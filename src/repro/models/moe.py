"""Mixture-of-Experts layer: top-k routing with GShard-style capacity
dispatch (one-hot einsum), SwiGLU experts, auxiliary load-balance loss.

The dispatch/combine construction is the dense-friendly formulation that
GSPMD shards cleanly: tokens on ("pod","data"), experts on "model" when
E % model_size == 0 (expert parallel — moonshot 64e/16), otherwise the expert
ffn dim on "model" (tensor parallel within experts — grok 8e/16).  Routing
statistics reuse the segment/one-hot machinery from repro.sparse (the paper's
scatter-reduce primitive applied to token->expert assignment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype=jnp.float32):
    kr, kg, kd = jax.random.split(key, 3)
    return {
        "router": dense_init(kr, d_model, n_experts, jnp.float32),
        "w_gate_up": (jax.random.normal(kg, (n_experts, d_model, 2 * d_ff))
                      / jnp.sqrt(d_model)).astype(dtype),
        "w_down": (jax.random.normal(kd, (n_experts, d_ff, d_model))
                   / jnp.sqrt(d_ff)).astype(dtype),
    }


def moe_apply(params, x, *, top_k: int, capacity_factor: float = 1.25):
    """x: (T, d) -> (y (T, d), aux_loss ()).  Tokens over capacity drop."""
    T, d = x.shape
    E = params["router"].shape[1]
    C = max(int(capacity_factor * top_k * T / E), 1)

    logits = x.astype(jnp.float32) @ params["router"]       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)       # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)        # renormalize

    # position of each (token, slot) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (T, k, E)
    flat = onehot.reshape(T * top_k, E)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(T, top_k, E)
    pos = (pos_in_expert * onehot).sum(-1)                   # (T, k)
    keep = pos < C
    onehot_kept = onehot * keep[..., None]

    # dispatch (T, E, C): token t -> slot pos in expert e
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32)       # (T, k, C)
    dispatch = jnp.einsum("tke,tkc->tec", onehot_kept, pos_oh)
    combine = jnp.einsum("tke,tkc,tk->tec", onehot_kept, pos_oh, gate_vals)

    xe = jnp.einsum("tec,td->ecd", dispatch, x.astype(jnp.float32))
    gu = jnp.einsum("ecd,edf->ecf", xe,
                    params["w_gate_up"].astype(jnp.float32))
    g, u = jnp.split(gu, 2, axis=-1)
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(jnp.float32))
    y = jnp.einsum("tec,ecd->td", combine, ye)

    # Switch-style load-balance auxiliary loss
    density = onehot.sum(1).mean(0)                          # (E,) token frac
    router_prob = probs.mean(0)
    aux = E * jnp.sum(density * router_prob)
    return y.astype(x.dtype), aux
