"""Factorization Machine (Rendle, ICDM'10) with huge sharded embedding tables.

logit(x) = b + sum_f w[f, x_f] + sum_{i<j} <v_i, v_j>
with the pairwise term computed by the O(nk) sum-square trick
(kernels/fm_interaction.py is the fused TPU kernel; ref path here).

The n_sparse=39 categorical fields share one concatenated table
(sum_f vocab_f rows) so a single row-sharded lookup serves all fields —
the paper's NUMA-interleaving analogue (DESIGN §4): rows mod-interleave
across the "model" mesh axis and partial lookups psum, exactly like the
EfficientIMM partial counters.

``fm_retrieval_scores`` scores one user context against n_candidates item
embeddings as a single batched mat-vec (no loop), for the retrieval_cand
shape.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str = "fm"
    n_sparse: int = 39
    embed_dim: int = 10
    vocab_per_field: int = 1_000_000
    interaction: str = "fm-2way"

    @property
    def total_rows(self) -> int:
        return self.n_sparse * self.vocab_per_field

    def field_offsets(self):
        return jnp.arange(self.n_sparse, dtype=jnp.int32) * self.vocab_per_field


def init_fm(key, cfg: FMConfig, dtype=jnp.float32):
    kv, kw = jax.random.split(key)
    return {
        "v": (jax.random.normal(kv, (cfg.total_rows, cfg.embed_dim))
              * 0.01).astype(dtype),
        "w": jnp.zeros((cfg.total_rows,), dtype),
        "b": jnp.zeros((), dtype),
    }


def fm_logits(params, cfg: FMConfig, sparse_idx):
    """sparse_idx: (B, n_sparse) per-field categorical ids -> (B,) logits."""
    rows = sparse_idx + cfg.field_offsets()[None, :]      # global row ids
    v = jnp.take(params["v"], rows, axis=0)               # (B, F, K)
    w = jnp.take(params["w"], rows, axis=0)               # (B, F)
    pair = kref.fm_interaction_ref(v.astype(jnp.float32))
    return params["b"] + w.sum(axis=-1) + pair


def fm_loss(params, cfg: FMConfig, sparse_idx, labels):
    """Binary cross entropy on {0,1} CTR labels."""
    logits = fm_logits(params, cfg, sparse_idx).astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def fm_retrieval_scores(params, cfg: FMConfig, user_idx, candidate_rows):
    """user_idx: (n_user_fields,) context ids; candidate_rows: (C,) global
    row ids of candidate items.  FM score decomposes as
        s(c) = const_user + w_c + <sum_user v, v_c>
    (the candidate self-interaction is zero for one-hot fields), so scoring
    1M candidates is one mat-vec.
    """
    user_rows = user_idx + cfg.field_offsets()[: user_idx.shape[0]]
    vu = jnp.take(params["v"], user_rows, axis=0)          # (Fu, K)
    wu = jnp.take(params["w"], user_rows, axis=0)
    su = vu.sum(axis=0)                                    # (K,)
    user_pair = kref.fm_interaction_ref(vu[None].astype(jnp.float32))[0]
    const = params["b"] + wu.sum() + user_pair
    vc = jnp.take(params["v"], candidate_rows, axis=0)     # (C, K)
    wc = jnp.take(params["w"], candidate_rows, axis=0)
    return const + wc + vc @ su
