from repro.models.recsys.fm import (
    FMConfig, init_fm, fm_logits, fm_loss, fm_retrieval_scores,
)

__all__ = ["FMConfig", "init_fm", "fm_logits", "fm_loss",
           "fm_retrieval_scores"]
