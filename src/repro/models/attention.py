"""Attention layers: RoPE, GQA, sliding window, blockwise (flash-style) jnp
path, and KV-cache decode.

The blockwise path is the jnp twin of kernels/flash_attention.py: a lax.scan
over KV chunks carrying the online-softmax state.  It is what the dry-run
lowers for long sequences, so the compiled HLO has the same
O(S·chunk) working set as the TPU kernel instead of an O(S^2) score tensor
(this is what makes the 32k-prefill cells memory-realistic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


# ------------------------------------------------------------------ RoPE ----

def rope_freqs(d_head: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ------------------------------------------------- blockwise attention -----

@functools.partial(
    jax.jit, static_argnames=("causal", "window", "chunk"))
def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        chunk: int = 1024, kv_len=None, q_offset=None):
    """Online-softmax attention scanning KV chunks (flash-style, pure jnp).

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  ``kv_len`` (optional, (B,))
    masks cache positions >= kv_len (decode with a partially filled cache).
    ``q_offset`` (scalar, may be traced) is the absolute position of query 0;
    default right-aligns queries to the keys (Skv - Sq) — chunked prefill
    passes the chunk start instead.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    chunk = min(chunk, Skv)
    n_chunks = -(-Skv // chunk)
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))

    qg = q.reshape(B, Hkv, group, Sq, D).astype(jnp.float32) * scale
    kc = k.reshape(B, Hkv, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)

    if q_offset is None:
        q_offset = Skv - Sq
    qpos = jnp.arange(Sq) + q_offset                   # (Sq,)
    limit = jnp.full((B,), Skv) if kv_len is None else kv_len

    def step(carry, inp):
        m, l, acc, c_idx = carry
        kb, vb = inp                                   # (B, Hkv, chunk, D)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kb.astype(jnp.float32))
        kpos = c_idx * chunk + jnp.arange(chunk)       # (chunk,)
        mask = kpos[None, :] < limit[:, None]          # (B, chunk)
        mask = mask[:, None, None, None, :]
        if causal:
            mask = jnp.logical_and(mask, (kpos[None, :] <= qpos[:, None])[None, None, None])
        if window and window > 0:
            mask = jnp.logical_and(mask, (kpos[None, :] > qpos[:, None] - window)[None, None, None])
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new, c_idx + 1), None

    m0 = jnp.full((B, Hkv, group, Sq, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group, Sq, 1), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, group, Sq, D), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, acc0, jnp.int32(0)), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              kv_len=None, blockwise_threshold: int = 2048,
              use_pallas=None):
    """Dispatch: Pallas flash on TPU, blockwise jnp for long sequences,
    plain reference for short ones."""
    Skv = k.shape[2]
    if use_pallas or (use_pallas is None and jax.default_backend() == "tpu"):
        if kv_len is None:
            return kops.flash_attention(q, k, v, causal=causal, window=window)
    if Skv > blockwise_threshold or kv_len is not None:
        return blockwise_attention(q, k, v, causal=causal, window=window,
                                   kv_len=kv_len)
    from repro.kernels import ref
    return ref.attention_ref(q, k, v, causal=causal, window=window)
