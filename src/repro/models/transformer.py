"""Decoder-only transformer LM: RoPE + GQA + optional sliding window +
optional QKV bias + optional MoE FFN; layers stacked and scanned (compile
time O(1) in depth), full per-layer remat.

MoE uses *gather-based* dispatch (repro.models.moe builds the routing
tensors; this module selects gather dispatch for roofline honesty — no
O(T·E·C) dispatch einsum; see DESIGN §4).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import rms_norm, dense_init
from repro.models.attention import apply_rope, attention, blockwise_attention


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1000
    d_head: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    window: int = 0              # sliding window; 0 = full causal
    rope_theta: float = 10000.0
    # MoE (n_experts == 0 -> dense FFN)
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    # mesh axes to shard the MoE dispatch buffers' capacity axis over
    # (set by launch/steps.py; requires an ambient mesh context). Without
    # it GSPMD replicates the (E, C, d) buffers across the data axis.
    moe_shard_axes: tuple = ()
    # "ep" (experts over model: moonshot 64e) or "tpe" (TP-in-expert over
    # model on the ff axis: grok 8e) — controls the dispatch-buffer specs
    moe_partition: str = "tpe"
    # "dense" = GSPMD gather/scatter (reference, any device count);
    # "shard_map" = explicit all-to-all pipeline (models/moe_sharded.py,
    # production path; requires moe_sharded.MESH set by the launcher)
    moe_impl: str = "dense"
    # sequence-parallel activation constraints (set by launch/steps.py):
    # x/(q)/ffn activations are pinned to P(act_batch_axes, act_seq_axis)
    # on (B, S, ...) so GSPMD cannot replicate attention scores or remat
    # carries across the model axis (the minicpm 36-head case).
    act_batch_axes: tuple = ()
    act_seq_axis: str = ""
    # muP-ish scaling (minicpm)
    emb_scale: float = 1.0
    residual_scale: float = 1.0
    logit_scale: float = 1.0
    dtype: str = "float32"
    remat: bool = True
    # serving
    max_cache_len: int = 0       # 0 -> set per call

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    def param_count(self) -> int:
        d, hd = self.d_model, self.head_dim
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.n_experts:
            ffn = self.n_experts * (d * 2 * self.d_ff + self.d_ff * d) \
                + d * self.n_experts
        else:
            ffn = d * 2 * self.d_ff + self.d_ff * d
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d

    def active_param_count(self) -> int:
        """6·N_active·D accounting for MoE top-k (DESIGN roofline)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        hd = self.head_dim
        attn = d * hd * (self.n_heads * 2 + self.n_kv_heads * 2)
        ffn = self.top_k * (d * 2 * self.d_ff + self.d_ff * d) \
            + d * self.n_experts
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d


# ----------------------------------------------------------------- init ----

def _init_layer(key, cfg: LMConfig, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "ln1": jnp.ones((d,), dtype),
        "ln2": jnp.ones((d,), dtype),
        "wq": dense_init(ks[0], d, H * hd, dtype),
        "wk": dense_init(ks[1], d, Hkv * hd, dtype),
        "wv": dense_init(ks[2], d, Hkv * hd, dtype),
        "wo": dense_init(ks[3], H * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((Hkv * hd,), dtype)
        p["bv"] = jnp.zeros((Hkv * hd,), dtype)
    if cfg.n_experts:
        p["router"] = dense_init(ks[4], d, cfg.n_experts, jnp.float32)
        p["w_gate_up"] = (jax.random.normal(
            ks[5], (cfg.n_experts, d, 2 * cfg.d_ff)) / jnp.sqrt(d)).astype(dtype)
        p["w_down"] = (jax.random.normal(
            ks[6], (cfg.n_experts, cfg.d_ff, d)) / jnp.sqrt(cfg.d_ff)).astype(dtype)
    else:
        p["w_gate_up"] = dense_init(ks[5], d, 2 * cfg.d_ff, dtype)
        p["w_down"] = dense_init(ks[6], cfg.d_ff, d, dtype)
    return p


def init_lm(key, cfg: LMConfig):
    dtype = jnp.dtype(cfg.dtype)
    k_emb, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    return {
        "embed": (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dtype),
        "layers": layers,
        "ln_f": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab, dtype),
    }


# -------------------------------------------------------------- MoE ffn ----

def _moe_ffn(p, x2d, cfg: LMConfig):
    """Gather-based top-k dispatch: O(T·k·d) data movement + honest expert
    GEMM flops (no dense dispatch einsum)."""
    T, d = x2d.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(int(cfg.capacity_factor * k * T / E), 1)

    logits = x2d.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # sort-based routing: position-in-expert via a stable argsort over the
    # flattened expert ids — O(T*k) metadata instead of the O(T*k*E)
    # one-hot+cumsum (which dominates device memory at 131k tokens x 64e)
    flat_eid = gate_idx.reshape(-1)                          # (T*k,)
    order = jnp.argsort(flat_eid, stable=True)
    sorted_eid = flat_eid[order]
    seg_start = jnp.searchsorted(sorted_eid,
                                 jnp.arange(E, dtype=sorted_eid.dtype))
    pos_sorted = (jnp.arange(T * k, dtype=jnp.int32)
                  - seg_start[sorted_eid].astype(jnp.int32))
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted)
    pos = pos.reshape(T, k)
    keep = pos < C
    flat_slot = jnp.where(keep, gate_idx * C + pos, E * C)   # sentinel drop

    token_ids = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k))
    slot_token = jnp.zeros((E * C,), jnp.int32).at[flat_slot.reshape(-1)].set(
        token_ids.reshape(-1), mode="drop")
    slot_valid = jnp.zeros((E * C,), jnp.bool_).at[flat_slot.reshape(-1)].set(
        True, mode="drop")

    def shard_moe(t, kind):
        """Pin (E, C, ...) dispatch buffers to the MoE partition layout:
        'ep'  -> experts over "model", capacity over the data axes;
        'tpe' -> capacity over data, ff (gu only) over "model".
        Without these GSPMD replicates the buffers across the model axis
        and must all-gather the expert weights per layer."""
        if not cfg.moe_shard_axes:
            return t
        from jax.sharding import PartitionSpec as P
        dp = tuple(cfg.moe_shard_axes)
        if cfg.moe_partition == "ep":
            spec = P("model", dp, *([None] * (t.ndim - 2)))
        else:
            spec = P(None, dp,
                     *(["model" if kind == "gu" else None]
                       * (t.ndim - 2)))
        return jax.lax.with_sharding_constraint(t, spec)

    # shard the slot maps FIRST so the token gather lands pre-sharded
    slot_token = shard_moe(slot_token.reshape(E, C), "idx")
    slot_valid = shard_moe(slot_valid.reshape(E, C), "idx")
    if cfg.moe_shard_axes:
        # replicate the token table for the dispatch gather: ONE all-gather
        # of (T, d) per layer instead of per-shard partial gathers psum'd
        # at (E, C, d) size (16x more wire + a replicated dispatch buffer)
        from jax.sharding import PartitionSpec as P
        x_src = jax.lax.with_sharding_constraint(x2d, P(None, None))
    else:
        x_src = x2d
    xe = jnp.where(slot_valid[..., None], x_src[slot_token], 0.0)
    xe = shard_moe(xe, "xe")
    # bf16 expert GEMMs (f32 accumulation happens in the MXU); keeping the
    # (E, C, ff) activations in bf16 halves the dominant MoE buffers
    gu = shard_moe(jnp.einsum("ecd,edf->ecf", xe, p["w_gate_up"]), "gu")
    g, u = jnp.split(gu, 2, axis=-1)
    ye = shard_moe(jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                              p["w_down"]), "ye")
    # combine: scatter each slot's weighted output back to its token.
    # (T, d)-sized scatter-add instead of a (T, k, d) gather — k x less
    # cross-shard traffic when slots and tokens live on different shards.
    # NOTE: the scatter indexes with the 2D (E, C) slot map directly — a
    # flattening reshape of the (E, C, d) buffer merges an unsharded axis
    # with the dp-sharded capacity axis, which GSPMD can only realize by
    # replicating (7.5 GiB at grok-prefill scale; EXPERIMENTS §Perf).
    slot_gate = jnp.zeros((E * C,), jnp.float32).at[
        flat_slot.reshape(-1)].set((gate_vals * keep).reshape(-1),
                                   mode="drop")
    slot_gate = shard_moe(slot_gate.reshape(E, C), "idx")
    weighted = shard_moe(
        (ye * slot_gate[..., None].astype(ye.dtype)), "ye")
    y0 = jnp.zeros((T, d), jnp.float32)
    if cfg.moe_shard_axes:
        # token rows are batch-major -> the dp sharding survives the
        # (B, chunk) -> T merge; without the pin the scatter output
        # materializes replicated (3 GiB f32 at grok-prefill scale)
        from jax.sharding import PartitionSpec as P
        y0 = jax.lax.with_sharding_constraint(
            y0, P(tuple(cfg.moe_shard_axes), None))
    y = y0.at[slot_token].add(weighted.astype(jnp.float32), mode="drop")
    # padding slots carry gate 0 (token 0) -> no contribution

    density = jax.ops.segment_sum(
        jnp.ones_like(flat_eid, jnp.float32), flat_eid, E) / T
    aux = E * jnp.sum(density * probs.mean(0))
    return y.astype(x2d.dtype), aux


def _dense_ffn(p, x2d):
    """Works on any leading batch dims (keeps 3D (B, S, d) layouts intact
    so sequence sharding survives — no (B*S, d) reshape resharding)."""
    gu = x2d @ p["w_gate_up"]
    g, u = jnp.split(gu, 2, axis=-1)
    return jax.nn.silu(g) * u @ p["w_down"], jnp.float32(0.0)


def _act_shard(x, cfg: LMConfig):
    """Pin (B, S, ...) activations to the data/sequence-parallel layout."""
    if not cfg.act_seq_axis and not cfg.act_batch_axes:
        return x
    from jax.sharding import PartitionSpec as P
    bt = tuple(cfg.act_batch_axes) or None
    seq = cfg.act_seq_axis or None
    spec = P(bt, seq, *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, spec)


def _q_shard(q, cfg: LMConfig):
    """q: (B, H, Sq, hd) — shard the query sequence axis."""
    if not cfg.act_seq_axis:
        return q
    from jax.sharding import PartitionSpec as P
    bt = tuple(cfg.act_batch_axes) or None
    return jax.lax.with_sharding_constraint(
        q, P(bt, None, cfg.act_seq_axis, None))


# -------------------------------------------------------------- forward ----

def _attn_block(p, x, cfg: LMConfig, positions):
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["ln1"])
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    q = apply_rope(q, positions[None, None, :], cfg.rope_theta)
    k = apply_rope(k, positions[None, None, :], cfg.rope_theta)
    q = _q_shard(q, cfg)        # seq-parallel: queries sharded, KV gathered
    out = attention(q, k, v, causal=True, window=cfg.window)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return _act_shard(out @ p["wo"], cfg), (k, v)


def _layer_fn(x_aux, p, cfg: LMConfig, positions):
    x, aux = x_aux
    x = _act_shard(x, cfg)
    attn_out, _ = _attn_block(p, x, cfg, positions)
    x = x + attn_out * cfg.residual_scale
    h = rms_norm(x, p["ln2"])
    B, S, d = h.shape
    if cfg.n_experts and cfg.moe_impl == "shard_map":
        from repro.models import moe_sharded
        y, a = moe_sharded.moe_ffn_sharded(p, h, cfg)
    elif cfg.n_experts:
        y, a = _moe_ffn(p, h.reshape(B * S, d), cfg)
        y = y.reshape(B, S, d)
    else:
        y, a = _dense_ffn(p, h)
        y = _act_shard(y, cfg)
    x = x + y * cfg.residual_scale
    return (x, aux + a), None


def lm_hidden(params, cfg: LMConfig, tokens):
    """tokens (B, S) -> (final normed hidden (B, S, d), aux_loss ())."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0) * cfg.emb_scale
    positions = jnp.arange(S)

    body = partial(_layer_fn, cfg=cfg, positions=positions)
    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(
        lambda carry, p: body(carry, p),
        (x, jnp.float32(0.0)), params["layers"])

    return rms_norm(x, params["ln_f"]), aux / cfg.n_layers


def lm_forward(params, cfg: LMConfig, tokens):
    """tokens (B, S) -> (logits (B, S, V), aux_loss ())."""
    x, aux = lm_hidden(params, cfg, tokens)
    logits = (x @ params["lm_head"]) * cfg.logit_scale
    return logits, aux


def lm_loss(params, cfg: LMConfig, tokens, labels, *, ce_chunk: int = 512):
    """Next-token cross entropy (labels = tokens shifted by caller).

    The (B, S, V) logit tensor never materializes: the CE scans the sequence
    in ``ce_chunk`` slices with remat, so only one (B, chunk, V) slice is
    live at a time (fwd AND bwd) — the memory fix that keeps 150k-vocab
    archs inside per-device HBM at 1M-token batches (EXPERIMENTS §Dry-run).
    """
    x, aux = lm_hidden(params, cfg, tokens)                  # (B, S, d)
    B, S, d = x.shape
    chunk = min(ce_chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = x.reshape(B, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def ce_chunk_fn(carry, xl):
        nll_sum, n_tok = carry
        xb, lb = xl                                          # (B, chunk, d)
        logits = (xb @ params["lm_head"]).astype(jnp.float32) \
            * cfg.logit_scale
        logz = jax.nn.logsumexp(logits, axis=-1)             # (B, chunk)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
        mask = lb >= 0
        nll = (logz - gold) * mask
        return (nll_sum + nll.sum(), n_tok + mask.sum()), None

    (nll_sum, n_tok), _ = jax.lax.scan(
        ce_chunk_fn, (jnp.float32(0.0), jnp.int32(0)), (xc, lc))
    loss = nll_sum / jnp.maximum(n_tok, 1)
    return loss + cfg.aux_loss_weight * aux


def prefill(params, cfg: LMConfig, tokens):
    """Serving prefill: last-position logits only (B, V) + per-layer KV.

    Returns (logits, cache) where cache = {"k","v"} of shape
    (L, B, Hkv, S, hd) plus the filled length.
    """
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0) * cfg.emb_scale
    positions = jnp.arange(S)

    def body(carry, p):
        x, aux = carry
        attn_out, (k, v) = _attn_block(p, x, cfg, positions)
        x = x + attn_out * cfg.residual_scale
        h = rms_norm(x, p["ln2"])
        if cfg.n_experts:
            y, a = _moe_ffn(p, h.reshape(B * S, -1), cfg)
        else:
            y, a = _dense_ffn(p, h.reshape(B * S, -1))
        x = x + y.reshape(B, S, -1) * cfg.residual_scale
        return (x, aux + a), (k, v)

    fn = jax.checkpoint(body) if cfg.remat else body
    (x, _), (ks, vs) = jax.lax.scan(fn, (x, jnp.float32(0.0)),
                                    params["layers"])
    x = rms_norm(x[:, -1:], params["ln_f"])
    logits = (x @ params["lm_head"]) * cfg.logit_scale
    return logits[:, 0], {"k": ks, "v": vs, "len": jnp.int32(S)}


def prefill_chunked(params, cfg: LMConfig, tokens, *, chunk: int = 4096):
    """Chunked (Sarathi-style) prefill: the sequence is processed in fixed
    chunks so per-chunk MoE dispatch buffers stay bounded — what makes the
    32k-prefill cells of the MoE archs memory-feasible (DESIGN §4).

    Returns (last-position logits (B, V), cache {k, v, len}) like prefill().
    """
    B, S = tokens.shape
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cache_k = jnp.zeros((cfg.n_layers, B, Hkv, S, hd), jnp.bfloat16)
    cache_v = jnp.zeros_like(cache_k)

    def chunk_body(carry, ci):
        ck, cv, _ = carry
        toks = jax.lax.dynamic_slice(tokens, (0, ci * chunk), (B, chunk))
        x = jnp.take(params["embed"], toks, axis=0) * cfg.emb_scale
        positions = ci * chunk + jnp.arange(chunk)
        kv_len = jnp.full((B,), (ci + 1) * chunk, jnp.int32)

        def layer_body(inner, inp):
            # caches ride in the carry (in-place per-layer updates alias
            # in the while loop; scan xs/ys would keep input+output cache
            # stacks live simultaneously — see decode_step)
            x, ck, cv = inner
            p, li = inp
            kc = ck[li]
            vc = cv[li]
            h = rms_norm(x, p["ln1"])
            q = h @ p["wq"]
            k = h @ p["wk"]
            v = h @ p["wv"]
            if cfg.qkv_bias:
                q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
            q = q.reshape(B, chunk, H, hd).transpose(0, 2, 1, 3)
            k = k.reshape(B, chunk, Hkv, hd).transpose(0, 2, 1, 3)
            v = v.reshape(B, chunk, Hkv, hd).transpose(0, 2, 1, 3)
            q = apply_rope(q, positions[None, None, :], cfg.rope_theta)
            k = apply_rope(k, positions[None, None, :], cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice(
                kc, k.astype(kc.dtype), (0, 0, ci * chunk, 0))
            vc = jax.lax.dynamic_update_slice(
                vc, v.astype(vc.dtype), (0, 0, ci * chunk, 0))
            # attend to everything cached so far; kv_len masks the unfilled
            # tail, q_offset = chunk start gives in-chunk causality
            out = blockwise_attention(
                q, kc.astype(q.dtype), vc.astype(q.dtype), causal=True,
                window=cfg.window, kv_len=kv_len, q_offset=ci * chunk)
            out = out.transpose(0, 2, 1, 3).reshape(B, chunk, H * hd)
            x = x + (out @ p["wo"]) * cfg.residual_scale
            h2 = rms_norm(x, p["ln2"])
            if cfg.n_experts:
                y, _ = _moe_ffn(p, h2.reshape(B * chunk, -1), cfg)
            else:
                y, _ = _dense_ffn(p, h2.reshape(B * chunk, -1))
            x = x + y.reshape(B, chunk, -1) * cfg.residual_scale
            ck = jax.lax.dynamic_update_index_in_dim(ck, kc, li, 0)
            cv = jax.lax.dynamic_update_index_in_dim(cv, vc, li, 0)
            return (x, ck, cv), None

        body = jax.checkpoint(layer_body) if cfg.remat else layer_body
        (x, ck, cv), _ = jax.lax.scan(
            body, (x, ck, cv),
            (params["layers"], jnp.arange(cfg.n_layers)))
        return (ck, cv, x[:, -1]), None

    x0_last = jnp.zeros((B, cfg.d_model), jnp.dtype(cfg.dtype))
    (cache_k, cache_v, x_last), _ = jax.lax.scan(
        chunk_body, (cache_k, cache_v, x0_last), jnp.arange(n_chunks))
    x_last = rms_norm(x_last, params["ln_f"])
    logits = (x_last @ params["lm_head"]) * cfg.logit_scale
    return logits, {"k": cache_k, "v": cache_v, "len": jnp.int32(S)}


# --------------------------------------------------------------- decode ----

def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.int32(0),
    }


def decode_step(params, cfg: LMConfig, cache, tokens):
    """One token for every sequence. tokens (B, 1) -> (next (B, 1), cache).

    With cfg.window > 0 the cache is a ring buffer of size window (what makes
    long_500k decoding O(window) — see DESIGN §4).
    """
    B = tokens.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = cache["len"]
    max_len = cache["k"].shape[3]
    slot = pos % max_len if cfg.window > 0 else jnp.minimum(pos, max_len - 1)

    x = jnp.take(params["embed"], tokens, axis=0) * cfg.emb_scale  # (B,1,d)

    # absolute positions stored in each cache slot (ring-buffer aware);
    # after this step's write, slot ``slot`` holds position ``pos`` which the
    # formula already yields ((pos - slot) % max_len == 0).
    slots = jnp.arange(max_len)
    if cfg.window > 0:
        kpos = pos - ((pos - slots) % max_len)
    else:
        kpos = slots
    kv_valid = (kpos >= 0) & (kpos <= pos)

    def body(carry, inp):
        # NOTE: the caches ride in the CARRY (updated in place per layer),
        # not in scan xs/ys — while-loop carries alias in HLO, so the cache
        # stays single-resident. The xs/ys form kept input+output stacks
        # live simultaneously (2x cache + an unaliased update chain;
        # EXPERIMENTS §Perf).
        x, ck, cv = carry
        p, li = inp
        kc = ck[li]
        vc = cv[li]
        h = rms_norm(x, p["ln1"])
        q = h @ p["wq"]
        k = h @ p["wk"]
        v = h @ p["wv"]
        if cfg.qkv_bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        q = q.reshape(B, 1, H, hd).transpose(0, 2, 1, 3)
        k = k.reshape(B, 1, Hkv, hd).transpose(0, 2, 1, 3)
        v = v.reshape(B, 1, Hkv, hd).transpose(0, 2, 1, 3)
        q = apply_rope(q, jnp.full((1, 1, 1), pos), cfg.rope_theta)
        k = apply_rope(k, jnp.full((1, 1, 1), pos), cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(
            kc, k.astype(kc.dtype), (0, 0, slot, 0))
        vc = jax.lax.dynamic_update_slice(
            vc, v.astype(vc.dtype), (0, 0, slot, 0))
        # decode attention: masked einsum over the cache; scores in the
        # cache dtype (bf16) with f32 accumulation — no f32 cache copies,
        # and the S contraction keeps sequence-sharded caches sharded
        # (a blockwise/chunked variant was tried and REVERTED: its chunk
        # reshape breaks the S-sharding and forces per-layer cache
        # all-gathers — EXPERIMENTS §Perf)
        qg = q.reshape(B, Hkv, H // Hkv, 1, hd)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(kc.dtype), kc,
                       preferred_element_type=jnp.float32) / (hd ** 0.5)
        mask = kv_valid & (kpos <= pos)
        if cfg.window > 0:
            mask = mask & (kpos > pos - cfg.window)
        s = jnp.where(mask[None, None, None, None, :], s, -1e30)
        probs = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", probs.astype(kc.dtype), vc,
                         preferred_element_type=jnp.float32)
        out = out.reshape(B, H, 1, hd).transpose(0, 2, 1, 3).reshape(
            B, 1, H * hd)
        x = x + (out.astype(x.dtype) @ p["wo"]) * cfg.residual_scale
        h2 = rms_norm(x, p["ln2"])
        if cfg.n_experts:
            y, _ = _moe_ffn(p, h2.reshape(B, -1), cfg)
        else:
            y, _ = _dense_ffn(p, h2.reshape(B, -1))
        x = x + y.reshape(B, 1, -1) * cfg.residual_scale
        ck = jax.lax.dynamic_update_index_in_dim(ck, kc, li, 0)
        cv = jax.lax.dynamic_update_index_in_dim(cv, vc, li, 0)
        return (x, ck, cv), None

    (x, ks, vs), _ = jax.lax.scan(
        body, (x, cache["k"], cache["v"]),
        (params["layers"], jnp.arange(cfg.n_layers)))
    x = rms_norm(x, params["ln_f"])
    logits = (x @ params["lm_head"]) * cfg.logit_scale
    next_tok = jnp.argmax(logits, axis=-1).astype(tokens.dtype)
    return next_tok, {"k": ks, "v": vs, "len": pos + 1}
