"""Synthetic node features/labels for GNN training examples.

Features carry signal about a hidden community assignment (planted
partition): feature = one-hot(community) @ mixing + noise; the label is the
community, so a 2-layer GNN can learn it through neighborhood smoothing.
"""
from __future__ import annotations

import numpy as np


def synthetic_node_features(n_nodes: int, d_feat: int, n_classes: int,
                            *, seed: int = 0, noise: float = 1.0):
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, n_classes, size=n_nodes)
    mixing = rng.normal(0, 1.0, size=(n_classes, d_feat))
    feats = mixing[comm] + rng.normal(0, noise, size=(n_nodes, d_feat))
    return feats.astype(np.float32), comm.astype(np.int32)
