"""Host-side prefetcher: overlaps numpy batch synthesis with device compute.

A single background thread keeps ``depth`` batches ready; on TPU this hides
the host data path behind the device step (the standard input-pipeline
overlap; on CPU-only containers it degrades gracefully to a FIFO).
"""
from __future__ import annotations

import queue
import threading


class Prefetcher:
    def __init__(self, iterator, depth: int = 2):
        self._it = iterator
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
