"""Synthetic CTR click stream for the FM recsys arch.

Ground-truth model: a hidden low-rank FM over the categorical fields; labels
are Bernoulli draws from its sigmoid. A learner with the same family can
recover it, so examples/recsys_ctr shows real AUC/loss improvement.
"""
from __future__ import annotations

import numpy as np


def synthetic_click_batches(n_fields: int, vocab_per_field: int, batch: int,
                            steps: int, *, dim: int = 4, seed: int = 0,
                            shard: int = 0):
    rng0 = np.random.default_rng(seed)
    # hidden FM parameters (shared across steps)
    v_true = rng0.normal(0, 0.3, size=(n_fields, vocab_per_field, dim))
    w_true = rng0.normal(0, 0.3, size=(n_fields, vocab_per_field))

    for step in range(steps):
        rng = np.random.default_rng((seed * 7919 + step) * 104_729 + shard)
        idx = rng.integers(0, vocab_per_field, size=(batch, n_fields))
        emb = v_true[np.arange(n_fields)[None, :], idx]      # (B, F, K)
        s = emb.sum(axis=1)
        s2 = (emb * emb).sum(axis=1)
        pair = 0.5 * (s * s - s2).sum(axis=-1)
        lin = w_true[np.arange(n_fields)[None, :], idx].sum(axis=1)
        logit = lin + pair
        p = 1.0 / (1.0 + np.exp(-logit))
        labels = (rng.random(batch) < p).astype(np.float32)
        yield idx.astype(np.int32), labels
