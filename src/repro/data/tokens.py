"""Deterministic synthetic token pipeline (shard-aware, seeded).

Generates Zipf-distributed token streams with local n-gram structure so a
~100M LM shows a real, monotonically decreasing loss curve (examples/train_lm).
Every batch is a pure function of (seed, step, shard) — restart-safe without
data-loader state in checkpoints, and each data shard draws a disjoint stream.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    batch: int              # per-host batch
    seq_len: int
    seed: int = 0
    shard: int = 0
    num_shards: int = 1
    zipf_a: float = 1.2

    def batch_at(self, step: int):
        """-> (tokens (batch, seq_len) int32, labels (batch, seq_len) int32).

        Labels are next-token targets (tokens shifted left; final label is
        masked with -1).
        """
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard)
        b, s, v = self.batch, self.seq_len, self.vocab
        # Zipf base stream + deterministic bigram structure: with p=0.5 the
        # next token is f(prev) (a fixed random permutation), giving the LM
        # something learnable.
        base = rng.zipf(self.zipf_a, size=(b, s)).astype(np.int64)
        base = np.minimum(base, v - 1)
        perm_rng = np.random.default_rng(self.seed)  # shared across steps
        perm = perm_rng.permutation(v)
        copy_mask = rng.random((b, s)) < 0.5
        toks = base.copy()
        for i in range(1, s):
            follow = perm[toks[:, i - 1]]
            toks[:, i] = np.where(copy_mask[:, i], follow, base[:, i])
        labels = np.concatenate(
            [toks[:, 1:], np.full((b, 1), -1, np.int64)], axis=1)
        return toks.astype(np.int32), labels.astype(np.int32)


def synthetic_token_batches(vocab: int, batch: int, seq_len: int, steps: int,
                            *, seed: int = 0, shard: int = 0,
                            num_shards: int = 1):
    pipe = TokenPipeline(vocab=vocab, batch=batch, seq_len=seq_len, seed=seed,
                         shard=shard, num_shards=num_shards)
    for step in range(steps):
        yield pipe.batch_at(step)
