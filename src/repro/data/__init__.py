from repro.data.tokens import synthetic_token_batches, TokenPipeline
from repro.data.clicks import synthetic_click_batches
from repro.data.graph_feats import synthetic_node_features
from repro.data.prefetch import Prefetcher

__all__ = [
    "synthetic_token_batches",
    "TokenPipeline",
    "synthetic_click_batches",
    "synthetic_node_features",
    "Prefetcher",
]
