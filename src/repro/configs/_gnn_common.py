"""Shared scaffolding for the 4 GNN architecture configs.

All four GNN archs share the assigned shape set; the per-arch input pytrees
differ (graphcast needs edge features, egnn/equiformer need coordinates,
graphsage's ``minibatch_lg`` uses its native sampled-block form).

``minibatch_lg`` sizes follow the assignment: 1024 seed nodes with 15-10
fan-out.  For edge-list archs the sampled blocks are materialized as the
induced bipartite subgraph (hop edges only), which is the standard
message-flow-graph lowering of neighbor sampling.
"""
from __future__ import annotations

from repro.configs.base import ShapeDef


def gnn_shapes():
    return {
        "full_graph_sm": ShapeDef(
            "full_graph_sm", "train",
            {"n_nodes": 2_708, "n_edges": 10_556, "d_feat": 1_433,
             "n_classes": 7},
            note="cora-scale full-batch"),
        "minibatch_lg": ShapeDef(
            "minibatch_lg", "train",
            {"n_nodes": 232_965, "n_edges": 114_615_892,
             "batch_nodes": 1_024, "fanout": (15, 10),
             "d_feat": 602, "n_classes": 41},
            note="reddit-scale sampled training; per-step inputs are the"
                 " sampled blocks (1024 seeds x 15 x 10)"),
        "ogb_products": ShapeDef(
            "ogb_products", "train",
            {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
             "n_classes": 47},
            note="full-batch-large"),
        "molecule": ShapeDef(
            "molecule", "train",
            {"n_nodes": 30, "n_edges": 64, "batch": 128},
            note="batched small graphs as a disjoint union"
                 " (N=3840, E=8192)"),
    }


def minibatch_subgraph_dims(batch_nodes: int, fanout):
    """Node/edge counts of the sampled message-flow graph."""
    f1, f2 = fanout
    n_hop1 = batch_nodes * f1
    n_hop2 = n_hop1 * f2
    n_nodes = batch_nodes + n_hop1 + n_hop2
    n_edges = n_hop1 + n_hop2
    return n_nodes, n_edges
