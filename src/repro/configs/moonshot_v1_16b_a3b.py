"""moonshot-v1-16b-a3b — Moonlight-16B-A3B MoE LM.

[hf:moonshotai/Moonlight-16B-A3B; hf] — assigned config:
48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840, MoE 64 experts
top-6.  (With the assigned dims the total parameter count works out to
~28B with ~3.3B active — the "A3B" active size matches; see DESIGN.)
"""
from repro.configs.base import ArchDef, register
from repro.configs._lm_common import lm_shapes, lm_smoke_step
from repro.models.transformer import LMConfig, init_lm

FULL = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840,
    n_experts=64, top_k=6, capacity_factor=1.25,
    dtype="bfloat16",
)

SMOKE = LMConfig(
    name="moonshot-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=48, vocab=512,
    n_experts=8, top_k=2,
)

ARCH = register(ArchDef(
    arch_id="moonshot-v1-16b-a3b",
    family="lm",
    source="hf:moonshotai/Moonlight-16B-A3B",
    config=FULL,
    smoke_config=SMOKE,
    shapes=lm_shapes(window=0, arch_note="full attention, MoE"),
    init_fn=init_lm,
    smoke_step=lm_smoke_step,
    technique_applicable=True,
    technique_note=("partial: MoE token->expert dispatch is a reduce-by-key"
                    " scatter — reuses the repro.sparse one-hot/segment"
                    " machinery (DESIGN §4)"),
))
