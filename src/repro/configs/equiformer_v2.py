"""equiformer-v2 — SO(2)-eSCN equivariant graph attention.

[arXiv:2306.12059; unverified] — assigned config:
n_layers=12 d_hidden=128 l_max=6 m_max=2 n_heads=8 equivariance=SO(2)-eSCN.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchDef, register
from repro.configs._gnn_common import gnn_shapes
from repro.models.gnn.equiformer import (
    EquiformerConfig, init_equiformer, forward_edges, loss_edges,
)

FULL = EquiformerConfig(
    n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8,
)

SMOKE = EquiformerConfig(
    n_layers=2, d_hidden=16, l_max=2, m_max=1, n_heads=2, d_feat=8,
    remat=False,
)


def _smoke_step(params, cfg, key):
    n, e = 16, 48
    k1, k2, k3, k4 = jax.random.split(key, 4)
    nf = jax.random.normal(k1, (n, cfg.d_feat))
    pos = jax.random.normal(k2, (n, 3))
    es = jax.random.randint(k3, (e,), 0, n)
    ed = jax.random.randint(k4, (e,), 0, n)
    inv, out = forward_edges(params, cfg, nf, pos, es, ed, n)
    targets = jnp.zeros((n, cfg.n_out))
    loss, grads = jax.value_and_grad(loss_edges)(
        params, cfg, nf, pos, es, ed, targets, n)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    return {"inv": inv, "out": out, "loss": loss, "grad_norm": gnorm}


ARCH = register(ArchDef(
    arch_id="equiformer-v2",
    family="gnn",
    source="arXiv:2306.12059",
    config=FULL,
    smoke_config=SMOKE,
    shapes=gnn_shapes(),
    init_fn=init_equiformer,
    smoke_step=_smoke_step,
    technique_applicable=True,
    technique_note=("direct: irrep message aggregation is gather ->"
                    " segment_sum over edges (DESIGN §4); the eSCN SO(2)"
                    " trick replaces the O(L^6) CG tensor product"),
))
