"""minicpm-2b — MiniCPM-2B dense LM (WSD schedule, muP-style scaling).

[arXiv:2404.06395; hf] — assigned config:
40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753.

MiniCPM's muP constants (paper §3): embedding scale 12, residual scale
1.4/sqrt(n_layers), logit scale 1/(d_model/256).  Trains with the WSD
(warmup-stable-decay) schedule — wired in launch/train.py via
``optim.schedule.wsd_schedule``.

36 heads do not divide the 16-way model axis -> this arch uses the FSDP
(ZeRO-3) sharding policy instead of tensor parallelism (launch/shardings).
"""
from repro.configs.base import ArchDef, register
from repro.configs._lm_common import lm_shapes, lm_smoke_step
from repro.models.transformer import LMConfig, init_lm

FULL = LMConfig(
    name="minicpm-2b",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753,
    emb_scale=12.0,
    residual_scale=1.4 / (40 ** 0.5),
    logit_scale=1.0 / (2304 / 256),
    dtype="bfloat16",
)

SMOKE = LMConfig(
    name="minicpm-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab=512,
    emb_scale=12.0,
    residual_scale=1.4 / (2 ** 0.5),
    logit_scale=0.25,
)

ARCH = register(ArchDef(
    arch_id="minicpm-2b",
    family="lm",
    source="arXiv:2404.06395",
    config=FULL,
    smoke_config=SMOKE,
    shapes=lm_shapes(window=0, arch_note="full attention, dense"),
    init_fn=init_lm,
    smoke_step=lm_smoke_step,
    technique_applicable=False,
    technique_note="dense LM: no sparse scatter hot path (DESIGN §4)",
))
