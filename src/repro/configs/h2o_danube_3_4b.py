"""h2o-danube-3-4b — H2O.ai Danube3 dense LM with sliding-window attention.

[arXiv:2401.16818; unverified] — assigned config:
24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, llama+mistral mix,
SWA.  Window = 4096 (the Mistral-style SWA the Danube line inherits).

The SWA ring-buffer KV cache is what makes ``long_500k`` runnable: decode
cost and cache size are O(window), independent of the 524k context.
"""
from repro.configs.base import ArchDef, register
from repro.configs._lm_common import lm_shapes, lm_smoke_step
from repro.models.transformer import LMConfig, init_lm

FULL = LMConfig(
    name="h2o-danube-3-4b",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000,
    window=4096,
    dtype="bfloat16",
)

SMOKE = LMConfig(
    name="danube-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512,
    window=8,
)

ARCH = register(ArchDef(
    arch_id="h2o-danube-3-4b",
    family="lm",
    source="arXiv:2401.16818",
    config=FULL,
    smoke_config=SMOKE,
    shapes=lm_shapes(window=4096, arch_note="SWA window 4096"),
    init_fn=init_lm,
    smoke_step=lm_smoke_step,
    technique_applicable=False,
    technique_note="dense LM: no sparse scatter hot path (DESIGN §4)",
))
