"""egnn — E(n)-equivariant GNN (Satorras et al. 2021).

[arXiv:2102.09844; paper] — assigned config: n_layers=4 d_hidden=64,
equivariance=E(n).
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchDef, register
from repro.configs._gnn_common import gnn_shapes
from repro.models.gnn.egnn import (
    EGNNConfig, init_egnn, forward_edges, loss_edges,
)

FULL = EGNNConfig(n_layers=4, d_hidden=64)

SMOKE = EGNNConfig(n_layers=2, d_hidden=16, d_feat=8)


def _smoke_step(params, cfg, key):
    n, e = 16, 48
    k1, k2, k3, k4 = jax.random.split(key, 4)
    nf = jax.random.normal(k1, (n, cfg.d_feat))
    pos = jax.random.normal(k2, (n, 3))
    es = jax.random.randint(k3, (e,), 0, n)
    ed = jax.random.randint(k4, (e,), 0, n)
    h, x, energy = forward_edges(params, cfg, nf, pos, es, ed, n)
    loss, grads = jax.value_and_grad(loss_edges)(
        params, cfg, nf, pos, es, ed, pos, n)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    return {"h": h, "x": x, "energy": energy, "loss": loss,
            "grad_norm": gnorm}


ARCH = register(ArchDef(
    arch_id="egnn",
    family="gnn",
    source="arXiv:2102.09844",
    config=FULL,
    smoke_config=SMOKE,
    shapes=gnn_shapes(),
    init_fn=init_egnn,
    smoke_step=_smoke_step,
    technique_applicable=True,
    technique_note="direct: message passing = gather -> segment reduce",
))
