"""grok-1-314b — xAI Grok-1 MoE LM.

[hf:xai-org/grok-1; unverified] — assigned config:
64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts
top-2.
"""
from repro.configs.base import ArchDef, register
from repro.configs._lm_common import lm_shapes, lm_smoke_step
from repro.models.transformer import LMConfig, init_lm

FULL = LMConfig(
    name="grok-1-314b",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    n_experts=8, top_k=2, capacity_factor=1.25,
    dtype="bfloat16",
)

SMOKE = LMConfig(
    name="grok-smoke",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=96, vocab=512,
    n_experts=4, top_k=2,
)

ARCH = register(ArchDef(
    arch_id="grok-1-314b",
    family="lm",
    source="hf:xai-org/grok-1",
    config=FULL,
    smoke_config=SMOKE,
    shapes=lm_shapes(window=0, arch_note="full attention, MoE"),
    init_fn=init_lm,
    smoke_step=lm_smoke_step,
    technique_applicable=True,
    technique_note=("partial: MoE dispatch only (DESIGN §4); attention/FFN"
                    " dense"),
))
