"""Architecture registry scaffolding.

Each assigned architecture contributes an ``ArchDef``:
  * ``config``        — the exact published configuration (full scale),
  * ``smoke_config``  — a reduced same-family configuration for CPU tests,
  * ``shapes``        — its assigned input-shape cells (name -> ShapeDef),
  * hooks used by launch/dryrun.py, tests and benchmarks.

The FULL configs are only ever touched via ``jax.eval_shape`` /
``.lower()`` (ShapeDtypeStruct, no allocation); smoke configs run for real.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    name: str
    kind: str                  # "train" | "prefill" | "decode" | "serve"
    dims: dict                 # free-form dims (seq_len, batch, n_nodes, ...)
    note: str = ""
    skip: bool = False         # e.g. long_500k on pure full-attention archs
    skip_reason: str = ""


@dataclasses.dataclass(frozen=True)
class ArchDef:
    arch_id: str
    family: str                # "lm" | "gnn" | "recsys"
    source: str                # citation tag from the assignment
    config: Any
    smoke_config: Any
    shapes: dict
    # smoke hooks (run for real on CPU):
    #   init_fn(key, cfg) -> params
    #   smoke_step(params, cfg, key) -> dict of output arrays (checked
    #       finite + shape by tests)
    init_fn: Callable = None
    smoke_step: Callable = None
    technique_applicable: bool = False   # paper's scatter/partition scheme
    technique_note: str = ""

    def shape(self, name: str) -> ShapeDef:
        return self.shapes[name]


_REGISTRY: dict[str, ArchDef] = {}


def register(arch: ArchDef) -> ArchDef:
    _REGISTRY[arch.arch_id] = arch
    return arch


def get_arch(arch_id: str) -> ArchDef:
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def all_archs() -> dict[str, ArchDef]:
    return dict(_REGISTRY)


def all_cells(include_skipped: bool = False):
    """[(arch_id, shape_name)] for every assigned cell (40 total)."""
    cells = []
    for aid, arch in sorted(_REGISTRY.items()):
        for sname, sdef in arch.shapes.items():
            if sdef.skip and not include_skipped:
                continue
            cells.append((aid, sname))
    return cells
