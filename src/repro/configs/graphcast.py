"""graphcast — encode-process-decode mesh GNN (DeepMind GraphCast).

[arXiv:2212.12794; unverified] — assigned config:
n_layers=16 d_hidden=512 mesh_refinement=6 aggregator=sum n_vars=227.

On the assigned generic graph shapes the processor runs over the given edge
list; the icosahedral multi-mesh (refinement 6) defines the edge list in the
weather deployment (DESIGN §4).  The encoder input width follows each
shape's ``d_feat`` (falling back to n_vars=227 where the shape doesn't fix
one).
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchDef, register
from repro.configs._gnn_common import gnn_shapes
from repro.models.gnn.graphcast import (
    GraphCastConfig, init_graphcast, forward_edges, loss_edges,
)

FULL = GraphCastConfig(
    n_layers=16, d_hidden=512, mesh_refinement=6, aggregator="sum",
    n_vars=227, d_edge_in=4,
)

SMOKE = GraphCastConfig(
    n_layers=2, d_hidden=32, mesh_refinement=1, aggregator="sum",
    n_vars=11, d_edge_in=4, remat=False,
)


def _smoke_step(params, cfg, key):
    n, e = 24, 80
    k1, k2, k3, k4 = jax.random.split(key, 4)
    nf = jax.random.normal(k1, (n, cfg.n_vars))
    ef = jax.random.normal(k2, (e, cfg.d_edge_in))
    es = jax.random.randint(k3, (e,), 0, n)
    ed = jax.random.randint(k4, (e,), 0, n)
    out = forward_edges(params, cfg, nf, ef, es, ed, n)
    loss, grads = jax.value_and_grad(loss_edges)(
        params, cfg, nf, ef, es, ed, nf, n)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    return {"out": out, "loss": loss, "grad_norm": gnorm}


ARCH = register(ArchDef(
    arch_id="graphcast",
    family="gnn",
    source="arXiv:2212.12794",
    config=FULL,
    smoke_config=SMOKE,
    shapes=gnn_shapes(),
    init_fn=init_graphcast,
    smoke_step=_smoke_step,
    technique_applicable=True,
    technique_note=("direct: edge update + sum-aggregate = gather ->"
                    " segment_sum, the EfficientIMM counter pattern;"
                    " dst-block edge partitioning = paper C2 (DESIGN §4)"),
))
