"""fm — Factorization Machine (Rendle, ICDM'10).

[ICDM'10 (Rendle); paper] — assigned config: n_sparse=39 embed_dim=10,
interaction=fm-2way via the O(nk) sum-square trick.

Embedding tables: 39 categorical fields x 1M rows each (criteo-scale) share
one concatenated 39M x 10 table, row-sharded over the "model" mesh axis
(launch/shardings.py) — the paper's NUMA-interleaving analogue.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchDef, ShapeDef, register
from repro.models.recsys.fm import (
    FMConfig, init_fm, fm_logits, fm_loss, fm_retrieval_scores,
)

FULL = FMConfig(n_sparse=39, embed_dim=10, vocab_per_field=1_000_000)

SMOKE = FMConfig(n_sparse=6, embed_dim=4, vocab_per_field=128)


def fm_shapes():
    return {
        "train_batch": ShapeDef(
            "train_batch", "train", {"batch": 65_536}),
        "serve_p99": ShapeDef(
            "serve_p99", "serve", {"batch": 512},
            note="online-inference latency shape"),
        "serve_bulk": ShapeDef(
            "serve_bulk", "serve", {"batch": 262_144},
            note="offline scoring"),
        "retrieval_cand": ShapeDef(
            "retrieval_cand", "serve",
            {"batch": 1, "n_candidates": 1_000_000},
            note="one query vs 1M candidates as a single batched mat-vec"),
    }


def _smoke_step(params, cfg, key):
    k1, k2, k3 = jax.random.split(key, 3)
    idx = jax.random.randint(k1, (32, cfg.n_sparse), 0, cfg.vocab_per_field)
    labels = (jax.random.uniform(k2, (32,)) < 0.5).astype(jnp.float32)
    logits = fm_logits(params, cfg, idx)
    loss, grads = jax.value_and_grad(fm_loss)(params, cfg, idx, labels)
    cand = jax.random.randint(k3, (64,), 0, cfg.total_rows)
    scores = fm_retrieval_scores(
        params, cfg, idx[0, :4], cand)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    return {"logits": logits, "loss": loss, "scores": scores,
            "grad_norm": gnorm}


ARCH = register(ArchDef(
    arch_id="fm",
    family="recsys",
    source="ICDM'10 (Rendle)",
    config=FULL,
    smoke_config=SMOKE,
    shapes=fm_shapes(),
    init_fn=init_fm,
    smoke_step=_smoke_step,
    technique_applicable=True,
    technique_note=("direct: EmbeddingBag = take + segment_sum (the counter"
                    " op); row-sharded tables = paper C2 NUMA interleaving;"
                    " dense-vs-sparse candidate scoring = C4 (DESIGN §4)"),
))
