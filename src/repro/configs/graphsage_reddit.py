"""graphsage-reddit — GraphSAGE with mean aggregator, 25-10 fan-out.

[arXiv:1706.02216; paper] — assigned config: n_layers=2 d_hidden=128
aggregator=mean sample_sizes=25-10.  The ``minibatch_lg`` cell uses the
native sampled-block form (its own fan-out 15-10 per the shape assignment);
the full-graph cells use the edge-list form.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchDef, register
from repro.configs._gnn_common import gnn_shapes
from repro.models.gnn.graphsage import (
    SageConfig, init_sage, forward_blocks, forward_edges,
    loss_blocks, loss_edges,
)

FULL = SageConfig(
    n_layers=2, d_hidden=128, d_feat=602, n_classes=41,
    aggregator="mean", sample_sizes=(25, 10),
)

SMOKE = SageConfig(
    n_layers=2, d_hidden=16, d_feat=12, n_classes=5,
    aggregator="mean", sample_sizes=(3, 2),
)


def _smoke_step(params, cfg, key):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    # block mode
    B, (f1, f2) = 4, cfg.sample_sizes
    x_seed = jax.random.normal(k1, (B, cfg.d_feat))
    x_n1 = jax.random.normal(k2, (B, f1, cfg.d_feat))
    x_n2 = jax.random.normal(k3, (B * f1, f2, cfg.d_feat))
    labels = jax.random.randint(k4, (B,), 0, cfg.n_classes)
    logits = forward_blocks(params, cfg, x_seed, x_n1, x_n2)
    loss, grads = jax.value_and_grad(loss_blocks)(
        params, cfg, x_seed, x_n1, x_n2, labels)
    # edge mode
    n, e = 20, 60
    nf = jax.random.normal(k5, (n, cfg.d_feat))
    es = jax.random.randint(k1, (e,), 0, n)
    ed = jax.random.randint(k2, (e,), 0, n)
    logits_full = forward_edges(params, cfg, nf, es, ed, n)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    return {"logits": logits, "logits_full": logits_full, "loss": loss,
            "grad_norm": gnorm}


ARCH = register(ArchDef(
    arch_id="graphsage-reddit",
    family="gnn",
    source="arXiv:1706.02216",
    config=FULL,
    smoke_config=SMOKE,
    shapes=gnn_shapes(),
    init_fn=init_sage,
    smoke_step=_smoke_step,
    technique_applicable=True,
    technique_note=("direct: mean-aggregate = gather -> segment reduce;"
                    " the neighbor sampler (graphs/sampler.py) feeds the"
                    " minibatch cells"),
))
