"""qwen1.5-0.5b — Qwen1.5-0.5B dense LM with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf] — assigned config:
24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936, QKV bias.
"""
from repro.configs.base import ArchDef, register
from repro.configs._lm_common import lm_shapes, lm_smoke_step
from repro.models.transformer import LMConfig, init_lm

FULL = LMConfig(
    name="qwen1.5-0.5b",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2816, vocab=151936,
    qkv_bias=True,
    dtype="bfloat16",
)

SMOKE = LMConfig(
    name="qwen-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=512,
    qkv_bias=True,
)

ARCH = register(ArchDef(
    arch_id="qwen1.5-0.5b",
    family="lm",
    source="hf:Qwen/Qwen1.5-0.5B",
    config=FULL,
    smoke_config=SMOKE,
    shapes=lm_shapes(window=0, arch_note="full attention, dense"),
    init_fn=init_lm,
    smoke_step=lm_smoke_step,
    technique_applicable=False,
    technique_note="dense LM: no sparse scatter hot path (DESIGN §4)",
))
