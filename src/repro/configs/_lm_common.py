"""Shared scaffolding for the 5 LM-family architecture configs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeDef
from repro.models.transformer import (
    LMConfig, init_lm, lm_loss, prefill, decode_step, init_kv_cache,
)


def lm_shapes(*, window: int = 0, arch_note: str = ""):
    """The assigned LM shape set.  ``long_500k`` runs only for sub-quadratic
    archs (sliding-window attention -> fixed-size ring KV cache)."""
    full_attn = window <= 0
    return {
        "train_4k": ShapeDef(
            "train_4k", "train",
            {"seq_len": 4096, "global_batch": 256}),
        "prefill_32k": ShapeDef(
            "prefill_32k", "prefill",
            {"seq_len": 32768, "global_batch": 32}),
        "decode_32k": ShapeDef(
            "decode_32k", "decode",
            {"seq_len": 32768, "global_batch": 128}),
        "long_500k": ShapeDef(
            "long_500k", "decode",
            {"seq_len": 524288, "global_batch": 1},
            skip=full_attn,
            skip_reason=(
                "pure full-attention arch: 500k decode needs a sub-quadratic"
                " attention variant, none specified in the source"
                + (f" ({arch_note})" if arch_note else ""))),
    }


def lm_smoke_step(params, cfg: LMConfig, key):
    """One forward+backward+decode on tiny shapes; returns checkable dict."""
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((2, 1), -1, tokens.dtype)], axis=1)
    loss, grads = jax.value_and_grad(lm_loss)(params, cfg, tokens, labels)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    logits, cache = prefill(params, cfg, tokens)
    dc = init_kv_cache(cfg, 2, max(cfg.window, 32) if cfg.window else 32)
    nxt, dc = decode_step(params, cfg, dc, tokens[:, :1])
    return {"loss": loss, "grad_norm": gnorm, "prefill_logits": logits,
            "next_token": nxt}
