"""IMM experiment configs for the paper's 8 SNAP graphs (Table I / III).

Each entry pairs the SNAP graph stats with the paper's hyper-parameters
(k=50, eps=0.5) and the CPU-scale replica factor the benchmarks use.
``imm_dryrun_shapes`` defines the sharded-IMM cells the dry-run lowers
(theta x |V| bitmap selection + IC sampling steps on the production mesh).
"""
from __future__ import annotations

import dataclasses

from repro.core.imm import IMMConfig
from repro.graphs.datasets import SNAP_STATS


@dataclasses.dataclass(frozen=True)
class IMMExperiment:
    graph: str
    n: int
    m: int
    directed: bool
    cfg_ic: IMMConfig
    cfg_lt: IMMConfig
    bench_scale: float        # CPU benchmark shrink factor


def _mk(graph: str, bench_scale: float) -> IMMExperiment:
    n, m, directed = SNAP_STATS[graph]
    return IMMExperiment(
        graph=graph, n=n, m=m, directed=directed,
        cfg_ic=IMMConfig(k=50, eps=0.5, model="IC"),
        cfg_lt=IMMConfig(k=50, eps=0.5, model="LT"),
        bench_scale=bench_scale,
    )


IMM_EXPERIMENTS = {
    "com-Amazon":  _mk("com-Amazon", 0.01),
    "com-YouTube": _mk("com-YouTube", 0.004),
    "com-DBLP":    _mk("com-DBLP", 0.01),
    "com-LJ":      _mk("com-LJ", 0.001),
    "soc-Pokec":   _mk("soc-Pokec", 0.002),
    "as-Skitter":  _mk("as-Skitter", 0.002),
    "web-Google":  _mk("web-Google", 0.004),
    "Twitter7":    _mk("Twitter7", 0.0001),
}


# Sharded-IMM dry-run cells: (theta, n) selection problems at production
# scale.  theta per the paper's regimes (IC ~1e4, LT ~1e8 is capped by the
# bitmap-memory budget — the adaptive representation handles LT's sparse
# sets; the dry-run lowers the dense path, which dominates compute).
IMM_DRYRUN_CELLS = {
    "imm_select_youtube_ic": {
        "n": 1_134_890, "theta": 16_384, "k": 50, "model": "IC",
        "note": "dense bitmap selection, com-YouTube scale"},
    "imm_select_lj_ic": {
        "n": 3_997_962, "theta": 8_192, "k": 50, "model": "IC",
        "note": "dense bitmap selection, com-LJ scale"},
    "imm_sample_google_ic": {
        "n": 875_713, "m": 5_105_039, "batch": 4_096, "bfs_steps": 16,
        "model": "IC", "note": "sparse frontier sampling, web-Google scale"},
}
