"""IMM experiment configs for the paper's 8 SNAP graphs (Table I / III).

Each entry pairs the SNAP graph stats with the paper's hyper-parameters
(k=50, eps=0.5) and the CPU-scale replica factor the benchmarks use.
``imm_dryrun_shapes`` defines the sharded-IMM cells the dry-run lowers
(theta x |V| bitmap selection + IC sampling steps on the production mesh).
``campaign_ks`` is the multi-query sweep a shared `InfluenceEngine` store
answers after one sampling pass (examples/influence_campaign.py and the
IMServer workload in launch/serve.py).

``make_im_mesh`` is the one mesh-configuration entry point every IM
driver shares (launch/im_run.py, launch/serve.py,
examples/influence_campaign.py, benchmarks/table3_runtime.py,
benchmarks/sharding_scaling.py): it maps a ``--mesh`` flag value — an
int/"auto" (1D theta sharding) or ``"RxC"`` (2D theta x vertex) — onto a
``jax.sharding.Mesh`` over ``THETA_AXIS``/``VERTEX_AXIS`` that the
`InfluenceEngine` uses to shard its RRR store (paper C1, both axes);
``mesh_engine_kwargs`` turns the mesh back into the engine's
``mesh``/``theta_axes``/``vertex_axis`` keywords so drivers stay
one-liners.  ``make_theta_mesh`` remains as the 1D-only spelling.
"""
from __future__ import annotations

import dataclasses

from repro.core.engine import IMMConfig
from repro.graphs.datasets import SNAP_STATS

# the mesh axis the RRR-set theta dimension shards over, everywhere — the
# ShardedStore, the sampler batch placement, and sharded selection all key
# off this name
THETA_AXIS = "data"
# the mesh axis the vertex dimension shards over on 2D meshes — arena
# columns, sampler traversal tables, counter partials, and selection all
# key off this name
VERTEX_AXIS = "vertex"


def make_theta_mesh(shards=None, *, axis: str = THETA_AXIS):
    """Resolve a ``--mesh`` flag into a theta-sharding mesh (or None).

    ``None``/``0`` -> no mesh: single-device engine, replicated
    `BitmapStore` (the sensible one-device default).  ``"auto"`` -> one
    theta shard per local device.  An int -> that many shards, clipped to
    the available device count so pod-sized flags degrade gracefully on a
    laptop (1 shard on 1 device — still the sharded code path, same
    results; sharding never changes results, only layout).  An
    already-built ``Mesh`` passes through unchanged, so programmatic
    callers need no flag-vs-mesh dispatch.
    """
    if shards in (None, 0, "0", "none"):
        return None
    if hasattr(shards, "shape"):        # already a Mesh
        return shards
    import jax

    avail = jax.device_count()
    n = avail if shards == "auto" else min(int(shards), avail)
    return jax.make_mesh((n,), (axis,))


def make_im_mesh(spec=None, *, theta_axis: str = THETA_AXIS,
                 vertex_axis: str = VERTEX_AXIS):
    """Resolve a ``--mesh`` flag into a 1D *or* 2D influence mesh.

    Accepts everything `make_theta_mesh` does (None/0, int, ``"auto"``,
    a pre-built ``Mesh``) plus the 2D spellings ``"RxC"`` (e.g.
    ``"2x4"``: R theta shards x C vertex shards) and a ``(R, C)`` tuple.
    2D shapes clip to the available device count the same graceful way
    the 1D path does — the vertex axis shrinks first (theta sharding is
    the cheaper win: no frontier exchange), down to a 1-tile mesh on one
    device, which still runs the full 2D code path with identical
    results.
    """
    if spec in (None, 0, "0", "none"):
        return None
    if hasattr(spec, "shape"):          # already a Mesh
        return spec
    if isinstance(spec, str) and "x" in spec.lower():
        dt, dv = (int(p) for p in spec.lower().split("x", 1))
    elif isinstance(spec, (tuple, list)):
        dt, dv = int(spec[0]), int(spec[1])
    else:
        return make_theta_mesh(spec, axis=theta_axis)
    if dt < 1 or dv < 1:
        raise ValueError(f"mesh shape {dt}x{dv} must be >= 1x1")
    import jax

    avail = jax.device_count()
    dt = max(min(dt, avail), 1)             # theta sharding survives...
    dv = max(min(dv, avail // dt), 1)       # ...the vertex axis shrinks
    return jax.make_mesh((dt, dv), (theta_axis, vertex_axis))


def mesh_engine_kwargs(mesh) -> dict:
    """`InfluenceEngine`/`StreamEngine` keyword arguments for a mesh from
    `make_im_mesh`: ``{}`` for None, otherwise ``mesh`` + ``theta_axes``
    (every axis that is not ``VERTEX_AXIS`` — so 1D meshes with custom
    axis names work too), plus ``vertex_axis`` when the mesh carries
    ``VERTEX_AXIS`` — drivers construct engines as ``Engine(g, cfg,
    **mesh_engine_kwargs(mesh))`` with no shape dispatch of their own."""
    if mesh is None:
        return {}
    names = tuple(mesh.axis_names)
    kw = {"mesh": mesh,
          "theta_axes": tuple(a for a in names if a != VERTEX_AXIS)}
    if VERTEX_AXIS in names:
        kw["vertex_axis"] = VERTEX_AXIS
    return kw

# seed-set sizes an influence campaign sweeps against one sampled store —
# the engine memoizes per-k selections, so the sweep costs one selection
# kernel per k and zero additional sampling
CAMPAIGN_KS = (5, 10, 20, 50)


@dataclasses.dataclass(frozen=True)
class IMMExperiment:
    graph: str
    n: int
    m: int
    directed: bool
    cfg_ic: IMMConfig
    cfg_lt: IMMConfig
    # the two scenario models the sampler decomposition shipped: weighted
    # cascade (1/indeg edge probs) and generalized triggering (the LT
    # weights as independent marginals) — both run every coin backend
    cfg_wc: IMMConfig
    cfg_gt: IMMConfig
    bench_scale: float        # CPU benchmark shrink factor
    campaign_ks: tuple = CAMPAIGN_KS


def _mk(graph: str, bench_scale: float) -> IMMExperiment:
    n, m, directed = SNAP_STATS[graph]
    return IMMExperiment(
        graph=graph, n=n, m=m, directed=directed,
        cfg_ic=IMMConfig(k=50, eps=0.5, model="IC"),
        cfg_lt=IMMConfig(k=50, eps=0.5, model="LT"),
        cfg_wc=IMMConfig(k=50, eps=0.5, model="WC"),
        cfg_gt=IMMConfig(k=50, eps=0.5, model="GT"),
        bench_scale=bench_scale,
    )


IMM_EXPERIMENTS = {
    "com-Amazon":  _mk("com-Amazon", 0.01),
    "com-YouTube": _mk("com-YouTube", 0.004),
    "com-DBLP":    _mk("com-DBLP", 0.01),
    "com-LJ":      _mk("com-LJ", 0.001),
    "soc-Pokec":   _mk("soc-Pokec", 0.002),
    "as-Skitter":  _mk("as-Skitter", 0.002),
    "web-Google":  _mk("web-Google", 0.004),
    "Twitter7":    _mk("Twitter7", 0.0001),
}


# Sharded-IMM dry-run cells: (theta, n) selection problems at production
# scale.  theta per the paper's regimes (IC ~1e4, LT ~1e8 is capped by the
# bitmap-memory budget — the adaptive representation handles LT's sparse
# sets; the dry-run lowers the dense path, which dominates compute).
IMM_DRYRUN_CELLS = {
    "imm_select_youtube_ic": {
        "n": 1_134_890, "theta": 16_384, "k": 50, "model": "IC",
        "note": "dense bitmap selection, com-YouTube scale"},
    "imm_select_lj_ic": {
        "n": 3_997_962, "theta": 8_192, "k": 50, "model": "IC",
        "note": "dense bitmap selection, com-LJ scale"},
    "imm_sample_google_ic": {
        "n": 875_713, "m": 5_105_039, "batch": 4_096, "bfs_steps": 16,
        "model": "IC", "note": "sparse frontier sampling, web-Google scale"},
}


# Sampler-matrix benchmark cells (benchmarks/sampler_matrix.py -> BENCH_4):
# the model x backend grid timed on one synthetic graph per size class.
# ``backends`` lists the traversal backends each coin model sweeps (the
# walk-family LT row runs the walk backend only); ``tiny`` is the CI
# smoke shape.
SAMPLER_MATRIX_CELLS = {
    "tiny":    {"n": 192, "m": 1024, "theta": 256, "batch": 128},
    "default": {"n": 1024, "m": 8192, "theta": 4096, "batch": 256},
}
SAMPLER_MATRIX_BACKENDS = ("dense", "sparse", "pallas")


# Multi-query serving cells: one resident engine store answering batched
# sigma(S) queries (the IMServer regime).  ``queries`` is the coalesced
# batch width, ``l_pad`` the padded seed-set length — together with the
# pow2 store capacity these fix the fused membership kernel's shapes.
IM_SERVE_CELLS = {
    "imm_serve_youtube_ic": {
        "n": 1_134_890, "theta": 16_384, "queries": 256, "l_pad": 64,
        "model": "IC", "note": "batched influence queries, com-YouTube scale"},
    "imm_serve_amazon_ic": {
        "n": 334_863, "theta": 16_384, "queries": 1_024, "l_pad": 16,
        "model": "IC", "note": "high-QPS small-set queries, com-Amazon scale"},
}
