"""Architecture + experiment registry.

Importing this package registers all 10 assigned architectures:

    from repro.configs import get_arch, all_archs, all_cells
    arch = get_arch("grok-1-314b")
"""
from repro.configs.base import (
    ArchDef, ShapeDef, register, get_arch, all_archs, all_cells,
)

# importing the modules registers the archs
from repro.configs import (          # noqa: F401
    moonshot_v1_16b_a3b,
    grok_1_314b,
    h2o_danube_3_4b,
    minicpm_2b,
    qwen1_5_0_5b,
    graphcast,
    equiformer_v2,
    egnn,
    graphsage_reddit,
    fm,
)
from repro.configs.imm_snap import IMM_EXPERIMENTS, IMM_DRYRUN_CELLS

__all__ = [
    "ArchDef", "ShapeDef", "register", "get_arch", "all_archs", "all_cells",
    "IMM_EXPERIMENTS", "IMM_DRYRUN_CELLS",
]
