"""Jit'd dispatch wrappers: Pallas kernel on TPU, ref.py oracle elsewhere.

``use_pallas=None`` auto-detects the backend.  ``interpret=True`` forces the
Pallas path through the interpreter (CPU validation — what the tests use).

`ic_frontier_step` is also the execution step of the engine's ``pallas``
traversal backend (``repro.core.sampler``: ``make_sampler(model,
"pallas")`` / ``IMMConfig(backend="pallas")``): the sampler loop calls
through this dispatch, so a pallas-backed engine runs the fused MXU
kernel on TPU and falls back to the bitwise-equivalent jnp oracle
anywhere else — same math, so off-TPU results match the ``dense``
backend exactly.

Every wrapper records the resolved implementation on the
``kernels.dispatch{kernel=...,impl=pallas|interpret|oracle}`` obs
counter, so benches and CI can *prove* which path ran instead of
inferring it from ``device_kind``.  The recording happens in the host
Python wrapper — i.e. at trace time when the call sits inside ``jit`` /
``shard_map`` — so the counter counts *compilations routed through each
impl*, not executions (a cached jit re-executes without re-dispatching).
That is exactly the question CI asks ("which impl was compiled in?"),
and it keeps the obs package's no-device-code contract intact.
"""
from __future__ import annotations

import jax

from repro import obs
from repro.kernels import ref
from repro.kernels.commit import arena_commit as _commit_pallas
from repro.kernels.coverage_matvec import coverage_matvec as _coverage_pallas
from repro.kernels.fused_select import fused_select as _select_pallas
from repro.kernels.ic_frontier import ic_frontier_step as _frontier_pallas
from repro.kernels.fm_interaction import fm_interaction as _fm_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.packed_count import packed_count as _packed_count_pallas
from repro.kernels.packed_count import token_count as _token_count_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(use_pallas=None, interpret: bool = False) -> str:
    """The impl a dispatch with these flags routes to, without calling it:
    ``"interpret"`` (Pallas through the interpreter), ``"pallas"``
    (compiled kernel), or ``"oracle"`` (the jnp reference)."""
    if interpret:
        return "interpret"
    if use_pallas or (use_pallas is None and _on_tpu()):
        return "pallas"
    return "oracle"


def _dispatch(kernel: str, use_pallas, interpret) -> bool:
    """Resolve the impl, record ``kernels.dispatch``, return whether the
    Pallas entry point (compiled or interpreted) should run."""
    impl = resolve_impl(use_pallas, interpret)
    obs.counter("kernels.dispatch", kernel=kernel, impl=impl).add(1)
    return impl != "oracle"


def coverage_matvec(alive, R, *, use_pallas=None, interpret=False, **kw):
    if _dispatch("coverage_matvec", use_pallas, interpret):
        return _coverage_pallas(alive, R, interpret=interpret, **kw)
    return ref.coverage_matvec_ref(alive, R)


def fused_select(alive, R, *, use_pallas=None, interpret=False, **kw):
    if _dispatch("fused_select", use_pallas, interpret):
        return _select_pallas(alive, R, interpret=interpret, **kw)
    return ref.fused_select_ref(alive, R)


def ic_frontier_step(frontier, visited, logq, rand, *, use_pallas=None,
                     interpret=False, **kw):
    if _dispatch("ic_frontier_step", use_pallas, interpret):
        return _frontier_pallas(frontier, visited, logq, rand,
                                interpret=interpret, **kw)
    return ref.ic_frontier_ref(frontier, visited, logq, rand).astype("uint8")


def arena_commit(rows, *, kind="bitmap", use_pallas=None, interpret=False,
                 **kw):
    if _dispatch("arena_commit", use_pallas, interpret):
        return _commit_pallas(rows, kind=kind, interpret=interpret, **kw)
    return ref.arena_commit_ref(rows, kind)


def packed_count(packed, alive, *, n, use_pallas=None, interpret=False,
                 **kw):
    if _dispatch("packed_count", use_pallas, interpret):
        return _packed_count_pallas(packed, alive, n=n,
                                    interpret=interpret, **kw)
    return ref.packed_count_ref(packed, alive, n)


def token_count(tokens, alive, *, n, use_pallas=None, interpret=False,
                **kw):
    if _dispatch("token_count", use_pallas, interpret):
        return _token_count_pallas(tokens, alive, n=n,
                                   interpret=interpret, **kw)
    return ref.token_count_ref(tokens, alive, n)


def fm_interaction(v, *, use_pallas=None, interpret=False, **kw):
    if _dispatch("fm_interaction", use_pallas, interpret):
        return _fm_pallas(v, interpret=interpret, **kw)
    return ref.fm_interaction_ref(v)


def flash_attention(q, k, v, *, causal=True, window=0, use_pallas=None,
                    interpret=False, **kw):
    if _dispatch("flash_attention", use_pallas, interpret):
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             interpret=interpret, **kw)
    return ref.attention_ref(q, k, v, causal=causal, window=window)
