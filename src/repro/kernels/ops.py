"""Jit'd dispatch wrappers: Pallas kernel on TPU, ref.py oracle elsewhere.

``use_pallas=None`` auto-detects the backend.  ``interpret=True`` forces the
Pallas path through the interpreter (CPU validation — what the tests use).

`ic_frontier_step` is also the execution step of the engine's ``pallas``
traversal backend (``repro.core.sampler``: ``make_sampler(model,
"pallas")`` / ``IMMConfig(backend="pallas")``): the sampler loop calls
through this dispatch, so a pallas-backed engine runs the fused MXU
kernel on TPU and falls back to the bitwise-equivalent jnp oracle
anywhere else — same math, so off-TPU results match the ``dense``
backend exactly.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.coverage_matvec import coverage_matvec as _coverage_pallas
from repro.kernels.fused_select import fused_select as _select_pallas
from repro.kernels.ic_frontier import ic_frontier_step as _frontier_pallas
from repro.kernels.fm_interaction import fm_interaction as _fm_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.packed_count import packed_count as _packed_count_pallas
from repro.kernels.packed_count import token_count as _token_count_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def coverage_matvec(alive, R, *, use_pallas=None, interpret=False, **kw):
    if use_pallas or (use_pallas is None and _on_tpu()) or interpret:
        return _coverage_pallas(alive, R, interpret=interpret, **kw)
    return ref.coverage_matvec_ref(alive, R)


def fused_select(alive, R, *, use_pallas=None, interpret=False, **kw):
    if use_pallas or (use_pallas is None and _on_tpu()) or interpret:
        return _select_pallas(alive, R, interpret=interpret, **kw)
    return ref.fused_select_ref(alive, R)


def ic_frontier_step(frontier, visited, logq, rand, *, use_pallas=None,
                     interpret=False, **kw):
    if use_pallas or (use_pallas is None and _on_tpu()) or interpret:
        return _frontier_pallas(frontier, visited, logq, rand,
                                interpret=interpret, **kw)
    return ref.ic_frontier_ref(frontier, visited, logq, rand).astype("uint8")


def packed_count(packed, alive, *, n, use_pallas=None, interpret=False,
                 **kw):
    if use_pallas or (use_pallas is None and _on_tpu()) or interpret:
        return _packed_count_pallas(packed, alive, n=n,
                                    interpret=interpret, **kw)
    return ref.packed_count_ref(packed, alive, n)


def token_count(tokens, alive, *, n, use_pallas=None, interpret=False,
                **kw):
    if use_pallas or (use_pallas is None and _on_tpu()) or interpret:
        return _token_count_pallas(tokens, alive, n=n,
                                   interpret=interpret, **kw)
    return ref.token_count_ref(tokens, alive, n)


def fm_interaction(v, *, use_pallas=None, interpret=False, **kw):
    if use_pallas or (use_pallas is None and _on_tpu()) or interpret:
        return _fm_pallas(v, interpret=interpret, **kw)
    return ref.fm_interaction_ref(v)


def flash_attention(q, k, v, *, causal=True, window=0, use_pallas=None,
                    interpret=False, **kw):
    if use_pallas or (use_pallas is None and _on_tpu()) or interpret:
        return _flash_pallas(q, k, v, causal=causal, window=window,
                             interpret=interpret, **kw)
    return ref.attention_ref(q, k, v, causal=causal, window=window)
