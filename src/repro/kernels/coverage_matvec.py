"""Pallas TPU kernel: EfficientIMM counter rebuild ``counter = alive @ R``.

The RRRset bitmap block streams HBM->VMEM tile by tile and the masked
mat-vec runs on the MXU; the theta axis is the minor grid dimension so the
output tile accumulates in place across theta tiles (revisited output block —
the canonical TPU accumulation pattern).

Block shapes: alive (1, Tt), R (Tt, Tn), out (1, Tn) — all 2D and
128-aligned on the lane axis for MXU/VPU friendliness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import _pad


DEFAULT_TILE_THETA = 256
DEFAULT_TILE_N = 512


def _kernel(alive_ref, r_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = alive_ref[...].astype(jnp.float32)          # (1, Tt)
    r = r_ref[...].astype(jnp.float32)              # (Tt, Tn)
    out_ref[...] += jnp.dot(a, r, preferred_element_type=jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("tile_theta", "tile_n", "interpret"))
def coverage_matvec(alive, R, *, tile_theta: int = DEFAULT_TILE_THETA,
                    tile_n: int = DEFAULT_TILE_N, interpret: bool = False):
    """alive: (theta,) f32/bool; R: (theta, n) uint8 -> (n,) f32 counter."""
    theta, n = R.shape
    tt = min(tile_theta, theta)
    tn = min(tile_n, n)
    alive2 = _pad.pad_to(alive.astype(jnp.float32), 0, tt)[None, :]
    Rp = _pad.pad_to(_pad.pad_to(R, 0, tt), 1, tn)
    grid = (pl.cdiv(n, tn), pl.cdiv(theta, tt))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tt), lambda i, j: (0, j)),
            pl.BlockSpec((tt, tn), lambda i, j: (j, i)),
        ],
        out_specs=pl.BlockSpec((1, tn), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Rp.shape[1]), jnp.float32),
        interpret=interpret,
    )(alive2, Rp)
    return out[0, :n]
