"""Pallas TPU kernel: fused counter rebuild + arg-max (paper C3 applied to
Find_Most_Influential_Set).

One greedy round = mat-vec + global arg-max.  Unfused, the (n,) counter
round-trips HBM between the two; fused, each counter tile lives only in a
VMEM scratch accumulator and is reduced to a per-tile (max, argmax) pair the
moment its theta accumulation completes.  The tiny (n/Tn,) pair vector is
reduced in jnp by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _pad


def _kernel(alive_ref, r_ref, max_ref, idx_ref, acc_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = alive_ref[...].astype(jnp.float32)
    r = r_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(a, r, preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _reduce():
        c = acc_ref[0, :]                            # (Tn,)
        local = jnp.argmax(c)
        tn = c.shape[0]
        max_ref[0, 0] = c[local]
        idx_ref[0, 0] = (i * tn + local).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("tile_theta", "tile_n", "interpret"))
def fused_select(alive, R, *, tile_theta: int = 256, tile_n: int = 512,
                 interpret: bool = False):
    """-> (max_count () f32, argmax () int32) over counter = alive @ R."""
    theta, n = R.shape
    tt = min(tile_theta, theta)
    tn = min(tile_n, n)
    alive2 = _pad.pad_to(alive.astype(jnp.float32), 0, tt)[None, :]
    Rp = _pad.pad_to(_pad.pad_to(R, 0, tt), 1, tn)
    ni, nj = pl.cdiv(n, tn), pl.cdiv(theta, tt)
    maxs, idxs = pl.pallas_call(
        _kernel,
        grid=(ni, nj),
        in_specs=[
            pl.BlockSpec((1, tt), lambda i, j: (0, j)),
            pl.BlockSpec((tt, tn), lambda i, j: (j, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, i)),
            pl.BlockSpec((1, 1), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, ni), jnp.float32),
            jax.ShapeDtypeStruct((1, ni), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, tn), jnp.float32)],
        interpret=interpret,
    )(alive2, Rp)
    # padded columns carry counter 0; mask them so argmax stays in-range
    masked = jnp.where(idxs[0] < n, maxs[0], -jnp.inf)
    best_tile = jnp.argmax(masked)
    return maxs[0, best_tile], idxs[0, best_tile]
