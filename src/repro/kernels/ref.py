"""Pure-jnp oracles for every Pallas kernel (the CPU/dry-run execution path).

Each function is the semantic ground truth that the corresponding kernel in
this package must match (tests/test_kernels.py sweeps shapes/dtypes in
interpret mode against these).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def coverage_matvec_ref(alive, R):
    """alive: (theta,) f32/bool, R: (theta, n) uint8 -> counter (n,) f32.

    The EfficientIMM counter rebuild (paper C5): counter[v] = #survivor sets
    containing v.
    """
    return alive.astype(jnp.float32) @ R.astype(jnp.float32)


def fused_select_ref(alive, R):
    """-> (max_count () f32, argmax () int32): one greedy round's reduction."""
    counter = coverage_matvec_ref(alive, R)
    return jnp.max(counter), jnp.argmax(counter).astype(jnp.int32)


def ic_frontier_ref(frontier, visited, logq, rand):
    """One probabilistic-BFS step in the log-semiring formulation.

    frontier/visited: (B, n) bool; logq: (n, n) f32 (log(1-p), reverse
    orientation); rand: (B, n) uniform draws.
    Returns new activations (B, n) bool.
    """
    acc = frontier.astype(jnp.float32) @ logq
    p_act = -jnp.expm1(acc)
    return jnp.logical_and(rand < p_act, ~visited)


def fm_interaction_ref(v):
    """FM 2-way interaction via the O(nk) sum-square trick (Rendle ICDM'10).

    v: (B, F, K) field embeddings (already multiplied by feature values).
    Returns (B,) f32: sum_k 0.5 * ((sum_f v)^2 - sum_f v^2).
    """
    s = v.sum(axis=1)
    s2 = (v * v).sum(axis=1)
    return (0.5 * (s * s - s2)).sum(axis=-1)


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """Grouped-query attention oracle.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); Hq % Hkv == 0.
    window > 0 adds sliding-window masking (attend to keys in
    (pos - window, pos]).  Query positions are right-aligned to the keys
    (q position i corresponds to absolute position Skv - Sq + i), which
    covers both prefill (Sq == Skv) and decode (Sq == 1).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(jnp.float32)
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq) + (Skv - Sq)
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window and window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def arena_commit_ref(rows, kind: str = "bitmap"):
    """rows: (B, n) uint8/bool 0/1 -> (stored, colsum (n,) int32).

    The fused encode-and-count oracle: ``stored`` is the at-rest block
    (identity for ``"bitmap"``, LSB-first `pack_bits` for ``"packed"``)
    and ``colsum`` is the batch's per-vertex counter contribution — the
    two quantities the store write path needs, in one definition.
    """
    rows = rows.astype(jnp.uint8)
    colsum = rows.sum(axis=0, dtype=jnp.int32)
    if kind == "bitmap":
        return rows, colsum
    if kind != "packed":
        raise ValueError(f"arena_commit kind must be bitmap|packed, "
                         f"got {kind!r}")
    from repro.core.pack.codec import pack_bits
    return pack_bits(rows), colsum


def packed_count_ref(packed, alive, n: int):
    """packed: (theta, ceil(n/8)) uint8 bit-packed rows (LSB-first),
    alive: (theta,) f32/bool -> counter (n,) int32.

    The decode-and-count oracle for bit-packed arenas: unpack to 0/1
    bits, then the exact f32 masked matmul (`coverage_matvec_ref`).
    """
    from repro.core.pack.codec import unpack_bits
    bits = unpack_bits(packed, int(n))
    return (alive.astype(jnp.float32)
            @ bits.astype(jnp.float32)).astype(jnp.int32)


def token_count_ref(tokens, alive, n: int):
    """tokens: (theta, s_pad) int32 literal/run token rows (see
    ``repro.core.pack.codec``), alive: (theta,) -> counter (n,) int32."""
    from repro.core.pack.codec import token_decode
    bits = token_decode(tokens, int(n))
    return (alive.astype(jnp.float32)
            @ bits.astype(jnp.float32)).astype(jnp.int32)
