"""Pallas TPU kernels: decode-and-count over encoded RRR arenas.

The IMPack counting path (HBMax direction): arenas rest bit-packed
(8 vertices per byte) or token-compressed (per-row literal/run token
lists over the packed bytes — see ``repro.core.pack.codec``), and the
greedy counter rebuild ``counter[v] = #alive sets containing v`` decodes
*inside* the kernel, so the logical ``(theta, n)`` uint8 arena never
materializes in HBM.

`packed_count` — grid ``(n_byte_tiles, row_tiles)`` with rows as the
contraction (minor) axis: each step unpacks a ``(Tr, Tb)`` byte tile to
``(Tr, Tb*8)`` bits with shift/mask ops on the VPU and accumulates
``alive_tile @ bits`` on the MXU into VMEM scratch; the epilogue writes
the column tile once on the last row step.

`token_count` — grid ``(col_tiles, row_tiles)``: each step rebuilds the
``(Tr, Tn)`` bit tile from the rows' token lists by comparing token
blocks against the tile's column ids (literal tokens contribute their
byte's bit, run tokens cover their 32-byte superblock; the sentinel's
code 0 never sets a bit), OR-reducing over the token axis in chunks to
bound the broadcast, then accumulates the same masked matmul.

Both return exact integer counts (f32 accumulation of 0/1 products);
``interpret=True`` validates on CPU against the jnp oracles in
``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _pad

_SB = 32        # token superblock: bytes per saturated-run token
_BASE = 512     # token = block * _BASE + code
_SAT = 256      # code marking a saturated run


def _packed_kernel(alive_ref, packed_ref, out_ref, acc_ref):
    rr = pl.program_id(1)
    nr = pl.num_programs(1)

    @pl.when(rr == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bytes_ = packed_ref[...].astype(jnp.int32)              # (Tr, Tb)
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 8), 2)
    bits = ((bytes_[:, :, None] >> shifts) & 1).astype(jnp.float32)
    bits = bits.reshape(bytes_.shape[0], -1)                # (Tr, Tb*8)
    acc_ref[...] += jnp.dot(alive_ref[...], bits,
                            preferred_element_type=jnp.float32)

    @pl.when(rr == nr - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("n", "tile_r", "tile_b", "interpret"))
def packed_count(packed, alive, *, n: int, tile_r: int = 256,
                 tile_b: int = 64, interpret: bool = False):
    """packed: (theta, ceil(n/8)) uint8, alive: (theta,) f32/bool ->
    counter (n,) int32."""
    theta, nb = packed.shape
    tr, tb = min(tile_r, max(theta, 1)), min(tile_b, nb)
    # neutral padding: zero bytes decode to zero bits, zero alive rows
    # contribute nothing
    pp = _pad.pad_to(_pad.pad_to(packed, 0, tr), 1, tb)
    ap = _pad.pad_to(alive.astype(jnp.float32).reshape(1, -1), 1, tr)
    grid = (pl.cdiv(nb, tb), pl.cdiv(theta, tr))
    out = pl.pallas_call(
        _packed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tr), lambda j, r: (0, r)),
            pl.BlockSpec((tr, tb), lambda j, r: (r, j)),
        ],
        out_specs=pl.BlockSpec((1, tb * 8), lambda j, r: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, pl.cdiv(nb, tb) * tb * 8),
                                       jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, tb * 8), jnp.float32)],
        interpret=interpret,
    )(ap, pp)
    return out[0, :n]


def _token_kernel(alive_ref, tokens_ref, out_ref, acc_ref, *, chunk: int):
    rr = pl.program_id(1)
    nr = pl.num_programs(1)

    @pl.when(rr == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    toks = tokens_ref[...]                                  # (Tr, S) int32
    tr, s_pad = toks.shape
    tn = out_ref.shape[-1]
    cols = (pl.program_id(0) * tn
            + jax.lax.broadcasted_iota(jnp.int32, (1, tn), 1))
    cblk = cols >> 3                                        # (1, Tn)
    cbit = cols & 7
    csb = (cblk // _SB) * _SB
    bits = jnp.zeros((tr, tn), jnp.float32)
    for s0 in range(0, s_pad, chunk):
        t = toks[:, s0:s0 + chunk]                          # (Tr, CH)
        blk = t // _BASE
        code = t - blk * _BASE
        lit = ((code < _SAT)[:, :, None]
               & (blk[:, :, None] == cblk[None, :, :])
               & (((code[:, :, None] >> cbit[None, :, :]) & 1) > 0))
        sat = ((code == _SAT)[:, :, None]
               & (blk[:, :, None] == csb[None, :, :]))
        bits = jnp.maximum(
            bits, (lit | sat).any(axis=1).astype(jnp.float32))
    acc_ref[...] += jnp.dot(alive_ref[...], bits,
                            preferred_element_type=jnp.float32)

    @pl.when(rr == nr - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("n", "tile_r", "tile_n", "chunk", "interpret"))
def token_count(tokens, alive, *, n: int, tile_r: int = 8,
                tile_n: int = 256, chunk: int = 8,
                interpret: bool = False):
    """tokens: (theta, s_pad) int32 (see codec format), alive: (theta,)
    f32/bool -> counter (n,) int32.  Sentinel tokens (code 0 at the
    past-the-end block) decode to nothing; pad columns past ``n`` stay
    zero because the encoder zero-pads the trailing byte."""
    theta, s_pad = tokens.shape
    tr = min(tile_r, max(theta, 1))
    tn = tile_n
    tp = _pad.pad_to(tokens, 0, tr)  # zero-pad rows: block 0 code 0 -> no bits
    ap = _pad.pad_to(alive.astype(jnp.float32).reshape(1, -1), 1, tr)
    ncols = -(-n // tn) * tn
    grid = (ncols // tn, pl.cdiv(theta, tr))
    kernel = functools.partial(_token_kernel, chunk=min(chunk, s_pad))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tr), lambda j, r: (0, r)),
            pl.BlockSpec((tr, s_pad), lambda j, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((1, tn), lambda j, r: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, ncols), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, tn), jnp.float32)],
        interpret=interpret,
    )(ap, tp)
    return out[0, :n]
