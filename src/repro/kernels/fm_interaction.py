"""Pallas TPU kernel: FM 2-way interaction (Rendle's sum-square trick).

out[b] = 0.5 * sum_k ((sum_f v[b,f,k])^2 - sum_f v[b,f,k]^2)

The (B, F, K) embedded batch streams through VMEM in batch tiles; both field
reductions happen in-register, so the (B, K) intermediates never hit HBM —
the fusion matters at recsys batch sizes (train_batch=65536).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import _pad


def _kernel(v_ref, out_ref):
    v = v_ref[...].astype(jnp.float32)              # (Tb, F, K)
    s = v.sum(axis=1)                               # (Tb, K)
    s2 = (v * v).sum(axis=1)
    out_ref[...] = (0.5 * (s * s - s2)).sum(axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def fm_interaction(v, *, tile_b: int = 1024, interpret: bool = False):
    """v: (B, F, K) -> (B,) f32."""
    B, F, K = v.shape
    tb = min(tile_b, B)
    vp = _pad.pad_to(v, 0, tb)
    out = pl.pallas_call(
        _kernel,
        grid=(pl.cdiv(B, tb),),
        in_specs=[pl.BlockSpec((tb, F, K), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((tb, 1), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((vp.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(vp)
    return out[:B, 0]
