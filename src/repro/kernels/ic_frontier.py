"""Pallas TPU kernel: one IC probabilistic-BFS frontier expansion step.

The beyond-paper MXU formulation (DESIGN §2): the probability that vertex u
is activated by the current frontier is 1 - prod_{v in F}(1 - p), so one BFS
step is ``new = (rand < 1 - exp(frontier @ logq)) & ~visited`` — a matmul in
the log-semiring fused with Bernoulli sampling and the visited-bitmap mask
(the paper's hottest data structure, Alg. 3 line 8).

Grid: (B/Tb, n/Tn, n/Tk) with the contraction axis minor; the logits
accumulate in VMEM scratch and the sampling epilogue fires on the last k
tile, so the (B, n) logit matrix never materializes in HBM.

On a 2D (theta x vertex) mesh this kernel runs inside the dense loop's
double-buffered frontier dispatch (``core/sampler.py::_dense_loop`` with
``overlap=True``): the loop state carries the vertex-axis all-gathered
frontier, so the collective producing step t+1's ``frontier`` operand is
issued while this kernel computes step t — the all-gather hides behind
the MXU matmul instead of serializing with it.  The kernel itself is
oblivious: it always sees a full-width ``(B, n)`` frontier operand.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _pad


def _kernel(front_ref, logq_ref, rand_ref, visited_ref, out_ref, acc_ref):
    kk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    f = front_ref[...].astype(jnp.float32)          # (Tb, Tk)
    q = logq_ref[...]                               # (Tk, Tn)
    acc_ref[...] += jnp.dot(f, q, preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _sample():
        p_act = -jnp.expm1(acc_ref[...])            # 1 - exp(acc)
        new = (rand_ref[...] < p_act) & (visited_ref[...] == 0)
        out_ref[...] = new.astype(jnp.uint8)


@functools.partial(
    jax.jit,
    static_argnames=("tile_b", "tile_n", "tile_k", "interpret"))
def ic_frontier_step(frontier, visited, logq, rand, *, tile_b: int = 128,
                     tile_n: int = 512, tile_k: int = 512,
                     interpret: bool = False):
    """frontier/visited: (B, n) uint8/bool; logq: (n, n) f32; rand: (B, n).

    Returns new activations (B, n) uint8.
    """
    B, n = frontier.shape
    tb, tn, tk = min(tile_b, B), min(tile_n, n), min(tile_k, n)
    # neutral-element padding: frontier 0 (no contribution), visited 1
    # (suppresses activation in padded columns), rand 1 (coin never fires)
    fp = _pad.pad_to(_pad.pad_to(frontier.astype(jnp.uint8), 0, tb), 1, tk)
    lp = _pad.pad_to(_pad.pad_to(logq, 0, tk), 1, tn)
    rp = _pad.pad_to(_pad.pad_to(rand, 0, tb, 1.0), 1, tn, 1.0)
    vp = _pad.pad_to(_pad.pad_to(visited.astype(jnp.uint8), 0, tb, 1), 1, tn, 1)
    grid = (pl.cdiv(B, tb), pl.cdiv(n, tn), pl.cdiv(n, tk))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, tk), lambda b, i, k: (b, k)),
            pl.BlockSpec((tk, tn), lambda b, i, k: (k, i)),
            pl.BlockSpec((tb, tn), lambda b, i, k: (b, i)),
            pl.BlockSpec((tb, tn), lambda b, i, k: (b, i)),
        ],
        out_specs=pl.BlockSpec((tb, tn), lambda b, i, k: (b, i)),
        out_shape=jax.ShapeDtypeStruct((fp.shape[0], rp.shape[1]), jnp.uint8),
        scratch_shapes=[pltpu.VMEM((tb, tn), jnp.float32)],
        interpret=interpret,
    )(fp, lp, rp, vp)
    return out[:B, :n]
