"""Tile-multiple padding helpers shared by the kernel wrappers.

Pallas pads out-of-bounds blocks with undefined values (NaN in interpret
mode), so every wrapper pads its operands explicitly with neutral elements
and slices the result back.
"""
from __future__ import annotations

import jax.numpy as jnp


def pad_to(x, axis: int, mult: int, value=0):
    size = x.shape[axis]
    target = -(-size // mult) * mult
    if target == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - size)
    return jnp.pad(x, widths, constant_values=value)
