"""Pallas TPU kernels for the paper's compute hot spots.

<name>.py          pl.pallas_call + BlockSpec implementation (TPU target)
ref.py             pure-jnp oracles (CPU + dry-run execution path)
ops.py             jit'd dispatch wrappers (backend auto-detect)

Validated in interpret mode against ref.py (tests/test_kernels.py).
"""
from repro.kernels import ops, ref
from repro.kernels.ops import (
    coverage_matvec,
    fused_select,
    ic_frontier_step,
    fm_interaction,
    flash_attention,
)

__all__ = [
    "ops", "ref", "coverage_matvec", "fused_select", "ic_frontier_step",
    "fm_interaction", "flash_attention",
]
