"""Pallas TPU kernel: causal GQA flash attention with optional sliding window.

Online-softmax tiling (FlashAttention-2 schedule adapted to TPU):
grid = (B * Hq, Sq/Tq, Skv/Tk), KV minor; running (m, l, acc) live in VMEM
scratch, so attention probabilities never materialize in HBM.  The sliding
window path (h2o-danube-3) masks keys outside (pos - W, pos] and is what
makes the ``long_500k`` cell sub-quadratic.

GQA is handled in the BlockSpec index maps: query head h reads KV head
h // (Hq // Hkv) — no repeat/broadcast materialization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _pad

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, sq: int, skv: int,
            tq: int, tk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # (Tq, D)
    k = k_ref[0].astype(jnp.float32)                 # (Tk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    # absolute positions (queries right-aligned to keys)
    qpos = iq * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0) \
        + (skv - sq)
    kpos = ik * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
    mask = kpos < skv                     # drop tile padding beyond true Skv
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                              # (Tq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                           # (Tq, Tk)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "tile_q", "tile_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    tile_q: int = 128, tile_k: int = 128,
                    interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Skv, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    tq = min(tile_q, Sq)
    tk = min(tile_k, Skv)

    qf = _pad.pad_to(q.reshape(B * Hq, Sq, D), 1, tq)
    kf = _pad.pad_to(k.reshape(B * Hkv, Skv, D), 1, tk)
    vf = _pad.pad_to(v.reshape(B * Hkv, Skv, D), 1, tk)
    sq_pad, skv_pad = qf.shape[1], kf.shape[1]

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        sq=Sq, skv=Skv, tq=tq, tk=tk)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, pl.cdiv(sq_pad, tq), pl.cdiv(skv_pad, tk)),
        in_specs=[
            pl.BlockSpec((1, tq, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, tk, D),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
            pl.BlockSpec((1, tk, D),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, sq_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, 1), jnp.float32),
            pltpu.VMEM((tq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :Sq].reshape(B, Hq, Sq, D)
