"""Pallas TPU kernel: fused arena commit — encode + per-vertex count in
one pass over a sampled batch.

The tail of the sample->write->count chain (PR 10).  The traversal loop's
final ``visited (B, n)`` block is consumed tile by tile: each tile is
converted to its at-rest form (identity for bitmap arenas, LSB-first
8-bits-per-byte packing for packed arenas — the MXU does the packing as a
structured mat-mul against a {0, 2^j} weight matrix) and its per-vertex
column sum is accumulated into the fused counter contribution in the same
VMEM residency.  Unfused, the store's write path re-reads the batch from
HBM once to encode and once to count; fused, the batch block streams
HBM->VMEM exactly once.

Grid: ``(col_tiles, row_tiles)`` with rows minor, so the ``(1, Tn)``
counter output block is revisited across row tiles and accumulates in
place (the canonical TPU accumulation pattern).  Zero row/column padding
is neutral for both outputs: padded bits pack to zero bytes and add zero
to every column count — exactly what `repro.core.pack.codec.pack_bits`
does with a non-multiple-of-8 width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import _pad


DEFAULT_TILE_ROWS = 128
DEFAULT_TILE_N = 512
DEFAULT_TILE_BYTES = 64


def _bitmap_kernel(rows_ref, stored_ref, colsum_ref):
    r = pl.program_id(1)
    rows = rows_ref[...]
    stored_ref[...] = rows

    @pl.when(r == 0)
    def _init():
        colsum_ref[...] = jnp.zeros_like(colsum_ref)

    colsum_ref[...] += rows.astype(jnp.int32).sum(axis=0, keepdims=True)


def _packed_kernel(rows_ref, stored_ref, colsum_ref):
    r = pl.program_id(1)
    rows = rows_ref[...]                                # (Tb, 8 * Tw) 0/1
    tw8 = rows.shape[1]
    tw = tw8 // 8
    # byte j of the tile is sum_i bits[8j + i] << i: a structured matmul
    # against W[c, j] = 2^(c % 8) * [c // 8 == j] — exact in f32 (<= 255)
    cc = jax.lax.broadcasted_iota(jnp.int32, (tw8, tw), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (tw8, tw), 1)
    weights = jnp.where(cc // 8 == jj,
                        jnp.left_shift(1, cc % 8), 0).astype(jnp.float32)
    packed = jnp.dot(rows.astype(jnp.float32), weights,
                     preferred_element_type=jnp.float32)
    stored_ref[...] = packed.astype(jnp.uint8)

    @pl.when(r == 0)
    def _init():
        colsum_ref[...] = jnp.zeros_like(colsum_ref)

    colsum_ref[...] += rows.astype(jnp.int32).sum(axis=0, keepdims=True)


@functools.partial(
    jax.jit,
    static_argnames=("kind", "tile_rows", "tile_n", "tile_bytes",
                     "interpret"))
def arena_commit(rows, *, kind: str = "bitmap",
                 tile_rows: int = DEFAULT_TILE_ROWS,
                 tile_n: int = DEFAULT_TILE_N,
                 tile_bytes: int = DEFAULT_TILE_BYTES,
                 interpret: bool = False):
    """rows: (B, n) uint8/bool 0/1 membership rows.

    Returns ``(stored, colsum)`` where ``stored`` is the at-rest block —
    ``(B, n) uint8`` for ``kind="bitmap"``, ``(B, ceil(n/8)) uint8``
    LSB-first packed bytes for ``kind="packed"`` (bitwise-equal to
    ``pack_bits``) — and ``colsum (n,) int32`` is the batch's fused
    per-vertex counter contribution.
    """
    rows = rows.astype(jnp.uint8)
    B, n = rows.shape
    tb = min(tile_rows, B)
    if kind == "bitmap":
        tn = min(tile_n, n)
        rowsp = _pad.pad_to(_pad.pad_to(rows, 0, tb), 1, tn)
        nc, nr = pl.cdiv(n, tn), pl.cdiv(B, tb)
        stored, colsum = pl.pallas_call(
            _bitmap_kernel,
            grid=(nc, nr),
            in_specs=[pl.BlockSpec((tb, tn), lambda c, r: (r, c))],
            out_specs=[
                pl.BlockSpec((tb, tn), lambda c, r: (r, c)),
                pl.BlockSpec((1, tn), lambda c, r: (0, c)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct(rowsp.shape, jnp.uint8),
                jax.ShapeDtypeStruct((1, rowsp.shape[1]), jnp.int32),
            ],
            interpret=interpret,
        )(rowsp)
        return stored[:B, :n], colsum[0, :n]
    if kind != "packed":
        raise ValueError(f"arena_commit kind must be bitmap|packed, "
                         f"got {kind!r}")
    W = -(-n // 8)
    tw = min(tile_bytes, W)
    tw8 = tw * 8
    rowsp = _pad.pad_to(_pad.pad_to(rows, 0, tb), 1, tw8)
    nc, nr = rowsp.shape[1] // tw8, pl.cdiv(B, tb)
    stored, colsum = pl.pallas_call(
        _packed_kernel,
        grid=(nc, nr),
        in_specs=[pl.BlockSpec((tb, tw8), lambda c, r: (r, c))],
        out_specs=[
            pl.BlockSpec((tb, tw), lambda c, r: (r, c)),
            pl.BlockSpec((1, tw8), lambda c, r: (0, c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rowsp.shape[0], nc * tw), jnp.uint8),
            jax.ShapeDtypeStruct((1, rowsp.shape[1]), jnp.int32),
        ],
        interpret=interpret,
    )(rowsp)
    return stored[:B, :W], colsum[0, :n]
