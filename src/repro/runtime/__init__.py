from repro.runtime.loop import TrainLoop, LoopConfig, StepResult
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.compression import (
    compress_int8, decompress_int8, compressed_allreduce_spec,
    ErrorFeedbackState, init_error_feedback, compress_with_feedback,
)
from repro.runtime.elastic import reshard_tree, ElasticPlan

__all__ = [
    "TrainLoop", "LoopConfig", "StepResult",
    "StragglerMonitor",
    "compress_int8", "decompress_int8", "compressed_allreduce_spec",
    "ErrorFeedbackState", "init_error_feedback", "compress_with_feedback",
    "reshard_tree", "ElasticPlan",
]
