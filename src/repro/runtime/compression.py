"""Gradient compression: int8 quantization with error feedback.

Opt-in wrapper around the data-parallel gradient reduction: each leaf is
quantized to int8 with a per-leaf max-abs scale before the all-reduce, and
the quantization residual is carried to the next step (error feedback — the
standard fix that keeps SGD/Adam convergence intact, cf. 1-bit SGD /
EF-SignSGD lineage).  4x less DP all-reduce traffic; EXPERIMENTS §Perf
quantifies the collective-term change on the hillclimbed cells.

Implementation notes: the quantize/dequantize pair is jit-safe pure jnp and
runs *inside* the train step; on a real mesh the all-reduce then moves int8.
(GSPMD reduces over the quantized tensors via psum of dequantized partials
within shard_map — see launch/train.py wiring.)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def compress_int8(x):
    """-> (q int8, scale f32 ()) with symmetric max-abs scaling."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


@dataclasses.dataclass
class ErrorFeedbackState:
    residual: dict


def init_error_feedback(grads):
    return {"residual": jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)}


def compress_with_feedback(grads, ef_state):
    """Quantize (grad + residual); residual' = input - dequantized.

    Returns (quantized tree of (q, scale) pairs, new ef_state).
    """
    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = jax.tree.leaves(ef_state["residual"])
    q_out, res_out = [], []
    for g, r in zip(g_leaves, r_leaves):
        x = g.astype(jnp.float32) + r
        q, s = compress_int8(x)
        q_out.append((q, s))
        res_out.append(x - decompress_int8(q, s))
    return (jax.tree.unflatten(treedef, q_out),
            {"residual": jax.tree.unflatten(treedef, res_out)})


def compressed_allreduce_spec(grads_bytes_f32: int) -> dict:
    """Napkin model of the collective-term saving (EXPERIMENTS §Perf)."""
    return {
        "fp32_bytes": grads_bytes_f32,
        "int8_bytes": grads_bytes_f32 // 4,
        "saving": 4.0,
    }
