"""Elastic re-meshing: restore a checkpoint into a different mesh.

Checkpoints store logical (un-sharded) arrays, so elasticity is just
"device_put with the new sharding".  ``ElasticPlan`` captures the mapping
from a tree of logical arrays to NamedSharding specs for the *current* mesh;
``reshard_tree`` applies it.  Scaling the data axis up/down between runs
changes only the plan, not the checkpoint (EXPERIMENTS exercises 8->4 and
4->8 device restores on the host-platform mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ElasticPlan:
    mesh: Mesh
    spec_fn: Callable  # leaf path tuple -> PartitionSpec

    def sharding_for(self, path) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_fn(path))


def _paths(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            yield from _paths(tree[k], prefix + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _paths(v, prefix + (i,))
    else:
        yield prefix, tree


def reshard_tree(tree, plan: ElasticPlan):
    """device_put every leaf with the plan's sharding for its path."""
    flat = list(_paths(tree))
    out_leaves = [
        jax.device_put(leaf, plan.sharding_for(path)) for path, leaf in flat
    ]
    # rebuild structure
    it = iter(out_leaves)

    def rebuild(t):
        if isinstance(t, dict):
            return {k: rebuild(t[k]) for k in sorted(t.keys())}
        if isinstance(t, (list, tuple)):
            vals = [rebuild(v) for v in t]
            return vals if isinstance(t, list) else tuple(vals)
        return next(it)

    return rebuild(tree)


def replicated_plan(mesh: Mesh) -> ElasticPlan:
    return ElasticPlan(mesh=mesh, spec_fn=lambda path: P())
