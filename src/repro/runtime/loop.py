"""Fault-tolerant training loop: checkpoint/restart, retry, straggler watch.

The loop owns nothing model-specific: it drives a ``step_fn(state, batch) ->
(state, metrics)`` (already jitted/sharded by the caller), a batch source
``batch_fn(step) -> batch`` (pure function of step — restart-safe), and a
CheckpointManager.

Failure handling:
  * a step raising an exception (device OOM, interconnect error, injected
    fault) is retried up to ``max_retries`` from the last good state;
  * if retries are exhausted, the loop restores from the newest checkpoint
    and replays forward (batches are pure functions of the step index, so
    replay is bitwise-deterministic on the same mesh);
  * the StragglerMonitor flags slow steps; after 3 consecutive flags the
    loop checkpoints immediately and raises ``RemeshRequested`` so the
    launcher can rebuild the mesh without the straggling host (elastic.py
    handles restoring into the smaller mesh).

``inject_fault`` (step -> bool) exists for tests: it makes the loop's
recovery paths unit-testable on CPU.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.checkpoint.store import CheckpointManager
from repro.runtime.straggler import StragglerMonitor


class RemeshRequested(RuntimeError):
    """Raised when persistent straggling suggests a sick host; the launcher
    should rebuild the mesh and resume from the checkpoint just written."""


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    checkpoint_dir: str
    save_every: int = 100
    keep: int = 3
    max_retries: int = 2
    log_every: int = 10
    straggler_threshold: float = 2.0


@dataclasses.dataclass
class StepResult:
    step: int
    metrics: dict
    step_time: float
    retried: int = 0
    restored: bool = False


class TrainLoop:
    def __init__(self, cfg: LoopConfig, step_fn: Callable,
                 batch_fn: Callable, init_fn: Callable,
                 inject_fault: Optional[Callable] = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.init_fn = init_fn
        self.inject_fault = inject_fault
        self.manager = CheckpointManager(
            cfg.checkpoint_dir, save_every=cfg.save_every, keep=cfg.keep)
        self.monitor = StragglerMonitor(threshold=cfg.straggler_threshold)
        self.history: list[StepResult] = []
        self.recoveries = 0

    # -- single step with retry + restore-from-checkpoint ------------------
    def _run_step(self, step: int, state):
        retries = 0
        restored = False
        while True:
            try:
                if self.inject_fault is not None and \
                        self.inject_fault(step, retries):
                    raise RuntimeError(f"injected fault at step {step}")
                batch = self.batch_fn(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                metrics = jax.tree.map(
                    lambda x: x.block_until_ready()
                    if hasattr(x, "block_until_ready") else x, metrics)
                dt = time.perf_counter() - t0
                return state, metrics, dt, retries, restored
            except RemeshRequested:
                raise
            except Exception:
                retries += 1
                if retries <= self.cfg.max_retries:
                    continue
                # retries exhausted -> restore newest checkpoint
                ck_step, tree = self.manager.restore_or_init(self.init_fn)
                if isinstance(tree, tuple) and len(tree) == 2 and \
                        isinstance(tree[1], dict) and "state" in tree[1]:
                    state = tree[1]["state"]
                else:
                    state = tree if ck_step else self.init_fn()
                self.recoveries += 1
                retries = 0
                restored = True
                if ck_step < step:
                    # replay forward deterministically to ``step``
                    for s in range(ck_step, step):
                        state, _ = self.step_fn(state, self.batch_fn(s))

    # -- main loop ----------------------------------------------------------
    def run(self, start_state=None, start_step: int = 0):
        if start_state is None:
            start_step, start_state = self.manager.restore_or_init(
                self.init_fn)
        state = start_state
        for step in range(start_step, self.cfg.total_steps):
            state, metrics, dt, retried, restored = self._run_step(step, state)
            flagged = self.monitor.observe(step, dt)
            self.history.append(StepResult(step, metrics, dt, retried,
                                           restored))
            self.manager.maybe_save(step + 1, state)
            if flagged and self.monitor.unhealthy:
                self.manager.save(step + 1, state)
                raise RemeshRequested(
                    f"persistent straggling at step {step} "
                    f"(ewma {self.monitor.ewma:.4f}s)")
        self.manager.save(self.cfg.total_steps, state)
        return state
