"""Straggler detection via step-time EWMA (runtime-layer load balancing).

The paper balances work by producer-consumer stealing inside a shared-memory
node; SPMD is lockstep so imbalance shows up as *whole-step* slowdown
attributable to the slowest participant. The monitor keeps an EWMA and
flags steps slower than ``threshold`` x the smoothed time; the loop reacts by
(a) logging the event, (b) optionally re-planning microbatch assignment at
the next step boundary (callback), and (c) counting consecutive flags so the
fault-tolerant loop can trigger a checkpoint + re-mesh when a chip is sick.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.2           # EWMA weight of the newest sample
    threshold: float = 2.0       # flag if step_time > threshold * ewma
    warmup_steps: int = 3        # ignore compile-dominated first steps
    ewma: float = 0.0
    seen: int = 0
    consecutive_flags: int = 0
    events: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, step_time: float) -> bool:
        """Record one step; returns True if flagged as straggling."""
        self.seen += 1
        if self.seen <= self.warmup_steps:
            self.ewma = step_time
            return False
        flagged = step_time > self.threshold * max(self.ewma, 1e-9)
        # EWMA excludes flagged outliers so one hiccup doesn't mask the next
        if not flagged:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
            self.consecutive_flags = 0
        else:
            self.consecutive_flags += 1
            self.events.append((step, step_time, self.ewma))
        return flagged

    @property
    def unhealthy(self) -> bool:
        """3+ consecutive straggling steps — the re-mesh trigger."""
        return self.consecutive_flags >= 3
