"""EmbeddingBag built from gather + segment reduce (JAX has no native one).

Two variants:
  * ``embedding_bag`` — single-device: ``jnp.take`` + segment reduce.
  * ``sharded_embedding_lookup`` — table row-sharded across a mesh axis
    (the recsys "huge table" case and the paper's NUMA-interleaving analogue):
    every shard gathers the rows it owns (others contribute zero) and the
    partials are ``psum``-combined — identical structure to the EfficientIMM
    partial-counter reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sparse.segment import segment_sum, segment_max, segment_mean


def embedding_bag(table, indices, offsets=None, mode: str = "sum"):
    """torch.nn.EmbeddingBag semantics.

    table: (vocab, dim). indices: (nnz,) int32. offsets: (bags,) start offset
    per bag (None → indices is (bags, fixed_len) multi-hot).
    Padding index == vocab contributes zero.
    """
    vocab, dim = table.shape
    if offsets is None:
        bags, L = indices.shape
        flat = indices.reshape(-1)
        seg = jnp.repeat(jnp.arange(bags, dtype=jnp.int32), L)
    else:
        (nnz,) = indices.shape
        bags = offsets.shape[0]
        positions = jnp.arange(nnz, dtype=jnp.int32)
        seg = jnp.searchsorted(offsets, positions, side="right").astype(jnp.int32) - 1
        flat = indices
    safe = jnp.clip(flat, 0, vocab - 1)
    rows = jnp.take(table, safe, axis=0)
    valid = (flat >= 0) & (flat < vocab)
    rows = jnp.where(valid[:, None], rows, 0.0)
    if mode == "sum":
        return segment_sum(rows, seg, bags)
    if mode == "mean":
        return segment_mean(rows, seg, bags)
    if mode == "max":
        out = segment_max(jnp.where(valid[:, None], rows, -jnp.inf), seg, bags)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(f"unknown mode {mode}")


def sharded_embedding_lookup(local_table, global_indices, *, axis_name: str,
                             shard_rows: int):
    """Gather rows from a row-sharded table inside ``shard_map``.

    local_table: (shard_rows, dim) — this shard's contiguous row block.
    global_indices: any int32 shape of *global* row ids (replicated).
    Returns the full gathered embeddings, combined across ``axis_name``.
    """
    shard = jax.lax.axis_index(axis_name)
    lo = shard * shard_rows
    local_ids = global_indices - lo
    hit = (local_ids >= 0) & (local_ids < shard_rows)
    safe = jnp.clip(local_ids, 0, shard_rows - 1)
    rows = jnp.take(local_table, safe, axis=0)
    rows = jnp.where(hit[..., None], rows, 0.0)
    return jax.lax.psum(rows, axis_name)
