"""Sparse/ragged primitives shared by IMM counters, GNN message passing and
recsys embedding lookups.

JAX has no native EmbeddingBag or CSR/CSC sparse support (BCOO only), so the
message-passing / bag-reduce primitives are built here from ``jnp.take`` +
``jax.ops.segment_sum`` — this layer IS part of the system (see DESIGN §3).
"""
from repro.sparse.segment import (
    segment_sum,
    segment_max,
    segment_mean,
    segment_softmax,
    sorted_segment_sum,
)
from repro.sparse.scatter import (
    scatter_add,
    scatter_or,
    bincount_weighted,
    one_hot_matmul_count,
)
from repro.sparse.embedding_bag import (
    embedding_bag,
    sharded_embedding_lookup,
)

__all__ = [
    "segment_sum",
    "segment_max",
    "segment_mean",
    "segment_softmax",
    "sorted_segment_sum",
    "scatter_add",
    "scatter_or",
    "bincount_weighted",
    "one_hot_matmul_count",
    "embedding_bag",
    "sharded_embedding_lookup",
]
