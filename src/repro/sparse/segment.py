"""Segment reductions — the reduce-by-key primitive.

This is the TPU analogue of EfficientIMM's atomic counter update: a thread's
``lock incq`` scatter becomes a (vectorized) segment reduction over the keys
owned by this shard, followed by a cross-shard ``psum`` at the caller.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum(data, segment_ids, num_segments: int):
    """Sum ``data`` rows into ``num_segments`` buckets keyed by ``segment_ids``.

    Out-of-range ids (e.g. padding set to ``num_segments``) are dropped.
    """
    return jax.ops.segment_sum(
        data, segment_ids, num_segments=num_segments, indices_are_sorted=False
    )


def sorted_segment_sum(data, segment_ids, num_segments: int):
    """Variant asserting pre-sorted ids (dst-block partitioned edge lists)."""
    return jax.ops.segment_sum(
        data, segment_ids, num_segments=num_segments, indices_are_sorted=True
    )


def segment_max(data, segment_ids, num_segments: int):
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def segment_mean(data, segment_ids, num_segments: int):
    total = segment_sum(data, segment_ids, num_segments)
    ones = jnp.ones(data.shape[:1], dtype=total.dtype)
    count = segment_sum(ones, segment_ids, num_segments)
    count = jnp.maximum(count, 1)
    if total.ndim > count.ndim:
        count = count.reshape(count.shape + (1,) * (total.ndim - count.ndim))
    return total / count


def segment_softmax(logits, segment_ids, num_segments: int):
    """Softmax over variable-length segments (GAT-style edge softmax)."""
    seg_max = segment_max(logits, segment_ids, num_segments)
    # Out-of-range padding rows see -inf max; guard with finite fill.
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = logits - seg_max[segment_ids]
    expd = jnp.exp(shifted)
    denom = segment_sum(expd, segment_ids, num_segments)
    denom = jnp.maximum(denom, 1e-30)
    return expd / denom[segment_ids]
