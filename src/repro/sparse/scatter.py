"""Scatter patterns used by the IMM counters and GNN aggregation.

``bincount_weighted`` is the vertex-occurrence counter of Algorithm 2
(EfficientIMM Find_Most_Influential_Set): every RRRset scatters +1 into the
global counter for each member vertex. Padding uses the sentinel id
``num_buckets`` which lands in a dropped overflow bucket.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.sparse.segment import segment_sum, segment_max


def scatter_add(target, idx, updates):
    """target.at[idx].add(updates) with out-of-range drop semantics."""
    return target.at[idx].add(updates, mode="drop")


def scatter_or(target, idx, updates):
    return target.at[idx].max(updates, mode="drop")


def bincount_weighted(idx, weights, num_buckets: int):
    """Weighted histogram: out[b] = sum_i weights[i] * [idx[i] == b].

    idx may contain the sentinel value ``num_buckets`` (padding) — dropped.
    Works for any idx shape; weights must broadcast against idx.
    """
    flat_idx = idx.reshape(-1)
    flat_w = jnp.broadcast_to(weights, idx.shape).reshape(-1)
    return segment_sum(flat_w, flat_idx, num_buckets)


def one_hot_matmul_count(idx, weights, num_buckets: int, dtype=jnp.float32):
    """Dense-friendly counter: onehot(idx) contracted with weights on the MXU.

    Mathematically identical to ``bincount_weighted``; preferred on TPU when
    idx blocks are small and the bucket axis is sharded (the adaptive dense
    branch of DESIGN §2 C4).
    """
    onehot = (idx[..., None] == jnp.arange(num_buckets, dtype=idx.dtype)).astype(dtype)
    w = jnp.broadcast_to(weights, idx.shape).astype(dtype)
    return jnp.einsum("...n,...->n", onehot, w)
