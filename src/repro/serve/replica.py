"""Read replicas with epoch-consistent snapshot fan-out.

One engine serializes every query on one lock; past a point, read
throughput scales only by *copying* the resident store.  A
`ReplicaGroup` keeps ``n`` read-only engine replicas of a primary: a
``sync`` takes **one** snapshot tree of the primary (under the caller's
tenant lock, so the snapshot is a single consistent store state — one
epoch, never a torn mix) and fans it out to every replica through
`repro.core.engine.InfluenceEngine.replicate` /
``restore_tree(clone_tree(...))``.  All replicas therefore hold bitwise
the same store, tagged with the epoch it was taken at: a query answered
by *any* replica is identical to any other replica's answer, and
identical to the primary's answer at that epoch.

Replicas are deliberately allowed to lag the primary (that is what makes
them cheap): the tier routes only relaxed-SLO queries here and tags the
answers with ``synced_epoch``.  Strict-SLO queries keep hitting the
primary.  Because the fan-out path is the elastic snapshot restore, a
mesh-sharded primary fans out to mesh-sharded replicas unchanged.
"""
from __future__ import annotations

import threading
import time

import numpy as np

from repro import obs
from repro.checkpoint import store as ckpt


def _base_engine(primary):
    """The `InfluenceEngine` under a primary (unwraps `StreamEngine`)."""
    return primary.engine if hasattr(primary, "engine") else primary


class ReplicaGroup:
    """``n`` epoch-consistent read replicas of one primary engine."""

    def __init__(self, primary, n_replicas: int):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.primary = primary
        self.n_replicas = int(n_replicas)
        self.replicas: list = []
        self.synced_epoch = -1          # no sync yet: group not servable
        self.syncs = 0
        self.bytes_shipped = 0
        self.reads = 0
        self._rr = 0
        self._lock = threading.Lock()

    @property
    def servable(self) -> bool:
        return self.synced_epoch >= 0

    def sync(self, epoch: int = None) -> int:
        """Fan the primary's current store out to every replica.

        Call under the tenant lock: the snapshot tree is read once from
        a quiescent primary, deep-copied per replica (`clone_tree` — the
        primary donates its arena buffers on its next write, replicas
        must own theirs), and restored everywhere, so the whole group
        lands on one store state.  ``epoch`` tags the group (default:
        the primary's current epoch).  Returns the synced epoch."""
        t0 = time.perf_counter()
        with obs.span("replica.sync", tier="serve",
                      replicas=self.n_replicas):
            base = _base_engine(self.primary)
            tree = base.snapshot_tree()
            per_replica = ckpt.tree_bytes(tree)
            with self._lock:
                if not self.replicas:
                    self.replicas = [base.replicate(tree)
                                     for _ in range(self.n_replicas)]
                else:
                    for r in self.replicas:
                        r.restore_tree(ckpt.clone_tree(tree))
                for r in self.replicas:
                    if r.graph is not base.graph:
                        r.rebind_graph(base.graph)  # deltas moved the graph
                self.synced_epoch = (int(epoch) if epoch is not None
                                     else getattr(self.primary, "epoch", 0))
                self.syncs += 1
                self.bytes_shipped += per_replica * self.n_replicas
        obs.histogram("serve.replica_sync_ms").observe(
            (time.perf_counter() - t0) * 1e3)
        return self.synced_epoch

    def _next(self):
        with self._lock:
            if not self.replicas:
                raise RuntimeError("ReplicaGroup serves only after sync()")
            r = self.replicas[self._rr % len(self.replicas)]
            self._rr += 1
            self.reads += 1
            return r

    # ----------------------------------------------------------- queries

    def influences(self, seed_sets) -> np.ndarray:
        """Batched sigma(S) from the next replica (round-robin)."""
        return self._next().influences(seed_sets)

    def select(self, k: int):
        """Top-k from the next replica (round-robin; each replica keeps
        its own memoization, warmed independently)."""
        return self._next().select(k)

    def stats(self) -> dict:
        return {"replicas": self.n_replicas, "synced_epoch": self.synced_epoch,
                "syncs": self.syncs, "bytes_shipped": self.bytes_shipped,
                "reads": self.reads}
