"""Tenant registry — one campaign, one (or one slot on a) serving engine.

A *tenant* is a campaign being served by the tier: its graph, its IMM
config, its resident-store target theta, and its serving contract (SLO
class, fairness weight, admission queue depth, replica count).  The
`TenantSpec` is the declarative half; `Tenant` is the runtime object the
tier schedules — it owns the engine (a `StreamEngine` for evolving
graphs, a plain `InfluenceEngine` for static ones), the per-tenant
admission queue state, the engine lock every query and refresh slice
serializes on, and the serving statistics.

**Engine pools.**  Tenants normally get their own engine, but several
campaigns planning against the *same* network (the competitive-IM
scenario: two brands seeding one social graph) can share one engine
slot: ``TenantSpec(share_engine_with="other")`` points the new tenant at
an already-registered tenant's engine and lock.  The shared store is
sampled once and amortizes across every tenant on the slot; admission,
fairness, and the result cache stay per-tenant (cache keys include the
tenant name, so two campaigns' sigma(S) streams never collide).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional

from repro.core.engine import IMMConfig, InfluenceEngine
from repro.core.store import StorePressurePolicy
from repro.graphs.csr import Graph
from repro.stream.engine import StreamEngine

#: SLO classes the tier routes on: "strict" answers always come from the
#: tenant's primary engine at its current epoch; "relaxed" answers may be
#: served by a read replica at the last epoch-consistent sync (bounded
#: staleness in exchange for read scaling off the primary).
SLO_CLASSES = ("strict", "relaxed")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Declarative tenant description the tier registers.

    Parameters
    ----------
    name : unique tenant id (cache keys and stats key off it).
    graph : the campaign's network (ignored with ``share_engine_with``).
    cfg : engine config; None = `IMMConfig()` defaults.
    theta : resident-store target the engine samples at registration.
    streaming : serve through a `StreamEngine` (graph deltas allowed).
    slo : "strict" | "relaxed" (see `SLO_CLASSES`).
    weight : deficit-round-robin fairness weight *and* refresh-budget
        priority multiplier (2.0 = twice the service per round and twice
        the repair budget per unit backlog).
    max_pending : admission-control queue depth; submits past it are
        rejected, not enqueued.
    replicas : read replicas kept epoch-consistent by snapshot fan-out
        (relaxed-SLO queries route to them).
    policy : optional bounded-memory store policy (streaming tenants).
    share_engine_with : name of an already-registered tenant whose
        engine (and lock) this tenant shares — a slot on the shared
        engine pool instead of a private engine.
    latency_slo_ms : optional per-query latency objective; answers
        slower than this are counted in the tier's
        ``serve.slo_violations`` metric (observability only — routing
        never keys on it).
    """
    name: str
    graph: Optional[Graph] = None
    cfg: Optional[IMMConfig] = None
    theta: int = 1024
    streaming: bool = False
    slo: str = "strict"
    weight: float = 1.0
    max_pending: int = 1024
    replicas: int = 0
    policy: Optional[StorePressurePolicy] = None
    share_engine_with: Optional[str] = None
    latency_slo_ms: Optional[float] = None

    def __post_init__(self):
        if self.slo not in SLO_CLASSES:
            raise ValueError(
                f"tenant {self.name!r}: slo must be one of {SLO_CLASSES}, "
                f"got {self.slo!r}")
        if self.latency_slo_ms is not None and self.latency_slo_ms <= 0:
            raise ValueError(
                f"tenant {self.name!r}: latency_slo_ms must be > 0, got "
                f"{self.latency_slo_ms}")
        if self.weight <= 0:
            raise ValueError(
                f"tenant {self.name!r}: weight must be > 0, got "
                f"{self.weight}")
        if self.max_pending < 1:
            raise ValueError(
                f"tenant {self.name!r}: max_pending must be >= 1, got "
                f"{self.max_pending}")
        if self.graph is None and self.share_engine_with is None:
            raise ValueError(
                f"tenant {self.name!r} needs a graph (or an engine slot "
                f"via share_engine_with)")


class Tenant:
    """Runtime tenant: engine + lock + serving counters.

    ``lock`` serializes every engine access — query batches, delta
    application, refresh slices, and replica snapshots all hold it, so a
    batch answered under the lock reads exactly one store state (the
    epoch-consistency guarantee; stores donate their arena buffers on
    repair writes, so an unlocked reader could observe a deleted
    buffer).  With ``share_engine_with`` the lock object *is* the host
    tenant's, so co-located campaigns serialize on their shared store.
    """

    def __init__(self, spec: TenantSpec, *, engine=None, lock=None,
                 mesh_kwargs: dict = None):
        self.spec = spec
        self.name = spec.name
        if engine is not None:
            self.engine = engine
            self.lock = lock if lock is not None else threading.RLock()
            self.owns_engine = False
        else:
            kw = dict(mesh_kwargs or {})
            cfg = spec.cfg if spec.cfg is not None else IMMConfig()
            if spec.streaming:
                self.engine = StreamEngine(spec.graph, cfg,
                                           policy=spec.policy, **kw)
            else:
                if spec.policy is not None:
                    raise ValueError(
                        f"tenant {spec.name!r}: StorePressurePolicy needs "
                        f"streaming=True (static stores never evict)")
                self.engine = InfluenceEngine(spec.graph, cfg, **kw)
            self.engine.extend(spec.theta)
            self.lock = threading.RLock()
            self.owns_engine = True
        # serving counters (tier-maintained; reads are monitoring-only)
        self.submitted = 0
        self.rejected = 0
        self.served = 0
        self.cache_hits = 0
        self.replica_reads = 0
        self.deltas_applied = 0
        self.served_epoch = self.epoch

    # ------------------------------------------------------------- state

    @property
    def streaming(self) -> bool:
        return hasattr(self.engine, "apply_delta")

    @property
    def epoch(self) -> int:
        """The engine's current epoch (0 forever for static tenants)."""
        return getattr(self.engine, "epoch", 0)

    @property
    def backlog(self) -> int:
        """Staleness backlog the refresh scheduler allocates against."""
        return getattr(self.engine, "stale", 0)

    @property
    def graph(self) -> Graph:
        return self.engine.graph

    def stats(self) -> dict:
        return {
            "slo": self.spec.slo,
            "weight": self.spec.weight,
            "submitted": self.submitted,
            "rejected": self.rejected,
            "served": self.served,
            "cache_hits": self.cache_hits,
            "replica_reads": self.replica_reads,
            "epoch": self.epoch,
            "served_epoch": self.served_epoch,
            "backlog": self.backlog,
            "deltas_applied": self.deltas_applied,
            "refreshes": getattr(self.engine, "refreshes", 0),
            "rows_repaired": getattr(self.engine, "rows_repaired", 0),
            "shared_engine": not self.owns_engine,
        }
