"""SLO-aware refresh scheduling: spend the repair budget where the graph
actually changed.

The tier has one global ``refresh_budget`` (rows repaired per scheduling
step — the knob that bounds repair's interference with serving).  A
single-tenant server just calls ``engine.refresh(budget)``; a tier must
*split* the budget, and splitting it evenly is exactly the mistake the
source paper's dynamic load balancing exists to avoid: tenants whose
graphs barely changed would burn budget on empty refresh passes while a
tenant hit by a hub mutation sits on a huge stale backlog.

`RefreshScheduler.allocate` therefore distributes the budget
proportionally to each streaming tenant's *weighted staleness backlog*
(``weight * engine.stale`` — the reverse-touch invalidation counts from
``repro.stream.invalidate``, surfaced by `StreamEngine.backlog`), with
largest-remainder rounding so the integer budgets sum exactly to the
global one, and a floor of one row per backlogged tenant whenever the
budget covers them (refresh progress is batch-granular, so even a
1-row allocation repairs that tenant's smallest stale batch — no tenant's
backlog is starved indefinitely).  Tenants with zero backlog get zero
budget: allocation — and hence repair work — tracks where deltas landed,
not tenant count.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class RefreshAllocation:
    """One tenant's slice of a scheduling step's global budget."""
    tenant: str
    budget: int          # rows of repair granted this step
    backlog: int         # staleness backlog observed at allocation time


class RefreshScheduler:
    """Splits a global per-step repair budget across tenant backlogs."""

    def __init__(self, budget: int):
        if budget < 1:
            raise ValueError(f"refresh budget must be >= 1, got {budget}")
        self.budget = int(budget)
        self.steps = 0
        self.rows_granted = 0

    def allocate(self, backlogs: dict[str, int],
                 weights: dict[str, float] = None) -> list[RefreshAllocation]:
        """Budget split for one step.

        ``backlogs`` maps tenant -> staleness backlog (zero-backlog
        tenants may be included; they get nothing).  ``weights`` maps
        tenant -> SLO priority multiplier (default 1.0).  Returns
        allocations for backlogged tenants, largest share first; the
        granted budgets sum to ``min(self.budget, sum(backlogs))``.
        """
        weights = weights or {}
        live = {t: int(b) for t, b in backlogs.items() if b > 0}
        if not live:
            return []
        shares = {t: b * float(weights.get(t, 1.0)) for t, b in live.items()}
        total_share = sum(shares.values())
        budget = min(self.budget, sum(live.values()))
        # floor of 1 for every backlogged tenant the budget can cover
        # (deterministically prefer the largest shares when it cannot),
        # then largest-remainder proportional split of the rest
        order = sorted(live, key=lambda t: (-shares[t], t))
        covered = order[:budget]
        grant = {t: 1 for t in covered}
        rest = budget - len(covered)
        if rest > 0:
            quota = {t: rest * shares[t] / total_share for t in covered}
            for t in covered:
                extra = min(int(quota[t]), live[t] - grant[t])
                grant[t] += extra
                rest -= extra
            # remainders: largest fractional part first, capped at backlog
            frac = sorted(covered,
                          key=lambda t: (-(quota[t] - int(quota[t])), t))
            i = 0
            while rest > 0 and any(grant[t] < live[t] for t in covered):
                t = frac[i % len(frac)]
                if grant[t] < live[t]:
                    grant[t] += 1
                    rest -= 1
                i += 1
        self.steps += 1
        out = [RefreshAllocation(t, grant[t], live[t])
               for t in order if t in grant and grant[t] > 0]
        self.rows_granted += sum(a.budget for a in out)
        return out
