"""Epoch-keyed sigma(S) result cache — cross-query reuse with exact
invalidation.

Influence queries repeat: dashboards poll the same campaign seed set,
what-if explorations re-ask earlier candidates, several clients watch one
leaderboard.  Every such repeat is a full fused store pass without a
cache — and at a *consistent* store (zero staleness backlog) a sigma(S)
answer is a *pure function of (tenant, epoch, seed set)*: each epoch has
exactly one consistent store state (refresh repairs stale rows back to
the state a fresh engine would sample — the streaming equivalence
invariant), and the fused membership kernel is deterministic over it, so
a cached value is bitwise identical to recomputing.  Mid-repair states
(``stale > 0``) change *within* an epoch, so the tier never reads or
writes the cache for them — degraded-fidelity answers are computed
fresh every time.

The key is therefore ``(tenant, epoch, frozenset(S))``:

  * ``frozenset`` because coverage is order- and multiplicity-invariant
    in the seed set — ``[3, 1, 3]`` and ``[1, 3]`` are the same query;
  * ``epoch`` because that is exactly when the answer can change — and
    exactly when old entries die: the tier calls `advance` the moment a
    tenant's ``served_epoch`` moves, which drops every entry of that
    tenant from any other epoch.  Entries can never be served across an
    epoch advance (tested in tests/test_serve_tier.py).

Capacity is a global LRU over all tenants (``max_entries``); epoch
invalidation is exact and immediate, LRU eviction handles the long tail
of one-off queries inside an epoch.
"""
from __future__ import annotations

from collections import OrderedDict


class ResultCache:
    """LRU cache of sigma(S) answers keyed ``(tenant, epoch, frozenset)``."""

    def __init__(self, max_entries: int = 65536):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = int(max_entries)
        self._data: OrderedDict[tuple, float] = OrderedDict()
        self._tenant_keys: dict[str, set] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    @staticmethod
    def key(tenant: str, epoch: int, seeds) -> tuple:
        """The cache key for one query (seed order/duplicates erased)."""
        return (tenant, int(epoch), frozenset(int(s) for s in seeds))

    def __len__(self) -> int:
        return len(self._data)

    def entries(self, tenant: str = None) -> int:
        if tenant is None:
            return len(self._data)
        return len(self._tenant_keys.get(tenant, ()))

    def epochs(self, tenant: str) -> set:
        """The epochs the tenant currently has entries under (after
        `advance` this is at most a singleton — the invariant the tests
        pin)."""
        return {k[1] for k in self._tenant_keys.get(tenant, ())}

    def get(self, key: tuple):
        """Cached value or None; a hit refreshes LRU recency."""
        val = self._data.get(key)
        if val is None:
            self.misses += 1
            return None
        self._data.move_to_end(key)
        self.hits += 1
        return val

    def put(self, key: tuple, value: float) -> None:
        if key not in self._data and len(self._data) >= self.max_entries:
            old, _ = self._data.popitem(last=False)
            self._tenant_keys[old[0]].discard(old)
            self.evictions += 1
        self._data[key] = float(value)
        self._data.move_to_end(key)
        self._tenant_keys.setdefault(key[0], set()).add(key)

    def advance(self, tenant: str, epoch: int) -> int:
        """The tenant's served epoch moved to ``epoch``: drop every entry
        of that tenant from any other epoch (they can never be served
        again — queries are always answered at the current served
        epoch).  Returns the number of invalidated entries."""
        keys = self._tenant_keys.get(tenant)
        if not keys:
            return 0
        dead = [k for k in keys if k[1] != int(epoch)]
        for k in dead:
            del self._data[k]
            keys.discard(k)
        self.invalidations += len(dead)
        return len(dead)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"entries": len(self._data), "hits": self.hits,
                "misses": self.misses, "hit_rate": round(self.hit_rate, 4),
                "evictions": self.evictions,
                "invalidations": self.invalidations}
