"""IMServe — the multi-tenant influence-serving tier.

The layer *above* the engines: `launch/serve.py`'s `IMServer` is one
engine, one lock, one refresh thread; this module multiplexes many
campaigns over engines with the policies production serving actually
needs:

  * **tenant registry** (`repro.serve.tenant`): each campaign gets its
    own `StreamEngine`/`InfluenceEngine` — or a slot on a shared engine
    for campaigns planning against the same network;
  * **admission control + fairness** (`repro.serve.admission`):
    per-tenant bounded queues (floods are rejected at the door) drained
    in deficit-round-robin order, so a heavy tenant can neither starve
    nor be starved;
  * **epoch-keyed result cache** (`repro.serve.cache`): sigma(S) keyed
    on ``(tenant, epoch, frozenset(S))``, invalidated exactly when the
    tenant's served epoch advances — a hit is bitwise identical to
    recomputing;
  * **replica read scaling** (`repro.serve.replica`): relaxed-SLO
    queries route to read replicas kept epoch-consistent by snapshot
    fan-out, strict-SLO queries always hit the primary;
  * **SLO-aware refresh** (`repro.serve.scheduler`): one global repair
    budget split across tenants proportional to weighted staleness
    backlog, spent either cooperatively (`refresh_step`) or continuously
    on a background worker.

Concurrency model: every engine access — a tenant's query batch, a
delta, a refresh slice, a replica snapshot — holds that tenant's lock,
so each batch is answered against exactly one store state and tagged
with its epoch (no torn reads; tested under racing threads in
tests/test_serve_tier.py).  Different tenants' engines proceed in
parallel — except on a device mesh, where every tenant's collectives
target the same devices and all engine dispatch serializes on one lock
(see ``__init__``).  The tier's own lock covers only host-side
queue/result bookkeeping and is never held across engine work.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro import obs
from repro.serve.admission import (
    AdmissionError, DeficitRoundRobin, QueryTicket,
)
from repro.serve.cache import ResultCache
from repro.serve.replica import ReplicaGroup
from repro.serve.scheduler import RefreshAllocation, RefreshScheduler
from repro.serve.tenant import Tenant, TenantSpec


@dataclasses.dataclass(frozen=True)
class ServedQuery:
    """One answered query: the value, the epoch it was computed at, and
    how it was served (cache / replica / primary) plus latency."""
    ticket: int
    tenant: str
    value: float
    epoch: int
    cached: bool
    replica: bool
    latency_s: float


class IMServe:
    """Multi-tenant influence-serving tier over pooled engines.

    Parameters
    ----------
    quantum : DRR quantum — queries a weight-1.0 tenant may serve per
        scheduling round.
    cache_entries : global LRU capacity of the sigma(S) result cache.
    refresh_budget : rows of stale-RRR repair per `refresh_step`, split
        across tenants by the SLO-aware scheduler; None disables tier
        refresh (call tenant engines directly).
    mesh_kwargs : `InfluenceEngine` mesh keywords applied to every
        tenant engine this tier constructs (build with
        ``configs.imm_snap.mesh_engine_kwargs``).
    """

    def __init__(self, *, quantum: int = 8, cache_entries: int = 65536,
                 refresh_budget: Optional[int] = None,
                 mesh_kwargs: dict = None):
        self.tenants: dict[str, Tenant] = {}
        self.replica_groups: dict[str, ReplicaGroup] = {}
        self.cache = ResultCache(cache_entries)
        self.queue = DeficitRoundRobin(quantum)
        self.scheduler = (RefreshScheduler(refresh_budget)
                          if refresh_budget is not None else None)
        self.mesh_kwargs = dict(mesh_kwargs or {})
        self.queries_served = 0
        self._results: dict[int, ServedQuery] = {}
        self._next_ticket = 0
        # On a device mesh every tenant's engine dispatches collectives
        # over the SAME devices; two tenants launching sharded
        # computations from different threads can interleave their
        # collectives' device-level rendezvous and deadlock the client
        # (observed on forced multi-device CPU).  Meshed tenants
        # therefore all share this one dispatch lock — cross-tenant
        # engine parallelism only exists off-mesh.
        self._mesh_lock = threading.RLock()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._worker: Optional[threading.Thread] = None

    # ------------------------------------------------------------ tenants

    def register(self, spec: TenantSpec) -> Tenant:
        """Register a tenant: build (or share) its engine, sample its
        resident store to ``spec.theta``, arm its admission queue, and
        fan out its initial replica set."""
        if spec.name in self.tenants:
            raise ValueError(f"tenant {spec.name!r} already registered")
        if spec.share_engine_with is not None:
            host = self.tenants.get(spec.share_engine_with)
            if host is None:
                raise ValueError(
                    f"tenant {spec.name!r}: share_engine_with names "
                    f"unknown tenant {spec.share_engine_with!r}")
            tenant = Tenant(spec, engine=host.engine, lock=host.lock)
        else:
            tenant = Tenant(spec, mesh_kwargs=self.mesh_kwargs)
            if self.mesh_kwargs.get("mesh") is not None:
                tenant.lock = self._mesh_lock   # see __init__

        self.tenants[spec.name] = tenant
        self.queue.register(spec.name, weight=spec.weight,
                            max_pending=spec.max_pending)
        if spec.replicas > 0:
            group = ReplicaGroup(tenant.engine, spec.replicas)
            with tenant.lock:
                group.sync(tenant.epoch)
            self.replica_groups[spec.name] = group
        return tenant

    def _tenant(self, name: str) -> Tenant:
        t = self.tenants.get(name)
        if t is None:
            raise KeyError(f"unknown tenant {name!r}")
        return t

    # ------------------------------------------------------------ queries

    def try_submit(self, tenant: str, seed_set) -> Optional[int]:
        """Admission-controlled submit: ticket id, or None when the
        tenant's queue is at its cap (the rejection is counted)."""
        t = self._tenant(tenant)
        seeds = np.asarray(seed_set, np.int32).reshape(-1)
        with self._lock:
            ticket = QueryTicket(self._next_ticket, tenant, seeds,
                                 t_submit=time.monotonic())
            self._next_ticket += 1
            t.submitted += 1
            if not self.queue.try_submit(ticket):
                t.rejected += 1
                obs.counter("serve.rejected", tenant=tenant).add(1)
                return None
            obs.gauge("serve.queue_depth", tenant=tenant).set(
                self.queue.pending(tenant))
        return ticket.id

    def submit(self, tenant: str, seed_set) -> int:
        """Like `try_submit` but raises `AdmissionError` on rejection."""
        tid = self.try_submit(tenant, seed_set)
        if tid is None:
            t = self._tenant(tenant)
            raise AdmissionError(
                f"tenant {tenant!r}: queue full "
                f"({self.queue.pending(tenant)}/{t.spec.max_pending} "
                f"pending)")
        return tid

    @property
    def pending(self) -> int:
        with self._lock:
            return self.queue.pending()

    def _serve_batch(self, tenant: Tenant,
                     tickets: list[QueryTicket]) -> dict[int, float]:
        """Answer one tenant's DRR share against one store state."""
        name = tenant.name
        group = self.replica_groups.get(name)
        use_replica = (tenant.spec.slo == "relaxed" and group is not None
                       and group.servable)
        with obs.span("serve.batch", tier="serve", tenant=name,
                      queries=len(tickets)), tenant.lock:
            epoch = group.synced_epoch if use_replica else tenant.epoch
            if epoch != tenant.served_epoch:
                # the moment served_epoch advances is the moment older
                # entries become unreachable — drop them now, exactly once
                self.cache.advance(name, epoch)
                tenant.served_epoch = epoch
            # sigma(S) is a pure function of (tenant, epoch, S) only at a
            # CONSISTENT store: mid-repair (stale > 0) the store keeps
            # changing within the epoch, so those degraded-fidelity
            # answers bypass the cache entirely.  Replica stores only
            # change at sync, which always bumps synced_epoch.
            consistent = (use_replica
                          or getattr(tenant.engine, "stale", 0) == 0)
            keys = [self.cache.key(name, epoch, t.seeds) for t in tickets]
            vals: dict[int, tuple[float, bool]] = {}
            misses = []
            with obs.span("cache", tier="serve", tenant=name):
                for tk, key in zip(tickets, keys):
                    hit = self.cache.get(key) if consistent else None
                    if hit is not None:
                        vals[tk.id] = (hit, True)
                    else:
                        misses.append((tk, key))
            if misses:
                backend = group if use_replica else tenant.engine
                fresh = backend.influences([tk.seeds for tk, _ in misses])
                for (tk, key), v in zip(misses, np.asarray(fresh)):
                    if consistent:
                        self.cache.put(key, float(v))
                    vals[tk.id] = (float(v), False)
        now = time.monotonic()
        out = {}
        with self._lock:
            for tk in tickets:
                v, cached = vals[tk.id]
                self._results[tk.id] = ServedQuery(
                    tk.id, name, v, epoch, cached, use_replica,
                    now - tk.t_submit)
                out[tk.id] = v
            tenant.served += len(tickets)
            tenant.cache_hits += sum(1 for v in vals.values() if v[1])
            if use_replica:
                tenant.replica_reads += len(tickets)
            self.queries_served += len(tickets)
        if obs.enabled():
            hits = sum(1 for v in vals.values() if v[1])
            if consistent:
                obs.counter("serve.cache_hits", tenant=name).add(hits)
                obs.counter("serve.cache_misses",
                            tenant=name).add(len(misses))
            else:
                # degraded-fidelity answers skipped the cache entirely
                obs.counter("serve.cache_bypass",
                            tenant=name).add(len(tickets))
            lat = obs.histogram("serve.latency_ms", tenant=name)
            slo_ms = tenant.spec.latency_slo_ms
            violations = 0
            for tk in tickets:
                ms = (now - tk.t_submit) * 1e3
                lat.observe(ms)
                if slo_ms is not None and ms > slo_ms:
                    violations += 1
            if violations:
                obs.counter("serve.slo_violations",
                            tenant=name).add(violations)
        return out

    def pump(self) -> dict[int, float]:
        """One DRR scheduling round: every backlogged tenant serves its
        weighted share, each share answered as one fused batch against
        one epoch.  Returns ``{ticket: value}`` for the round."""
        with obs.span("admission", tier="serve"), self._lock:
            round_ = self.queue.take_round()
        if obs.enabled():
            obs.counter("serve.drr_rounds").add(1)
            for name, tickets in round_:
                obs.gauge("serve.queue_depth", tenant=name).set(
                    self.queue.pending(name))
        results = {}
        for name, tickets in round_:
            results.update(self._serve_batch(self._tenant(name), tickets))
        return results

    def flush(self) -> dict[int, float]:
        """Pump until every queue is empty (still round-by-round fair)."""
        results = {}
        while self.pending:
            results.update(self.pump())
        return results

    def result(self, ticket: int) -> Optional[ServedQuery]:
        """The `ServedQuery` record for an answered ticket (None while
        pending / unknown)."""
        with self._lock:
            return self._results.get(ticket)

    def select(self, tenant: str, k: int):
        """Top-k selection for one tenant (strict SLO hits the primary's
        memoized selection; relaxed routes to a replica)."""
        t = self._tenant(tenant)
        group = self.replica_groups.get(tenant)
        if t.spec.slo == "relaxed" and group is not None and group.servable:
            return group.select(k)
        with t.lock:
            return t.engine.select(k)

    # ------------------------------------------------------------- deltas

    def apply_delta(self, tenant: str, delta) -> int:
        """Forward a `GraphDelta` to a streaming tenant: its epoch
        advances, touched resident rows go stale (reverse-touch
        invalidation), and the refresh scheduler starts allocating
        budget to the new backlog.  Returns newly stale rows."""
        t = self._tenant(tenant)
        if not t.streaming:
            raise ValueError(
                f"tenant {tenant!r} is static (streaming=False); deltas "
                f"need a StreamEngine tenant")
        with t.lock:
            stale = t.engine.apply_delta(delta)
        t.deltas_applied += 1
        return stale

    # ------------------------------------------------------------ refresh

    def refresh_step(self) -> list[RefreshAllocation]:
        """One SLO-aware scheduling step: split the global budget across
        streaming tenants by weighted backlog, run each slice under its
        tenant lock, then re-sync replica groups whose primary reached a
        consistent newer epoch.  Returns the allocations granted."""
        if self.scheduler is None:
            raise ValueError("tier was built without a refresh_budget")
        backlogs, weights = {}, {}
        for name, t in self.tenants.items():
            if t.streaming and t.owns_engine:
                backlogs[name] = t.backlog
                weights[name] = t.spec.weight
        allocations = self.scheduler.allocate(backlogs, weights)
        for a in allocations:
            t = self.tenants[a.tenant]
            with t.lock:
                t.engine.refresh(a.budget)
        self.sync_replicas()
        return allocations

    def sync_replicas(self) -> int:
        """Fan out fresh snapshots to every replica group whose primary
        has advanced past the group's synced epoch and is consistent
        (zero backlog — syncing mid-repair would replicate a store no
        epoch ever served).  Returns groups synced."""
        synced = 0
        for name, group in self.replica_groups.items():
            t = self.tenants[name]
            with t.lock:
                if (t.epoch != group.synced_epoch
                        and getattr(t.engine, "stale", 0) == 0):
                    group.sync(t.epoch)
                    synced += 1
        return synced

    @property
    def backlog(self) -> int:
        """Total staleness backlog across streaming tenants."""
        return sum(t.backlog for t in self.tenants.values()
                   if t.owns_engine)

    # ----------------------------------------------- background refresh

    def start_refresh_worker(self) -> None:
        """Run `refresh_step` continuously on a daemon thread
        (idempotent; needs a ``refresh_budget``)."""
        if self.scheduler is None:
            raise ValueError("refresh worker needs a refresh_budget")
        if self._worker is not None and self._worker.is_alive():
            return
        self._stop.clear()
        self._worker = threading.Thread(
            target=self._refresh_loop, name="imserve-refresh", daemon=True)
        self._worker.start()

    def stop_refresh_worker(self) -> None:
        """Stop and join the worker (idempotent, safe after close)."""
        self._stop.set()
        worker, self._worker = self._worker, None
        if worker is not None and worker is not threading.current_thread():
            worker.join()

    close = stop_refresh_worker

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop_refresh_worker()

    @property
    def refreshing(self) -> bool:
        return self._worker is not None and self._worker.is_alive()

    def _refresh_loop(self):
        while not self._stop.is_set():
            if self.refresh_step():
                # yield between slices: python locks are unfair, a hot
                # loop could starve query threads blocked on tenant locks
                time.sleep(1e-4)
            else:
                self._stop.wait(0.002)

    def drain(self, timeout: Optional[float] = 30.0) -> bool:
        """Block until every streaming tenant's backlog is repaired
        (True) or ``timeout`` elapses (False; None = wait forever).
        Without a running worker, refresh steps run inline."""
        deadline = (None if timeout is None
                    else time.monotonic() + float(timeout))
        while self.backlog > 0:
            if self.refreshing:
                time.sleep(0.002)
            else:
                self.refresh_step()
            # deadline checked *after* each step, so a finite timeout
            # still makes forward progress on the inline path (same
            # contract as IMServer.drain)
            if (self.backlog > 0 and deadline is not None
                    and time.monotonic() > deadline):
                return False
        return True

    # -------------------------------------------------------------- stats

    def stats(self) -> dict:
        """Monitoring snapshot: per-tenant counters, cache, scheduler,
        and replica-group stats."""
        out = {
            "tenants": {n: t.stats() for n, t in self.tenants.items()},
            "cache": self.cache.stats(),
            "queries_served": self.queries_served,
            "pending": self.pending,
        }
        if self.scheduler is not None:
            out["refresh"] = {"budget": self.scheduler.budget,
                              "steps": self.scheduler.steps,
                              "rows_granted": self.scheduler.rows_granted}
        if self.replica_groups:
            out["replicas"] = {n: g.stats()
                               for n, g in self.replica_groups.items()}
        return out

    def metrics(self) -> dict:
        """The obs metrics-registry snapshot (counters / gauges /
        histograms — see docs/observability.md for the catalog).  Empty
        maps unless ``repro.obs`` is enabled."""
        return obs.snapshot()
