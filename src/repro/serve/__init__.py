"""IMServe — multi-tenant influence serving (the tier above the engines).

Public surface:

  * `IMServe` / `ServedQuery` — the tier: tenant registry, admission +
    DRR fairness, epoch-keyed result cache, replica routing, SLO-aware
    refresh scheduling (`repro.serve.tier`);
  * `TenantSpec` / `Tenant` — campaign declaration + runtime object
    (`repro.serve.tenant`);
  * `ResultCache` — the ``(tenant, epoch, frozenset(S))`` sigma cache
    (`repro.serve.cache`);
  * `DeficitRoundRobin` / `QueryTicket` / `AdmissionError` — the
    admission-controlled fair queue (`repro.serve.admission`);
  * `RefreshScheduler` / `RefreshAllocation` — backlog-proportional
    budget splitting (`repro.serve.scheduler`);
  * `ReplicaGroup` — epoch-consistent snapshot fan-out for read scaling
    (`repro.serve.replica`);
  * `make_trace` / `TraceEvent` / `zipf_rates` / `trace_summary` — the
    trace-driven load generator (`repro.serve.trace`).

See docs/serving.md for the architecture.
"""
from repro.serve.admission import (       # noqa: F401
    AdmissionError, DeficitRoundRobin, QueryTicket,
)
from repro.serve.cache import ResultCache               # noqa: F401
from repro.serve.replica import ReplicaGroup            # noqa: F401
from repro.serve.scheduler import (                     # noqa: F401
    RefreshAllocation, RefreshScheduler,
)
from repro.serve.tenant import Tenant, TenantSpec       # noqa: F401
from repro.serve.tier import IMServe, ServedQuery       # noqa: F401
from repro.serve.trace import (                         # noqa: F401
    KIND_DELTA, KIND_QUERY, TraceEvent, make_trace, replay,
    trace_summary, zipf_rates,
)
