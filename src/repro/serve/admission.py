"""Admission control + deficit-round-robin fairness for the query queue.

One heavy tenant must not starve the others — neither by flooding the
queue (admission control caps each tenant's pending depth; excess
submits are *rejected at the door* instead of growing an unbounded
backlog that inflates every tenant's latency) nor by monopolizing
service order (deficit round robin guarantees every backlogged tenant a
weighted share of each scheduling round).

DRR here is the classic scheme with unit query cost: each round, every
tenant with pending queries earns ``quantum * weight`` deficit credit,
serves queries while credit lasts, and keeps the remainder for the next
round; a tenant whose queue empties forfeits its credit (no hoarding).
Per round a backlogged tenant therefore serves at least
``floor(quantum * weight)`` queries and at most that plus one carried
round of credit — the starvation-freedom bound the fairness tests pin.

The scheduler is deliberately host-side and deterministic: round order
is registration order, and the tier batches each tenant's share into one
fused ``influences`` kernel call, so fairness granularity and kernel
batching coincide.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


class AdmissionError(RuntimeError):
    """A submit was rejected: the tenant's pending queue is full."""


@dataclasses.dataclass
class QueryTicket:
    """One admitted sigma(S) query waiting for service."""
    id: int
    tenant: str
    seeds: np.ndarray
    t_submit: float = 0.0


class _TenantQueue:
    __slots__ = ("queue", "weight", "max_pending", "deficit")

    def __init__(self, weight: float, max_pending: int):
        self.queue: deque[QueryTicket] = deque()
        self.weight = float(weight)
        self.max_pending = int(max_pending)
        self.deficit = 0.0


class DeficitRoundRobin:
    """Admission-controlled per-tenant queues under DRR service."""

    def __init__(self, quantum: int = 8):
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        self.quantum = int(quantum)
        self._tenants: dict[str, _TenantQueue] = {}

    def register(self, tenant: str, *, weight: float = 1.0,
                 max_pending: int = 1024) -> None:
        if tenant in self._tenants:
            raise ValueError(f"tenant {tenant!r} already registered")
        self._tenants[tenant] = _TenantQueue(weight, max_pending)

    # ---------------------------------------------------------- admission

    def try_submit(self, ticket: QueryTicket) -> bool:
        """Admit ``ticket`` unless the tenant's queue is at its cap.
        Returns False (rejected) instead of raising."""
        tq = self._tenants[ticket.tenant]
        if len(tq.queue) >= tq.max_pending:
            return False
        tq.queue.append(ticket)
        return True

    def submit(self, ticket: QueryTicket) -> None:
        if not self.try_submit(ticket):
            tq = self._tenants[ticket.tenant]
            raise AdmissionError(
                f"tenant {ticket.tenant!r}: queue full "
                f"({len(tq.queue)}/{tq.max_pending} pending)")

    # ------------------------------------------------------------ service

    def pending(self, tenant: str = None) -> int:
        if tenant is not None:
            return len(self._tenants[tenant].queue)
        return sum(len(t.queue) for t in self._tenants.values())

    def take_round(self) -> list[tuple[str, list[QueryTicket]]]:
        """One DRR round: ``[(tenant, tickets), ...]`` in registration
        order, each tenant's list bounded by its accumulated deficit.
        Empty when nothing is pending."""
        out = []
        for name, tq in self._tenants.items():
            if not tq.queue:
                tq.deficit = 0.0          # no hoarding across idle rounds
                continue
            tq.deficit += self.quantum * tq.weight
            batch = []
            while tq.queue and tq.deficit >= 1.0:
                batch.append(tq.queue.popleft())
                tq.deficit -= 1.0
            if not tq.queue:
                tq.deficit = 0.0
            if batch:
                out.append((name, batch))
        return out
