"""Trace-driven load generation: arrival-process query streams
interleaved with graph deltas.

The serving tier is only honest if it is measured under the traffic
shape it claims to handle: many tenants with *unequal* demand, queries
arriving as a point process (not back-to-back batches), popular seed
sets recurring (the cache's reason to exist), and — for streaming
tenants — `GraphDelta` batches landing mid-stream.  `make_trace` builds
exactly that, deterministically from one rng seed:

  * per-tenant Poisson arrivals (exponential inter-arrival gaps) with
    per-tenant rates — pass a ``skew`` to draw Zipf-like rates, the
    heavy-tenant-vs-long-tail mix the fairness machinery exists for;
  * each query is a random seed set, except a ``hot_fraction`` drawn
    from a small per-tenant pool of recurring "dashboard" sets (cache
    hits come from these; the pool is re-drawn per epoch-advance only by
    the graph, not the trace — the cache decides what an epoch means);
  * streaming tenants get delta events on a fixed period, each generated
    against that tenant's *evolving* graph (deltas validate strictly, so
    the generator applies them as it goes) with the long-tail
    ``max_dst_indeg`` churn shape from `repro.stream.delta.random_delta`.

Events come back merged and time-sorted; replaying them in order (as
`benchmarks/serve_tier.py` and the tier CLI do) reproduces the same
workload bit-for-bit for any seed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.stream.delta import GraphDelta, random_delta

KIND_QUERY = "query"
KIND_DELTA = "delta"


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One timestamped workload event."""
    t: float                      # arrival time, seconds from trace start
    tenant: str
    kind: str                     # KIND_QUERY | KIND_DELTA
    seeds: Optional[np.ndarray] = None      # KIND_QUERY
    delta: Optional[GraphDelta] = None      # KIND_DELTA


def zipf_rates(names, total_qps: float, skew: float, rng) -> dict:
    """Per-tenant arrival rates summing to ``total_qps`` with a Zipf
    profile of exponent ``skew`` over a random tenant order (skew=0 is
    uniform; 1.0+ concentrates most traffic on one tenant)."""
    order = list(names)
    rng.shuffle(order)
    raw = np.array([1.0 / (i + 1) ** skew for i in range(len(order))])
    raw = raw / raw.sum() * total_qps
    return {t: float(r) for t, r in zip(order, raw)}


def _poisson_times(rate: float, duration: float, rng) -> np.ndarray:
    if rate <= 0:
        return np.zeros((0,))
    gaps = rng.exponential(1.0 / rate, size=max(int(rate * duration * 2), 16))
    times = np.cumsum(gaps)
    while times.size and times[-1] < duration:
        more = np.cumsum(rng.exponential(1.0 / rate, size=16)) + times[-1]
        times = np.concatenate([times, more])
    return times[times < duration]


def make_trace(graphs: dict, *, duration: float = 1.0,
               qps: dict | float = 100.0,
               streaming: dict = None,
               delta_period: float = 0.25, delta_ops: int = 4,
               max_dst_indeg: int = 8,
               set_sizes: tuple[int, int] = (1, 8),
               hot_fraction: float = 0.5, hot_pool: int = 8,
               seed: int = 0) -> list[TraceEvent]:
    """Build a merged, time-sorted multi-tenant event trace.

    Parameters
    ----------
    graphs : tenant name -> `Graph` the tenant's queries draw vertices
        from (streaming tenants: the graph the delta stream evolves).
    duration : trace length in virtual seconds.
    qps : scalar rate applied to every tenant, or tenant -> rate
        (build skewed maps with `zipf_rates`).
    streaming : tenant -> bool; True adds a delta stream for that tenant
        (default: no deltas).
    delta_period : virtual seconds between a streaming tenant's deltas.
    delta_ops : inserts = deletes = reweights per delta.
    set_sizes : inclusive (min, max) query seed-set size.
    hot_fraction : probability a query re-asks one of ``hot_pool``
        recurring per-tenant seed sets instead of a fresh random one.
    seed : one seed determines the entire trace.
    """
    rng = np.random.default_rng(seed)
    streaming = streaming or {}
    lo, hi = set_sizes
    events: list[TraceEvent] = []
    for name in sorted(graphs):
        g = graphs[name]
        rate = qps[name] if isinstance(qps, dict) else float(qps)
        hot = [rng.choice(g.n, size=int(rng.integers(lo, hi + 1)),
                          replace=False).astype(np.int32)
               for _ in range(hot_pool)]
        for t in _poisson_times(rate, duration, rng):
            if rng.random() < hot_fraction:
                seeds = hot[int(rng.integers(len(hot)))]
            else:
                seeds = rng.choice(
                    g.n, size=int(rng.integers(lo, hi + 1)),
                    replace=False).astype(np.int32)
            events.append(TraceEvent(float(t), name, KIND_QUERY,
                                     seeds=seeds))
        if streaming.get(name):
            gg, tick = g, delta_period
            while tick < duration:
                d = random_delta(gg, rng, inserts=delta_ops,
                                 deletes=delta_ops, reweights=delta_ops,
                                 max_dst_indeg=max_dst_indeg)
                events.append(TraceEvent(float(tick), name, KIND_DELTA,
                                         delta=d))
                gg = d.apply(gg)
                tick += delta_period
    # stable tiebreak (tenant, kind) keeps replay deterministic when two
    # events share a timestamp
    events.sort(key=lambda e: (e.t, e.tenant, e.kind))
    return events


def replay(tier, events: list[TraceEvent], *,
           pump_every: int = 16) -> tuple[dict, int]:
    """Replay a trace through an `IMServe` tier in event order.

    Queries go through admission (`try_submit` — rejections are counted,
    not retried), deltas through `apply_delta`; the tier is pumped
    whenever ``pump_every`` queries are pending and flushed at the end,
    so service stays batched *and* DRR-fair under the trace's arrival
    order.  Returns ``({ticket: value}, rejected_count)``; per-query
    latency/epoch records live in ``tier.result(ticket)``.
    """
    answered: dict[int, float] = {}
    rejected = 0
    for e in events:
        if e.kind == KIND_DELTA:
            tier.apply_delta(e.tenant, e.delta)
        else:
            if tier.try_submit(e.tenant, e.seeds) is None:
                rejected += 1
        if tier.pending >= pump_every:
            answered.update(tier.pump())
    answered.update(tier.flush())
    return answered, rejected


def trace_summary(events: list[TraceEvent]) -> dict:
    """Per-tenant event counts (queries, deltas) for logging."""
    out: dict[str, dict] = {}
    for e in events:
        d = out.setdefault(e.tenant, {"queries": 0, "deltas": 0})
        d["queries" if e.kind == KIND_QUERY else "deltas"] += 1
    return out
