"""LR schedules. WSD (warmup-stable-decay) is the MiniCPM schedule
(arXiv:2404.06395) assigned to that architecture's config."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup: int):
    return jnp.minimum(step.astype(jnp.float32) / max(warmup, 1), 1.0)


def wsd_schedule(step, *, warmup: int, stable: int, decay: int,
                 final_frac: float = 0.1):
    """Warmup -> flat -> exponential-ish (linear here) decay to final_frac."""
    s = step.astype(jnp.float32)
    warm = s / max(warmup, 1)
    in_decay = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
    decay_mult = 1.0 - (1.0 - final_frac) * in_decay
    return jnp.where(s < warmup, warm, decay_mult)


def cosine_schedule(step, *, warmup: int, total: int, final_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = s / max(warmup, 1)
    t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(s < warmup, warm, cos)
