from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    """Global-norm clip without materializing f32 copies of bf16 leaves.

    The squared-norm reduction accumulates in f32 (``dtype=``) while the
    elementwise square stays in the leaf dtype — bf16 has the full f32
    exponent range, so no under/overflow, and the mantissa loss is
    irrelevant for a clipping threshold.  The old ``g.astype(f32)``
    formulation materialized a 6 GiB f32 copy of grok's biggest leaf
    (EXPERIMENTS §Perf).
    """
    leaves = jax.tree.leaves(grads)
    total = jnp.sqrt(sum(jnp.sum(jnp.square(g), dtype=jnp.float32)
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(total, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), total
