"""AdamW with dtype-configurable moments.

``moment_dtype="bfloat16"`` halves the optimizer-state HBM footprint — the
knob that lets grok-1-scale training fit v5e (see EXPERIMENTS §Dry-run).
State layout mirrors the param pytree so GSPMD shards moments exactly like
their parameters (plus optional extra data-axis sharding from launch/train).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                 # peak; schedules multiply this
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"
    # leaves larger than this many elements update via a lax.scan over
    # their leading (layer-stack) axis, bounding the f32 temporaries of
    # the update math to one slice at a time (grok-scale leaves)
    chunked_update_min_size: int = 1 << 28


def adamw_init(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd_math(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        update = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (update + cfg.weight_decay * p32)
        return new_p.astype(p.dtype), mu32.astype(mdt), nu32.astype(mdt)

    # NOTE: a lax.scan-chunked update was tried for grok-scale leaves and
    # REVERTED: scan breaks XLA's input->output buffer aliasing, so the
    # carried copies cost more than the f32 temporaries saved
    # (EXPERIMENTS §Perf records the measurement).
    upd = upd_math

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}
