from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import wsd_schedule, cosine_schedule, linear_warmup
from repro.optim.clip import clip_by_global_norm

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update",
    "wsd_schedule", "cosine_schedule", "linear_warmup",
    "clip_by_global_norm",
]
