"""Persistent RRR-set arenas — the resident store behind `InfluenceEngine`.

The paper's C3/C4/C5 optimizations all hinge on *where the sampled RRR sets
live*: fused counting writes into a store-owned counter, the adaptive
representation is a property of the store, and selection reads the store
without reshaping it.  This module makes that explicit:

  * ``RRRStore``   — the protocol every backend implements: in-place
    ``add_batch``, a shape-stable ``view()`` for selection, fused per-node
    ``counter`` (C3), per-set ``sizes``, batched membership queries
    (``hits``), and ``state()``/``from_state`` for snapshots.
  * ``BitmapStore`` — ``(capacity, n) uint8`` bitmap arena.  Capacity is a
    power of two grown by amortized doubling; batches are written in place
    with a donated ``dynamic_update_slice`` so the hot loop never re-concats
    O(theta) rows and jit recompilations are bounded by O(log theta)
    distinct arena shapes.  Converts to index lists lazily (C4) via a
    version-keyed cache.
  * ``IndexStore``  — ``(capacity, L) int32`` index-list arena (sentinel
    ``n``), for regimes where sets are sparse from the start (LT walks,
    huge graphs); widens ``L`` by power-of-two steps as larger sets arrive.

Both backends preserve exact equivalence with the historical pad-to-pow2
selection inputs: padding rows are all-zero (bitmap) / all-sentinel
(indices) and masked by ``view().valid``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Protocol, runtime_checkable

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.adaptive import bitmap_to_indices

MIN_CAPACITY = 16     # matches the historical pad floor (1 << 4)
MIN_INDEX_PAD = 4     # matches the historical l_pad floor (1 << 2)


def next_pow2(x: int, floor: int = MIN_CAPACITY) -> int:
    """Smallest power of two >= max(x, floor)."""
    cap = max(int(floor), 1)
    while cap < x:
        cap <<= 1
    return cap


@dataclasses.dataclass(frozen=True)
class StoreView:
    """Read-only picture of an arena handed to a `SelectionStrategy`.

    ``R`` is ``(capacity, n) uint8`` bitmaps when ``representation ==
    "bitmap"`` and ``(capacity, L) int32`` sentinel-padded index lists when
    ``representation == "indices"``; rows at index >= ``count`` are padding
    and are masked out by ``valid``.

    Views alias the live arena buffer, which `add_batch` donates to its
    in-place writer — a view is only safe to read until the store's next
    write (on accelerator backends the donated buffer is literally
    deleted).  Consume a view before mutating the store; re-call ``view()``
    after.
    """
    representation: str
    R: jnp.ndarray
    valid: jnp.ndarray
    n: int
    count: int


@partial(jax.jit, donate_argnums=(0,))
def _write_rows(arena, rows, start):
    """In-place (donated) row-block write at dynamic offset ``start``."""
    start_idx = (start,) + (jnp.int32(0),) * (arena.ndim - 1)
    return jax.lax.dynamic_update_slice(arena, rows, start_idx)


@jax.jit
def _bitmap_hits(R, valid, S):
    """Fraction of valid sets hit by each seed row. S: (Q, L) int32."""
    memb = R[:, S.reshape(-1)].reshape((R.shape[0],) + S.shape) > 0
    hit = memb.any(axis=2) & valid[:, None]
    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)
    return hit.sum(axis=0).astype(jnp.float32) / n_valid


@jax.jit
def _index_hits(R_idx, valid, S):
    """Index-list membership version of `_bitmap_hits` (lax.map bounds the
    (capacity, L, Lq) broadcast to one query at a time)."""
    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)

    def one(s):
        memb = (R_idx[:, :, None] == s[None, None, :]).any(axis=(1, 2))
        return (memb & valid).sum(dtype=jnp.int32)

    hits = jax.lax.map(one, S)
    return hits.astype(jnp.float32) / n_valid


@runtime_checkable
class RRRStore(Protocol):
    """Protocol for RRR-set stores consumed by `InfluenceEngine`."""
    representation: str
    n: int
    count: int
    capacity: int
    version: int
    counter: jnp.ndarray
    sizes: jnp.ndarray

    def add_batch(self, visited, counter=None) -> None: ...
    def view(self) -> StoreView: ...
    def hits(self, S) -> jnp.ndarray: ...
    def coverage_stats(self) -> tuple[float, int]: ...
    def state(self) -> dict: ...


class _ArenaBase:
    """Shared arena bookkeeping: pow2 capacity, doubling, fused counter."""

    def __init__(self, n: int, *, capacity: int = MIN_CAPACITY):
        self.n = int(n)
        self.capacity = next_pow2(capacity)
        self.count = 0
        self.version = 0
        self.sizes = jnp.zeros((self.capacity,), jnp.int32)
        self.counter = jnp.zeros((self.n,), jnp.int32)

    def _grow_rows(self, need: int):
        new_cap = next_pow2(need, self.capacity)
        if new_cap == self.capacity:
            return
        self._realloc(new_cap)
        sizes = jnp.zeros((new_cap,), jnp.int32)
        self.sizes = _write_rows(sizes, self.sizes, jnp.int32(0))
        self.capacity = new_cap

    def _finish_add(self, batch_sizes, counter):
        B = batch_sizes.shape[0]
        self.sizes = _write_rows(self.sizes, batch_sizes, jnp.int32(self.count))
        self.counter = self.counter + counter
        self.count += int(B)
        self.version += 1

    def _valid(self):
        return jnp.arange(self.capacity) < self.count

    def coverage_stats(self) -> tuple[float, int]:
        """(avg fractional set coverage, max set size) over stored sets."""
        sizes = np.asarray(self.sizes)
        avg_cov = float(sizes.sum()) / max(self.count, 1) / self.n
        return avg_cov, max(int(sizes.max()) if sizes.size else 1, 1)

    def _base_state(self) -> dict:
        return {
            "n": np.int64(self.n),
            "count": np.int64(self.count),
            "sizes": np.asarray(self.sizes),
            "counter": np.asarray(self.counter),
        }


class BitmapStore(_ArenaBase):
    """Dense bitmap arena: ``(capacity, n) uint8``, zero-padded rows."""

    representation = "bitmap"

    def __init__(self, n: int, *, capacity: int = MIN_CAPACITY):
        super().__init__(n, capacity=capacity)
        self.R = jnp.zeros((self.capacity, self.n), jnp.uint8)
        self._idx_cache = None      # (version, l_pad) -> R_idx

    def _realloc(self, new_cap: int):
        R = jnp.zeros((new_cap, self.n), jnp.uint8)
        self.R = _write_rows(R, self.R, jnp.int32(0))

    def add_batch(self, visited, counter=None) -> None:
        visited = jnp.asarray(visited).astype(jnp.uint8)
        self._grow_rows(self.count + visited.shape[0])
        if counter is None:
            counter = visited.sum(axis=0, dtype=jnp.int32)
        self.R = _write_rows(self.R, visited, jnp.int32(self.count))
        self._finish_add(visited.sum(axis=1, dtype=jnp.int32), counter)

    def view(self) -> StoreView:
        return StoreView("bitmap", self.R, self._valid(), self.n, self.count)

    def index_view(self, l_pad: int) -> StoreView:
        """Lazy C4 conversion; cached until the arena next changes."""
        key = (self.version, int(l_pad))
        if self._idx_cache is None or self._idx_cache[0] != key:
            self._idx_cache = (key, bitmap_to_indices(self.R, int(l_pad)))
        return StoreView("indices", self._idx_cache[1], self._valid(),
                         self.n, self.count)

    def hits(self, S) -> jnp.ndarray:
        return _bitmap_hits(self.R, self._valid(), jnp.asarray(S, jnp.int32))

    def state(self) -> dict:
        st = self._base_state()
        st["kind"] = np.asarray("bitmap")
        st["R"] = np.asarray(self.R)
        return st

    @classmethod
    def from_state(cls, st) -> "BitmapStore":
        store = cls(int(st["n"]), capacity=st["R"].shape[0])
        store.R = jnp.asarray(st["R"], jnp.uint8)
        store.sizes = jnp.asarray(st["sizes"], jnp.int32)
        store.counter = jnp.asarray(st["counter"], jnp.int32)
        store.count = int(st["count"])
        return store


class IndexStore(_ArenaBase):
    """Sparse index-list arena: ``(capacity, L) int32`` with sentinel ``n``.

    ``L`` widens by power-of-two steps when a batch contains a larger set
    (the widened columns backfill with the sentinel, so old rows keep their
    meaning).  Incoming bitmap batches are converted on write — after that
    the bitmaps are dropped, so resident memory is O(theta * L) not
    O(theta * n).
    """

    representation = "indices"

    def __init__(self, n: int, *, capacity: int = MIN_CAPACITY,
                 l_pad: int = MIN_INDEX_PAD):
        super().__init__(n, capacity=capacity)
        self.l_pad = next_pow2(l_pad, MIN_INDEX_PAD)
        self.R = jnp.full((self.capacity, self.l_pad), self.n, jnp.int32)

    def _realloc(self, new_cap: int):
        R = jnp.full((new_cap, self.l_pad), self.n, jnp.int32)
        self.R = _write_rows(R, self.R, jnp.int32(0))

    def _widen(self, l_need: int):
        new_l = next_pow2(l_need, self.l_pad)
        if new_l == self.l_pad:
            return
        pad = jnp.full((self.capacity, new_l - self.l_pad), self.n, jnp.int32)
        self.R = jnp.concatenate([self.R, pad], axis=1)
        self.l_pad = new_l

    def add_batch(self, visited, counter=None) -> None:
        visited = jnp.asarray(visited).astype(jnp.uint8)
        batch_sizes = visited.sum(axis=1, dtype=jnp.int32)
        self._widen(int(batch_sizes.max()))
        self._grow_rows(self.count + visited.shape[0])
        if counter is None:
            counter = visited.sum(axis=0, dtype=jnp.int32)
        rows = bitmap_to_indices(visited, self.l_pad)
        self.R = _write_rows(self.R, rows, jnp.int32(self.count))
        self._finish_add(batch_sizes, counter)

    def view(self) -> StoreView:
        return StoreView("indices", self.R, self._valid(), self.n, self.count)

    def hits(self, S) -> jnp.ndarray:
        return _index_hits(self.R, self._valid(), jnp.asarray(S, jnp.int32))

    def state(self) -> dict:
        st = self._base_state()
        st["kind"] = np.asarray("indices")
        st["R"] = np.asarray(self.R)
        return st

    @classmethod
    def from_state(cls, st) -> "IndexStore":
        store = cls(int(st["n"]), capacity=st["R"].shape[0],
                    l_pad=st["R"].shape[1])
        store.R = jnp.asarray(st["R"], jnp.int32)
        store.sizes = jnp.asarray(st["sizes"], jnp.int32)
        store.counter = jnp.asarray(st["counter"], jnp.int32)
        store.count = int(st["count"])
        return store


STORE_KINDS = {"bitmap": BitmapStore, "indices": IndexStore}


def make_store(kind: str, n: int, **kw) -> RRRStore:
    """Store factory: ``"auto"`` (bitmap, the back-compat default),
    ``"bitmap"``, or ``"indices"``."""
    kind = "bitmap" if kind == "auto" else kind
    try:
        return STORE_KINDS[kind](n, **kw)
    except KeyError:
        raise ValueError(
            f"unknown store kind {kind!r}; have {sorted(STORE_KINDS)}")


def store_from_state(st) -> RRRStore:
    """Rebuild a store from a `state()` tree (snapshot restore path)."""
    kind = str(np.asarray(st["kind"]))
    try:
        return STORE_KINDS[kind].from_state(st)
    except KeyError:
        raise ValueError(f"snapshot has unknown store kind {kind!r}")
