"""Persistent RRR-set arenas — the resident store behind `InfluenceEngine`.

The paper's C1/C3/C4/C5 optimizations all hinge on *where the sampled RRR
sets live*: fused counting writes into a store-owned counter, the adaptive
representation is a property of the store, the NUMA/device partitioning of
the sets is a property of the store, and selection reads the store without
reshaping it.  This module makes that explicit:

  * ``RRRStore``   — the protocol every backend implements: in-place
    ``add_batch``, a shape-stable ``view()`` for selection, fused per-node
    ``counter`` (C3), per-set ``sizes``, batched membership queries
    (``hits``), and ``state()``/``from_state`` for snapshots.
  * ``BitmapStore`` — single-device ``(capacity, n) uint8`` bitmap arena.
    Capacity is a power of two grown by amortized doubling; batches are
    written in place with a donated ``dynamic_update_slice`` so the hot
    loop never re-concats O(theta) rows and jit recompilations are bounded
    by O(log theta) distinct arena shapes.  Converts to index lists lazily
    (C4) via a version-keyed cache.
  * ``IndexStore``  — ``(capacity, L) int32`` index-list arena (sentinel
    ``n``), for regimes where sets are sparse from the start (LT walks,
    huge graphs); widens ``L`` by power-of-two steps as larger sets arrive.
  * ``ShardedStore`` — the paper's C1 partitioning end-to-end: a bitmap
    arena whose theta axis is sharded across a ``jax.sharding.Mesh``.
    Every device owns a ``(cap_local, n)`` block; batch writes, fused
    counting, and per-shard growth all happen device-locally inside a
    donated ``shard_map`` kernel, so the full ``(theta, n)`` arena never
    exists on any single device and theta scales with device count.

All backends preserve exact equivalence with the historical pad-to-pow2
selection inputs: padding rows are all-zero (bitmap) / all-sentinel
(indices) and masked by ``view().valid``.  For ``ShardedStore``, row
*placement* is a layout detail, not a semantic one — selection, ``hits``
and the global counter are permutation-invariant over rows (every
reduction is an exact integer sum), so results are seed-for-seed
identical to a ``BitmapStore`` fed the same sample stream, on any mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Protocol, runtime_checkable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.adaptive import bitmap_to_indices

MIN_CAPACITY = 16     # matches the historical pad floor (1 << 4)
MIN_INDEX_PAD = 4     # matches the historical l_pad floor (1 << 2)


def next_pow2(x: int, floor: int = MIN_CAPACITY) -> int:
    """Smallest power of two >= max(x, floor)."""
    cap = max(int(floor), 1)
    while cap < x:
        cap <<= 1
    return cap


@dataclasses.dataclass(frozen=True)
class StoreView:
    """Read-only picture of an arena handed to a `SelectionStrategy`.

    ``R`` is ``(capacity, n) uint8`` bitmaps when ``representation ==
    "bitmap"`` and ``(capacity, L) int32`` sentinel-padded index lists when
    ``representation == "indices"``.  For single-device stores, rows at
    index >= ``count`` are padding and ``valid`` is the prefix mask
    ``arange(capacity) < count``.  For `ShardedStore` views, ``R`` is the
    *sharded* global arena (``P(theta_axes, None)``), valid rows are a
    per-shard prefix rather than a global one, and ``valid`` (sharded
    ``P(theta_axes)``) masks exactly the rows each shard has filled —
    consumers must always mask by ``valid`` instead of assuming
    contiguity.

    Views alias the live arena buffer, which `add_batch` donates to its
    in-place writer — a view is only safe to read until the store's next
    write (on accelerator backends the donated buffer is literally
    deleted).  Consume a view before mutating the store; re-call ``view()``
    after.
    """
    representation: str
    R: jnp.ndarray
    valid: jnp.ndarray
    n: int
    count: int


def _coverage_stats(sizes, count: int, n: int) -> tuple[float, int]:
    """(avg fractional set coverage, max set size) from a sizes array —
    padding entries are zero, so sums/maxes ignore them."""
    sizes = np.asarray(sizes)
    avg_cov = float(sizes.sum()) / max(count, 1) / n
    return avg_cov, max(int(sizes.max()) if sizes.size else 1, 1)


@partial(jax.jit, donate_argnums=(0,))
def _write_rows(arena, rows, start):
    """In-place (donated) row-block write at dynamic offset ``start``."""
    start_idx = (start,) + (jnp.int32(0),) * (arena.ndim - 1)
    return jax.lax.dynamic_update_slice(arena, rows, start_idx)


@jax.jit
def _bitmap_hits(R, valid, S):
    """Fraction of valid sets hit by each seed row. S: (Q, L) int32."""
    memb = R[:, S.reshape(-1)].reshape((R.shape[0],) + S.shape) > 0
    hit = memb.any(axis=2) & valid[:, None]
    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)
    return hit.sum(axis=0).astype(jnp.float32) / n_valid


@jax.jit
def _index_hits(R_idx, valid, S):
    """Index-list membership version of `_bitmap_hits` (lax.map bounds the
    (capacity, L, Lq) broadcast to one query at a time)."""
    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)

    def one(s):
        memb = (R_idx[:, :, None] == s[None, None, :]).any(axis=(1, 2))
        return (memb & valid).sum(dtype=jnp.int32)

    hits = jax.lax.map(one, S)
    return hits.astype(jnp.float32) / n_valid


@runtime_checkable
class RRRStore(Protocol):
    """Protocol for RRR-set stores consumed by `InfluenceEngine`.

    ``add_batch(visited, counter=None)`` takes ``(B, n) uint8`` bitmaps and
    appends them in place (implementations donate their arena buffer — do
    not hold references to a previous ``view()`` across a write).
    ``counter`` is the sampler's fused ``(n,) int32`` batch contribution;
    backends may recompute it locally instead (``ShardedStore`` does, so
    the count stays shard-local).  ``view()`` returns a `StoreView` whose
    arrays alias live buffers; ``hits(S)`` answers ``(Q, L) int32`` seed-
    set membership queries as per-query covered fractions ``(Q,) f32``;
    ``state()`` returns a host pytree for `checkpoint.store`.
    """
    representation: str
    n: int
    count: int
    capacity: int
    version: int
    counter: jnp.ndarray
    sizes: jnp.ndarray

    def add_batch(self, visited, counter=None) -> None: ...
    def view(self) -> StoreView: ...
    def hits(self, S) -> jnp.ndarray: ...
    def coverage_stats(self) -> tuple[float, int]: ...
    def state(self) -> dict: ...


class _ArenaBase:
    """Shared arena bookkeeping: pow2 capacity, doubling, fused counter."""

    def __init__(self, n: int, *, capacity: int = MIN_CAPACITY):
        self.n = int(n)
        self.capacity = next_pow2(capacity)
        self.count = 0
        self.version = 0
        self.sizes = jnp.zeros((self.capacity,), jnp.int32)
        self.counter = jnp.zeros((self.n,), jnp.int32)

    def _grow_rows(self, need: int):
        new_cap = next_pow2(need, self.capacity)
        if new_cap == self.capacity:
            return
        self._realloc(new_cap)
        sizes = jnp.zeros((new_cap,), jnp.int32)
        self.sizes = _write_rows(sizes, self.sizes, jnp.int32(0))
        self.capacity = new_cap

    def _finish_add(self, batch_sizes, counter):
        B = batch_sizes.shape[0]
        self.sizes = _write_rows(self.sizes, batch_sizes, jnp.int32(self.count))
        self.counter = self.counter + counter
        self.count += int(B)
        self.version += 1

    def _valid(self):
        return jnp.arange(self.capacity) < self.count

    def coverage_stats(self) -> tuple[float, int]:
        """(avg fractional set coverage, max set size) over stored sets."""
        return _coverage_stats(self.sizes, self.count, self.n)

    def _base_state(self) -> dict:
        return {
            "n": np.int64(self.n),
            "count": np.int64(self.count),
            "sizes": np.asarray(self.sizes),
            "counter": np.asarray(self.counter),
        }


class BitmapStore(_ArenaBase):
    """Dense single-device bitmap arena: ``(capacity, n) uint8``,
    zero-padded rows, unsharded (replicated from the mesh's point of
    view).  Use `ShardedStore` when theta must scale past one device."""

    representation = "bitmap"

    def __init__(self, n: int, *, capacity: int = MIN_CAPACITY):
        super().__init__(n, capacity=capacity)
        self.R = jnp.zeros((self.capacity, self.n), jnp.uint8)
        self._idx_cache = None      # (version, l_pad) -> R_idx

    def _realloc(self, new_cap: int):
        R = jnp.zeros((new_cap, self.n), jnp.uint8)
        self.R = _write_rows(R, self.R, jnp.int32(0))

    def add_batch(self, visited, counter=None) -> None:
        """Append ``visited (B, n) uint8`` rows in place.

        The arena buffer is donated to the writer — any outstanding
        ``view()`` of this store is invalidated by this call.  ``counter``
        is the sampler's fused ``(n,) int32`` contribution (computed here
        when absent).
        """
        visited = jnp.asarray(visited).astype(jnp.uint8)
        self._grow_rows(self.count + visited.shape[0])
        if counter is None:
            counter = visited.sum(axis=0, dtype=jnp.int32)
        self.R = _write_rows(self.R, visited, jnp.int32(self.count))
        self._finish_add(visited.sum(axis=1, dtype=jnp.int32), counter)

    def view(self) -> StoreView:
        """Aliasing `StoreView` of the live ``(capacity, n)`` arena with
        the prefix mask ``arange(capacity) < count``; read it before the
        next ``add_batch`` (which donates the buffer)."""
        return StoreView("bitmap", self.R, self._valid(), self.n, self.count)

    def index_view(self, l_pad: int) -> StoreView:
        """Lazy C4 conversion; cached until the arena next changes."""
        key = (self.version, int(l_pad))
        if self._idx_cache is None or self._idx_cache[0] != key:
            self._idx_cache = (key, bitmap_to_indices(self.R, int(l_pad)))
        return StoreView("indices", self._idx_cache[1], self._valid(),
                         self.n, self.count)

    def hits(self, S) -> jnp.ndarray:
        """Covered fraction per query: ``S (Q, L) int32`` -> ``(Q,) f32``."""
        return _bitmap_hits(self.R, self._valid(), jnp.asarray(S, jnp.int32))

    def state(self) -> dict:
        """Host snapshot pytree: full ``(capacity, n)`` arena plus
        counters (kind tag ``"bitmap"``)."""
        st = self._base_state()
        st["kind"] = np.asarray("bitmap")
        st["R"] = np.asarray(self.R)
        return st

    @classmethod
    def from_state(cls, st) -> "BitmapStore":
        store = cls(int(st["n"]), capacity=st["R"].shape[0])
        store.R = jnp.asarray(st["R"], jnp.uint8)
        store.sizes = jnp.asarray(st["sizes"], jnp.int32)
        store.counter = jnp.asarray(st["counter"], jnp.int32)
        store.count = int(st["count"])
        return store

    @classmethod
    def from_rows(cls, rows, n: int) -> "BitmapStore":
        """Build a store holding exactly ``rows (count, n) uint8`` — the
        cross-layout restore path (e.g. a `ShardedStore` snapshot opened
        without a mesh)."""
        store = cls(int(n), capacity=max(int(rows.shape[0]), MIN_CAPACITY))
        if rows.shape[0]:
            store.add_batch(jnp.asarray(rows, jnp.uint8))
        return store


class IndexStore(_ArenaBase):
    """Sparse index-list arena: ``(capacity, L) int32`` with sentinel ``n``.

    ``L`` widens by power-of-two steps when a batch contains a larger set
    (the widened columns backfill with the sentinel, so old rows keep their
    meaning).  Incoming bitmap batches are converted on write — after that
    the bitmaps are dropped, so resident memory is O(theta * L) not
    O(theta * n).
    """

    representation = "indices"

    def __init__(self, n: int, *, capacity: int = MIN_CAPACITY,
                 l_pad: int = MIN_INDEX_PAD):
        super().__init__(n, capacity=capacity)
        self.l_pad = next_pow2(l_pad, MIN_INDEX_PAD)
        self.R = jnp.full((self.capacity, self.l_pad), self.n, jnp.int32)

    def _realloc(self, new_cap: int):
        R = jnp.full((new_cap, self.l_pad), self.n, jnp.int32)
        self.R = _write_rows(R, self.R, jnp.int32(0))

    def _widen(self, l_need: int):
        new_l = next_pow2(l_need, self.l_pad)
        if new_l == self.l_pad:
            return
        pad = jnp.full((self.capacity, new_l - self.l_pad), self.n, jnp.int32)
        self.R = jnp.concatenate([self.R, pad], axis=1)
        self.l_pad = new_l

    def add_batch(self, visited, counter=None) -> None:
        visited = jnp.asarray(visited).astype(jnp.uint8)
        batch_sizes = visited.sum(axis=1, dtype=jnp.int32)
        self._widen(int(batch_sizes.max()))
        self._grow_rows(self.count + visited.shape[0])
        if counter is None:
            counter = visited.sum(axis=0, dtype=jnp.int32)
        rows = bitmap_to_indices(visited, self.l_pad)
        self.R = _write_rows(self.R, rows, jnp.int32(self.count))
        self._finish_add(batch_sizes, counter)

    def view(self) -> StoreView:
        return StoreView("indices", self.R, self._valid(), self.n, self.count)

    def hits(self, S) -> jnp.ndarray:
        return _index_hits(self.R, self._valid(), jnp.asarray(S, jnp.int32))

    def state(self) -> dict:
        st = self._base_state()
        st["kind"] = np.asarray("indices")
        st["R"] = np.asarray(self.R)
        return st

    @classmethod
    def from_state(cls, st) -> "IndexStore":
        store = cls(int(st["n"]), capacity=st["R"].shape[0],
                    l_pad=st["R"].shape[1])
        store.R = jnp.asarray(st["R"], jnp.int32)
        store.sizes = jnp.asarray(st["sizes"], jnp.int32)
        store.counter = jnp.asarray(st["counter"], jnp.int32)
        store.count = int(st["count"])
        return store


# ------------------------------------------------------- sharded (C1) ----


def _sharded_zeros(shape, dtype, sharding):
    """Zeros *born sharded*: allocated under jit with ``out_shardings`` so
    the full logical array is never materialized on a single device."""
    return jax.jit(partial(jnp.zeros, shape, dtype),
                   out_shardings=sharding)()


@functools.lru_cache(maxsize=None)
def _sharded_write_kernels(mesh, theta_axes):
    """Compiled per-(mesh, axes) store kernels, shared across stores.

    Returns ``(write, valid)``:
      * ``write(R, sizes, counter, counts, rows, incs)`` — every shard
        writes its ``(b, n)`` block of the batch into its local arena at
        its own row offset ``counts[shard]``, fuses the local size/counter
        updates (C3 done shard-locally), and advances its count by
        ``incs[shard]``.  ``R``/``sizes``/``counter``/``counts`` are
        donated — the store's previous buffers are dead after the call.
      * ``valid(counts, sizes)`` — per-shard prefix mask
        ``local_iota < counts[shard]`` as a global ``P(theta_axes)`` bool
        array (``sizes`` is only a shape donor).
    """
    sp_rows, sp_vec = P(theta_axes, None), P(theta_axes)

    def write(R, sizes, counter, counts, rows, incs):
        start = counts[0]
        R = jax.lax.dynamic_update_slice(R, rows, (start, jnp.int32(0)))
        live = jnp.arange(rows.shape[0], dtype=jnp.int32) < incs[0]
        row_sizes = jnp.where(live, rows.sum(axis=1, dtype=jnp.int32), 0)
        sizes = jax.lax.dynamic_update_slice(sizes, row_sizes, (start,))
        counter = counter + rows.sum(axis=0, dtype=jnp.int32)[None, :]
        return R, sizes, counter, counts + incs

    write_fn = jax.jit(
        shard_map(write, mesh=mesh,
                  in_specs=(sp_rows, sp_vec, sp_rows, sp_vec, sp_rows,
                            sp_vec),
                  out_specs=(sp_rows, sp_vec, sp_rows, sp_vec)),
        donate_argnums=(0, 1, 2, 3))

    def valid(counts, sizes):
        return jnp.arange(sizes.shape[0], dtype=jnp.int32) < counts[0]

    valid_fn = jax.jit(shard_map(
        valid, mesh=mesh, in_specs=(sp_vec, sp_vec), out_specs=sp_vec))
    return write_fn, valid_fn


@functools.lru_cache(maxsize=None)
def _sharded_grow_kernel(mesh, theta_axes, pad):
    """Per-shard capacity doubling: every shard zero-pads its own
    ``(cap_local, n)`` block to ``(cap_local + pad, n)`` locally (no
    gather, no cross-device traffic; the copy itself is not donatable
    because the output shape differs, but doubling amortizes it)."""
    sp_rows, sp_vec = P(theta_axes, None), P(theta_axes)

    def grow(R, sizes):
        return (jnp.pad(R, ((0, pad), (0, 0))),
                jnp.pad(sizes, ((0, pad),)))

    return jax.jit(shard_map(grow, mesh=mesh, in_specs=(sp_rows, sp_vec),
                             out_specs=(sp_rows, sp_vec)))


class ShardedStore:
    """Mesh-sharded dense bitmap arena — the paper's C1 RRR-set
    partitioning applied to the *store itself*, not just selection.

    State layout over ``D = prod(mesh.shape[a] for a in theta_axes)``
    shards:

      * ``R``       — ``(D * cap_local, n) uint8``, ``P(theta_axes, None)``:
        shard ``d`` owns rows ``[d * cap_local, (d+1) * cap_local)``.  The
        full arena never exists on one device; per-device memory is
        ``cap_local * n`` bytes, so theta scales with device count.
      * ``sizes``   — ``(D * cap_local,) int32``, ``P(theta_axes)``,
        aligned with ``R`` rows.
      * counter     — per-shard partials ``(D, n) int32``,
        ``P(theta_axes, None)``; the ``counter`` property reduces them to
        the replicated global fused counter for host consumers (selection
        never needs it — it reduces shard-locally and psums).
      * row counts  — ``(D,) int32``, ``P(theta_axes)``, plus a host
        mirror that drives growth logic without device syncs.

    ``add_batch`` splits each sampled batch into D equal row blocks
    (zero-padding the tail when ``B % D != 0``; pad rows are masked, not
    counted) and runs the donated shard_map write kernel: each device
    writes its block into its local arena slot and fuses its local size /
    counter updates.  Capacity grows *per shard* by amortized doubling
    (``cap_local`` is a power of two), so jit retraces stay O(log theta)
    and growth copies are device-local.

    Row placement across shards is a layout detail: selection, ``hits``
    and the global counter are permutation-invariant over rows (exact
    integer sums), so a `ShardedStore` fed the same sample stream as a
    `BitmapStore` yields bit-identical selections on any mesh size.

    ``snapshot``/``restore`` go through ``state()``/``from_state``: the
    snapshot stores valid rows *compacted* on host (shard order), so a
    snapshot taken on one mesh restores onto any other mesh — or into a
    plain `BitmapStore` when no mesh is available (see
    `store_from_state`).
    """

    representation = "bitmap"

    def __init__(self, n: int, *, mesh, theta_axes=("data",),
                 capacity: int = MIN_CAPACITY):
        if mesh is None:
            raise ValueError("ShardedStore needs a jax.sharding.Mesh")
        if isinstance(theta_axes, str):
            theta_axes = (theta_axes,)
        self.n = int(n)
        self.mesh = mesh
        self.theta_axes = tuple(theta_axes)
        self.D = int(np.prod([mesh.shape[a] for a in self.theta_axes]))
        self.cap_local = next_pow2(-(-int(capacity) // self.D))
        self.version = 0
        self._sh_rows = NamedSharding(mesh, P(self.theta_axes, None))
        self._sh_vec = NamedSharding(mesh, P(self.theta_axes))
        self._counts_host = np.zeros((self.D,), np.int64)
        self.R = _sharded_zeros(
            (self.D * self.cap_local, self.n), jnp.uint8, self._sh_rows)
        self.sizes = _sharded_zeros(
            (self.D * self.cap_local,), jnp.int32, self._sh_vec)
        self._counter = _sharded_zeros(
            (self.D, self.n), jnp.int32, self._sh_rows)
        self._counts = _sharded_zeros((self.D,), jnp.int32, self._sh_vec)
        self._write_fn, self._valid_fn = _sharded_write_kernels(
            mesh, self.theta_axes)

    # ------------------------------------------------------------ shape ----

    @property
    def capacity(self) -> int:
        """Global row capacity (``D * cap_local``)."""
        return self.D * self.cap_local

    @property
    def count(self) -> int:
        """Total stored RRR sets across all shards."""
        return int(self._counts_host.sum())

    @property
    def counts(self) -> np.ndarray:
        """Per-shard valid row counts ``(D,)`` (host copy)."""
        return self._counts_host.copy()

    @property
    def counter(self) -> jnp.ndarray:
        """Global fused counter ``(n,) int32`` — reduces the per-shard
        partials (an all-reduce; host/reporting use only, the selection
        kernels consume the partials shard-locally)."""
        return self._counter.sum(axis=0)

    @property
    def batch_sharding(self) -> NamedSharding:
        """Sharding a sampler should place its ``(B, n)`` batch with so
        the store write is a pure device-local slice update (rows
        block-partitioned over ``theta_axes``, vertices replicated)."""
        return self._sh_rows

    # ---------------------------------------------------------- writing ----

    def _grow_rows(self, incoming: int):
        need = int(self._counts_host.max(initial=0)) + incoming
        new_cap = next_pow2(need, self.cap_local)
        if new_cap == self.cap_local:
            return
        grow = _sharded_grow_kernel(
            self.mesh, self.theta_axes, new_cap - self.cap_local)
        self.R, self.sizes = grow(self.R, self.sizes)
        self.cap_local = new_cap

    def add_batch(self, visited, counter=None) -> None:
        """Append ``visited (B, n) uint8`` rows, block-split across shards.

        Shard ``d`` receives rows ``[d*b, (d+1)*b)`` of the (zero-padded)
        batch, where ``b = ceil(B / D)``, and writes them at its local
        offset in place — the arena, sizes, counter and counts buffers are
        all donated, so outstanding views are invalidated.  ``counter`` is
        accepted for `RRRStore` API parity but ignored: the fused C3
        contribution is recomputed *inside* the write kernel from each
        shard's own rows, keeping the count device-local.
        """
        del counter  # recomputed shard-locally inside the write kernel
        visited = jnp.asarray(visited).astype(jnp.uint8)
        B = int(visited.shape[0])
        if B == 0:
            return
        b = -(-B // self.D)
        if b * self.D != B:
            visited = jnp.concatenate(
                [visited, jnp.zeros((b * self.D - B, self.n), jnp.uint8)])
        # no-op when the sampler already placed the batch with
        # ``batch_sharding``; otherwise reshards the (small) batch only
        visited = jax.device_put(visited, self._sh_rows)
        self._grow_rows(b)
        incs_np = np.clip(B - np.arange(self.D) * b, 0, b).astype(np.int32)
        incs = jax.device_put(jnp.asarray(incs_np), self._sh_vec)
        self.R, self.sizes, self._counter, self._counts = self._write_fn(
            self.R, self.sizes, self._counter, self._counts, visited, incs)
        self._counts_host += incs_np
        self.version += 1

    # ---------------------------------------------------------- reading ----

    def valid_mask(self) -> jnp.ndarray:
        """Sharded ``(D * cap_local,) bool`` mask of filled rows (the
        per-shard prefix ``local_iota < counts[shard]``)."""
        return self._valid_fn(self._counts, self.sizes)

    def view(self) -> StoreView:
        """`StoreView` over the *sharded* arena: ``R`` keeps its
        ``P(theta_axes, None)`` layout and ``valid`` its ``P(theta_axes)``
        layout, so sharded selection strategies consume the shards
        natively (zero resharding on entry).  Aliases live buffers —
        consume before the next ``add_batch``."""
        return StoreView("bitmap", self.R, self.valid_mask(), self.n,
                         self.count)

    def hits(self, S) -> jnp.ndarray:
        """Covered fraction per query: ``S (Q, L) int32`` -> ``(Q,) f32``.
        Each shard tests membership against its local rows; only the
        per-query hit counts cross devices (never arena rows)."""
        return _bitmap_hits(self.R, self.valid_mask(),
                            jnp.asarray(S, jnp.int32))

    def coverage_stats(self) -> tuple[float, int]:
        """(avg fractional set coverage, max set size) over stored sets."""
        return _coverage_stats(self.sizes, self.count, self.n)

    # ------------------------------------------------------ checkpointing ----

    def state(self) -> dict:
        """Host snapshot pytree (kind tag ``"sharded"``): the valid rows
        of every shard *compacted* into a contiguous ``(count, n)`` array
        (shard order), so restore redistributes onto any mesh shape — the
        elastic layout `checkpoint.store` promises.  This is the one
        deliberate host gather in the store's life cycle."""
        R = np.asarray(self.R)
        sizes = np.asarray(self.sizes)
        rows, row_sizes = [], []
        for d in range(self.D):
            c = int(self._counts_host[d])
            lo = d * self.cap_local
            rows.append(R[lo:lo + c])
            row_sizes.append(sizes[lo:lo + c])
        return {
            "kind": np.asarray("sharded"),
            "n": np.int64(self.n),
            "count": np.int64(self.count),
            "R": (np.concatenate(rows) if self.count
                  else np.zeros((0, self.n), np.uint8)),
            "sizes": (np.concatenate(row_sizes) if self.count
                      else np.zeros((0,), np.int32)),
            "counter": np.asarray(self.counter),
        }

    # rows staged per add_batch during restore: bounds the transient
    # single-device footprint of the host->device feed to CHUNK * n bytes
    # (the resident arena itself is born sharded and never gathers)
    RESTORE_CHUNK = 4096

    @classmethod
    def from_state(cls, st, *, mesh, theta_axes=("data",)) -> "ShardedStore":
        """Rebuild on ``mesh`` from a ``"sharded"`` (compact rows) *or*
        ``"bitmap"`` (full-capacity arena) snapshot: the valid rows are
        redistributed block-evenly across the new mesh's shards, and the
        fused counter/sizes are recomputed shard-locally (exactly equal to
        the saved ones).  Rows are fed in ``RESTORE_CHUNK``-row slices so
        an arena that only fits *because* it is sharded never transits any
        single device whole on restore."""
        n, count = int(st["n"]), int(st["count"])
        store = cls(n, mesh=mesh, theta_axes=theta_axes,
                    capacity=max(count, 1))
        rows = np.asarray(st["R"])[:count]
        chunk = max(cls.RESTORE_CHUNK // max(store.D, 1), 1) * store.D
        for lo in range(0, count, chunk):
            store.add_batch(jnp.asarray(rows[lo:lo + chunk], jnp.uint8))
        return store


STORE_KINDS = {"bitmap": BitmapStore, "indices": IndexStore,
               "sharded": ShardedStore}


def make_store(kind: str, n: int, **kw) -> RRRStore:
    """Store factory: ``"auto"`` (bitmap, the back-compat default),
    ``"bitmap"``, ``"indices"``, or ``"sharded"`` (requires a ``mesh=``
    keyword; accepts ``theta_axes=``)."""
    kind = "bitmap" if kind == "auto" else kind
    try:
        ctor = STORE_KINDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown store kind {kind!r}; have {sorted(STORE_KINDS)}")
    return ctor(n, **kw)


def store_from_state(st, *, mesh=None, theta_axes=("data",)) -> RRRStore:
    """Rebuild a store from a `state()` tree (snapshot restore path).

    Snapshots are elastic across layouts: with ``mesh`` given, bitmap and
    sharded snapshots both restore into a `ShardedStore` on that mesh
    (rows redistributed); without one, a sharded snapshot restores into a
    compacted `BitmapStore`.  Index-list snapshots are single-device only
    (the sharded store is dense-only, like sharded selection).
    """
    kind = str(np.asarray(st["kind"]))
    if kind not in STORE_KINDS:
        raise ValueError(f"snapshot has unknown store kind {kind!r}")
    if mesh is not None:
        if kind == "indices":
            raise ValueError(
                "index-list snapshots cannot restore onto a mesh "
                "(ShardedStore is dense-only)")
        return ShardedStore.from_state(st, mesh=mesh, theta_axes=theta_axes)
    if kind == "sharded":
        return BitmapStore.from_rows(np.asarray(st["R"]), int(st["n"]))
    return STORE_KINDS[kind].from_state(st)
