"""Persistent RRR-set arenas — the resident store behind `InfluenceEngine`.

The paper's C1/C3/C4/C5 optimizations all hinge on *where the sampled RRR
sets live*: fused counting writes into a store-owned counter, the adaptive
representation is a property of the store, the NUMA/device partitioning of
the sets is a property of the store, and selection reads the store without
reshaping it.  This module makes that explicit:

  * ``RRRStore``   — the protocol every backend implements: in-place
    ``add_batch``, a shape-stable ``view()`` for selection, fused per-node
    ``counter`` (C3), per-set ``sizes``, batched membership queries
    (``hits``), and ``state()``/``from_state`` for snapshots.
  * ``BitmapStore`` — single-device ``(capacity, n) uint8`` bitmap arena.
    Capacity is a power of two grown by amortized doubling; batches are
    written in place with a donated ``dynamic_update_slice`` so the hot
    loop never re-concats O(theta) rows and jit recompilations are bounded
    by O(log theta) distinct arena shapes.  Converts to index lists lazily
    (C4) via a version-keyed cache.
  * ``IndexStore``  — ``(capacity, L) int32`` index-list arena (sentinel
    ``n``), for regimes where sets are sparse from the start (LT walks,
    huge graphs); widens ``L`` by power-of-two steps as larger sets arrive.
  * ``ShardedStore`` — the paper's C1 partitioning end-to-end: a bitmap
    arena sharded across a ``jax.sharding.Mesh`` — the theta axis over
    ``theta_axes`` and, on 2D meshes, the vertex axis over
    ``vertex_axis``.  Every device owns a ``(cap_local, n_local)`` tile
    (``n_local = ceil(n / Dv)``); batch writes, fused counting, the row
    lifecycle and per-shard growth all happen device-locally inside
    donated ``shard_map`` kernels, so the full ``(theta, n)`` arena
    never exists on any single device — theta scales with the theta axis
    and graph size with the vertex axis (docs/sharding.md).

All backends preserve exact equivalence with the historical pad-to-pow2
selection inputs: padding rows are all-zero (bitmap) / all-sentinel
(indices) and masked by ``view().valid``.  For ``ShardedStore``, row
*placement* is a layout detail, not a semantic one — selection, ``hits``
and the global counter are permutation-invariant over rows (every
reduction is an exact integer sum), so results are seed-for-seed
identical to a ``BitmapStore`` fed the same sample stream, on any mesh.

Streaming (``repro.stream``) adds a **row lifecycle** on top of the
grow-only arena: every filled row carries a ``live`` bit, and
``view().valid`` is ``filled & live`` — a killed (stale or evicted) row
drops out of selection, ``hits`` and the fused counter *immediately*,
with no rebuild.  Three primitives drive it, all in place:

  * ``kill_rows(mask)``    — mark rows dead and subtract their fused-
    counter contribution (invalidation and eviction share this path);
  * ``replace_rows(i, b)`` — overwrite dead slots with freshly sampled
    rows and revive them (the streaming refresh write);
  * ``compact()``          — rewrite live rows to the arena head (per
    shard for `ShardedStore`), reclaiming dead slots; returns an
    old-slot -> new-slot remap so callers tracking row provenance can
    follow the move.

`StorePressurePolicy` bounds resident memory (``max_rows`` /
``max_bytes``): ``add_batch`` under a policy first compacts (dead rows
are the first victims — staleness-first), then evicts the oldest live
rows, so arena capacity never exceeds the cap on an indefinite stream.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Protocol, runtime_checkable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.compat import shard_map
from repro.core.adaptive import bitmap_to_indices
from repro.graphs.partition import VertexPartition, vertex_partition

MIN_CAPACITY = 16     # matches the historical pad floor (1 << 4)
MIN_INDEX_PAD = 4     # matches the historical l_pad floor (1 << 2)


def next_pow2(x: int, floor: int = MIN_CAPACITY) -> int:
    """Smallest power of two >= max(x, floor)."""
    cap = max(int(floor), 1)
    while cap < x:
        cap <<= 1
    return cap


@dataclasses.dataclass(frozen=True)
class StorePressurePolicy:
    """Bounded-memory contract for an indefinite stream of batches.

    ``max_rows`` caps the arena's row capacity directly; ``max_bytes``
    caps it through the backend's *physical* bytes-per-row (``n`` for
    bitmaps, ``4 * l_pad`` for index lists, ``ceil(n/8)`` packed,
    ``4 * s_pad`` compressed); when both are set the tighter one wins.
    Victim order under pressure is **staleness-first**: dead
    (stale/invalidated) rows are reclaimed by compaction before any live
    row is touched, then the *oldest* live rows are evicted FIFO — the
    lowest-information residents under a growing theta schedule (HBMax's
    observation: early small-theta samples are the cheapest to drop).

    ``ladder`` makes the eviction-vs-compression tradeoff explicit
    (IMPack): an ordered tuple of codec kinds (subset of ``("packed",
    "compressed")``) the arena may morph *down* through when a write
    would not fit — compress-before-evict.  Each step shrinks
    bytes-per-row, so a ``max_bytes`` cap admits more rows; only when
    the ladder is exhausted do live rows get evicted.  Backends that
    cannot morph their layout (`BitmapStore`, `IndexStore`) ignore the
    ladder; `repro.core.pack.CodecStore` and codec-bearing
    `ShardedStore` arenas honor it.
    """
    max_rows: int | None = None
    max_bytes: int | None = None
    ladder: tuple = ()

    def row_cap(self, row_bytes: int) -> int | None:
        """Effective row capacity for a backend storing ``row_bytes`` per
        row, or None when the policy is unbounded."""
        caps = []
        if self.max_rows is not None:
            caps.append(int(self.max_rows))
        if self.max_bytes is not None:
            caps.append(int(self.max_bytes) // max(int(row_bytes), 1))
        if not caps:
            return None
        cap = min(caps)
        if cap < 1:
            raise ValueError(
                f"StorePressurePolicy resolves to a row cap of {cap} "
                f"(row_bytes={row_bytes}); the cap must hold >= 1 row")
        return cap


_LADDER_RANK = {"bitmap": 0, "packed": 1, "compressed": 2}


def _ladder_next(current_kind: str, ladder) -> str | None:
    """Next codec kind a pressure ladder may morph ``current_kind`` down
    to, or None when the ladder is exhausted.  Only strictly-denser
    kinds qualify — a ladder can never decompress an arena."""
    rank = _LADDER_RANK.get(current_kind, 0)
    for kind in ladder:
        if _LADDER_RANK.get(kind, -1) > rank:
            return kind
    return None


@dataclasses.dataclass(frozen=True)
class StoreView:
    """Read-only picture of an arena handed to a `SelectionStrategy`.

    ``R`` is ``(capacity, n) uint8`` bitmaps when ``representation ==
    "bitmap"`` and ``(capacity, L) int32`` sentinel-padded index lists when
    ``representation == "indices"``.  For single-device stores, rows at
    index >= ``count`` are padding and ``valid`` is the prefix mask
    ``arange(capacity) < count``.  For `ShardedStore` views, ``R`` is the
    *sharded* global arena (``P(theta_axes, vertex_axis)``; column count
    ``n_pad >= n`` on 2D meshes — pad columns are all-zero, and index
    views hold *local* vertex ids per tile), valid rows are a per-shard
    prefix rather than a global one, and ``valid`` (sharded
    ``P(theta_axes)``) masks exactly the rows each shard has filled —
    consumers must always mask by ``valid`` instead of assuming
    contiguity.

    Views alias the live arena buffer, which `add_batch` donates to its
    in-place writer — a view is only safe to read until the store's next
    write (on accelerator backends the donated buffer is literally
    deleted).  Consume a view before mutating the store; re-call ``view()``
    after.
    """
    representation: str
    R: jnp.ndarray
    valid: jnp.ndarray
    n: int
    count: int


def _coverage_stats(sizes, count: int, n: int) -> tuple[float, int]:
    """(avg fractional set coverage, max set size) from a sizes array —
    padding entries are zero, so sums/maxes ignore them."""
    sizes = np.asarray(sizes)
    avg_cov = float(sizes.sum()) / max(count, 1) / n
    return avg_cov, max(int(sizes.max()) if sizes.size else 1, 1)


@partial(jax.jit, donate_argnums=(0,))
def _write_rows(arena, rows, start):
    """In-place (donated) row-block write at dynamic offset ``start``."""
    start_idx = (start,) + (jnp.int32(0),) * (arena.ndim - 1)
    return jax.lax.dynamic_update_slice(arena, rows, start_idx)


@partial(jax.jit, donate_argnums=(0, 1))
def _compact_rows(R, sizes, keep, fill):
    """Stable-partition live rows to the arena head, dead slots to
    ``fill`` padding.  The sort key ``(~keep) * cap + iota`` is unique, so
    the permutation is deterministic and order-preserving among kept rows
    (oldest rows stay first — the FIFO order eviction relies on)."""
    cap = keep.shape[0]
    iota = jnp.arange(cap, dtype=jnp.int32)
    perm = jnp.argsort(jnp.where(keep, 0, 1) * cap + iota)
    newvalid = iota < keep.sum(dtype=jnp.int32)
    R = jnp.where(newvalid[:, None], R[perm], fill)
    sizes = jnp.where(newvalid, sizes[perm], 0)
    return R, sizes


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _replace_rows_kernel(R, sizes, live, counter, idx, rows, row_sizes,
                         contrib):
    """Scatter fresh ``rows`` into dead slots ``idx``, revive their live
    bits, and add the replacement contribution to the fused counter.
    ``idx`` entries of -1 are padding (callers pad the target count to a
    power of two so jit retraces stay O(log capacity)) — they scatter
    out-of-bounds and drop; their ``contrib`` share is pre-masked."""
    tgt = jnp.where(idx >= 0, idx, R.shape[0])
    R = R.at[tgt].set(rows, mode="drop")
    sizes = sizes.at[tgt].set(row_sizes, mode="drop")
    live = live.at[tgt].set(True, mode="drop")
    return R, sizes, live, counter + contrib


def _restore_live(store, st) -> None:
    """Re-apply a snapshot's live bits (absent in pre-streaming
    snapshots, where every filled row is live)."""
    if "live" in st:
        live = np.asarray(st["live"]).astype(bool)
        store.live = jnp.asarray(live)
        store.dead = int(store.count - live[:store.count].sum())


@jax.jit
def _bitmap_hits(R, valid, S):
    """Fraction of valid sets hit by each seed row. S: (Q, L) int32."""
    memb = R[:, S.reshape(-1)].reshape((R.shape[0],) + S.shape) > 0
    hit = memb.any(axis=2) & valid[:, None]
    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)
    return hit.sum(axis=0).astype(jnp.float32) / n_valid


@jax.jit
def _index_hits(R_idx, valid, S):
    """Index-list membership version of `_bitmap_hits` (lax.map bounds the
    (capacity, L, Lq) broadcast to one query at a time)."""
    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)

    def one(s):
        memb = (R_idx[:, :, None] == s[None, None, :]).any(axis=(1, 2))
        return (memb & valid).sum(dtype=jnp.int32)

    hits = jax.lax.map(one, S)
    return hits.astype(jnp.float32) / n_valid


@runtime_checkable
class RRRStore(Protocol):
    """Protocol for RRR-set stores consumed by `InfluenceEngine`.

    ``add_batch(visited, counter=None)`` takes ``(B, n) uint8`` bitmaps and
    appends them in place (implementations donate their arena buffer — do
    not hold references to a previous ``view()`` across a write),
    returning the slot index each row landed in (streaming provenance).
    ``counter`` is the sampler's fused ``(n,) int32`` batch contribution;
    backends may recompute it locally instead (``ShardedStore`` does, so
    the count stays shard-local).  ``view()`` returns a `StoreView` whose
    arrays alias live buffers; ``hits(S)`` answers ``(Q, L) int32`` seed-
    set membership queries as per-query covered fractions ``(Q,) f32``;
    ``state()`` returns a host pytree for `checkpoint.store`.  Streaming
    consumers additionally use the row lifecycle (``kill_rows`` /
    ``replace_rows`` / ``compact``, ``live_count``, ``row_cap``) — see
    the module docstring.
    """
    representation: str
    n: int
    count: int
    capacity: int
    version: int
    counter: jnp.ndarray
    sizes: jnp.ndarray

    def add_batch(self, visited, counter=None) -> np.ndarray: ...
    def view(self) -> StoreView: ...
    def hits(self, S) -> jnp.ndarray: ...
    def coverage_stats(self) -> tuple[float, int]: ...
    def state(self) -> dict: ...


class _ArenaBase:
    """Shared arena bookkeeping: pow2 capacity, doubling, fused counter,
    and the streaming row lifecycle (live bits, kill/replace/compact,
    pressure-policy eviction)."""

    def __init__(self, n: int, *, capacity: int = MIN_CAPACITY,
                 policy: StorePressurePolicy | None = None):
        self.n = int(n)
        self.capacity = next_pow2(capacity)
        self.count = 0
        self.dead = 0           # filled rows whose live bit is cleared
        self.version = 0
        self.policy = policy
        self.track_remaps = False   # StreamEngine opts in to remap logging
        self._remaps: list[np.ndarray] = []
        self.sizes = jnp.zeros((self.capacity,), jnp.int32)
        self.counter = jnp.zeros((self.n,), jnp.int32)
        self.live = jnp.ones((self.capacity,), jnp.bool_)

    def _grow_rows(self, need: int):
        new_cap = next_pow2(need, self.capacity)
        cap = self.row_cap
        if cap is not None:
            # capacity is clamped to the policy cap (possibly non-pow2);
            # _ensure_room already guaranteed need <= cap
            new_cap = min(new_cap, max(cap, self.capacity))
        if new_cap == self.capacity:
            return
        self._realloc(new_cap)
        sizes = jnp.zeros((new_cap,), jnp.int32)
        self.sizes = _write_rows(sizes, self.sizes, jnp.int32(0))
        self.live = jnp.concatenate(
            [self.live, jnp.ones((new_cap - self.capacity,), jnp.bool_)])
        self.capacity = new_cap

    def _finish_add(self, batch_sizes, counter):
        B = batch_sizes.shape[0]
        self.sizes = _write_rows(self.sizes, batch_sizes, jnp.int32(self.count))
        self.counter = self.counter + counter
        self._note_write(int(B))

    def _note_write(self, B: int):
        """Host-side bookkeeping after ``B`` rows landed in the arena —
        shared by `add_batch` and the fused sample->write->count path
        (`repro.core.fused`), which commits rows without ever staging a
        separate batch array."""
        self.count += int(B)
        self.version += 1
        if obs.enabled():
            # host arithmetic only — shapes the store already tracks,
            # never a device read
            obs.counter("store.rows_written").add(int(B))
            obs.gauge("store.occupancy").set(self.count / self.capacity)
            # physical at-rest bytes (_row_bytes is per-backend: packed
            # and compressed arenas report their encoded width, not the
            # logical uint8 bitmap width)
            arena = self.capacity * self._row_bytes()
            obs.gauge("store.arena_bytes").set(arena)
            obs.gauge("store.bytes_per_device").set(arena)
            obs.gauge("store.compress_ratio").set(
                self.capacity * self.n / max(arena, 1))

    def _valid(self):
        return (jnp.arange(self.capacity) < self.count) & self.live

    def coverage_stats(self) -> tuple[float, int]:
        """(avg fractional set coverage, max set size) over *live* sets
        (killed rows have their sizes zeroed)."""
        return _coverage_stats(self.sizes, self.live_count, self.n)

    # ---------------------------------------------------- row lifecycle ----

    @property
    def live_count(self) -> int:
        """Filled rows that are still live (the streaming effective theta)."""
        return self.count - self.dead

    @property
    def row_cap(self) -> int | None:
        """Policy row capacity for this backend, or None (unbounded)."""
        if self.policy is None:
            return None
        return self.policy.row_cap(self._row_bytes())

    def live_mask(self) -> jnp.ndarray:
        """``(capacity,) bool`` live bits (True for unfilled slots too —
        mask by the fill prefix, as ``view().valid`` does)."""
        return self.live

    def drain_remaps(self) -> list[np.ndarray]:
        """Pop the slot remaps recorded since the last drain (only
        populated while ``track_remaps`` is set).  Each entry maps
        old slot -> new slot, with -1 for reclaimed slots; apply them in
        order to follow rows across compactions."""
        out, self._remaps = self._remaps, []
        return out

    def kill_rows(self, dead) -> int:
        """Mark rows dead (stale or evicted): they leave ``view().valid``,
        ``hits`` and the fused counter immediately; their slots are
        reclaimed by the next `compact`.  ``dead`` is a ``(capacity,)``
        bool mask (host or device); bits outside the filled-and-live set
        are ignored.  Returns the number of newly dead rows."""
        dead = jnp.asarray(dead) & self._valid()
        k = int(np.asarray(dead.sum()))
        if k == 0:
            return 0
        self.counter = self.counter - self._row_contrib(dead)
        self.sizes = jnp.where(dead, 0, self.sizes)
        self.live = self.live & ~dead
        self.dead += k
        self.version += 1
        obs.counter("store.rows_killed").add(k)
        return k

    def replace_rows(self, idx, rows) -> None:
        """Overwrite dead slots ``idx (K,) int`` with fresh ``rows (K, n)
        uint8`` bitmaps and revive them — the streaming refresh write.
        Targets must be filled, dead slots (enforced); ``idx`` entries of
        -1 are padding and ignored (callers may pre-pad; this method also
        pads the batch to a power of two to bound jit retraces)."""
        idx = np.asarray(idx, np.int64)
        real = idx >= 0
        k = int(real.sum())
        if k == 0:
            return
        live_host = np.asarray(self.live)
        if (idx[real] >= self.count).any() or live_host[idx[real]].any():
            raise ValueError(
                "replace_rows targets must be filled, dead slots "
                "(kill_rows them first)")
        with obs.span("store.write", tier="store", kind="replace"):
            rows = jnp.asarray(rows).astype(jnp.uint8)
            pad = next_pow2(idx.shape[0], 1) - idx.shape[0]
            if pad:
                idx = np.concatenate([idx, np.full(pad, -1, np.int64)])
                rows = jnp.concatenate(
                    [rows, jnp.zeros((pad, rows.shape[1]), jnp.uint8)])
            mask = jnp.asarray(idx >= 0)
            rows = rows * mask[:, None].astype(jnp.uint8)  # zero pad rows
            row_sizes = rows.sum(axis=1, dtype=jnp.int32)
            stored = self._rows_for_storage(rows)
            self.R, self.sizes, self.live, self.counter = \
                _replace_rows_kernel(
                    self.R, self.sizes, self.live, self.counter,
                    jnp.asarray(idx, jnp.int32), stored, row_sizes,
                    rows.sum(axis=0, dtype=jnp.int32))
            self.dead -= k
            self.version += 1
        obs.counter("store.rows_replaced").add(k)

    def compact(self) -> np.ndarray | None:
        """Rewrite live rows to the arena head in place, reclaiming dead
        slots.  Returns the old->new slot remap (-1 for reclaimed slots),
        or None when there was nothing to reclaim."""
        if self.dead == 0:
            return None
        keep = np.asarray(self._valid())
        self.R, self.sizes = _compact_rows(
            self.R, self.sizes, jnp.asarray(keep), self._fill_value())
        remap = np.full(self.capacity, -1, np.int64)
        remap[keep] = np.arange(int(keep.sum()))
        self.count = int(keep.sum())
        self.dead = 0
        self.live = jnp.ones((self.capacity,), jnp.bool_)
        self.version += 1
        obs.counter("store.compactions").add(1)
        if self.track_remaps:
            self._remaps.append(remap)
        return remap

    def _compress_step(self) -> bool:
        """Morph the arena one step down the policy ladder (see
        `StorePressurePolicy.ladder`); returns True when a step was
        taken.  Backends with a fixed layout cannot morph."""
        return False

    def _ensure_room(self, incoming: int):
        """Pressure-policy enforcement before a batch write, in
        compress-before-evict order: reclaim dead slots first
        (staleness-first victim order), then walk the codec ladder —
        each step shrinks bytes-per-row, so a ``max_bytes`` cap admits
        more rows — and only when the ladder is exhausted evict the
        oldest live rows FIFO until ``incoming`` rows fit."""
        cap = self.row_cap
        if cap is None:
            return
        if self.count + incoming > cap and self.dead:
            self.compact()
        while self.count + incoming > cap and self._compress_step():
            cap = self.row_cap
        if incoming > cap:
            raise ValueError(
                f"batch of {incoming} rows exceeds the policy row cap "
                f"of {cap}")
        if self.count + incoming <= cap:
            return
        self.compact()
        over = self.count + incoming - cap
        if over > 0:
            evicted = self.kill_rows(jnp.arange(self.capacity) < over)
            obs.counter("store.rows_evicted").add(evicted)
            self.compact()

    def _base_state(self) -> dict:
        return {
            "n": np.int64(self.n),
            "count": np.int64(self.count),
            "sizes": np.asarray(self.sizes),
            "counter": np.asarray(self.counter),
            "live": np.asarray(self.live),
        }


class BitmapStore(_ArenaBase):
    """Dense single-device bitmap arena: ``(capacity, n) uint8``,
    zero-padded rows, unsharded (replicated from the mesh's point of
    view).  Use `ShardedStore` when theta must scale past one device."""

    representation = "bitmap"

    def __init__(self, n: int, *, capacity: int = MIN_CAPACITY,
                 policy: StorePressurePolicy | None = None):
        super().__init__(n, capacity=capacity, policy=policy)
        self.R = jnp.zeros((self.capacity, self.n), jnp.uint8)
        self._idx_cache = None      # (version, l_pad) -> R_idx

    def _realloc(self, new_cap: int):
        R = jnp.zeros((new_cap, self.n), jnp.uint8)
        self.R = _write_rows(R, self.R, jnp.int32(0))

    def _row_bytes(self) -> int:
        return self.n

    def _fill_value(self):
        return jnp.uint8(0)

    def _rows_for_storage(self, rows):
        return rows

    def _row_contrib(self, mask):
        """Fused-counter contribution of the masked rows (exact: counts
        fit f32 integers)."""
        return (mask.astype(jnp.float32)
                @ self.R.astype(jnp.float32)).astype(jnp.int32)

    def add_batch(self, visited, counter=None) -> np.ndarray:
        """Append ``visited (B, n) uint8`` rows in place.

        The arena buffer is donated to the writer — any outstanding
        ``view()`` of this store is invalidated by this call.  ``counter``
        is the sampler's fused ``(n,) int32`` contribution (computed here
        when absent).  Returns the slot indices the batch rows landed in
        (streaming consumers track row provenance with them).  Under a
        `StorePressurePolicy` the write may first compact and evict (see
        ``_ensure_room``).
        """
        with obs.span("store.write", tier="store", kind="bitmap"):
            visited = jnp.asarray(visited).astype(jnp.uint8)
            B = int(visited.shape[0])
            self._ensure_room(B)
            self._grow_rows(self.count + B)
            if counter is None:
                counter = visited.sum(axis=0, dtype=jnp.int32)
            slots = np.arange(self.count, self.count + B, dtype=np.int64)
            self.R = _write_rows(self.R, visited, jnp.int32(self.count))
            self._finish_add(visited.sum(axis=1, dtype=jnp.int32), counter)
        return slots

    def view(self) -> StoreView:
        """Aliasing `StoreView` of the live ``(capacity, n)`` arena with
        the prefix mask ``arange(capacity) < count``; read it before the
        next ``add_batch`` (which donates the buffer)."""
        return StoreView("bitmap", self.R, self._valid(), self.n, self.count)

    def index_view(self, l_pad: int) -> StoreView:
        """Lazy C4 conversion; cached until the arena next changes."""
        key = (self.version, int(l_pad))
        if self._idx_cache is None or self._idx_cache[0] != key:
            self._idx_cache = (key, bitmap_to_indices(self.R, int(l_pad)))
        return StoreView("indices", self._idx_cache[1], self._valid(),
                         self.n, self.count)

    def hits(self, S) -> jnp.ndarray:
        """Covered fraction per query: ``S (Q, L) int32`` -> ``(Q,) f32``."""
        with obs.span("count", tier="store", kind="bitmap"):
            return _bitmap_hits(self.R, self._valid(),
                                jnp.asarray(S, jnp.int32))

    def state(self) -> dict:
        """Host snapshot pytree: full ``(capacity, n)`` arena plus
        counters (kind tag ``"bitmap"``)."""
        st = self._base_state()
        st["kind"] = np.asarray("bitmap")
        st["R"] = np.asarray(self.R)
        return st

    @classmethod
    def from_state(cls, st) -> "BitmapStore":
        store = cls(int(st["n"]), capacity=st["R"].shape[0])
        store.R = jnp.asarray(st["R"], jnp.uint8)
        store.sizes = jnp.asarray(st["sizes"], jnp.int32)
        store.counter = jnp.asarray(st["counter"], jnp.int32)
        store.count = int(st["count"])
        _restore_live(store, st)
        return store

    @classmethod
    def from_rows(cls, rows, n: int) -> "BitmapStore":
        """Build a store holding exactly ``rows (count, n) uint8`` — the
        cross-layout restore path (e.g. a `ShardedStore` snapshot opened
        without a mesh).  ``_restore_slots`` records where each input row
        landed (snapshot-row -> slot), so provenance trackers
        (`repro.stream.StreamEngine`) can follow rows through a restore."""
        store = cls(int(n), capacity=max(int(rows.shape[0]), MIN_CAPACITY))
        if rows.shape[0]:
            store._restore_slots = store.add_batch(jnp.asarray(rows, jnp.uint8))
        else:
            store._restore_slots = np.zeros((0,), np.int64)
        return store


class IndexStore(_ArenaBase):
    """Sparse index-list arena: ``(capacity, L) int32`` with sentinel ``n``.

    ``L`` widens by power-of-two steps when a batch contains a larger set
    (the widened columns backfill with the sentinel, so old rows keep their
    meaning).  Incoming bitmap batches are converted on write — after that
    the bitmaps are dropped, so resident memory is O(theta * L) not
    O(theta * n).
    """

    representation = "indices"

    def __init__(self, n: int, *, capacity: int = MIN_CAPACITY,
                 l_pad: int = MIN_INDEX_PAD,
                 policy: StorePressurePolicy | None = None):
        super().__init__(n, capacity=capacity, policy=policy)
        self.l_pad = next_pow2(l_pad, MIN_INDEX_PAD)
        self.R = jnp.full((self.capacity, self.l_pad), self.n, jnp.int32)

    def _realloc(self, new_cap: int):
        R = jnp.full((new_cap, self.l_pad), self.n, jnp.int32)
        self.R = _write_rows(R, self.R, jnp.int32(0))

    def _widen(self, l_need: int):
        new_l = next_pow2(l_need, self.l_pad)
        if new_l == self.l_pad:
            return
        pad = jnp.full((self.capacity, new_l - self.l_pad), self.n, jnp.int32)
        self.R = jnp.concatenate([self.R, pad], axis=1)
        self.l_pad = new_l

    def _row_bytes(self) -> int:
        return 4 * self.l_pad

    def _fill_value(self):
        return jnp.int32(self.n)

    def _rows_for_storage(self, rows):
        self._widen(int(rows.sum(axis=1).max()))
        return bitmap_to_indices(rows, self.l_pad)

    def _row_contrib(self, mask):
        w = jnp.broadcast_to(mask[:, None], self.R.shape)
        return (jnp.zeros((self.n,), jnp.float32)
                .at[self.R.reshape(-1)]
                .add(w.reshape(-1).astype(jnp.float32), mode="drop")
                .astype(jnp.int32))

    def add_batch(self, visited, counter=None) -> np.ndarray:
        with obs.span("store.write", tier="store", kind="indices"):
            visited = jnp.asarray(visited).astype(jnp.uint8)
            B = int(visited.shape[0])
            batch_sizes = visited.sum(axis=1, dtype=jnp.int32)
            self._widen(int(batch_sizes.max()))
            self._ensure_room(B)
            self._grow_rows(self.count + B)
            if counter is None:
                counter = visited.sum(axis=0, dtype=jnp.int32)
            rows = bitmap_to_indices(visited, self.l_pad)
            slots = np.arange(self.count, self.count + B, dtype=np.int64)
            self.R = _write_rows(self.R, rows, jnp.int32(self.count))
            self._finish_add(batch_sizes, counter)
        return slots

    def add_index_batch(self, rows, counter=None) -> np.ndarray:
        """Append pre-converted index rows ``(B, L) int32`` (ascending,
        sentinel >= n) — the native-emission write path (C4 routed
        per-backend: a sparse-backend sampler emits lists directly via
        ``emit_l`` and no ``(B, n)`` bitmap ever materializes between the
        sampler and the arena).  ``counter`` is the sampler's fused
        ``(n,) int32`` contribution (recomputed by scatter when absent);
        the arena widens to ``L`` if needed and narrower rows backfill
        with the sentinel.  Returns the landing slots, like `add_batch`.
        """
        with obs.span("store.write", tier="store", kind="indices"):
            rows = jnp.asarray(rows, jnp.int32)
            B, L = int(rows.shape[0]), int(rows.shape[1])
            batch_sizes = (rows < self.n).sum(axis=1, dtype=jnp.int32)
            self._widen(L)
            if L < self.l_pad:
                rows = jnp.concatenate(
                    [rows, jnp.full((B, self.l_pad - L), self.n, jnp.int32)],
                    axis=1)
            # normalize any emitter sentinel (>= n) to the store's (== n)
            rows = jnp.where(rows < self.n, rows, self.n)
            self._ensure_room(B)
            self._grow_rows(self.count + B)
            if counter is None:
                counter = (jnp.zeros((self.n,), jnp.int32)
                           .at[rows.reshape(-1)].add(1, mode="drop"))
            slots = np.arange(self.count, self.count + B, dtype=np.int64)
            self.R = _write_rows(self.R, rows, jnp.int32(self.count))
            self._finish_add(batch_sizes, counter)
        return slots

    def view(self) -> StoreView:
        return StoreView("indices", self.R, self._valid(), self.n, self.count)

    def hits(self, S) -> jnp.ndarray:
        with obs.span("count", tier="store", kind="indices"):
            return _index_hits(self.R, self._valid(),
                               jnp.asarray(S, jnp.int32))

    def state(self) -> dict:
        st = self._base_state()
        st["kind"] = np.asarray("indices")
        st["R"] = np.asarray(self.R)
        return st

    @classmethod
    def from_state(cls, st) -> "IndexStore":
        store = cls(int(st["n"]), capacity=st["R"].shape[0],
                    l_pad=st["R"].shape[1])
        store.R = jnp.asarray(st["R"], jnp.int32)
        store.sizes = jnp.asarray(st["sizes"], jnp.int32)
        store.counter = jnp.asarray(st["counter"], jnp.int32)
        store.count = int(st["count"])
        _restore_live(store, st)
        return store


# ------------------------------------------------------- sharded (C1) ----


def _sharded_zeros(shape, dtype, sharding):
    """Zeros *born sharded*: allocated under jit with ``out_shardings`` so
    the full logical array is never materialized on a single device."""
    return jax.jit(partial(jnp.zeros, shape, dtype),
                   out_shardings=sharding)()


def _sharded_ones(shape, dtype, sharding):
    """Ones born sharded (see `_sharded_zeros`)."""
    return jax.jit(partial(jnp.ones, shape, dtype),
                   out_shardings=sharding)()


def _psum_if(x, axis):
    """``psum`` over a mesh axis when one is given (the vertex axis is
    None on 1D meshes, where every per-row reduction is already whole)."""
    return x if axis is None else jax.lax.psum(x, axis)


def _tile_write_body(codec, vertex_axis):
    """The per-tile arena write body (the function `shard_map` runs on
    every (theta-shard, vertex-shard) tile): encode + write the batch
    block at the shard's row offset, fuse the size/counter updates, and
    advance the shard count.  Shared verbatim between the unfused
    `_sharded_write_kernels` path and the fused sample->write->count
    chain (`repro.core.fused`), so both compile the identical trace."""

    def write(R, sizes, counter, counts, rows, incs):
        start = counts[0]
        stored = rows if codec is None else codec.encode(rows)
        R = jax.lax.dynamic_update_slice(R, stored, (start, jnp.int32(0)))
        live = jnp.arange(rows.shape[0], dtype=jnp.int32) < incs[0]
        row_sizes = _psum_if(rows.sum(axis=1, dtype=jnp.int32), vertex_axis)
        row_sizes = jnp.where(live, row_sizes, 0)
        sizes = jax.lax.dynamic_update_slice(sizes, row_sizes, (start,))
        counter = counter + rows.sum(axis=0, dtype=jnp.int32)[None, :]
        return R, sizes, counter, counts + incs

    return write


@functools.lru_cache(maxsize=None)
def _sharded_write_kernels(mesh, theta_axes, vertex_axis, codec=None):
    """Compiled per-(mesh, axes) store kernels, shared across stores.

    Returns ``(write, valid)``:
      * ``write(R, sizes, counter, counts, rows, incs)`` — every
        (theta-shard, vertex-shard) tile writes its ``(b, n_local)`` block
        of the batch into its local arena tile at its theta shard's row
        offset ``counts[shard]``, fuses the local size/counter updates (C3
        done tile-locally; on a 2D mesh the per-row sizes are the one
        vertex-axis psum — a ``(b,)`` int vector, never arena columns),
        and advances the theta shard's count by ``incs[shard]``.
        ``R``/``sizes``/``counter``/``counts`` are donated — the store's
        previous buffers are dead after the call.
      * ``valid(counts, sizes)`` — per-shard prefix mask
        ``local_iota < counts[shard]`` as a global ``P(theta_axes)`` bool
        array (``sizes`` is only a shape donor).

    ``codec`` (a hashable ``repro.core.pack.codec`` tile codec, or None
    for the raw bitmap layout) encodes each tile's batch block before the
    arena write — sizes and counter partials are still computed from the
    *bit* rows, so the fused C3 path is layout-invariant.  Pack-on-write
    is fused: the encoded block is a jit temporary of the write kernel.
    """
    sp_rows, sp_vec = P(theta_axes, vertex_axis), P(theta_axes)
    write = _tile_write_body(codec, vertex_axis)

    write_fn = jax.jit(
        shard_map(write, mesh=mesh,
                  in_specs=(sp_rows, sp_vec, sp_rows, sp_vec, sp_rows,
                            sp_vec),
                  out_specs=(sp_rows, sp_vec, sp_rows, sp_vec)),
        donate_argnums=(0, 1, 2, 3))

    def valid(counts, sizes):
        return jnp.arange(sizes.shape[0], dtype=jnp.int32) < counts[0]

    valid_fn = jax.jit(shard_map(
        valid, mesh=mesh, in_specs=(sp_vec, sp_vec), out_specs=sp_vec))
    return write_fn, valid_fn


@functools.lru_cache(maxsize=None)
def _sharded_hits_kernel(mesh, theta_axes, vertex_axis, codec=None):
    """Membership queries with both arena axes resident: each tile tests
    the queried vertices that fall inside its own column block against its
    own rows; the vertex axis combines per-(row, query) hit bits with one
    psum-or (a ``(cap_local, Q)`` bool — rows x queries, never columns),
    and the theta axis reduces only the final ``(Q,)`` counts.

    ``starts`` is the replicated ``(Dv + 1,) int32`` block-boundary array
    of the store's `VertexPartition` — shard ``s`` owns global vertices
    ``[starts[s], starts[s+1])`` — so one compiled kernel serves equal
    *and* edge-balanced layouts (the boundaries are data, not shape)."""
    sp_rows, sp_vec = P(theta_axes, vertex_axis), P(theta_axes)

    def hits(R, valid, S, starts):
        n_local = R.shape[1] if codec is None else codec.n_cols
        flat = S.reshape(-1)
        if vertex_axis is None:
            lidx, ok = flat, jnp.ones(flat.shape, jnp.bool_)
        else:
            shard = jax.lax.axis_index(vertex_axis)
            lo = starts[shard]
            lidx = flat - lo
            ok = (flat >= lo) & (flat < starts[shard + 1])
        lidx = jnp.clip(lidx, 0, n_local - 1)
        memb = (jnp.take(R, lidx, axis=1) > 0 if codec is None
                else codec.decode_cols(R, lidx))
        memb = (memb & ok[None, :]).reshape((R.shape[0],) + S.shape)
        hit = memb.any(axis=2)                       # (cap_local, Q)
        hit = _psum_if(hit.astype(jnp.int32), vertex_axis) > 0
        hit = hit & valid[:, None]
        counts = jax.lax.psum(
            hit.sum(axis=0).astype(jnp.float32), theta_axes)
        n_valid = jnp.maximum(
            jax.lax.psum(valid.sum(dtype=jnp.float32), theta_axes), 1.0)
        return counts / n_valid

    return jax.jit(shard_map(
        hits, mesh=mesh, in_specs=(sp_rows, sp_vec, P(), P()),
        out_specs=P()))


@functools.lru_cache(maxsize=None)
def _sharded_touch_kernel(mesh, theta_axes, vertex_axis, codec=None):
    """Reverse-touch (streaming invalidation) with both axes local: each
    tile checks the touched vertices inside its own column block against
    its own rows; only the ``(cap_local,)`` per-row partial hit bits cross
    the vertex axis (psum-or), and the result stays ``P(theta_axes)``.
    ``starts`` carries the partition block boundaries, as in
    `_sharded_hits_kernel`."""
    sp_rows, sp_vec = P(theta_axes, vertex_axis), P(theta_axes)

    def touch(R, verts, vmask, starts):
        n_local = R.shape[1] if codec is None else codec.n_cols
        if vertex_axis is None:
            lidx, ok = verts, vmask
        else:
            shard = jax.lax.axis_index(vertex_axis)
            lo = starts[shard]
            lidx = verts - lo
            ok = vmask & (verts >= lo) & (verts < starts[shard + 1])
        lidx = jnp.clip(lidx, 0, n_local - 1)
        memb = (jnp.take(R, lidx, axis=1) > 0 if codec is None
                else codec.decode_cols(R, lidx))
        local = (memb & ok[None, :]).any(axis=1)
        return _psum_if(local.astype(jnp.int32), vertex_axis) > 0

    return jax.jit(shard_map(
        touch, mesh=mesh, in_specs=(sp_rows, P(), P(), P()),
        out_specs=sp_vec))


@functools.lru_cache(maxsize=None)
def _sharded_index_kernels(mesh, theta_axes, vertex_axis, l_pad,
                           codec=None):
    """Per-tile C4 conversion: each (theta, vertex) tile rewrites its own
    ``(cap_local, n_local)`` bitmap block as ``(cap_local, l_pad)``
    *local-id* index lists (sentinel ``n_local``) — no cross-device
    traffic at all; the index view is born with the arena's own 2D
    layout.  ``l_pad`` is the per-vertex-shard C4 width (sized from the
    max *local* set size, which shrinks as vertex shards are added)."""
    sp_rows = P(theta_axes, vertex_axis)

    def convert(R):
        return bitmap_to_indices(R if codec is None else codec.decode(R),
                                 l_pad)

    return jax.jit(shard_map(
        convert, mesh=mesh, in_specs=(sp_rows,), out_specs=sp_rows))


@functools.lru_cache(maxsize=None)
def _sharded_localmax_kernel(mesh, theta_axes, vertex_axis, codec=None):
    """Max per-vertex-shard set size over valid rows — the statistic the
    per-shard C4 threshold keys on.  Tile-local row popcounts, one scalar
    psum-max; nothing row- or column-sized crosses devices."""
    sp_rows, sp_vec = P(theta_axes, vertex_axis), P(theta_axes)

    def localmax(R, valid):
        sz = (R.sum(axis=1, dtype=jnp.int32) if codec is None
              else codec.row_popcount(R))
        sz = sz * valid.astype(jnp.int32)
        m = jnp.max(sz, initial=0)
        axes = theta_axes + ((vertex_axis,) if vertex_axis else ())
        return jax.lax.pmax(m, axes)[None]

    return jax.jit(shard_map(
        localmax, mesh=mesh, in_specs=(sp_rows, sp_vec), out_specs=P()))


@functools.lru_cache(maxsize=None)
def _sharded_grow_kernel(mesh, theta_axes, vertex_axis, pad):
    """Per-shard capacity doubling: every tile zero-pads its own
    ``(cap_local, n_local)`` block to ``(cap_local + pad, n_local)``
    locally (no gather, no cross-device traffic; the copy itself is not
    donatable because the output shape differs, but doubling amortizes
    it).  Live bits pad with True (unfilled slots are live-by-default)."""
    sp_rows, sp_vec = P(theta_axes, vertex_axis), P(theta_axes)

    def grow(R, sizes, live):
        return (jnp.pad(R, ((0, pad), (0, 0))),
                jnp.pad(sizes, ((0, pad),)),
                jnp.pad(live, ((0, pad),), constant_values=True))

    return jax.jit(shard_map(grow, mesh=mesh,
                             in_specs=(sp_rows, sp_vec, sp_vec),
                             out_specs=(sp_rows, sp_vec, sp_vec)))


@functools.lru_cache(maxsize=None)
def _sharded_stream_kernels(mesh, theta_axes, vertex_axis, codec=None):
    """Compiled per-(mesh, axes) streaming row-lifecycle kernels.

    Returns ``(kill, replace, compact)``, each tile-local in *both* axes
    (the kill contribution, the replace scatter, and the compaction
    permutation all act on a tile's own ``(cap_local, n_local)`` block;
    on 2D meshes the only vertex-axis collective is the ``(K,)`` psum of
    replacement row sizes — a reduced quantity, never arena columns):
      * ``kill(R, counter, sizes, live, dead)`` — subtract the dead local
        rows' contribution from the tile's counter partial, zero their
        sizes, clear their live bits.  counter/sizes/live donated.
      * ``replace(R, counter, sizes, live, offs, idx, rows)`` — ``idx``
        arrives replicated and ``rows`` vertex-sharded ``P(None,
        vertex_axis)``; each tile scatters its own column slice of the
        rows whose global slot falls in its theta block (out-of-block
        targets are dropped), revives their live bits, and adds its share
        of the contribution to its counter partial.  All state donated.
      * ``compact(R, sizes, live, counts)`` — stable-partition the live
        local rows to the tile's arena head and return the new per-shard
        counts; dead slots zero out.  The permutation depends only on
        ``P(theta_axes)`` state, so every vertex tile of a theta shard
        permutes its columns identically.  R/sizes donated.
    """
    sp_rows, sp_vec = P(theta_axes, vertex_axis), P(theta_axes)

    def kill(R, counter, sizes, live, dead):
        bits = R if codec is None else codec.decode(R)
        contrib = dead.astype(jnp.float32) @ bits.astype(jnp.float32)
        counter = counter - contrib.astype(jnp.int32)[None, :]
        return counter, jnp.where(dead, 0, sizes), live & ~dead

    kill_fn = jax.jit(
        shard_map(kill, mesh=mesh,
                  in_specs=(sp_rows, sp_rows, sp_vec, sp_vec, sp_vec),
                  out_specs=(sp_rows, sp_vec, sp_vec)),
        donate_argnums=(1, 2, 3))

    def replace(R, counter, sizes, live, offs, idx, rows):
        cap_local = R.shape[0]
        lidx = idx - offs[0]
        ok = (lidx >= 0) & (lidx < cap_local)
        tgt = jnp.where(ok, lidx, cap_local)        # OOB -> dropped
        stored = rows if codec is None else codec.encode(rows)
        R = R.at[tgt].set(stored, mode="drop")
        contrib = (rows * ok[:, None]).sum(axis=0, dtype=jnp.int32)
        counter = counter + contrib[None, :]
        row_sizes = _psum_if(rows.sum(axis=1, dtype=jnp.int32), vertex_axis)
        sizes = sizes.at[tgt].set(row_sizes, mode="drop")
        live = live.at[tgt].set(True, mode="drop")
        return R, counter, sizes, live

    replace_fn = jax.jit(
        shard_map(replace, mesh=mesh,
                  in_specs=(sp_rows, sp_rows, sp_vec, sp_vec, sp_vec,
                            P(None), P(None, vertex_axis)),
                  out_specs=(sp_rows, sp_rows, sp_vec, sp_vec)),
        donate_argnums=(0, 1, 2, 3))

    def comp(R, sizes, live, counts):
        cap_local = R.shape[0]
        iota = jnp.arange(cap_local, dtype=jnp.int32)
        keep = (iota < counts[0]) & live
        perm = jnp.argsort(jnp.where(keep, 0, 1) * cap_local + iota)
        newvalid = iota < keep.sum(dtype=jnp.int32)
        R = jnp.where(newvalid[:, None], R[perm], 0)
        sizes = jnp.where(newvalid, sizes[perm], 0)
        return R, sizes, keep.sum(dtype=jnp.int32)[None]

    comp_fn = jax.jit(
        shard_map(comp, mesh=mesh,
                  in_specs=(sp_rows, sp_vec, sp_vec, sp_vec),
                  out_specs=(sp_rows, sp_vec, sp_vec)),
        donate_argnums=(0, 1))

    return kill_fn, replace_fn, comp_fn


@functools.lru_cache(maxsize=None)
def _sharded_recode_kernel(mesh, theta_axes, vertex_axis, codec_from,
                           codec_to):
    """Tile-local arena re-encode (``codec_from`` -> ``codec_to``) — the
    compress-ladder morph and token-width growth both route here.  Each
    tile decodes and re-encodes its own block; the decoded bits are a jit
    temporary, nothing crosses devices, and the output is born in the
    arena's own ``P(theta_axes, vertex_axis)`` layout (not donatable —
    the at-rest width changes)."""
    sp_rows = P(theta_axes, vertex_axis)

    def recode(R):
        return codec_to.encode(codec_from.decode(R))

    return jax.jit(shard_map(
        recode, mesh=mesh, in_specs=(sp_rows,), out_specs=sp_rows))


@functools.lru_cache(maxsize=None)
def _sharded_tokneed_kernel(mesh, theta_axes, vertex_axis, codec=None):
    """Max per-tile token count of an arena (or batch) — the statistic
    that sizes a `TokenCodec`'s ``s_pad`` before a compress-ladder morph
    or a token-width growth.  Tile-local `tokens_needed` row maxima, one
    scalar pmax over every mesh axis.  ``codec`` decodes an encoded
    resident arena first; None reads raw bit rows (a staged batch)."""
    from repro.core.pack.codec import tokens_needed
    sp_rows = P(theta_axes, vertex_axis)

    def need(X):
        bits = X if codec is None else codec.decode(X)
        m = jnp.max(tokens_needed(bits), initial=0)
        axes = theta_axes + ((vertex_axis,) if vertex_axis else ())
        return jax.lax.pmax(m, axes)[None]

    return jax.jit(shard_map(
        need, mesh=mesh, in_specs=(sp_rows,), out_specs=P()))


def _tile_codec(kind: str, n_cols: int, s_pad=None):
    """Per-tile codec for encoded sharded arenas (lazy import — the pack
    package itself imports this module)."""
    from repro.core.pack.codec import MIN_TOKEN_PAD, codec_for
    return codec_for(kind, n_cols,
                     MIN_TOKEN_PAD if s_pad is None else int(s_pad))


def _pad_cols(rows, n_pad: int):
    """Zero-pad ``(B, n)`` uint8 rows to the vertex-padded column count
    (a no-op on 1D/single-vertex layouts where ``n_pad == n``)."""
    pad = n_pad - rows.shape[1]
    if pad == 0:
        return rows
    return jnp.concatenate(
        [rows, jnp.zeros((rows.shape[0], pad), rows.dtype)], axis=1)


class ShardedStore:
    """Mesh-sharded dense bitmap arena — the paper's C1 RRR-set
    partitioning applied to the *store itself*, not just selection, on a
    mesh that can be 1D (theta only) or genuinely 2D (theta x vertex).

    State layout over ``Dt = prod(mesh.shape[a] for a in theta_axes)``
    theta shards and ``Dv = mesh.shape[vertex_axis]`` vertex shards
    (``Dv = 1`` when ``vertex_axis`` is None — the historical 1D layout):

      * ``R``       — ``(Dt * cap_local, n_pad) uint8``,
        ``P(theta_axes, vertex_axis)``: tile ``(t, v)`` owns rows
        ``[t * cap_local, (t+1) * cap_local)`` x columns
        ``[v * n_local, (v+1) * n_local)``, where ``n_local`` is the
        padded tile width of the store's `VertexPartition` and ``n_pad =
        Dv * n_local`` (pad columns carry no vertex and stay all-zero).
        The full ``(theta, n)`` arena never exists on one device;
        per-device memory is ``cap_local * n_local`` bytes, so **theta
        scales with the theta axis and n with the vertex axis** — graph
        size scales with the mesh, not with one device.  The layout may
        be the canonical equal blocks (``vertex_partition``; tile ``v``
        holds vertices ``[v * n_local, (v+1) * n_local)``) or an
        edge-balanced one (``balanced_vertex_partition``; tile ``v``
        holds the contiguous run ``[starts[v], starts[v+1])`` with
        data-dependent boundaries, padded to ``n_local`` columns) — both
        shared with selection and streaming reverse-touch through
        ``self.partition``.
      * ``sizes``   — ``(Dt * cap_local,) int32``, ``P(theta_axes)``
        (replicated over the vertex axis), aligned with ``R`` rows.
      * counter     — per-tile partials ``(Dt, n_pad) int32``,
        ``P(theta_axes, vertex_axis)`` — tile ``(t, v)`` counts its own
        rows over its own columns (the ``(Dt, Dv, n/Dv)`` partial layout,
        stored as a 2D array); the ``counter`` property reduces them to
        the global fused counter for host consumers (selection never
        needs it — it reduces tile-locally and psums).
      * row counts  — ``(Dt,) int32``, ``P(theta_axes)``, plus a host
        mirror that drives growth logic without device syncs.

    ``add_batch`` splits each sampled batch into Dt equal row blocks
    (zero-padding rows to ``ceil(B / Dt) * Dt`` and columns to ``n_pad``;
    pad rows are masked, not counted) and runs the donated shard_map
    write kernel: each tile writes its (row block, column block) of the
    batch into its local arena slot and fuses its local size / counter
    updates.  Capacity grows *per shard* by amortized doubling
    (``cap_local`` is a power of two), so jit retraces stay O(log theta)
    and growth copies are device-local.

    Placement across tiles is a layout detail: selection, ``hits``
    and the global counter are permutation-invariant over rows and exact
    integer sums over columns, so a `ShardedStore` fed the same sample
    stream as a `BitmapStore` yields bit-identical selections on any
    mesh shape — 1 device, 1D, or 2D.

    ``snapshot``/``restore`` go through ``state()``/``from_state``: the
    snapshot stores valid rows *compacted* on host (shard order, vertex
    padding stripped), so a snapshot taken on one layout restores onto
    any other — none <-> 1D <-> 2D — or into a plain `BitmapStore` when
    no mesh is available (see `store_from_state`).
    """

    def __init__(self, n: int, *, mesh, theta_axes=("data",),
                 vertex_axis=None, capacity: int = MIN_CAPACITY,
                 policy: StorePressurePolicy | None = None,
                 partition: VertexPartition | None = None,
                 codec: str = "bitmap", s_pad=None):
        if mesh is None:
            raise ValueError("ShardedStore needs a jax.sharding.Mesh")
        if isinstance(theta_axes, str):
            theta_axes = (theta_axes,)
        self.n = int(n)
        self.mesh = mesh
        self.theta_axes = tuple(theta_axes)
        self.vertex_axis = vertex_axis
        self.D = int(np.prod([mesh.shape[a] for a in self.theta_axes]))
        self.Dv = int(mesh.shape[vertex_axis]) if vertex_axis else 1
        if partition is None:
            partition = vertex_partition(self.n, self.Dv)
        elif partition.n != self.n or partition.shards != self.Dv:
            raise ValueError(
                f"partition covers n={partition.n} over "
                f"{partition.shards} shards; this store needs n={self.n} "
                f"over Dv={self.Dv}")
        self.partition = partition
        self.n_local, self.n_pad = partition.block, partition.n_pad
        # the per-tile at-rest codec: "bitmap" keeps the historical raw
        # layout; "packed"/"compressed" store each (theta, vertex) tile
        # encoded — every kernel decodes tile-locally (IMPack)
        self.codec = _tile_codec(codec, self.n_local, s_pad)
        self.w_local = self.codec.width
        self.w_pad = self.Dv * self.w_local
        self.cap_local = next_pow2(-(-int(capacity) // self.D))
        self.version = 0
        self.policy = policy
        self.track_remaps = False
        self._remaps: list[np.ndarray] = []
        self._sh_rows = NamedSharding(
            mesh, P(self.theta_axes, vertex_axis))
        self._sh_vec = NamedSharding(mesh, P(self.theta_axes))
        self._sh_rep = NamedSharding(mesh, P())
        self._sh_vrows = NamedSharding(mesh, P(None, vertex_axis))
        # partition block boundaries, replicated for the starts-aware
        # kernels; balanced layouts also carry the column gather maps
        # (global order <-> padded layout) — host-precomputed, O(n)
        self._starts_dev = jax.device_put(
            jnp.asarray(partition.starts, jnp.int32), self._sh_rep)
        if partition.is_equal:
            self._col_src = self._col_ok = self._cols_from_pad = None
        else:
            src = partition.source_cols()
            self._col_src = jnp.asarray(
                np.clip(src, 0, max(self.n - 1, 0)), jnp.int32)
            self._col_ok = jnp.asarray((src < self.n).astype(np.uint8))
            self._cols_from_pad = partition.padded_cols()
        self._counts_host = np.zeros((self.D,), np.int64)
        if policy is not None:
            cap = policy.row_cap(self._row_bytes())
            if cap // self.D < 1:
                raise ValueError(
                    f"policy row cap {cap} is below one row per shard "
                    f"(D={self.D})")
            self.cap_local = min(self.cap_local, cap // self.D)
        self._live_host = np.ones((self.D * self.cap_local,), bool)
        self.R = _sharded_zeros(
            (self.D * self.cap_local, self.w_pad), self.codec.dtype,
            self._sh_rows)
        self.sizes = _sharded_zeros(
            (self.D * self.cap_local,), jnp.int32, self._sh_vec)
        self.live = _sharded_ones(
            (self.D * self.cap_local,), jnp.bool_, self._sh_vec)
        self._counter = _sharded_zeros(
            (self.D, self.n_pad), jnp.int32, self._sh_rows)
        self._counts = _sharded_zeros((self.D,), jnp.int32, self._sh_vec)
        self._bind_kernels()
        self._idx_cache = None      # (version, l_pad) -> sharded R_idx

    def _bind_kernels(self):
        """(Re)bind the per-(mesh, axes, codec) compiled kernels — called
        at construction and after every codec morph.  ``_codec_arg`` is
        None for the raw bitmap layout so the historical kernel cache
        keys keep serving bitmap stores unchanged."""
        codec = None if self.codec.kind == "bitmap" else self.codec
        self._codec_arg = codec
        self._write_fn, self._valid_fn = _sharded_write_kernels(
            self.mesh, self.theta_axes, self.vertex_axis, codec)
        self._kill_fn, self._replace_fn, self._compact_fn = (
            _sharded_stream_kernels(
                self.mesh, self.theta_axes, self.vertex_axis, codec))
        self._hits_fn = _sharded_hits_kernel(
            self.mesh, self.theta_axes, self.vertex_axis, codec)

    def _row_bytes(self) -> int:
        """Physical at-rest bytes per global row — what byte-budget
        pressure policies meter.  Bitmap rows keep the historical
        logical-``n`` accounting (1 byte/vertex); encoded rows charge the
        padded tile width times the codec element size."""
        if self.codec.kind == "bitmap":
            return self.n
        return self.w_pad * jnp.dtype(self.codec.dtype).itemsize

    def _set_codec(self, codec):
        """Morph the resident arena to ``codec`` in place (tile-local
        decode/re-encode), rebind kernels, and invalidate derived
        views."""
        if codec == self.codec:
            return
        rec = _sharded_recode_kernel(
            self.mesh, self.theta_axes, self.vertex_axis, self.codec, codec)
        self.R = rec(self.R)
        self.codec = codec
        self.w_local = codec.width
        self.w_pad = self.Dv * self.w_local
        self._bind_kernels()
        self._idx_cache = None
        self.version += 1

    def _widen_tokens(self, rows_bits=None):
        """Grow the token codec's ``s_pad`` to fit ``rows_bits`` (a
        staged sharded bit batch; None re-measures the resident arena) —
        the `IndexStore` ``_widen`` analogue for compressed tiles."""
        from repro.core.pack.codec import MIN_TOKEN_PAD, TokenCodec
        if rows_bits is None:
            fn = _sharded_tokneed_kernel(
                self.mesh, self.theta_axes, self.vertex_axis,
                self._codec_arg)
            need = int(np.asarray(fn(self.R))[0])
        else:
            fn = _sharded_tokneed_kernel(
                self.mesh, self.theta_axes, self.vertex_axis, None)
            need = int(np.asarray(fn(rows_bits))[0])
        s_new = next_pow2(max(need, MIN_TOKEN_PAD), self.codec.s_pad)
        if s_new > self.codec.s_pad:
            self._set_codec(TokenCodec(self.n_local, s_new))

    def _compress_step(self) -> bool:
        """One rung up the policy's compress-before-evict ladder (see
        `StorePressurePolicy.ladder`): morph the arena to the next
        denser at-rest codec and report whether anything changed."""
        ladder = self.policy.ladder if self.policy is not None else ()
        nxt = _ladder_next(self.codec.kind, ladder)
        if nxt is None:
            return False
        if nxt == "compressed":
            from repro.core.pack.codec import MIN_TOKEN_PAD, TokenCodec
            fn = _sharded_tokneed_kernel(
                self.mesh, self.theta_axes, self.vertex_axis,
                self._codec_arg)
            need = int(np.asarray(fn(self.R))[0])
            new = TokenCodec(self.n_local,
                             next_pow2(max(need, 1), MIN_TOKEN_PAD))
        else:
            new = _tile_codec(nxt, self.n_local)
        self._set_codec(new)
        obs.counter("store.compress_steps").add(1)
        return True

    # ------------------------------------------------------------ shape ----

    @property
    def representation(self) -> str:
        """The at-rest tile codec kind (``"bitmap"``/``"packed"``/
        ``"compressed"``) — what engines dispatch selection on."""
        return self.codec.kind

    @property
    def capacity(self) -> int:
        """Global row capacity (``D * cap_local``)."""
        return self.D * self.cap_local

    @property
    def count(self) -> int:
        """Total stored RRR sets across all shards."""
        return int(self._counts_host.sum())

    @property
    def counts(self) -> np.ndarray:
        """Per-shard valid row counts ``(D,)`` (host copy)."""
        return self._counts_host.copy()

    def _filled_host(self) -> np.ndarray:
        """Host ``(D * cap_local,) bool`` per-shard fill-prefix mask."""
        iota = np.arange(self.cap_local)
        return (iota[None, :] < self._counts_host[:, None]).reshape(-1)

    @property
    def dead(self) -> int:
        """Filled rows whose live bit is cleared (stale/evicted)."""
        return int((self._filled_host() & ~self._live_host).sum())

    @property
    def live_count(self) -> int:
        """Filled rows that are still live (the streaming effective
        theta)."""
        return self.count - self.dead

    @property
    def row_cap(self) -> int | None:
        """Attainable policy row capacity, or None when unbounded.
        Floored to a multiple of the shard count (each shard holds
        ``cap // D`` rows) — reporting the raw policy cap would make
        ``extend``-to-cap loops spin forever on non-divisible caps."""
        if self.policy is None:
            return None
        cap = self.policy.row_cap(self._row_bytes())
        return (cap // self.D) * self.D

    def live_mask(self) -> jnp.ndarray:
        """Sharded ``(D * cap_local,) bool`` live bits."""
        return self.live

    def drain_remaps(self) -> list[np.ndarray]:
        """Pop slot remaps recorded since the last drain (compactions
        *and* per-shard growth — growth renumbers global slots because
        shard blocks move apart).  Only populated while ``track_remaps``
        is set."""
        out, self._remaps = self._remaps, []
        return out

    @property
    def counter(self) -> jnp.ndarray:
        """Global fused counter ``(n,) int32`` — reduces the per-tile
        partials over the theta axis and strips the vertex padding
        columns (an all-reduce; host/reporting use only, the selection
        kernels consume the partials tile-locally).  Always in *global*
        vertex order, whatever the column layout."""
        total = self._counter.sum(axis=0)
        if self.partition.is_equal:
            return total[:self.n]
        return jnp.take(total, jnp.asarray(self._cols_from_pad), axis=0)

    @property
    def batch_sharding(self) -> NamedSharding:
        """Sharding a sampler should place its ``(B, n)`` batch with so
        the store write is a pure device-local slice update (rows
        block-partitioned over ``theta_axes``, vertex columns over
        ``vertex_axis`` when the mesh is 2D) — each device samples
        exactly the (row, column) tile its arena shard will store.
        Under a *balanced* partition, GSPMD's equal column tiling of the
        ``(B, n)`` batch does not coincide with the arena's
        data-dependent boundaries; ``add_batch``'s layout gather performs
        the boundary re-tiling on the (small) batch, so traversal keeps
        its shape-stable equal tiling (and with it the positional coin
        streams) while the resident arena stays edge-balanced."""
        return self._sh_rows

    # ---------------------------------------------------------- writing ----

    def _layout_cols(self, rows):
        """Rearrange ``(B, n)`` global-order rows into the arena's padded
        column layout ``(B, n_pad)``: a zero-pad for the equal-block
        layout (columns already line up), a masked column gather for
        balanced layouts (pad columns land all-zero)."""
        if self.partition.is_equal:
            return _pad_cols(rows, self.n_pad)
        return (jnp.take(rows, self._col_src, axis=1)
                * self._col_ok[None, :].astype(rows.dtype))

    def _grow_rows(self, incoming: int):
        need = int(self._counts_host.max(initial=0)) + incoming
        new_cap = next_pow2(need, self.cap_local)
        cap = self.row_cap
        if cap is not None:
            new_cap = min(new_cap, max(cap // self.D, self.cap_local))
        if new_cap == self.cap_local:
            return
        grow = _sharded_grow_kernel(
            self.mesh, self.theta_axes, self.vertex_axis,
            new_cap - self.cap_local)
        self.R, self.sizes, self.live = grow(self.R, self.sizes, self.live)
        # shard blocks moved apart: global slot d*cap_local+i is now
        # d*new_cap+i — record the renumbering for provenance trackers
        old_cap = self.cap_local
        live_host = np.ones((self.D * new_cap,), bool)
        remap = np.empty((self.D * old_cap,), np.int64)
        for d in range(self.D):
            remap[d * old_cap:(d + 1) * old_cap] = (
                d * new_cap + np.arange(old_cap))
            live_host[d * new_cap:d * new_cap + old_cap] = (
                self._live_host[d * old_cap:(d + 1) * old_cap])
        self._live_host = live_host
        if self.track_remaps:
            self._remaps.append(remap)
        self.cap_local = new_cap

    def _ensure_room(self, b: int):
        """Per-shard pressure enforcement: compact away dead rows first,
        then climb the policy's compress ladder (each morph shrinks
        ``_row_bytes`` and so *raises* the byte-budget row cap), and only
        then evict each over-full shard's oldest live rows FIFO."""
        cap = self.row_cap
        if cap is None:
            return
        local_cap = cap // self.D
        if (int(self._counts_host.max(initial=0)) + b > local_cap
                and self.dead):
            self.compact()
        while (int(self._counts_host.max(initial=0)) + b > local_cap
               and self._compress_step()):
            cap = self.row_cap
            local_cap = cap // self.D
        if b > local_cap:
            raise ValueError(
                f"batch of {b} rows per shard exceeds the per-shard "
                f"policy cap of {local_cap} (row cap {cap} over "
                f"{self.D} shards)")
        if int(self._counts_host.max(initial=0)) + b <= local_cap:
            return
        self.compact()
        over = self._counts_host + b - local_cap
        if (over > 0).any():
            mask = np.zeros((self.D * self.cap_local,), bool)
            for d in range(self.D):
                if over[d] > 0:
                    lo = d * self.cap_local
                    mask[lo:lo + int(over[d])] = True
            evicted = self.kill_rows(mask)
            obs.counter("store.rows_evicted").add(evicted)
            self.compact()

    def add_batch(self, visited, counter=None) -> np.ndarray:
        """Append ``visited (B, n) uint8`` rows, block-split across shards.

        Shard ``d`` receives rows ``[d*b, (d+1)*b)`` of the (zero-padded)
        batch, where ``b = ceil(B / D)``, and writes them at its local
        offset in place — the arena, sizes, counter and counts buffers are
        all donated, so outstanding views are invalidated.  ``counter`` is
        accepted for `RRRStore` API parity but ignored: the fused C3
        contribution is recomputed *inside* the write kernel from each
        shard's own rows, keeping the count device-local.  Returns the
        global slot index of each batch row (provenance for streaming
        consumers); under a `StorePressurePolicy` the write may first
        compact and evict per shard.
        """
        del counter  # recomputed shard-locally inside the write kernel
        with obs.span("store.write", tier="store", kind="sharded"):
            visited = jnp.asarray(visited).astype(jnp.uint8)
            B = int(visited.shape[0])
            if B == 0:
                return np.zeros((0,), np.int64)
            visited = self._layout_cols(visited)
            b = -(-B // self.D)
            if b * self.D != B:
                visited = jnp.concatenate(
                    [visited,
                     jnp.zeros((b * self.D - B, self.n_pad), jnp.uint8)])
            # no-op when the sampler already placed the batch with
            # ``batch_sharding``; otherwise reshards the (small) batch only
            visited = jax.device_put(visited, self._sh_rows)
            if self.codec.kind == "compressed":
                self._widen_tokens(visited)
            kind_before = self.codec.kind
            self._ensure_room(b)
            if (self.codec.kind == "compressed"
                    and kind_before != "compressed"):
                # the pressure ladder just morphed to tokens sized off the
                # resident rows — the incoming batch may need wider ones
                self._widen_tokens(visited)
            self._grow_rows(b)
            incs_np = np.clip(B - np.arange(self.D) * b, 0, b).astype(np.int32)
            incs = jax.device_put(jnp.asarray(incs_np), self._sh_vec)
            slots = np.empty((B,), np.int64)
            for d in range(self.D):
                i0 = d * b
                cnt = int(incs_np[d])
                slots[i0:i0 + cnt] = (d * self.cap_local
                                      + self._counts_host[d] + np.arange(cnt))
            self.R, self.sizes, self._counter, self._counts = self._write_fn(
                self.R, self.sizes, self._counter, self._counts, visited, incs)
            self._counts_host += incs_np
        self._note_write(B)
        return slots

    def _note_write(self, B: int):
        """Host-side bookkeeping after ``B`` rows landed (``count`` is
        derived from ``_counts_host``, so unlike the arena stores only
        the version bump and gauges live here).  Shared by `add_batch`
        and the fused write chain (`repro.core.fused`)."""
        self.version += 1
        if obs.enabled():
            # host arithmetic on shard shapes only — never a device read;
            # byte gauges report *physical* at-rest bytes (the encoded
            # tile width), not the logical uint8 bitmap footprint
            itemsize = jnp.dtype(self.codec.dtype).itemsize
            arena = self.D * self.cap_local * self.w_pad * itemsize
            obs.counter("store.rows_written").add(B)
            obs.gauge("store.occupancy").set(self.count / self.capacity)
            obs.gauge("store.arena_bytes").set(arena)
            obs.gauge("store.bytes_per_device").set(
                self.cap_local * self.w_local * itemsize)
            obs.gauge("store.compress_ratio").set(
                self.D * self.cap_local * self.n_pad / max(arena, 1))

    # ----------------------------------------------------- row lifecycle ----

    def kill_rows(self, dead) -> int:
        """Mark rows dead shard-locally: each shard subtracts its dead
        rows' contribution from its own counter partial (nothing crosses
        devices).  ``dead`` is a global ``(D * cap_local,) bool`` mask
        (host or device); bits outside filled-and-live rows are ignored.
        Returns the number of newly dead rows."""
        dead_host = np.asarray(dead).astype(bool)
        dead_host &= self._filled_host() & self._live_host
        k = int(dead_host.sum())
        if k == 0:
            return 0
        dead_dev = jax.device_put(jnp.asarray(dead_host), self._sh_vec)
        self._counter, self.sizes, self.live = self._kill_fn(
            self.R, self._counter, self.sizes, self.live, dead_dev)
        self._live_host &= ~dead_host
        self.version += 1
        obs.counter("store.rows_killed").add(k)
        return k

    def replace_rows(self, idx, rows) -> None:
        """Overwrite dead slots with fresh rows (streaming refresh).
        ``idx`` is replicated into the kernel and ``rows`` enters
        vertex-sharded (``P(None, vertex_axis)``); each tile scatters
        only its own column slice of the targets inside its theta block.
        Targets must be filled, dead slots (enforced on host); ``idx``
        entries of -1 are padding (the batch pads to a power of two to
        bound retraces)."""
        idx = np.asarray(idx, np.int64)
        real = idx >= 0
        k = int(real.sum())
        if k == 0:
            return
        filled = self._filled_host()
        if ((idx[real] >= self.D * self.cap_local).any()
                or not filled[idx[real]].all()
                or self._live_host[idx[real]].any()):
            raise ValueError(
                "replace_rows targets must be filled, dead slots "
                "(kill_rows them first)")
        with obs.span("store.write", tier="store", kind="sharded-replace"):
            rows = self._layout_cols(jnp.asarray(rows).astype(jnp.uint8))
            if self.codec.kind == "compressed":
                from repro.core.pack.codec import (
                    MIN_TOKEN_PAD, TokenCodec, tokens_needed)
                need = int(jnp.max(
                    tokens_needed(rows.reshape(-1, self.n_local)),
                    initial=0))
                s_new = next_pow2(max(need, MIN_TOKEN_PAD),
                                  self.codec.s_pad)
                if s_new > self.codec.s_pad:
                    self._set_codec(TokenCodec(self.n_local, s_new))
            pad = next_pow2(idx.shape[0], 1) - idx.shape[0]
            if pad:
                idx = np.concatenate([idx, np.full(pad, -1, np.int64)])
                rows = jnp.concatenate(
                    [rows, jnp.zeros((pad, rows.shape[1]), jnp.uint8)])
                real = idx >= 0
            rows = jax.device_put(rows, self._sh_vrows)
            idx_dev = jax.device_put(jnp.asarray(idx, jnp.int32),
                                     self._sh_rep)
            offs = jax.device_put(
                jnp.arange(self.D, dtype=jnp.int32) * self.cap_local,
                self._sh_vec)
            self.R, self._counter, self.sizes, self.live = self._replace_fn(
                self.R, self._counter, self.sizes, self.live, offs, idx_dev,
                rows)
            self._live_host[idx[real]] = True
            self.version += 1
        obs.counter("store.rows_replaced").add(k)

    def compact(self) -> np.ndarray | None:
        """Rewrite each shard's live rows to its arena-block head in
        place, reclaiming dead slots shard-locally.  Returns the global
        old->new slot remap (-1 for reclaimed), or None if no shard had
        dead rows."""
        if self.dead == 0:
            return None
        keep = self._filled_host() & self._live_host
        self.R, self.sizes, self._counts = self._compact_fn(
            self.R, self.sizes, self.live, self._counts)
        self.live = _sharded_ones(
            (self.D * self.cap_local,), jnp.bool_, self._sh_vec)
        remap = np.full((self.D * self.cap_local,), -1, np.int64)
        for d in range(self.D):
            lo = d * self.cap_local
            kd = keep[lo:lo + self.cap_local]
            nkeep = int(kd.sum())
            remap[lo:lo + self.cap_local][kd] = lo + np.arange(nkeep)
            self._counts_host[d] = nkeep
        self._live_host = np.ones((self.D * self.cap_local,), bool)
        self.version += 1
        obs.counter("store.compactions").add(1)
        if self.track_remaps:
            self._remaps.append(remap)
        return remap

    # ---------------------------------------------------------- reading ----

    def valid_mask(self) -> jnp.ndarray:
        """Sharded ``(D * cap_local,) bool`` mask of filled *live* rows
        (the per-shard prefix ``local_iota < counts[shard]``, minus any
        rows killed by streaming invalidation/eviction)."""
        return self._valid_fn(self._counts, self.sizes) & self.live

    def view(self) -> StoreView:
        """`StoreView` over the *sharded* arena: ``R`` keeps its
        ``P(theta_axes, vertex_axis)`` layout and ``valid`` its
        ``P(theta_axes)`` layout, so sharded selection strategies consume
        the tiles natively (zero resharding on entry).  Aliases live
        buffers — consume before the next ``add_batch``."""
        return StoreView(self.representation, self.R, self.valid_mask(),
                         self.n, self.count)

    def hits(self, S) -> jnp.ndarray:
        """Covered fraction per query: ``S (Q, L) int32`` -> ``(Q,) f32``.
        Each tile tests membership of the queried vertices inside its own
        column block against its own rows; only per-(row, query) hit bits
        cross the vertex axis and per-query counts the theta axis (never
        arena rows or columns)."""
        with obs.span("count", tier="store", kind="sharded"):
            return self._hits_fn(self.R, self.valid_mask(),
                                 jnp.asarray(S, jnp.int32), self._starts_dev)

    def coverage_stats(self) -> tuple[float, int]:
        """(avg fractional set coverage, max set size) over live stored
        sets (killed rows have their sizes zeroed)."""
        return _coverage_stats(self.sizes, self.live_count, self.n)

    def max_local_size(self) -> int:
        """Max per-vertex-shard set size over valid rows — the statistic
        the per-shard C4 representation threshold keys on (each vertex
        shard sees only its ``n_local`` columns of every set, so local
        sizes shrink as vertex shards are added).  Cached per store
        version: one select calls this twice (representation choice,
        then index-view width) and must not launch the collective kernel
        and block on the host both times."""
        cache = getattr(self, "_localmax_cache", None)
        if cache is not None and cache[0] == self.version:
            return cache[1]
        fn = _sharded_localmax_kernel(
            self.mesh, self.theta_axes, self.vertex_axis, self._codec_arg)
        val = int(np.asarray(fn(self.R, self.valid_mask()))[0])
        self._localmax_cache = (self.version, val)
        return val

    def index_view(self, l_pad: int) -> StoreView:
        """Sharded C4 index view: each tile rewrites its own bitmap block
        as ``(cap_local, l_pad)`` *local-id* index lists (sentinel
        ``n_local``), entirely device-local — the view keeps the arena's
        ``P(theta_axes, vertex_axis)`` layout, so the sharded-sparse
        selection strategy consumes it with zero resharding.  Cached
        until the arena next changes."""
        key = (self.version, int(l_pad))
        if self._idx_cache is None or self._idx_cache[0] != key:
            fn = _sharded_index_kernels(
                self.mesh, self.theta_axes, self.vertex_axis, int(l_pad),
                self._codec_arg)
            self._idx_cache = (key, fn(self.R))
        return StoreView("indices", self._idx_cache[1], self.valid_mask(),
                         self.n, self.count)

    def rows_touching_cols(self, verts, vmask) -> jnp.ndarray:
        """``(capacity,) bool`` rows whose bitmap has a set bit in any
        masked ``verts`` column — the streaming reverse-touch query,
        tile-local in both axes (`repro.stream.invalidate` dispatches
        here on sharded stores)."""
        fn = _sharded_touch_kernel(
            self.mesh, self.theta_axes, self.vertex_axis, self._codec_arg)
        return fn(self.R, jnp.asarray(verts, jnp.int32),
                  jnp.asarray(vmask, jnp.bool_), self._starts_dev)

    # ------------------------------------------------------ checkpointing ----

    def state(self) -> dict:
        """Host snapshot pytree (kind tag ``"sharded"``): the *live*
        valid rows of every shard compacted into a contiguous
        ``(live_count, n)`` array (shard order, vertex padding columns
        stripped) — stale/killed rows are dropped at snapshot time — so
        restore redistributes onto any mesh layout (none <-> 1D <-> 2D),
        the elastic layout `checkpoint.store` promises.  This is the one
        deliberate host gather in the store's life cycle.  Rows are put
        back in *global* vertex-id order whatever the column layout, so
        a snapshot taken under a balanced partition restores onto equal
        blocks (or different balanced boundaries) unchanged — restore
        re-partitions elastically.  Encoded (packed/compressed) arenas
        are decoded per vertex tile on host first — snapshot rows are
        always the *bit* interchange format, so any at-rest codec
        restores into any other (the ``rep`` tag records the source
        representation for restore-target defaulting)."""
        R = np.asarray(self.R)
        if self.codec.kind != "bitmap":
            R = np.concatenate(
                [self.codec.decode_np(
                    R[:, v * self.w_local:(v + 1) * self.w_local])
                 for v in range(self.Dv)], axis=1)
        R = (R[:, :self.n] if self.partition.is_equal
             else R[:, self._cols_from_pad])
        sizes = np.asarray(self.sizes)
        keep = self._filled_host() & self._live_host
        live_count = int(keep.sum())
        return {
            "kind": np.asarray("sharded"),
            "rep": np.asarray(self.codec.kind),
            "n": np.int64(self.n),
            "count": np.int64(live_count),
            "R": (R[keep] if live_count
                  else np.zeros((0, self.n), np.uint8)),
            "sizes": (sizes[keep] if live_count
                      else np.zeros((0,), np.int32)),
            "counter": np.asarray(self.counter),
        }

    # rows staged per add_batch during restore: bounds the transient
    # single-device footprint of the host->device feed to CHUNK * n bytes
    # (the resident arena itself is born sharded and never gathers)
    RESTORE_CHUNK = 4096

    @classmethod
    def from_state(cls, st, *, mesh, theta_axes=("data",),
                   vertex_axis=None, partition=None,
                   codec: str = "bitmap") -> "ShardedStore":
        """Rebuild on ``mesh`` from any snapshot kind — ``"sharded"``
        (compact rows), ``"bitmap"`` (full-capacity arena), or encoded
        ``"packed"``/``"compressed"`` arenas (decoded to bit rows on
        host first): the valid rows are redistributed block-evenly
        across the new mesh's tiles (any theta x vertex layout) and
        re-encoded under ``codec``, and the fused counter/sizes are
        recomputed tile-locally (exactly equal to the saved ones).  Rows
        are fed in ``RESTORE_CHUNK``-row slices so an arena that only
        fits *because* it is sharded never transits any single device
        whole on restore."""
        n, rows = _live_rows_from_state(st)
        count = rows.shape[0]
        store = cls(n, mesh=mesh, theta_axes=theta_axes,
                    vertex_axis=vertex_axis, capacity=max(count, 1),
                    partition=partition, codec=codec)
        chunk = max(cls.RESTORE_CHUNK // max(store.D, 1), 1) * store.D
        slot_chunks = []
        for lo in range(0, count, chunk):
            slot_chunks.append(
                store.add_batch(jnp.asarray(rows[lo:lo + chunk], jnp.uint8)))
        # snapshot-row -> slot map for provenance trackers (row i of the
        # *live-filtered* snapshot rows landed in slot _restore_slots[i])
        store._restore_slots = (np.concatenate(slot_chunks) if slot_chunks
                                else np.zeros((0,), np.int64))
        return store


STORE_KINDS = {"bitmap": BitmapStore, "indices": IndexStore,
               "sharded": ShardedStore}

# kinds registered lazily by ``repro.core.pack`` (imported on demand so
# this module stays importable without the pack package loaded)
_PACK_KINDS = ("packed", "compressed")


def _load_pack_kinds():
    """Import the IMPack package, which registers the ``packed`` and
    ``compressed`` store kinds plus their selection strategies."""
    import repro.core.pack  # noqa: F401  (registration side effect)


def _live_rows_from_state(st) -> tuple[int, np.ndarray]:
    """Decode any snapshot kind to its live bit rows: ``(n, (count, n)
    uint8)``.  This is the cross-representation interchange path —
    bitmap / packed / compressed arenas and compact sharded rows all
    reduce to the same decoded form, which any target store's
    ``from_rows``/restore feed re-encodes."""
    kind = str(np.asarray(st["kind"]))
    n, count = int(st["n"]), int(st["count"])
    R = np.asarray(st["R"])
    if kind == "packed":
        from repro.core.pack.codec import unpack_bits_np
        rows = unpack_bits_np(R, n)
    elif kind == "compressed":
        from repro.core.pack.codec import token_decode_np
        rows = token_decode_np(R, n)
    elif kind == "indices":
        rows = np.zeros((R.shape[0], n), np.uint8)
        r, c = np.nonzero(R < n)
        rows[r, R[r, c]] = 1
    else:                       # bitmap / sharded: already bit rows
        rows = np.asarray(R, np.uint8)
    rows = rows[:count]
    if "live" in st:
        # full-arena snapshots may carry dead (stale) rows in place —
        # restore live rows only, like a compact sharded snapshot would
        rows = rows[np.asarray(st["live"])[:count].astype(bool)]
    return n, rows


def make_store(kind: str, n: int, **kw) -> RRRStore:
    """Store factory: ``"auto"`` (bitmap, the back-compat default),
    ``"bitmap"``, ``"indices"``, ``"packed"``, ``"compressed"``, or
    ``"sharded"`` (requires a ``mesh=`` keyword; accepts ``theta_axes=``
    and a ``codec=`` at-rest kind)."""
    kind = "bitmap" if kind == "auto" else kind
    if kind in _PACK_KINDS and kind not in STORE_KINDS:
        _load_pack_kinds()
    try:
        ctor = STORE_KINDS[kind]
    except KeyError:
        raise ValueError(
            f"unknown store kind {kind!r}; have "
            f"{sorted(set(STORE_KINDS) | set(_PACK_KINDS))}")
    return ctor(n, **kw)


def _restore_error(snap_kind: str, target: str, meshed: bool) -> ValueError:
    """The one coherent restore-matrix error: names every supported
    ``(representation, mesh)`` combination instead of hinting at a
    single alternative."""
    where = "on a mesh" if meshed else "without a mesh"
    return ValueError(
        f"cannot restore a {snap_kind!r} snapshot as representation "
        f"{target!r} {where}. Supported (representation, mesh) restore "
        "combinations: 'bitmap', 'packed', and 'compressed' each restore "
        "from any 'bitmap', 'packed', 'compressed', or 'sharded' "
        "snapshot, with or without a mesh (a meshed restore builds a "
        "ShardedStore whose tiles use that at-rest codec; snapshots are "
        "decoded-row interchange, so layout none/1D/2D and at-rest "
        "format are both elastic); 'indices' restores only from an "
        "'indices' snapshot and only without a mesh (the sharded "
        "resident arena is never index-list — on meshes the C4 index "
        "representation is a derived ShardedStore.index_view, and "
        "single-device cross-representation restores re-encode, which "
        "an index-list snapshot does not round-trip). Re-run with "
        "IMMConfig(store='bitmap'/'packed'/'compressed'/'auto') for a "
        "snapshot that restores anywhere.")


def store_from_state(st, *, mesh=None, theta_axes=("data",),
                     vertex_axis=None, partition=None,
                     kind: str | None = None) -> RRRStore:
    """Rebuild a store from a `state()` tree (snapshot restore path).

    Snapshots are elastic across layouts *and* at-rest formats: bitmap,
    packed, compressed, and sharded snapshots all carry (or decode to)
    plain bit rows, so any of them restores into any target
    representation.  ``kind`` picks the target (None keeps the
    snapshot's own representation — a ``"sharded"`` snapshot's ``rep``
    tag when present, else bitmap).  With ``mesh`` given the result is a
    `ShardedStore` whose tiles use the target codec; without one it is
    the matching single-device store.  Index-list snapshots are
    single-device, same-representation only (see `_restore_error`).
    """
    snap_kind = str(np.asarray(st["kind"]))
    known = set(STORE_KINDS) | set(_PACK_KINDS)
    if snap_kind not in known:
        raise ValueError(f"snapshot has unknown store kind {snap_kind!r}")
    default = snap_kind
    if snap_kind == "sharded":
        default = str(np.asarray(st["rep"])) if "rep" in st else "bitmap"
    target = default if kind is None else kind
    if mesh is not None:
        if snap_kind == "indices" or target == "indices":
            raise _restore_error(snap_kind, target, meshed=True)
        codec = target if target in _PACK_KINDS else "bitmap"
        return ShardedStore.from_state(st, mesh=mesh, theta_axes=theta_axes,
                                       vertex_axis=vertex_axis,
                                       partition=partition, codec=codec)
    if target == "sharded":
        raise ValueError(
            "target representation 'sharded' needs a mesh= argument")
    if target == "indices" or snap_kind == "indices":
        if target == "indices" and snap_kind == "indices":
            return IndexStore.from_state(st)
        raise _restore_error(snap_kind, target, meshed=False)
    if target in _PACK_KINDS:
        _load_pack_kinds()
    if target == snap_kind:
        # same representation, full-arena snapshot: restore in place
        return STORE_KINDS[target].from_state(st)
    n, rows = _live_rows_from_state(st)
    return STORE_KINDS[target].from_rows(rows, n)
