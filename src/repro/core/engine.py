"""InfluenceEngine — composable, resumable, multi-query IMM.

The monolithic ``imm(graph, cfg)`` call hid the paper's three tunable
subsystems (RRR storage C3/C4, counter update C5, theta scheduling) inside
one function that re-sampled from scratch per invocation.  This module
splits them apart around a stateful engine over a persistent `RRRStore`:

    engine = InfluenceEngine(graph, IMMConfig(model="IC"))
    result = engine.run()                 # Algorithm 1, exactly as before
    top10  = engine.select(10)            # more queries, NO re-sampling
    sigma  = engine.influence([5, 17])    # sigma(S) for any candidate set
    engine.snapshot(ckpt_dir)             # resumable via checkpoint.store

Pieces:
  * sampling is resolved through the sampler registry
    (``repro.core.sampler``): a ``DiffusionModel`` x ``TraversalBackend``
    composition — "IC/dense", "WC/sparse", "GT/pallas+stable",
    "LT/walk", ... via ``make_sampler`` — or any user-registered name
    (the legacy monolithic spellings still resolve, deprecated);
  * selection goes through the `SelectionStrategy` registry
    (``repro.core.selection.get_selection``: rebuild/decrement x
    dense/sparse/sharded) instead of if/elif dispatch;
  * sampled sets land in a preallocated `RRRStore` arena (amortized
    doubling, in-place batch writes — see ``repro.core.store``), so
    ``extend``/``select`` never re-concatenate O(theta) rows; with a mesh
    the arena is a `ShardedStore` — the theta axis lives partitioned
    across devices end-to-end (paper C1), so theta scales with device
    count instead of single-device memory;
  * ``select`` results are memoized per (store version, k, method): a
    campaign sweep over many k is sampling-free after the first solve.

``imm()`` in ``repro.core.imm`` is a thin wrapper over ``run()`` and is
seed-for-seed identical to the historical implementation.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.graphs.csr import Graph
from repro.core import martingale as mg
from repro.core.adaptive import choose_representation, l_pad_for
from repro.core.sampler import bind_sampler, default_sampler_name, get_sampler
from repro.core.selection import get_selection
import repro.core.pack  # noqa: F401 — registers IMPack stores/strategies
from repro.core.store import (
    RRRStore, ShardedStore, make_store, next_pow2, store_from_state,
)
from repro.checkpoint import store as ckpt
from repro.graphs.partition import resolve_partition

# the IMPack at-rest representations a cfg.store can name (beyond the
# legacy auto/bitmap/indices/sharded) — restore re-encodes into these
_PACK_REPS = ("packed", "compressed")


@dataclasses.dataclass
class IMMConfig:
    k: int = 50
    eps: float = 0.5
    ell: float = 1.0
    # diffusion model axis: "IC" | "LT" | "WC" | "GT" | any name passed to
    # repro.core.sampler.register_model
    model: str = "IC"
    # traversal backend axis: None = auto (dense below dense_sampler_max_n,
    # sparse above it; walk for walk-family models) | "dense" | "sparse" |
    # "pallas" (the fused MXU ic_frontier kernel; jnp oracle off-TPU) |
    # "walk" | any name passed to register_backend
    backend: Optional[str] = None
    # stability axis: identity-keyed counter-mode coins + positions
    # row-subset resampling (the delta-stable form streaming requires)
    stable: bool = False
    # force the Pallas ic_frontier kernel through the interpreter (CPU
    # kernel validation; default off-TPU dispatch uses the jnp oracle)
    pallas_interpret: bool = False
    batch: int = 256                  # RRR sets per sampling call
    max_theta: int = 1 << 16          # safety cap (config-controlled)
    dense_sampler_max_n: int = 4096   # use the MXU log-semiring sampler below
    selection_method: str = "rebuild"    # "rebuild" (C5) | "decrement"
    adaptive_representation: bool = True  # C4
    # below this n the dense bitmap wins regardless of coverage (the
    # mat-vec is MXU/cache-friendly and the bitmap->indices conversion
    # costs more than it saves — measured: LT replicas at n~4k ran 10x
    # slower through the index path; EXPERIMENTS §Paper-tables)
    sparse_rep_min_n: int = 65536
    fuse_counters: bool = True            # C3 (informational; sampler always fuses)
    switch_ratio: int = 32
    # "auto" resolves to "sharded" when the engine has a mesh, "bitmap"
    # otherwise; "sharded" demands a mesh.  "packed" (bit-packed, 8x
    # smaller at rest) and "compressed" (token lists, decode-and-count
    # reads) are the IMPack at-rest formats — on a mesh they resolve to a
    # ShardedStore whose tiles use that codec.  Representation never
    # changes results: all stores are seed-for-seed bitwise-identical
    store: str = "auto"   # "auto" | "bitmap" | "indices" | "packed"
    #                     # | "compressed" | "sharded"
    # vertex-axis column layout of a meshed store: "equal" keeps the
    # canonical contiguous equal blocks; "balanced" places the block
    # boundaries at the graph's dst-degree quantiles so per-shard edge
    # counts stay near-equal on power-law graphs (layout-only: seeds are
    # bitwise identical either way)
    partition: str = "equal"
    # double-buffer the 2D frontier all-gather behind the local logq
    # matmul (dense/pallas backends; ignored off-mesh).  Pure scheduling:
    # overlap on/off never changes a sampled set
    overlap: bool = True
    # fuse the sample->write->count chain into ONE jit per batch (the
    # (B, n) batch rows never rest as a separate device array — see
    # repro.core.fused): "auto" fuses whenever the store's at-rest form
    # supports it (bitmap/packed arenas, sharded bitmap/packed tiles),
    # "off" forces the historical two-call path.  Pure execution fusion:
    # the PRNG stream and every stored byte are bitwise-identical
    fused_pipeline: str = "auto"   # "auto" | "off"
    # full sampler-name override ("WC/pallas+stable", a legacy alias, or a
    # user registration); None = compose from (model, backend, stable)
    sampler: Optional[str] = None
    seed: int = 0


@dataclasses.dataclass
class IMMResult:
    seeds: np.ndarray
    influence: float          # n * covered_frac
    covered_frac: float
    theta: int
    rounds: int
    representation: str
    counter: np.ndarray       # fused global counter over all sampled sets


@dataclasses.dataclass(frozen=True)
class Selection:
    """One answered seed-selection query (no sampling state attached)."""
    seeds: np.ndarray
    covered_frac: float
    influence: float
    gains: np.ndarray
    representation: str
    theta: int                # store size the query was answered against


class InfluenceEngine:
    """Stateful IMM engine over a persistent RRR store.

    Parameters
    ----------
    graph, cfg : the problem and its knobs (see `IMMConfig`).
    store      : optional pre-built `RRRStore` (default: ``cfg.store``).
    mesh, theta_axes, vertex_axis : pass a mesh to run the paper's C1
        partitioning end-to-end — the engine then keeps its RRR arenas in
        a `ShardedStore` (theta axis partitioned over ``theta_axes``),
        samplers place their batches shard-local, and selection consumes
        the arena shards natively, psum-ing only reduced quantities.
        ``vertex_axis`` names a second mesh axis that shards the *vertex*
        dimension end-to-end: arena columns, sampler traversal tables,
        fused counter partials, and selection all hold only ``n / Dv``
        vertex columns per device, so theta scales with the theta axis
        and graph size with the vertex axis simultaneously (build the
        mesh with ``configs.imm_snap.make_im_mesh``).  Passing a
        pre-built `ShardedStore` implies its mesh and axes.

    A mesh-equipped engine is seed-for-seed identical to a single-device
    one for fixed ``cfg.seed`` — sharding changes layout, never results.
    """

    def __init__(self, graph: Graph, cfg: IMMConfig = None, *,
                 store: RRRStore = None, mesh=None,
                 theta_axes=("data",), vertex_axis=None):
        self.graph = graph
        self.cfg = cfg if cfg is not None else IMMConfig()
        if mesh is None and isinstance(store, ShardedStore):
            mesh, theta_axes = store.mesh, store.theta_axes
            vertex_axis = store.vertex_axis
        self.mesh = mesh
        self.theta_axes = tuple(theta_axes)
        self.vertex_axis = vertex_axis
        self.key = jax.random.PRNGKey(self.cfg.seed)
        if store is not None:
            self.store = store
        elif mesh is not None and self.cfg.store in ("auto", "sharded"):
            self.store = make_store(
                "sharded", graph.n, mesh=mesh, theta_axes=self.theta_axes,
                vertex_axis=vertex_axis,
                partition=self._resolve_partition(mesh, vertex_axis))
        elif mesh is not None and self.cfg.store in ("packed", "compressed"):
            # the IMPack at-rest formats shard like bitmaps — every tile
            # of the mesh arena is encoded with the configured codec
            self.store = make_store(
                "sharded", graph.n, mesh=mesh, theta_axes=self.theta_axes,
                vertex_axis=vertex_axis, codec=self.cfg.store,
                partition=self._resolve_partition(mesh, vertex_axis))
        elif mesh is not None and self.cfg.store == "indices":
            # fail fast: the sharded pipeline (store, selection, snapshot
            # restore) is dense-only, and the late failure used to surface
            # obscurely at the first select() or restore()
            raise ValueError(
                "store='indices' cannot be combined with a mesh: "
                "IndexStore (and its snapshots) is single-device only. "
                "Use a dense at-rest representation (store='auto', "
                "'bitmap', 'packed', or 'compressed'), all of which "
                "shard across the mesh.")
        elif self.cfg.store == "sharded":
            raise ValueError("store='sharded' needs a mesh")
        else:
            self.store = make_store(self.cfg.store, graph.n)
        self.sampler_name = self.cfg.sampler or default_sampler_name(
            graph, self.cfg)
        self._sample = bind_sampler(
            get_sampler(self.sampler_name), graph, self.cfg,
            placement=getattr(self.store, "batch_sharding", None))
        # C4 routed per-backend: when the arena is an IndexStore and the
        # bound sampler can emit index lists natively (the sparse
        # backend), batches flow sampler -> arena as lists — no (B, n)
        # bitmap densification and no bitmap_to_indices pass at the write
        self._reset_index_emission()
        self._rebind_fused()
        self._select_cache: dict = {}

    def _resolve_partition(self, mesh, vertex_axis):
        """The configured vertex-axis `VertexPartition` for a meshed
        store (None off-mesh/1D, where there is no vertex axis to lay
        out).  ``cfg.partition="balanced"`` derives the boundaries from
        the graph's dst degrees — deterministic per (graph, Dv), so
        replicas and restores rebuild the identical layout."""
        if mesh is None or vertex_axis is None:
            return None
        return resolve_partition(
            getattr(self.cfg, "partition", "equal"), self.graph.n,
            int(mesh.shape[vertex_axis]), dst=self.graph.edge_dst)

    def _reset_index_emission(self) -> None:
        """Recompute the native-emission width for the *current* store —
        zero (bitmap path) unless the store is an IndexStore and the
        bound sampler supports ``emit_l``.  Called at construction and
        after every store swap (restore is elastic across store kinds, so
        a stale width would route bitmap stores into the index path)."""
        self._emit_l = 0
        if (self.store.representation == "indices"
                and getattr(self._sample, "supports_index_emit", False)):
            self._emit_l = int(getattr(self.store, "l_pad", 4))

    def _rebind_fused(self) -> None:
        """(Re)build the fused sample->write->count extender for the
        current (store, bound sampler) pair — None when disabled or
        unsupported (index emission, IndexStore), in which case `extend`
        keeps the historical two-call path.  Called at construction and
        after every store swap or sampler rebind."""
        self._fused = None
        if getattr(self.cfg, "fused_pipeline", "auto") == "off" or self._emit_l:
            return
        from repro.core.fused import make_fused_extender
        self._fused = make_fused_extender(
            self.store, self._sample, self.cfg,
            sampler_name=self.sampler_name)

    # ------------------------------------------------------------ sampling

    @property
    def theta(self) -> int:
        return self.store.count

    def extend(self, theta: int) -> int:
        """Sample batches until the store holds >= ``theta`` RRR sets.

        Idempotent when the store is already large enough; returns the new
        store size.  The PRNG key stream is (key_i, sub_i) = split(key_{i-1})
        per batch — identical to the historical driver, so a fixed
        ``cfg.seed`` yields a bitwise-identical sample stream.  Under a
        `StorePressurePolicy` the target clamps to the store's row cap
        (the store evicts to make room, so the count would never pass it).
        """
        cap = getattr(self.store, "row_cap", None)
        target = theta if cap is None else min(theta, cap)
        with obs.span("extend", tier="engine", target=target):
            while self.store.count < target:
                self.key, sub = jax.random.split(self.key)
                if self._emit_l:
                    with obs.span("sample", tier="engine",
                                  sampler=self.sampler_name):
                        rows_idx, counter = self._sample_index_batch(sub)
                    self.store.add_index_batch(rows_idx, counter)
                elif (self._fused is not None
                        and self._fused.extend_once(sub)):
                    pass  # one fused jit did sample+write+count for sub
                else:
                    with obs.span("sample", tier="engine",
                                  sampler=self.sampler_name):
                        visited, counter, _ = self._sample(sub)
                    self.store.add_batch(visited, counter)
                obs.counter("engine.batches_sampled").add(1)
        obs.gauge("engine.theta").set(self.store.count)
        return self.store.count

    def _sample_index_batch(self, sub):
        """Draw one batch natively as index lists (C4 per-backend).  A
        row that comes back *full* may have been truncated at the
        emission width — double ``emit_l`` and re-emit with the same key
        (same coins, wider lists; bounded by O(log n) retries over the
        engine's lifetime, since the width only ever grows).  The width
        caps at ``n`` exactly (not the next power of two: the top_k
        inside the conversion cannot exceed the bitmap's minor dimension,
        and no set can hold more than n members)."""
        while True:
            rows_idx, counter, _ = self._sample(sub, emit_l=self._emit_l)
            if (self._emit_l >= self.graph.n
                    or not bool((rows_idx[:, -1] < self.graph.n).any())):
                return rows_idx, counter
            self._emit_l = min(self._emit_l * 2, self.graph.n)

    def sample_batch(self):
        """Advance the engine's PRNG stream by one batch without writing
        to the store: returns ``(batch_key, visited, counter)``.  The key
        chain is the same ``split`` sequence `extend` uses, so callers
        that record ``batch_key`` (streaming refresh) can later
        `resample` the identical batch."""
        self.key, sub = jax.random.split(self.key)
        visited, counter, _ = self._sample(sub)
        return np.asarray(sub), visited, counter

    @property
    def supports_row_resample(self) -> bool:
        """Whether the bound sampler can re-generate an arbitrary subset
        of a batch's rows (the stable samplers' ``positions`` hook)."""
        return "positions" in inspect.signature(self._sample).parameters

    def resample(self, batch_key, positions=None):
        """Re-run the sampler for a recorded batch key against the
        *current* graph: returns ``(visited, counter)``.  With a
        delta-stable sampler, rows whose traversal avoided all mutated
        vertices come back bitwise identical — the streaming repair path.
        ``positions`` (requires `supports_row_resample`) re-generates
        only those rows of the batch, so repair work is proportional to
        stale rows."""
        key = jnp.asarray(batch_key)
        if positions is None:
            visited, counter, _ = self._sample(key)
        else:
            visited, counter, _ = self._sample(
                key, positions=jnp.asarray(positions, jnp.int32))
        return visited, counter

    def rebind_graph(self, graph: Graph) -> None:
        """Point the engine at a mutated graph (streaming delta path):
        future sampling uses the new edges while the store's resident RRR
        sets are kept — `repro.stream` invalidates the stale ones.  The
        select memoization is NOT cleared here; stream consumers bump the
        store version (kill/replace) which keys the cache."""
        self.graph = graph
        self._sample = bind_sampler(
            get_sampler(self.sampler_name), graph, self.cfg,
            placement=getattr(self.store, "batch_sharding", None))
        self._rebind_fused()

    # ----------------------------------------------------------- selection

    def _choose_representation(self) -> str:
        """The C4 adaptive choice, generalized over at-rest formats: the
        answer is either ``"indices"`` (sparse sets past the switch
        ratio) or the store's own resident representation (``"bitmap"``
        / ``"packed"`` / ``"compressed"`` — the dense layouts all serve
        selection natively, so the store never converts except to the
        derived index view)."""
        rep = self.store.representation
        if rep == "indices":
            return "indices"
        cfg = self.cfg
        if cfg.adaptive_representation and self.graph.n >= cfg.sparse_rep_min_n:
            if isinstance(self.store, ShardedStore):
                # C4 per *vertex shard*: each shard's index lists hold
                # only its own n_local columns of every set, so both the
                # width threshold and the bitmap width it competes with
                # are local quantities — adding vertex shards makes the
                # index representation win earlier
                avg_cov, _ = self.store.coverage_stats()
                chosen = choose_representation(
                    avg_cov, self.store.n_local,
                    self.store.max_local_size(), cfg.switch_ratio)
            else:
                avg_cov, l_max = self.store.coverage_stats()
                chosen = choose_representation(
                    avg_cov, self.graph.n, l_max, cfg.switch_ratio)
            if chosen == "indices":
                return "indices"
        return rep

    def select(self, k: int = None, *, method: str = None) -> Selection:
        """Greedy max-coverage over the *current* store — re-queryable.

        Successive calls with the same (k, method) against an unchanged
        store return the memoized result; different k re-run only the
        selection kernel, never the sampler.
        """
        cfg = self.cfg
        k = min(cfg.k if k is None else int(k), self.graph.n)
        if k < 1:
            raise ValueError(f"select needs k >= 1, got {k}")
        method = method or cfg.selection_method
        cache_key = (self.store.version, self.store.count, k, method)
        hit = self._select_cache.get(cache_key)
        if hit is not None:
            obs.counter("engine.select_cache_hits").add(1)
            return hit
        obs.counter("engine.select_cache_misses").add(1)

        if self.mesh is not None:
            # a ShardedStore view hands its native arena tiles straight to
            # the strategy (no resharding — encoded packed/compressed
            # tiles decode inside the selection kernel through the
            # store's codec), a replicated BitmapStore view is scattered
            # on entry by shard_map.  The C4 adaptive choice runs here
            # too (per vertex shard): when sets are sparse enough,
            # selection consumes a tile-local index view through the
            # sharded-sparse strategy instead of the dense tiles
            if self.store.representation == "indices":
                raise ValueError(
                    "sharded selection requires a dense-at-rest store "
                    "(bitmap, packed, or compressed)")
            rep = self._choose_representation()
            if rep == "indices" and isinstance(self.store, ShardedStore):
                view = self.store.index_view(
                    l_pad_for(self.store.max_local_size()))
                layout = "sharded-sparse"
            else:
                rep = self.store.representation
                view, layout = self.store.view(), "sharded"
        else:
            rep = self._choose_representation()
            srep = self.store.representation
            if rep == "indices" and srep != "indices":
                _, l_max = self.store.coverage_stats()
                view = self.store.index_view(l_pad_for(l_max))
                layout = "sparse"
            else:
                view = self.store.view()
                layout = {"bitmap": "dense", "indices": "sparse",
                          "packed": "packed",
                          "compressed": "compressed"}[rep]
        strategy = get_selection(method, layout)
        with obs.span("select", tier="engine", k=k, method=method,
                      layout=layout):
            seeds, frac, gains = strategy(
                view, k, mesh=self.mesh, theta_axes=self.theta_axes,
                vertex_axis=self.vertex_axis,
                partition=getattr(self.store, "partition", None),
                codec=getattr(self.store, "codec", None),
                pallas_interpret=cfg.pallas_interpret)
        sel = Selection(
            seeds=np.asarray(seeds), covered_frac=float(frac),
            influence=float(frac) * self.graph.n, gains=np.asarray(gains),
            representation=rep, theta=self.store.count)
        self._select_cache[cache_key] = sel
        return sel

    # ----------------------------------------------------------- influence

    def influences(self, seed_sets: Sequence[Sequence[int]]) -> np.ndarray:
        """sigma(S) estimates for a batch of seed sets in one fused kernel.

        Sets may have different sizes; each is padded with its own first
        element (a no-op for coverage) and the query axis pads to a power
        of two, so recompilations stay bounded while any mix of campaign
        queries shares one store pass.
        """
        if not len(seed_sets):
            return np.zeros((0,), np.float64)
        sets = [np.asarray(s, np.int32).reshape(-1) for s in seed_sets]
        for i, s in enumerate(sets):
            if s.size == 0:
                raise ValueError(f"seed set {i} is empty")
            if (s < 0).any() or (s >= self.graph.n).any():
                raise ValueError(f"seed set {i} has out-of-range vertices")
        q = len(sets)
        l_pad = next_pow2(max(s.size for s in sets), 1)
        q_pad = next_pow2(q, 1)
        S = np.empty((q_pad, l_pad), np.int32)
        for i in range(q_pad):
            s = sets[min(i, q - 1)]
            S[i, :s.size] = s
            S[i, s.size:] = s[0]
        with obs.span("influence", tier="engine", queries=q):
            fracs = np.asarray(self.store.hits(S))[:q]
        return fracs.astype(np.float64) * self.graph.n

    def influence(self, seed_set: Sequence[int]) -> float:
        """sigma(S) ~= n * F_R(S) for one seed set against the store."""
        return float(self.influences([seed_set])[0])

    # ------------------------------------------------------- checkpointing

    def snapshot_tree(self) -> dict:
        """The engine's persistent state as a host pytree (store + PRNG
        key + meta) — `snapshot` saves exactly this; wrappers that keep
        state of their own (`repro.stream.StreamEngine`) embed it in a
        larger tree so one file restores the whole stack."""
        return {
            "store": self.store.state(),
            "key": np.asarray(self.key),
            "meta": {
                "n": np.int64(self.graph.n),
                "model": np.asarray(self.cfg.model),
                "sampler": np.asarray(self.sampler_name),
            },
        }

    def snapshot(self, directory: str, *, tag: str = "engine") -> str:
        """Persist store + PRNG state atomically (checkpoint.store format)."""
        return ckpt.save_named(directory, tag, self.snapshot_tree())

    def restore_tree(self, tree: dict) -> None:
        """Adopt a `snapshot_tree` (validates n/model, rebuilds the store
        elastically across layouts, resumes the PRNG stream)."""
        meta = tree["meta"]
        if int(meta["n"]) != self.graph.n:
            raise ValueError(
                f"snapshot is for n={int(meta['n'])}, graph has n={self.graph.n}")
        if str(np.asarray(meta["model"])) != self.cfg.model:
            raise ValueError(
                f"snapshot model {np.asarray(meta['model'])} != cfg.model "
                f"{self.cfg.model}")
        # elastic across layouts: a snapshot taken on any mesh (or none)
        # restores into this engine's *configured* store layout — sharded
        # engines reshard, engines that deliberately keep a replicated /
        # single-device store (cfg.store="bitmap" etc.) keep their kind
        mesh = self.mesh if isinstance(self.store, ShardedStore) else None
        vx = self.vertex_axis if mesh is not None else None
        # a packed/compressed-configured engine re-encodes whatever the
        # snapshot holds; legacy configs keep the snapshot's own kind
        target = (self.cfg.store if self.cfg.store in _PACK_REPS else None)
        self.store = store_from_state(
            tree["store"], mesh=mesh, theta_axes=self.theta_axes,
            vertex_axis=vx, partition=self._resolve_partition(mesh, vx),
            kind=target)
        self.key = jnp.asarray(tree["key"])
        self._reset_index_emission()
        self._rebind_fused()
        self._select_cache.clear()

    def restore(self, directory: str, *, tag: str = "engine") -> bool:
        """Resume from `snapshot`; returns False when none exists."""
        tree = ckpt.load_named(directory, tag)
        if tree is None:
            return False
        self.restore_tree(tree)
        return True

    def replicate(self, tree: dict = None) -> "InfluenceEngine":
        """A read replica of this engine: a new engine over the same
        graph/config/mesh whose store and PRNG state are restored from
        ``tree`` (default: a fresh ``snapshot_tree`` of this engine).

        The tree is deep-copied host-side first (`checkpoint.store.
        clone_tree`), so one snapshot fans out to any number of replicas
        none of which alias the primary's buffers — the primary keeps
        serving (and donating its arena on writes) while replicas answer
        ``select``/``influence`` queries bitwise-identically to the
        primary at the snapshot's store state.  Replicas restore through
        the same elastic path as `restore`, so a mesh-sharded primary
        fans out to mesh-sharded replicas."""
        if tree is None:
            tree = self.snapshot_tree()
        replica = InfluenceEngine(
            self.graph, self.cfg, mesh=self.mesh,
            theta_axes=self.theta_axes, vertex_axis=self.vertex_axis)
        replica.restore_tree(ckpt.clone_tree(tree))
        return replica

    # -------------------------------------------------- Algorithm 1 driver

    def run(self) -> IMMResult:
        """IMM Algorithm 1 (Sampling phase -> Set_Theta -> Selection).

        The martingale schedule gates `extend`; every intermediate coverage
        check reuses `select`'s memoization.  The store persists afterwards
        for further `select`/`influence` queries.
        """
        cfg, n = self.cfg, self.graph.n
        k = min(cfg.k, n)
        bounds = mg.compute_bounds(n, k, cfg.eps, cfg.ell)
        lb = 1.0
        rounds = 0

        with obs.span("run", tier="engine", n=n, k=k):
            for i in range(1, bounds.max_rounds + 1):
                rounds = i
                theta_i = min(mg.round_theta(bounds, i), cfg.max_theta)
                with obs.span("round", tier="engine", round=i,
                              theta=theta_i):
                    self.extend(theta_i)
                    sel = self.select(k)
                obs.counter("engine.rounds").add(1)
                if n * sel.covered_frac >= mg.round_target(bounds, i):
                    lb = mg.lower_bound_from_coverage(bounds, sel.covered_frac)
                    break
                if self.store.count >= cfg.max_theta:
                    lb = max(
                        mg.lower_bound_from_coverage(bounds, sel.covered_frac),
                        1.0)
                    break

            theta = min(mg.theta_from_lb(bounds, lb), cfg.max_theta)
            self.extend(theta)
            sel = self.select(k)
        return IMMResult(
            seeds=sel.seeds,
            influence=sel.influence,
            covered_frac=sel.covered_frac,
            theta=self.store.count,
            rounds=rounds,
            representation=sel.representation,
            counter=np.asarray(self.store.counter),
        )
