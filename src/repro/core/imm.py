"""IMM Algorithm 1 driver (Sampling phase -> Selection phase) with
EfficientIMM's optimizations wired in as config flags, so the paper-faithful
baseline and the optimized path are both first-class:

    IMMConfig(selection_method="decrement", fuse_counters=False,
              adaptive_representation=False)   # Ripples-style baseline
    IMMConfig()                                # EfficientIMM defaults

The driver orchestrates jitted sampling batches (host loop is data-dependent
exactly as in the paper) and pads theta to batch multiples for shape
stability.  Influence estimates: sigma(S) ~= n * F_R(S).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.csr import Graph
from repro.core import martingale as mg
from repro.core.sampler import make_logq, sample_ic_dense, sample_ic_sparse, sample_lt
from repro.core.selection import select_dense, select_sparse
from repro.core.adaptive import choose_representation, bitmap_to_indices


@dataclasses.dataclass
class IMMConfig:
    k: int = 50
    eps: float = 0.5
    ell: float = 1.0
    model: str = "IC"                 # "IC" | "LT"
    batch: int = 256                  # RRR sets per sampling call
    max_theta: int = 1 << 16          # safety cap (config-controlled)
    dense_sampler_max_n: int = 4096   # use the MXU log-semiring sampler below
    selection_method: str = "rebuild"    # "rebuild" (C5) | "decrement"
    adaptive_representation: bool = True  # C4
    # below this n the dense bitmap wins regardless of coverage (the
    # mat-vec is MXU/cache-friendly and the bitmap->indices conversion
    # costs more than it saves — measured: LT replicas at n~4k ran 10x
    # slower through the index path; EXPERIMENTS §Paper-tables)
    sparse_rep_min_n: int = 65536
    fuse_counters: bool = True            # C3 (informational; sampler always fuses)
    switch_ratio: int = 32
    seed: int = 0


@dataclasses.dataclass
class IMMResult:
    seeds: np.ndarray
    influence: float          # n * covered_frac
    covered_frac: float
    theta: int
    rounds: int
    representation: str
    counter: np.ndarray       # fused global counter over all sampled sets


class _RRRStore:
    """Grow-only store of sampled RRR bitmaps + fused counter (C3)."""

    def __init__(self, n: int):
        self.n = n
        self.batches = []
        self.counter = jnp.zeros((n,), jnp.int32)
        self.count = 0

    def add(self, visited, counter):
        self.batches.append(visited)
        self.counter = self.counter + counter
        self.count += visited.shape[0]

    def bitmaps(self, pad_to: Optional[int] = None):
        R = jnp.concatenate(self.batches, axis=0) if self.batches else \
            jnp.zeros((0, self.n), jnp.uint8)
        valid = jnp.ones((R.shape[0],), bool)
        if pad_to and R.shape[0] < pad_to:
            pad = pad_to - R.shape[0]
            R = jnp.concatenate([R, jnp.zeros((pad, self.n), jnp.uint8)])
            valid = jnp.concatenate([valid, jnp.zeros((pad,), bool)])
        return R, valid


def _sample_batch(graph: Graph, cfg: IMMConfig, key, logq):
    if cfg.model == "IC":
        if graph.n <= cfg.dense_sampler_max_n:
            return sample_ic_dense(key, logq, batch=cfg.batch)
        return sample_ic_sparse(
            key, graph.edge_src, graph.edge_dst, graph.in_prob,
            n_nodes=graph.n, batch=cfg.batch)
    return sample_lt(
        key, graph.dst_offsets, graph.in_src, graph.in_lt_cum,
        graph.in_lt_total, batch=cfg.batch)


def _select(store: _RRRStore, cfg: IMMConfig, graph: Graph):
    # pad theta to the next power of two to bound recompilations
    pad_to = 1 << max(int(math.ceil(math.log2(max(store.count, 1)))), 4)
    R, valid = store.bitmaps(pad_to)
    sizes = np.asarray(R.sum(axis=1), dtype=np.int64)
    avg_cov = float(sizes.sum()) / max(store.count, 1) / graph.n
    l_max = int(sizes.max()) if sizes.size else 1
    rep = "bitmap"
    if cfg.adaptive_representation and graph.n >= cfg.sparse_rep_min_n:
        rep = choose_representation(avg_cov, graph.n, max(l_max, 1),
                                    cfg.switch_ratio)
    if rep == "indices":
        l_pad = 1 << max(int(math.ceil(math.log2(max(l_max, 1)))), 2)
        R_idx = bitmap_to_indices(R, l_pad)
        seeds, frac, gains = select_sparse(
            R_idx, valid, graph.n, cfg.k, cfg.selection_method)
    else:
        seeds, frac, gains = select_dense(
            R, valid, cfg.k, cfg.selection_method)
    return seeds, float(frac), rep


def imm(graph: Graph, cfg: IMMConfig = IMMConfig()) -> IMMResult:
    n = graph.n
    k = min(cfg.k, n)
    bounds = mg.compute_bounds(n, k, cfg.eps, cfg.ell)
    key = jax.random.PRNGKey(cfg.seed)
    logq = make_logq(graph) if (
        cfg.model == "IC" and n <= cfg.dense_sampler_max_n) else None

    store = _RRRStore(n)
    lb = 1.0
    rounds = 0
    seeds, frac, rep = None, 0.0, "bitmap"

    # ---- Sampling phase (Alg. 1 lines 1-7) ----
    for i in range(1, bounds.max_rounds + 1):
        rounds = i
        theta_i = min(mg.round_theta(bounds, i), cfg.max_theta)
        while store.count < theta_i:
            key, sub = jax.random.split(key)
            visited, counter, _ = _sample_batch(graph, cfg, sub, logq)
            store.add(visited, counter)
        seeds, frac, rep = _select(store, cfg, graph)
        if n * frac >= mg.round_target(bounds, i):
            lb = mg.lower_bound_from_coverage(bounds, frac)
            break
        if store.count >= cfg.max_theta:
            lb = max(mg.lower_bound_from_coverage(bounds, frac), 1.0)
            break

    # ---- Set_Theta + top-up sampling (Alg. 1 lines 8-10) ----
    theta = min(mg.theta_from_lb(bounds, lb), cfg.max_theta)
    while store.count < theta:
        key, sub = jax.random.split(key)
        visited, counter, _ = _sample_batch(graph, cfg, sub, logq)
        store.add(visited, counter)

    # ---- Selection phase (Alg. 1 line 11) ----
    seeds, frac, rep = _select(store, cfg, graph)
    return IMMResult(
        seeds=np.asarray(seeds),
        influence=float(n * frac),
        covered_frac=frac,
        theta=store.count,
        rounds=rounds,
        representation=rep,
        counter=np.asarray(store.counter),
    )
