"""``imm(graph, cfg)`` — the one-shot IMM entry point (back-compat wrapper).

Historically this module owned the whole Algorithm-1 driver: a grow-only
list-of-batches store, if/elif sampler dispatch, and selection wired inline.
That machinery now lives in the stateful engine:

  * ``repro.core.engine.InfluenceEngine`` — Algorithm 1 plus incremental
    ``extend``/``select``/``influence`` multi-query serving and
    ``snapshot``/``restore`` resumability;
  * ``repro.core.store``   — preallocated bitmap/index RRR arenas (C3/C4);
  * ``repro.core.sampler`` — the DiffusionModel x TraversalBackend
    sampler matrix ("IC/dense", "WC/sparse", "GT/pallas", "LT/walk",
    ... composed by ``make_sampler``; legacy monolithic names resolve
    as deprecated aliases);
  * ``repro.core.selection`` — the `SelectionStrategy` registry
    (rebuild/decrement x dense/sparse/sharded, C5/C1).

``imm()`` constructs a fresh engine and runs it once; for a fixed
``cfg.seed`` it returns seeds identical to the historical implementation.
Callers that issue more than one query per sampled store should hold an
`InfluenceEngine` instead:

    engine = InfluenceEngine(graph, IMMConfig(model="IC"))
    result = engine.run()          # == imm(graph, cfg)
    more   = engine.select(10)     # extra queries, no re-sampling

The paper-faithful baseline and the optimized path both remain first-class:

    IMMConfig(selection_method="decrement", fuse_counters=False,
              adaptive_representation=False)   # Ripples-style baseline
    IMMConfig()                                # EfficientIMM defaults
"""
from __future__ import annotations

from repro.graphs.csr import Graph
from repro.core.engine import (          # noqa: F401  (re-exported API)
    IMMConfig, IMMResult, InfluenceEngine, Selection,
)


def imm(graph: Graph, cfg: IMMConfig = None) -> IMMResult:
    """Run IMM Algorithm 1 end-to-end and return the seed set.

    Thin wrapper over ``InfluenceEngine(graph, cfg).run()``; the engine
    (and its sampled store) is discarded afterwards.
    """
    return InfluenceEngine(graph, cfg if cfg is not None else IMMConfig()).run()
