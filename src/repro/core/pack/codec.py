"""Row codecs for RRR arenas: bitmap, bit-packed, and token-compressed.

A codec maps a batch of RRR membership rows — uint8 0/1 bitmaps of shape
``(B, n_cols)`` — to an at-rest representation and back.  Codecs are the
unit the stores compose over: `PackedBitmapStore`/`CompressedStore` hold
one codec for the whole arena, and `ShardedStore` holds one codec per
vertex tile (``n_cols = n_local``), swapping codecs in place when the
`StorePressurePolicy` ladder fires.  Every method is pure jnp so it can
run inside ``jit`` and ``shard_map`` bodies.

At-rest formats
---------------
* ``bitmap`` — the identity codec: one uint8 per vertex.
* ``packed`` — 8 vertices per byte, ``width = ceil(n_cols / 8)``.
  Bit ``j`` of byte ``b`` is vertex ``b * 8 + j`` (LSB-first).
* ``compressed`` — per-row token lists over the *packed* bytes, mixing
  two codes chosen per 32-byte superblock by density:

      token = block * 512 + code
      code < 256   -> dictionary literal: byte ``block`` equals ``code``
      code == 256  -> saturated run: 32 consecutive 0xFF bytes starting
                      at ``block`` (block % 32 == 0), i.e. 256 set bits
      sentinel     -> ``n_blocks_padded * 512`` (past-the-end block,
                      code 0: decodes to nothing)

  A fully-saturated superblock (dense rows) costs one run token instead
  of 32 literals; everything else pays one literal per nonzero byte
  (sparse rows degenerate to a pure dictionary list).  Rows are padded
  to ``s_pad`` tokens with the sentinel; `tokens_needed` gives the
  per-row count so stores can widen ``s_pad`` the way `IndexStore`
  widens ``l_pad``.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

TOKEN_BASE = 512       # tokens are block * TOKEN_BASE + code
SAT_CODE = 256         # code marking a saturated 32-byte run
SUPERBLOCK = 32        # bytes per run-length superblock
MIN_TOKEN_PAD = 8      # floor for CompressedStore s_pad

_BIT_WEIGHTS = np.array([1, 2, 4, 8, 16, 32, 64, 128], dtype=np.uint8)


def n_bytes_for(n_cols: int) -> int:
    """Packed width in bytes for an ``n_cols``-bit row."""
    return -(-int(n_cols) // 8)


def n_superblocks_for(n_cols: int) -> int:
    return -(-n_bytes_for(n_cols) // SUPERBLOCK)


def n_blocks_padded(n_cols: int) -> int:
    """Byte count rounded up to whole superblocks (token block space)."""
    return n_superblocks_for(n_cols) * SUPERBLOCK


def token_sentinel(n_cols: int) -> int:
    return n_blocks_padded(n_cols) * TOKEN_BASE


# ---------------------------------------------------------------------------
# bit packing


def pack_bits(bits):
    """(..., n) uint8 0/1 -> (..., ceil(n/8)) uint8, LSB-first."""
    n = bits.shape[-1]
    nb = n_bytes_for(n)
    pad = nb * 8 - n
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    grouped = bits.reshape(bits.shape[:-1] + (nb, 8)).astype(jnp.uint8)
    return (grouped * jnp.asarray(_BIT_WEIGHTS)).sum(
        axis=-1, dtype=jnp.uint8)


def unpack_bits(packed, n_cols: int):
    """(..., nb) uint8 -> (..., n_cols) uint8 0/1 (inverse of pack_bits)."""
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[..., None] >> shifts) & jnp.uint8(1)
    return bits.reshape(packed.shape[:-1] + (-1,))[..., :n_cols]


def pack_bits_np(bits: np.ndarray) -> np.ndarray:
    bits = np.ascontiguousarray(bits, dtype=np.uint8)
    return np.packbits(bits, axis=-1, bitorder="little")


def unpack_bits_np(packed: np.ndarray, n_cols: int) -> np.ndarray:
    out = np.unpackbits(np.ascontiguousarray(packed, dtype=np.uint8),
                        axis=-1, bitorder="little")
    return out[..., :n_cols]


def popcount_u8(x):
    """Per-byte population count (uint8 in, uint8 out)."""
    x = x.astype(jnp.uint8)
    v = x - ((x >> 1) & jnp.uint8(0x55))
    v = (v & jnp.uint8(0x33)) + ((v >> 2) & jnp.uint8(0x33))
    return (v + (v >> 4)) & jnp.uint8(0x0F)


def popcount_i32(x):
    """Population count of non-negative int32 values (int32 out)."""
    x = x.astype(jnp.int32)
    v = x - ((x >> 1) & 0x55555555)
    v = (v & 0x33333333) + ((v >> 2) & 0x33333333)
    v = (v + (v >> 4)) & 0x0F0F0F0F
    return (v * 0x01010101) >> 24


# ---------------------------------------------------------------------------
# token codec primitives (free functions so kernels/oracles can share the
# format math without holding a codec instance)


def _row_plan(bits):
    """Per-row byte/superblock masks behind the token layout.

    Returns ``(bytes_, lit_mask, sat_mask)`` where ``bytes_`` is the
    superblock-padded packed row, ``lit_mask`` marks bytes emitted as
    dictionary literals, and ``sat_mask`` marks saturated superblocks
    emitted as one run token.
    """
    n = bits.shape[-1]
    nbp = n_blocks_padded(n)
    bytes_ = pack_bits(bits)
    pad = nbp - bytes_.shape[-1]
    if pad:
        bytes_ = jnp.pad(bytes_, [(0, 0)] * (bytes_.ndim - 1) + [(0, pad)])
    grouped = bytes_.reshape(bytes_.shape[:-1] + (-1, SUPERBLOCK))
    sat_mask = (grouped == jnp.uint8(0xFF)).all(axis=-1)
    lit_mask = (bytes_ > 0) & ~jnp.repeat(sat_mask, SUPERBLOCK, axis=-1)
    return bytes_, lit_mask, sat_mask


def tokens_needed(bits):
    """(..., n) bit rows -> (...,) int32 token count under the codec."""
    _, lit_mask, sat_mask = _row_plan(bits)
    return (lit_mask.sum(axis=-1, dtype=jnp.int32)
            + sat_mask.sum(axis=-1, dtype=jnp.int32))


def token_encode(bits, s_pad: int):
    """(B, n) bit rows -> (B, s_pad) int32 tokens (sentinel padded).

    The caller must guarantee ``s_pad >= tokens_needed(bits).max()`` —
    overflow tokens are silently dropped (stores widen first, the way
    `IndexStore` widens ``l_pad``).
    """
    n = bits.shape[-1]
    nbp = n_blocks_padded(n)
    nsb = nbp // SUPERBLOCK
    sentinel = jnp.int32(token_sentinel(n))
    bytes_, lit_mask, sat_mask = _row_plan(bits)

    blocks = jnp.arange(nbp, dtype=jnp.int32)
    lit_vals = blocks * TOKEN_BASE + bytes_.astype(jnp.int32)
    sat_vals = (jnp.arange(nsb, dtype=jnp.int32) * SUPERBLOCK * TOKEN_BASE
                + SAT_CODE)
    vals = jnp.concatenate(
        [lit_vals, jnp.broadcast_to(sat_vals, bits.shape[:-1] + (nsb,))],
        axis=-1)
    mask = jnp.concatenate([lit_mask, sat_mask], axis=-1)

    # stable compaction: keep masked candidates in layout order (same
    # top_k trick as adaptive.bitmap_to_indices)
    total = nbp + nsb
    score = (mask.astype(jnp.int32) * (total + 1)
             - jnp.arange(total, dtype=jnp.int32))
    _, pick = jax.lax.top_k(score, min(s_pad, total))
    toks = jnp.where(jnp.take_along_axis(mask, pick, axis=-1),
                     jnp.take_along_axis(vals, pick, axis=-1), sentinel)
    if s_pad > total:
        toks = jnp.pad(toks, [(0, 0)] * (toks.ndim - 1)
                       + [(0, s_pad - total)], constant_values=sentinel)
    return toks


def token_decode(tokens, n_cols: int):
    """(B, s_pad) int32 tokens -> (B, n_cols) uint8 0/1 bit rows."""
    nbp = n_blocks_padded(n_cols)
    nsb = nbp // SUPERBLOCK
    blk = tokens // TOKEN_BASE
    code = tokens - blk * TOKEN_BASE

    def one(blk_r, code_r):
        # literal bytes: scatter into a one-slot-padded scratch so the
        # sentinel block (== nbp) and run tokens land harmlessly
        lit_idx = jnp.where(code_r < SAT_CODE, blk_r, nbp)
        bytes_ = jnp.zeros(nbp + 1, jnp.uint8).at[lit_idx].max(
            jnp.where(code_r < SAT_CODE, code_r, 0).astype(jnp.uint8))[:nbp]
        sat_idx = jnp.where(code_r == SAT_CODE, blk_r // SUPERBLOCK, nsb)
        sat = jnp.zeros(nsb + 1, jnp.uint8).at[sat_idx].max(
            jnp.uint8(1))[:nsb]
        bytes_ = jnp.maximum(
            bytes_, jnp.repeat(sat, SUPERBLOCK) * jnp.uint8(0xFF))
        return unpack_bits(bytes_, n_cols)

    return jax.vmap(one)(blk, code)


def token_decode_cols(tokens, cols):
    """Membership of global columns: (B, s_pad), (L,) -> (B, L) bool."""
    cols = cols.astype(jnp.int32)
    cblk = cols >> 3
    cbit = cols & 7
    csb = (cblk // SUPERBLOCK) * SUPERBLOCK
    blk = tokens // TOKEN_BASE
    code = tokens - blk * TOKEN_BASE
    lit = ((code < SAT_CODE)[..., None]
           & (blk[..., None] == cblk)
           & (((code[..., None] >> cbit) & 1) > 0))
    sat = (code == SAT_CODE)[..., None] & (blk[..., None] == csb)
    return (lit | sat).any(axis=-2)


def token_row_popcount(tokens):
    """(B, s_pad) tokens -> (B,) int32 set-bit counts (no decode)."""
    blk = tokens // TOKEN_BASE
    code = tokens - blk * TOKEN_BASE
    per = jnp.where(code == SAT_CODE, SUPERBLOCK * 8, popcount_i32(code))
    return per.sum(axis=-1, dtype=jnp.int32)


def token_decode_np(tokens: np.ndarray, n_cols: int) -> np.ndarray:
    """Host-side token decode for snapshot paths."""
    tokens = np.asarray(tokens, dtype=np.int64)
    nbp = n_blocks_padded(n_cols)
    blk = tokens // TOKEN_BASE
    code = tokens - blk * TOKEN_BASE
    out = np.zeros(tokens.shape[:-1] + (nbp,), dtype=np.uint8)
    rows = np.broadcast_to(
        np.arange(tokens.shape[0])[:, None], tokens.shape)
    # sentinel tokens live at the past-the-end block — not literals
    lit = (code < SAT_CODE) & (blk < nbp)
    out[rows[lit], blk[lit]] = code[lit].astype(np.uint8)
    sat = code == SAT_CODE
    for r, b in zip(rows[sat], blk[sat]):
        out[r, b:b + SUPERBLOCK] = 0xFF
    return unpack_bits_np(out, n_cols)


# ---------------------------------------------------------------------------
# codec objects (frozen + hashable: they key the sharded kernel caches)


@dataclasses.dataclass(frozen=True)
class BitmapCodec:
    """Identity codec: one uint8 per vertex (the PR-1 layout)."""
    n_cols: int
    kind: ClassVar[str] = "bitmap"
    dtype: ClassVar = jnp.uint8

    @property
    def width(self) -> int:
        return self.n_cols

    @property
    def fill(self) -> int:
        return 0

    def encode(self, bits):
        return bits.astype(jnp.uint8)

    def decode(self, stored):
        return stored

    def decode_cols(self, stored, cols):
        return jnp.take(stored, cols, axis=-1) > 0

    def row_popcount(self, stored):
        return stored.astype(jnp.int32).sum(axis=-1)

    def decode_np(self, stored: np.ndarray) -> np.ndarray:
        return np.asarray(stored, dtype=np.uint8)


@dataclasses.dataclass(frozen=True)
class PackedCodec:
    """Bit-packed codec: 8 vertices per byte, 8x smaller at rest."""
    n_cols: int
    kind: ClassVar[str] = "packed"
    dtype: ClassVar = jnp.uint8

    @property
    def width(self) -> int:
        return n_bytes_for(self.n_cols)

    @property
    def fill(self) -> int:
        return 0

    def encode(self, bits):
        return pack_bits(bits)

    def decode(self, stored):
        return unpack_bits(stored, self.n_cols)

    def decode_cols(self, stored, cols):
        cols = cols.astype(jnp.int32)
        bytes_ = jnp.take(stored, cols >> 3, axis=-1)
        return ((bytes_ >> (cols & 7).astype(jnp.uint8)) & 1) > 0

    def row_popcount(self, stored):
        return popcount_u8(stored).astype(jnp.int32).sum(axis=-1)

    def decode_np(self, stored: np.ndarray) -> np.ndarray:
        return unpack_bits_np(stored, self.n_cols)


@dataclasses.dataclass(frozen=True)
class TokenCodec:
    """Compressed-at-rest codec: per-row literal/run token lists."""
    n_cols: int
    s_pad: int
    kind: ClassVar[str] = "compressed"
    dtype: ClassVar = jnp.int32

    @property
    def width(self) -> int:
        return self.s_pad

    @property
    def fill(self) -> int:
        return token_sentinel(self.n_cols)

    def encode(self, bits):
        return token_encode(bits, self.s_pad)

    def decode(self, stored):
        return token_decode(stored, self.n_cols)

    def decode_cols(self, stored, cols):
        return token_decode_cols(stored, cols)

    def row_popcount(self, stored):
        return token_row_popcount(stored)

    def decode_np(self, stored: np.ndarray) -> np.ndarray:
        return token_decode_np(stored, self.n_cols)


def codec_for(kind: str, n_cols: int, s_pad: int = MIN_TOKEN_PAD):
    """Build the codec named ``kind`` (``bitmap``/``packed``/
    ``compressed``) for ``n_cols``-wide rows."""
    if kind == "bitmap":
        return BitmapCodec(int(n_cols))
    if kind == "packed":
        return PackedCodec(int(n_cols))
    if kind == "compressed":
        return TokenCodec(int(n_cols), int(s_pad))
    raise ValueError(
        f"unknown codec kind {kind!r}; expected one of "
        "'bitmap', 'packed', 'compressed'")
