"""Greedy max-coverage directly over encoded arenas.

`select_packed` decodes the bit-packed arena once inside jit and runs
the identical `select_dense` body — the decoded bits are a fusion
temporary, the at-rest arena stays 8x smaller.  `select_compressed`
never materializes the decoded arena at all: each greedy round rebuilds
the counter with the decode-and-count kernel (``kernels/ops.token_count``
— Pallas on TPU, jnp oracle elsewhere, ``interpret=True`` validates the
kernel on CPU) and tests the winner's membership by token comparison.
Both are bitwise-identical to `select_dense` over the same rows: counts
are integers in f32, so every argmax and tie-break agrees.

Registered layouts: ``{rebuild,decrement}-{packed,compressed}`` (the
sharded layouts reuse ``rebuild-sharded`` with a tile codec — see
`select_dense_sharded`).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.pack.codec import token_decode_cols, unpack_bits
from repro.core.selection import register_selection, select_dense
from repro.kernels import ops


@partial(jax.jit, static_argnames=("n", "k", "method"))
def select_packed(Rp, valid, n: int, k: int, method: str = "rebuild"):
    """Rp: (theta, ceil(n/8)) uint8 bit-packed rows; valid: (theta,)
    bool.  Returns (seeds (k,) int32, covered_frac () f32,
    gains (k,) int32) — bitwise-equal to ``select_dense`` on the
    unpacked rows."""
    return select_dense(unpack_bits(Rp, n), valid, k, method)


@partial(jax.jit,
         static_argnames=("n", "k", "method", "use_pallas", "interpret"))
def select_compressed(T, valid, n: int, k: int, method: str = "rebuild",
                      *, use_pallas=None, interpret: bool = False):
    """T: (theta, s_pad) int32 token rows (``repro.core.pack.codec``
    format); valid: (theta,) bool.  Greedy selection whose per-round
    counter comes from the decode-and-count kernel — the decoded
    ``(theta, n)`` arena never exists.  Returns (seeds, covered_frac,
    gains) bitwise-equal to ``select_dense`` on the decoded rows."""

    def counter_of(alive):
        return ops.token_count(
            T, alive.astype(jnp.float32), n=n,
            use_pallas=use_pallas, interpret=interpret).astype(jnp.float32)

    def member(v):
        return token_decode_cols(T, v.reshape(1))[:, 0]

    if method == "rebuild":
        def body(i, state):
            alive, seeds, gains = state
            counter = counter_of(alive)
            v = jnp.argmax(counter).astype(jnp.int32)
            covered = member(v) & alive
            gain = covered.sum(dtype=jnp.int32)
            return alive & ~covered, seeds.at[i].set(v), gains.at[i].set(gain)

        alive, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (valid, jnp.zeros((k,), jnp.int32), jnp.zeros((k,), jnp.int32)))
    elif method == "decrement":
        def body(i, state):
            alive, counter, seeds, gains = state
            v = jnp.argmax(counter).astype(jnp.int32)
            covered = member(v) & alive
            gain = covered.sum(dtype=jnp.int32)
            counter = counter - counter_of(covered)
            return (alive & ~covered, counter,
                    seeds.at[i].set(v), gains.at[i].set(gain))

        alive, _, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (valid, counter_of(valid), jnp.zeros((k,), jnp.int32),
             jnp.zeros((k,), jnp.int32)))
    else:
        raise ValueError(f"unknown method {method}")

    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)
    return seeds, gains.sum(dtype=jnp.float32) / n_valid, gains


def _packed_strategy(method):
    def run(view, k, **_):
        return select_packed(view.R, view.valid, view.n, k, method)
    return run


def _compressed_strategy(method):
    def run(view, k, *, pallas_interpret=False, **_):
        return select_compressed(view.R, view.valid, view.n, k, method,
                                 interpret=bool(pallas_interpret))
    return run


for _m in ("rebuild", "decrement"):
    register_selection(f"{_m}-packed", _packed_strategy(_m))
    register_selection(f"{_m}-compressed", _compressed_strategy(_m))
