"""Single-device compressed-at-rest RRR arenas behind the `RRRStore`
protocol.

`PackedBitmapStore` and `CompressedStore` are one arena class
(`CodecStore`) parameterized by the at-rest codec: rows arrive as
``(B, n) uint8`` bitmaps, are encoded on write (fused pack-on-write —
one donated jit does encode + dynamic_update_slice), and all reads
(counting, hits, index conversion, stream reverse-touch) decode on the
fly, so the logical ``(theta, n)`` arena never rests in memory.  Under a
`StorePressurePolicy` with a ``ladder``, an over-cap arena first morphs
its codec down the ladder (packed -> compressed) before any live row is
evicted — `_compress_step` swaps ``codec``/``R`` in place and the store
keeps its class, so ``representation`` follows ``codec.kind``.

The dense `BitmapStore` itself never morphs (its class is its layout);
the single-device ladder therefore starts at `PackedBitmapStore`, while
`ShardedStore` covers the full bitmap -> packed -> compressed ladder by
swapping per-tile codecs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.adaptive import bitmap_to_indices
from repro.core.pack.codec import (
    MIN_TOKEN_PAD,
    TokenCodec,
    codec_for,
    tokens_needed,
)
from repro.core.store import (
    MIN_CAPACITY,
    StoreView,
    _ArenaBase,
    _ladder_next,
    _restore_live,
    _write_rows,
    next_pow2,
)
from repro.kernels import ops


@partial(jax.jit, static_argnames=("codec",), donate_argnums=(0,))
def _encode_write(arena, bits, start, *, codec):
    """Fused pack-on-write: encode the bit batch and splice it into the
    (donated) arena at dynamic row offset ``start`` in one jit."""
    return jax.lax.dynamic_update_slice(
        arena, codec.encode(bits), (start, jnp.int32(0)))


@partial(jax.jit, static_argnames=("codec_from", "codec_to"))
def _recode(arena, *, codec_from, codec_to):
    """Whole-arena codec morph (the pressure-ladder step): decode under
    the old codec, re-encode under the new one.  The decoded bits are a
    jit temporary — they never rest."""
    return codec_to.encode(codec_from.decode(arena))


@partial(jax.jit, static_argnames=("codec",))
def _codec_hits(R, valid, S, *, codec):
    """`_bitmap_hits` semantics on an encoded arena: per-query covered
    fraction via ``decode_cols`` membership (lax.map bounds the decoded
    broadcast to one query at a time)."""
    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)

    def one(s):
        memb = codec.decode_cols(R, s).any(axis=-1)
        return (memb & valid).sum(dtype=jnp.int32)

    return jax.lax.map(one, S).astype(jnp.float32) / n_valid


class CodecStore(_ArenaBase):
    """Single-device encoded arena: ``(capacity, codec.width)`` of
    ``codec.dtype``.  See the module docstring; use the
    `PackedBitmapStore` / `CompressedStore` aliases to pick the initial
    codec."""

    _initial_kind = "packed"

    def __init__(self, n: int, *, capacity: int = MIN_CAPACITY,
                 policy=None, s_pad: int = MIN_TOKEN_PAD):
        super().__init__(n, capacity=capacity, policy=policy)
        self.codec = codec_for(self._initial_kind, self.n,
                               s_pad=next_pow2(s_pad, MIN_TOKEN_PAD))
        self.R = jnp.full((self.capacity, self.codec.width),
                          self._fill_value(), self.codec.dtype)
        self._idx_cache = None      # (version, l_pad) -> R_idx

    @property
    def representation(self) -> str:
        return self.codec.kind

    # ------------------------------------------------- arena base hooks ----

    def _realloc(self, new_cap: int):
        R = jnp.full((new_cap, self.codec.width), self._fill_value(),
                     self.codec.dtype)
        self.R = _write_rows(R, self.R, jnp.int32(0))

    def _row_bytes(self) -> int:
        # physical at-rest bytes per row — this is what the pressure
        # policy caps and what the obs byte gauges report
        return self.codec.width * jnp.dtype(self.codec.dtype).itemsize

    def _fill_value(self):
        return jnp.asarray(self.codec.fill, self.codec.dtype)

    def _rows_for_storage(self, rows):
        if isinstance(self.codec, TokenCodec):
            self._widen_tokens(int(tokens_needed(rows).max()))
        return self.codec.encode(rows)

    def _row_contrib(self, mask):
        # decode-and-count through the kernels/ops dispatch (jnp oracle
        # off-TPU, Pallas on TPU) — exact: integer counts in f32
        if self.codec.kind == "packed":
            return ops.packed_count(self.R, mask, n=self.n)
        return ops.token_count(self.R, mask, n=self.n)

    def _compress_step(self) -> bool:
        ladder = self.policy.ladder if self.policy is not None else ()
        nxt = _ladder_next(self.codec.kind, ladder)
        if nxt is None:
            return False
        if nxt == "compressed":
            # token width covering every resident row (fill rows decode
            # to all-zero bits and need 0 tokens)
            need = int(jnp.max(tokens_needed(self.codec.decode(self.R)),
                               initial=0))
            new_codec = codec_for(nxt, self.n,
                                  s_pad=next_pow2(max(need, 1),
                                                  MIN_TOKEN_PAD))
        else:
            new_codec = codec_for(nxt, self.n)
        self.R = _recode(self.R, codec_from=self.codec, codec_to=new_codec)
        self.codec = new_codec
        self.version += 1
        obs.counter("store.compress_steps").add(1)
        return True

    def _widen_tokens(self, s_need: int):
        new_s = next_pow2(s_need, self.codec.s_pad)
        if new_s == self.codec.s_pad:
            return
        pad = jnp.full((self.capacity, new_s - self.codec.s_pad),
                       self._fill_value(), self.codec.dtype)
        self.R = jnp.concatenate([self.R, pad], axis=1)
        self.codec = TokenCodec(self.n, new_s)
        self.version += 1

    # -------------------------------------------------------- RRR store ----

    def add_batch(self, visited, counter=None) -> np.ndarray:
        with obs.span("store.write", tier="store", kind=self.codec.kind):
            visited = jnp.asarray(visited).astype(jnp.uint8)
            B = int(visited.shape[0])
            batch_sizes = visited.sum(axis=1, dtype=jnp.int32)
            if isinstance(self.codec, TokenCodec):
                self._widen_tokens(int(tokens_needed(visited).max()))
            self._ensure_room(B)
            self._grow_rows(self.count + B)
            if counter is None:
                counter = visited.sum(axis=0, dtype=jnp.int32)
            slots = np.arange(self.count, self.count + B, dtype=np.int64)
            self.R = _encode_write(self.R, visited, jnp.int32(self.count),
                                   codec=self.codec)
            self._finish_add(batch_sizes, counter)
        return slots

    def view(self) -> StoreView:
        return StoreView(self.representation, self.R, self._valid(),
                         self.n, self.count)

    def index_view(self, l_pad: int) -> StoreView:
        """Lazy C4 conversion (decode is a jit temporary); cached until
        the arena next changes."""
        key = (self.version, int(l_pad))
        if self._idx_cache is None or self._idx_cache[0] != key:
            R_idx = jax.jit(
                lambda R: bitmap_to_indices(self.codec.decode(R),
                                            int(l_pad)))(self.R)
            self._idx_cache = (key, R_idx)
        return StoreView("indices", self._idx_cache[1], self._valid(),
                         self.n, self.count)

    def hits(self, S) -> jnp.ndarray:
        with obs.span("count", tier="store", kind=self.codec.kind):
            return _codec_hits(self.R, self._valid(),
                               jnp.asarray(S, jnp.int32), codec=self.codec)

    def state(self) -> dict:
        """Host snapshot: the *encoded* arena plus counters; kind tag is
        the codec kind (``"packed"``/``"compressed"``)."""
        st = self._base_state()
        st["kind"] = np.asarray(self.codec.kind)
        st["R"] = np.asarray(self.R)
        return st

    @classmethod
    def from_state(cls, st) -> "CodecStore":
        kind = str(st["kind"])
        R = np.asarray(st["R"])
        store = cls.__new__(cls)
        _ArenaBase.__init__(store, int(st["n"]), capacity=R.shape[0])
        store.codec = (codec_for(kind, store.n, s_pad=R.shape[1])
                       if kind == "compressed"
                       else codec_for(kind, store.n))
        store._idx_cache = None
        store.R = jnp.asarray(R, store.codec.dtype)
        store.sizes = jnp.asarray(st["sizes"], jnp.int32)
        store.counter = jnp.asarray(st["counter"], jnp.int32)
        store.count = int(st["count"])
        _restore_live(store, st)
        return store

    @classmethod
    def from_rows(cls, rows, n: int, *, policy=None) -> "CodecStore":
        """Build a store holding exactly ``rows (count, n) uint8`` bit
        rows — the cross-layout restore path.  ``_restore_slots`` maps
        snapshot row -> slot for streaming provenance."""
        store = cls(int(n), capacity=max(int(rows.shape[0]), MIN_CAPACITY),
                    policy=policy)
        if rows.shape[0]:
            store._restore_slots = store.add_batch(
                jnp.asarray(rows, jnp.uint8))
        else:
            store._restore_slots = np.zeros((0,), np.int64)
        return store


class PackedBitmapStore(CodecStore):
    """Bit-packed arena: ``(capacity, ceil(n/8)) uint8`` — 8x smaller at
    rest than `BitmapStore`, bitwise-identical in every answer."""
    _initial_kind = "packed"


class CompressedStore(CodecStore):
    """Compressed-at-rest arena: per-row literal/run token lists
    (``(capacity, s_pad) int32``), decode-and-count on every read."""
    _initial_kind = "compressed"


# register with the store factory (engine imports repro.core.pack;
# make_store/store_from_state lazy-import it)
from repro.core import store as _store_mod  # noqa: E402

_store_mod.STORE_KINDS["packed"] = PackedBitmapStore
_store_mod.STORE_KINDS["compressed"] = CompressedStore
