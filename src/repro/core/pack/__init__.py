"""IMPack: bit-packed and compressed-at-rest RRR arenas.

Importing this package registers `PackedBitmapStore` and
`CompressedStore` in `repro.core.store.STORE_KINDS` and their selection
strategies in `repro.core.selection.SELECTION_STRATEGIES` (the engine
imports it; `make_store`/`store_from_state` lazy-import it).
"""
from repro.core.pack.codec import (  # noqa: F401
    BitmapCodec,
    PackedCodec,
    TokenCodec,
    codec_for,
    pack_bits,
    pack_bits_np,
    tokens_needed,
    unpack_bits,
    unpack_bits_np,
)
from repro.core.pack.stores import (  # noqa: F401
    CompressedStore,
    PackedBitmapStore,
)
from repro.core.pack.selection import (  # noqa: F401
    select_compressed,
    select_packed,
)
