"""IMM (Influence Maximization via Martingales) — EfficientIMM edition.

The paper's primary contribution, as a composable JAX module:
  * martingale.py  — Tang'15 sampling bounds (theta estimation, OPT LB)
  * sampler.py     — batched RRR-set generation (IC dense/sparse, LT walk)
                     with fused in-place counter accumulation (paper C3)
  * selection.py   — greedy max-coverage: EfficientIMM RRR-partitioned
                     rebuild (C1+C5) and Ripples-style decremental baseline
  * adaptive.py    — bitmap vs index-list representation choice (C4)
  * imm.py         — Algorithm-1 driver + mesh-sharded selection/sampling
"""
from repro.core.martingale import IMMBounds, compute_bounds, theta_from_lb
from repro.core.sampler import (
    sample_ic_dense,
    sample_ic_sparse,
    sample_lt,
)
from repro.core.selection import (
    greedy_select,
    select_dense,
    select_sparse,
    select_dense_sharded,
)
from repro.core.adaptive import choose_representation, bitmap_to_indices, indices_to_bitmap
from repro.core.imm import imm, IMMResult, IMMConfig

__all__ = [
    "IMMBounds", "compute_bounds", "theta_from_lb",
    "sample_ic_dense", "sample_ic_sparse", "sample_lt",
    "greedy_select", "select_dense", "select_sparse", "select_dense_sharded",
    "choose_representation", "bitmap_to_indices", "indices_to_bitmap",
    "imm", "IMMResult", "IMMConfig",
]
