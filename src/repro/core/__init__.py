"""IMM (Influence Maximization via Martingales) — EfficientIMM edition.

The paper's primary contribution, as a composable JAX module:
  * martingale.py  — Tang'15 sampling bounds (theta estimation, OPT LB)
  * sampler.py     — batched RRR-set generation composed from orthogonal
                     axes: `DiffusionModel` (IC / WC / GT coin models, LT
                     walk) x `TraversalBackend` (dense log-semiring,
                     sparse edge-list, Pallas MXU kernel, walk) x a
                     delta-stability flag, with fused in-place counter
                     accumulation (paper C3) and the sampler registry
                     (`make_sampler` compositions + legacy aliases) the
                     engine resolves by name
  * selection.py   — greedy max-coverage: EfficientIMM RRR-partitioned
                     rebuild (C1+C5), Ripples-style decremental baseline,
                     and the `SelectionStrategy` registry
  * adaptive.py    — bitmap vs index-list representation choice (C4)
  * store.py       — preallocated RRR arenas (BitmapStore / IndexStore /
                     mesh-sharded ShardedStore, paper C1 end-to-end)
  * engine.py      — `InfluenceEngine`: Algorithm 1 + incremental
                     extend/select/influence multi-query serving and
                     snapshot/restore resumability
  * imm.py         — one-shot ``imm(graph, cfg)`` back-compat wrapper
"""
from repro.core.martingale import IMMBounds, compute_bounds, theta_from_lb
from repro.core.sampler import (
    sample_ic_dense,
    sample_ic_sparse,
    sample_lt,
    CoinModel,
    WalkModel,
    TraversalBackend,
    make_sampler,
    sampler_matrix,
    composed_name,
    stable_variant,
    register_model,
    get_model,
    registered_models,
    register_backend,
    get_backend,
    registered_backends,
    register_sampler,
    get_sampler,
    registered_samplers,
    default_sampler_name,
)
from repro.core.selection import (
    greedy_select,
    select_dense,
    select_sparse,
    select_dense_sharded,
    register_selection,
    get_selection,
)
from repro.core.adaptive import (
    choose_representation, bitmap_to_indices, indices_to_bitmap, l_pad_for,
)
from repro.core.store import (
    RRRStore, StoreView, BitmapStore, IndexStore, ShardedStore, make_store,
    store_from_state,
)
from repro.core.engine import (
    InfluenceEngine, Selection, IMMResult, IMMConfig,
)
from repro.core.imm import imm

__all__ = [
    "IMMBounds", "compute_bounds", "theta_from_lb",
    "sample_ic_dense", "sample_ic_sparse", "sample_lt",
    "CoinModel", "WalkModel", "TraversalBackend",
    "make_sampler", "sampler_matrix", "composed_name", "stable_variant",
    "register_model", "get_model", "registered_models",
    "register_backend", "get_backend", "registered_backends",
    "register_sampler", "get_sampler", "registered_samplers",
    "default_sampler_name",
    "greedy_select", "select_dense", "select_sparse", "select_dense_sharded",
    "register_selection", "get_selection",
    "choose_representation", "bitmap_to_indices", "indices_to_bitmap",
    "l_pad_for",
    "RRRStore", "StoreView", "BitmapStore", "IndexStore", "ShardedStore",
    "make_store", "store_from_state",
    "InfluenceEngine", "Selection",
    "imm", "IMMResult", "IMMConfig",
]
