"""Martingale sampling bounds from Tang, Shi, Xiao (SIGMOD'15), as used by
IMM Algorithm 1 (paper Alg. 1: Theta_Estimation / OPT_Lower_Bound / Set_Theta).

All quantities are host-side floats (they gate the Python-level sampling
loop); the heavy kernels are jitted elsewhere.
"""
from __future__ import annotations

import dataclasses
import math


def log_comb(n: int, k: int) -> float:
    """log(n choose k) via lgamma."""
    if k < 0 or k > n:
        return float("-inf")
    return (
        math.lgamma(n + 1) - math.lgamma(k + 1) - math.lgamma(n - k + 1)
    )


@dataclasses.dataclass(frozen=True)
class IMMBounds:
    n: int
    k: int
    eps: float
    ell: float           # adjusted ell' = ell * (1 + log 2 / log n)
    eps_prime: float     # sqrt(2) * eps
    lam_prime: float     # sampling-phase lambda'
    lam_star: float      # selection-phase lambda*
    max_rounds: int      # ceil(log2 n) - 1


def compute_bounds(n: int, k: int, eps: float, ell: float = 1.0) -> IMMBounds:
    n = max(int(n), 2)
    logn = math.log(n)
    # Tang'15 §4.2: replace ell by ell' so the union bound over the sampling
    # rounds still yields an overall 1 - 1/n^ell guarantee.
    ell_adj = ell * (1.0 + math.log(2.0) / logn)
    eps_p = math.sqrt(2.0) * eps
    logcnk = log_comb(n, k)
    loglog2n = math.log(max(math.log2(n), 1.0 + 1e-9))
    lam_prime = (
        (2.0 + 2.0 / 3.0 * eps_p)
        * (logcnk + ell_adj * logn + loglog2n)
        * n
        / (eps_p * eps_p)
    )
    alpha = math.sqrt(ell_adj * logn + math.log(2.0))
    beta = math.sqrt((1.0 - 1.0 / math.e) * (logcnk + ell_adj * logn + math.log(2.0)))
    lam_star = 2.0 * n * ((1.0 - 1.0 / math.e) * alpha + beta) ** 2 / (eps * eps)
    max_rounds = max(int(math.ceil(math.log2(n))) - 1, 1)
    return IMMBounds(
        n=n, k=k, eps=eps, ell=ell_adj, eps_prime=eps_p,
        lam_prime=lam_prime, lam_star=lam_star, max_rounds=max_rounds,
    )


def round_theta(bounds: IMMBounds, round_i: int) -> int:
    """theta_i = lambda' / x_i with x_i = n / 2^i (Alg. 1 sampling phase)."""
    x = bounds.n / (2.0 ** round_i)
    return int(math.ceil(bounds.lam_prime / x))


def round_target(bounds: IMMBounds, round_i: int) -> float:
    """Coverage target (1 + eps') * x_i that certifies the OPT lower bound."""
    x = bounds.n / (2.0 ** round_i)
    return (1.0 + bounds.eps_prime) * x


def lower_bound_from_coverage(bounds: IMMBounds, frac_covered: float) -> float:
    """OPT lower bound n*F(S)/(1+eps') once the round target is met."""
    return bounds.n * frac_covered / (1.0 + bounds.eps_prime)


def theta_from_lb(bounds: IMMBounds, lb: float) -> int:
    """Final theta = lambda* / LB (Alg. 1 Set_Theta)."""
    return int(math.ceil(bounds.lam_star / max(lb, 1.0)))
