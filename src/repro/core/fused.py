"""Fused sample->write->count extenders — the PR 10 kernel chain.

The historical `InfluenceEngine.extend` loop makes two device calls per
batch: the bound sampler returns a full ``(B, n)`` row block, then
``store.add_batch`` re-reads that block to encode/write arena tiles and
update the fused counter.  The batch therefore rests in HBM once purely
as a hand-off buffer.  This module inlines the sampler trace into the
same program as the arena commit (`repro.kernels.ops.arena_commit` —
Pallas on TPU, interpret for CPU validation, jnp oracle otherwise), so
the decoded ``(B, n)`` batch only ever exists as a jit temporary and XLA
is free to fuse the frontier loop's final state straight into the
encode + column-count pass.  What crosses the jit boundary is the
batch's *at-rest* arena block with its per-vertex counts already folded
— nothing the store has to re-read, re-encode, or re-count.

The single-device chain is deliberately TWO jits, not one: the
expensive program (sample -> encode -> count) closes over only
fixed-per-cfg shapes, so it compiles once per at-rest kind, while the
arena slice-write lives in a separate module-level jit whose shape
follows the pow2 capacity ladder.  Folding the write into the chain
would retrace the whole sampler at every capacity doubling — the
write's program is `dynamic_update_slice` + one add, so it is the right
side of the boundary to recompile.  The sharded chain splits along the
same line, per tile inside shard_map (`_make_sharded_chain` /
`_sharded_commit_fn`).

Bitwise equivalence with the two-call path is structural, not hoped-for:

  * the engine hands the extender the *bound* (already-jitted) sampler;
    calling a jitted function inside an outer jit inlines the identical
    trace, so the PRNG stream and every sampled bit match the unfused
    path seed-for-seed;
  * the sharded chain computes each tile's encoded block, live-masked
    vertex-axis size psum, and counter partial with the same per-tile
    arithmetic (and specs) as the unfused `_tile_write_body`, so arena
    bytes, sizes, counter partials and counts commit the same values;
  * the single-device chain writes `arena_commit`'s output, whose
    ``stored`` is bitwise-equal to the store codec's ``encode`` and whose
    ``colsum`` is the exact int32 column sum every sampler reports as its
    fused C3 contribution.

``extend_once(key) -> bool`` returns False when the store's *current*
at-rest form is outside the fused chain's coverage (token-compressed
tiles, or a pressure-ladder morph that lands there mid-write) — the
engine then falls back to the historical path with the SAME batch key,
so the sample stream is preserved across the boundary.
"""
from __future__ import annotations

from functools import lru_cache, partial

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.store import (
    BitmapStore, ShardedStore, _psum_if,
)
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
from repro.kernels import ops as kops

# the at-rest forms arena_commit covers; token-compressed rows fall back
_FUSED_KINDS = ("bitmap", "packed")


def make_fused_extender(store, sample, cfg, *, sampler_name: str):
    """The fused extender for ``(store, bound sampler)``, or None when
    the store kind has no fused chain (IndexStore emits index lists; the
    chain is dense-at-rest only)."""
    interpret = bool(getattr(cfg, "pallas_interpret", False))
    batch = int(cfg.batch)
    if isinstance(store, ShardedStore):
        return _ShardedFused(store, sample, batch,
                             sampler_name=sampler_name)
    from repro.core.pack.stores import CodecStore
    if isinstance(store, (BitmapStore, CodecStore)):
        return _ArenaFused(store, sample, batch, interpret=interpret,
                           sampler_name=sampler_name)
    return None


def _make_chain_fn(sample, kind: str, interpret: bool):
    """The fixed-shape half of a single-device fused batch: sample ->
    arena_commit (encode + column count in one kernel pass).  Shapes
    depend only on (batch, n), never on arena capacity, so this — the
    program that contains the whole sampler trace — compiles exactly
    once per at-rest kind."""

    @jax.jit
    def chain(key):
        visited, _, _ = sample(key)
        visited = visited.astype(jnp.uint8)
        stored, colsum = kops.arena_commit(visited, kind=kind,
                                           interpret=interpret)
        return stored, visited.sum(axis=1, dtype=jnp.int32), colsum

    return chain


@partial(jax.jit, donate_argnums=(0, 1, 2))
def _commit_write(R, sizes, counter, stored, batch_sizes, colsum, start):
    """The capacity-shaped half: donated in-place arena writes.  Tiny
    program — recompiling it at each pow2 capacity growth costs what the
    unfused path's `_write_rows` already pays, and being module-level
    its cache is shared by every engine in the process."""
    R = jax.lax.dynamic_update_slice(R, stored, (start, jnp.int32(0)))
    sizes = jax.lax.dynamic_update_slice(sizes, batch_sizes, (start,))
    return R, sizes, counter + colsum


class _ArenaFused:
    """Fused extender over the single-device arenas (`BitmapStore`,
    packed `CodecStore`).  One compiled chain per at-rest kind, cached —
    a pressure-ladder morph to tokens makes `extend_once` decline."""

    def __init__(self, store, sample, batch: int, *, interpret: bool,
                 sampler_name: str):
        self.store = store
        self._sample = sample
        self.batch = batch
        self.interpret = interpret
        self.sampler_name = sampler_name
        self._fns: dict = {}

    def extend_once(self, key) -> bool:
        s = self.store
        if s.representation not in _FUSED_KINDS:
            return False
        B = self.batch
        s._ensure_room(B)
        kind = s.representation
        if kind not in _FUSED_KINDS:
            # the compress ladder just morphed to token rows; the legacy
            # path (same key) handles token widening
            return False
        s._grow_rows(s.count + B)
        fn = self._fns.get(kind)
        if fn is None:
            fn = self._fns[kind] = _make_chain_fn(
                self._sample, kind, self.interpret)
        # chain and commit are separate device calls, so the spans are
        # siblings under the engine's extend — the same topology the
        # unfused sample + add_batch path reports
        with obs.span("sample", tier="engine", sampler=self.sampler_name,
                      fused=True):
            stored, batch_sizes, colsum = fn(key)
        with obs.span("store.write", tier="store", kind=kind,
                      fused=True):
            s.R, s.sizes, s.counter = _commit_write(
                s.R, s.sizes, s.counter, stored, batch_sizes, colsum,
                jnp.int32(s.count))
        s._note_write(B)
        return True


def _make_sharded_chain(sample, store, batch: int):
    """The fixed-shape half of a meshed fused batch: sample (shard-local
    placement) -> column layout -> per-tile encode + size/counter
    partials, under the same specs and the same per-tile arithmetic as
    the unfused `_tile_write_body` — encode from bit rows, live-masked
    vertex-axis psum for sizes, tile-local counter partial — so every
    committed value is bitwise the unfused one.  No arena operand means
    no recompile when the capacity ladder grows."""
    s = store
    codec, vertex_axis = s._codec_arg, s.vertex_axis
    sp_rows, sp_vec = P(s.theta_axes, s.vertex_axis), P(s.theta_axes)

    def tile(rows, incs):
        stored = rows if codec is None else codec.encode(rows)
        live = jnp.arange(rows.shape[0], dtype=jnp.int32) < incs[0]
        row_sizes = _psum_if(rows.sum(axis=1, dtype=jnp.int32),
                             vertex_axis)
        row_sizes = jnp.where(live, row_sizes, 0)
        counter_d = rows.sum(axis=0, dtype=jnp.int32)[None, :]
        return stored, row_sizes, counter_d

    enc = shard_map(tile, mesh=s.mesh, in_specs=(sp_rows, sp_vec),
                    out_specs=(sp_rows, sp_vec, sp_rows))
    b = -(-batch // s.D)
    pad = b * s.D - batch

    @jax.jit
    def chain(key, incs):
        visited, _, _ = sample(key)
        visited = s._layout_cols(visited.astype(jnp.uint8))
        if pad:
            visited = jnp.concatenate(
                [visited, jnp.zeros((pad, s.n_pad), jnp.uint8)])
        visited = jax.lax.with_sharding_constraint(visited, s._sh_rows)
        return enc(visited, incs)

    return chain


@lru_cache(maxsize=None)
def _sharded_commit_fn(mesh, theta_axes, vertex_axis):
    """The capacity-shaped half: donated per-tile arena writes of an
    already-encoded block plus the pre-computed size/counter partials.
    Cached per (mesh, axes) like `_sharded_write_kernels`, so its (tiny)
    per-capacity compiles are shared by every store in the process."""
    sp_rows, sp_vec = P(theta_axes, vertex_axis), P(theta_axes)

    def tile(R, sizes, counter, counts, stored, row_sizes, counter_d,
             incs):
        start = counts[0]
        R = jax.lax.dynamic_update_slice(R, stored, (start, jnp.int32(0)))
        sizes = jax.lax.dynamic_update_slice(sizes, row_sizes, (start,))
        return R, sizes, counter + counter_d, counts + incs

    return jax.jit(
        shard_map(tile, mesh=mesh,
                  in_specs=(sp_rows, sp_vec, sp_rows, sp_vec,
                            sp_rows, sp_vec, sp_rows, sp_vec),
                  out_specs=(sp_rows, sp_vec, sp_rows, sp_vec)),
        donate_argnums=(0, 1, 2, 3))


class _ShardedFused:
    """Fused extender over `ShardedStore` bitmap/packed tiles.  Chains
    are cached per tile codec (``_codec_arg``), so a ladder morph from
    bitmap to packed tiles recompiles once and keeps fusing; a morph to
    token tiles declines to the legacy path."""

    def __init__(self, store, sample, batch: int, *, sampler_name: str):
        self.store = store
        self._sample = sample
        self.batch = batch
        self.sampler_name = sampler_name
        b = -(-batch // store.D)
        self._incs_np = np.clip(
            batch - np.arange(store.D) * b, 0, b).astype(np.int32)
        self._b = b
        self._fns: dict = {}

    def extend_once(self, key) -> bool:
        s = self.store
        if s.codec.kind not in _FUSED_KINDS:
            return False
        s._ensure_room(self._b)
        if s.codec.kind not in _FUSED_KINDS:
            return False
        s._grow_rows(self._b)
        fn = self._fns.get(s._codec_arg)
        if fn is None:
            fn = self._fns[s._codec_arg] = _make_sharded_chain(
                self._sample, s, self.batch)
        commit = _sharded_commit_fn(s.mesh, s.theta_axes, s.vertex_axis)
        incs = jax.device_put(jnp.asarray(self._incs_np), s._sh_vec)
        with obs.span("sample", tier="engine", sampler=self.sampler_name,
                      fused=True):
            stored, row_sizes, counter_d = fn(key, incs)
        with obs.span("store.write", tier="store", kind=s.codec.kind,
                      fused=True):
            s.R, s.sizes, s._counter, s._counts = commit(
                s.R, s.sizes, s._counter, s._counts, stored,
                row_sizes, counter_d, incs)
            s._counts_host += self._incs_np
        s._note_write(self.batch)
        return True
