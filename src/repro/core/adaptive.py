"""Adaptive RRRset representation (paper C4).

Bitmaps cost n bits per set and give O(1) membership + MXU mat-vec counters;
index lists cost 32·L bits and give O(L) scatter counters.  The paper switches
per-set; under SPMD we switch per-*batch* (shape stability), using the same
byte/compute trade-off: prefer bitmaps once the average set covers more than
``1/switch_ratio`` of the graph (default 1/32 — the int32-vs-bit storage
break-even), or when the padded index length would exceed the bitmap width.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def choose_representation(avg_coverage: float, n: int, l_max: int,
                          switch_ratio: int = 32) -> str:
    """Returns "bitmap" or "indices" (paper's dynamic threshold)."""
    if l_max * switch_ratio >= n:
        return "bitmap"
    return "bitmap" if avg_coverage > 1.0 / switch_ratio else "indices"


def l_pad_for(l_max: int) -> int:
    """Padded index-list width for an observed max set size: next power of
    two, floor 4 — the shape the selection kernels compile against."""
    return 1 << max(int(math.ceil(math.log2(max(l_max, 1)))), 2)


def bitmap_to_indices(R, l_max: int):
    """(theta, n) uint8 -> (theta, l_max) int32 index lists, sentinel n.

    Sets longer than l_max are truncated — callers size l_max from the
    observed max set size (the paper sizes its adaptive threshold the same
    way).  Indices are emitted in ascending order (sorted sets, as Ripples
    keeps them).
    """
    theta, n = R.shape

    def row(r):
        # top_k over (flag, -index) picks set members first, ascending ids
        score = r.astype(jnp.int32) * n - jnp.arange(n, dtype=jnp.int32)
        vals, idx = jax.lax.top_k(score, l_max)
        return jnp.where(vals > 0, idx, n).astype(jnp.int32)

    out = jax.vmap(row)(R)
    return jnp.sort(out, axis=1)


def indices_to_bitmap(R_idx, n: int):
    """(theta, L) int32 (sentinel >= n) -> (theta, n) uint8."""
    theta, L = R_idx.shape
    R = jnp.zeros((theta, n), jnp.uint8)
    ones = jnp.ones(R_idx.shape, jnp.uint8)
    return R.at[jnp.arange(theta)[:, None], R_idx].max(ones, mode="drop")


def set_sizes(R_or_idx, representation: str, n: int):
    if representation == "bitmap":
        return R_or_idx.sum(axis=1, dtype=jnp.int32)
    return (R_or_idx < n).sum(axis=1, dtype=jnp.int32)
