"""Find_Most_Influential_Set (paper Alg. 2) — greedy max-coverage.

Two strategies, both over bitmap ``R (theta, n) uint8`` or index-list
``R_idx (theta, L) int32`` representations:

  * ``method="rebuild"``   — EfficientIMM (paper C5 "adaptive counter
    update"): every round recomputes the counter from the *surviving* sets:
    ``counter = alive @ R`` — on TPU a masked mat-vec that runs on the MXU
    (Pallas kernel: kernels/coverage_matvec.py / fused_select.py).
  * ``method="decrement"`` — Ripples-faithful baseline: keep a running
    counter and subtract the contribution of the sets covered by the newly
    selected seed.

The two are algebraically identical (property-tested); their cost profiles
differ exactly as the paper describes — with skewed graphs most sets contain
the first seeds, so the decremental update touches far more rows.

``select_dense_sharded`` is the multi-device version (paper C1 RRRset
partitioning, end-to-end since the `ShardedStore` rework): the theta axis
of ``R`` is sharded across the mesh and each device reduces over *its own
resident arena shard* — when fed a ``ShardedStore`` view the input specs
match the store's native ``P(theta_axes, None)`` layout, so no arena data
moves on entry.  Per greedy round only reduced quantities cross devices
(the ``(n,)`` counter psum standing in for the paper's atomic adds, and a
scalar gain); arena rows never do.  Both counter-update methods exist as
true implementations here: ``rebuild`` re-reduces the surviving local rows
every round (C5), ``decrement`` keeps a *local partial counter* per shard
and subtracts the covered local rows' contribution — the running-counter
baseline, executed shard-locally.

The `SelectionStrategy` registry at the bottom exposes all of these to the
`InfluenceEngine` as ``(method, layout)`` pairs — rebuild/decrement x
dense/sparse/sharded — so new strategies plug in via ``register_selection``
instead of growing an if/elif ladder in the driver.

Every strategy treats ``valid`` as an *arbitrary* row mask, not a prefix:
``alive`` starts from it, the counter reduction masks by it, and
``covered_frac`` normalizes by its popcount.  The streaming subsystem
(``repro.stream``) leans on exactly this contract — a `GraphDelta` clears
the live bits of stale RRR rows and they drop out of the very next
``select``/``hits`` with no rebuild and no kernel changes here.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.sparse.scatter import bincount_weighted


# ---------------------------------------------------------------- dense ----

@partial(jax.jit, static_argnames=("k", "method"))
def select_dense(R, valid, k: int, method: str = "rebuild"):
    """R: (theta, n) uint8 bitmaps; valid: (theta,) bool (generated sets).

    Single-device (arrays replicated / unsharded); ``valid`` may be any
    mask.  Returns (seeds (k,) int32, covered_frac () f32,
    gains (k,) int32).
    """
    theta, n = R.shape
    Rf = R.astype(jnp.float32)
    alive0 = valid

    def rebuild_round(alive):
        counter = alive.astype(jnp.float32) @ Rf            # (n,)
        v = jnp.argmax(counter).astype(jnp.int32)
        covered = (R[:, v] > 0) & alive
        gain = covered.sum(dtype=jnp.int32)
        return v, gain, alive & ~covered, counter

    if method == "rebuild":
        def body(i, state):
            alive, seeds, gains = state
            v, gain, alive, _ = rebuild_round(alive)
            return alive, seeds.at[i].set(v), gains.at[i].set(gain)

        alive, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (alive0, jnp.zeros((k,), jnp.int32), jnp.zeros((k,), jnp.int32)),
        )
    elif method == "decrement":
        counter0 = alive0.astype(jnp.float32) @ Rf

        def body(i, state):
            alive, counter, seeds, gains = state
            v = jnp.argmax(counter).astype(jnp.int32)
            covered = (R[:, v] > 0) & alive
            gain = covered.sum(dtype=jnp.int32)
            counter = counter - covered.astype(jnp.float32) @ Rf
            return (alive & ~covered, counter,
                    seeds.at[i].set(v), gains.at[i].set(gain))

        alive, _, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (alive0, counter0, jnp.zeros((k,), jnp.int32),
             jnp.zeros((k,), jnp.int32)),
        )
    else:
        raise ValueError(f"unknown method {method}")

    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)
    covered_frac = gains.sum(dtype=jnp.float32) / n_valid
    return seeds, covered_frac, gains


# --------------------------------------------------------------- sparse ----

@partial(jax.jit, static_argnames=("n", "k", "method"))
def select_sparse(R_idx, valid, n: int, k: int, method: str = "rebuild"):
    """R_idx: (theta, L) int32 with sentinel ``n`` padding; valid:
    (theta,) bool.  Single-device.  Returns (seeds (k,) int32,
    covered_frac () f32, gains (k,) int32)."""
    theta, L = R_idx.shape

    def counter_of(alive):
        return bincount_weighted(R_idx, alive.astype(jnp.float32)[:, None], n)

    def contains(v):
        return (R_idx == v).any(axis=1)

    if method == "rebuild":
        def body(i, state):
            alive, seeds, gains = state
            counter = counter_of(alive)
            v = jnp.argmax(counter).astype(jnp.int32)
            covered = contains(v) & alive
            gain = covered.sum(dtype=jnp.int32)
            return alive & ~covered, seeds.at[i].set(v), gains.at[i].set(gain)

        alive, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (valid, jnp.zeros((k,), jnp.int32), jnp.zeros((k,), jnp.int32)),
        )
    elif method == "decrement":
        counter0 = counter_of(valid)

        def body(i, state):
            alive, counter, seeds, gains = state
            v = jnp.argmax(counter).astype(jnp.int32)
            covered = contains(v) & alive
            gain = covered.sum(dtype=jnp.int32)
            counter = counter - bincount_weighted(
                R_idx, covered.astype(jnp.float32)[:, None], n)
            return (alive & ~covered, counter,
                    seeds.at[i].set(v), gains.at[i].set(gain))

        alive, _, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (valid, counter0, jnp.zeros((k,), jnp.int32),
             jnp.zeros((k,), jnp.int32)),
        )
    else:
        raise ValueError(f"unknown method {method}")

    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)
    return seeds, gains.sum(dtype=jnp.float32) / n_valid, gains


# -------------------------------------------------------------- sharded ----

def select_dense_sharded(mesh, R, valid, k: int, *,
                         theta_axes=("data",), vertex_axis=None,
                         method: str = "rebuild"):
    """EfficientIMM selection with the theta axis sharded over ``theta_axes``
    (paper C1) and, optionally, the vertex axis over ``vertex_axis``.

    ``R (theta, n) uint8`` and ``valid (theta,) bool`` enter with specs
    ``P(theta_axes, vertex_axis)`` / ``P(theta_axes)`` — a `ShardedStore`
    view already carries exactly this layout (with ``vertex_axis=None``),
    so its arena shards are consumed in place; replicated arrays are
    scattered on entry.  ``valid`` may be any mask, not just a prefix —
    sharded stores fill each shard independently.

    Inside shard_map each device owns a ``(theta_local, n[_local])`` block.
    Per greedy round only reduced quantities cross devices: the ``(n,)``
    counter ``psum`` (the paper's atomic global counter) and the scalar
    gain — never arena rows.  The greedy argmax is computed redundantly on
    every device (cheap, avoids a broadcast).

    ``method="rebuild"`` re-reduces the surviving local rows every round
    (C5).  ``method="decrement"`` is the true decremental update executed
    shard-locally: each device keeps a partial counter over its own rows
    and subtracts the contribution of its newly-covered rows, so the
    running global counter is ``psum`` of partials.  Both are exact over
    integer-valued f32 counts and return identical selections.

    Returns replicated ``(seeds (k,) int32, covered_frac () f32,
    gains (k,) int32)``.
    """
    axes = tuple(theta_axes)
    if method not in ("rebuild", "decrement"):
        raise ValueError(f"unknown method {method}")

    def local_select(R_local, valid_local):
        Rf = R_local.astype(jnp.float32)

        def pick(counter, alive):
            """Greedy argmax over the global counter -> (v, covered)."""
            if vertex_axis is not None:
                # vertex-sharded counter: argmax over local block, then a
                # global argmax over (value, global index) pairs.
                nloc = counter.shape[0]
                vloc = jnp.argmax(counter)
                val = counter[vloc]
                shard = jax.lax.axis_index(vertex_axis)
                gidx = shard * nloc + vloc
                vals = jax.lax.all_gather(val, vertex_axis)
                gidxs = jax.lax.all_gather(gidx, vertex_axis)
                v = gidxs[jnp.argmax(vals)].astype(jnp.int32)
                member = (R_local[:, jnp.clip(v - shard * nloc, 0, nloc - 1)]
                          > 0)
                member = jnp.where(
                    (v >= shard * nloc) & (v < (shard + 1) * nloc),
                    member, False)
                member = jax.lax.psum(
                    member.astype(jnp.int32), vertex_axis) > 0
            else:
                v = jnp.argmax(counter).astype(jnp.int32)
                member = R_local[:, v] > 0
            return v, member & alive

        if method == "rebuild":
            def body(i, state):
                alive, seeds, gains = state
                counter = jax.lax.psum(alive.astype(jnp.float32) @ Rf, axes)
                v, covered = pick(counter, alive)
                gain = jax.lax.psum(covered.sum(dtype=jnp.int32), axes)
                return (alive & ~covered,
                        seeds.at[i].set(v), gains.at[i].set(gain))

            alive, seeds, gains = jax.lax.fori_loop(
                0, k, body,
                (valid_local, jnp.zeros((k,), jnp.int32),
                 jnp.zeros((k,), jnp.int32)),
            )
        else:
            partial0 = valid_local.astype(jnp.float32) @ Rf

            def body(i, state):
                alive, partial, seeds, gains = state
                counter = jax.lax.psum(partial, axes)
                v, covered = pick(counter, alive)
                gain = jax.lax.psum(covered.sum(dtype=jnp.int32), axes)
                partial = partial - covered.astype(jnp.float32) @ Rf
                return (alive & ~covered, partial,
                        seeds.at[i].set(v), gains.at[i].set(gain))

            alive, _, seeds, gains = jax.lax.fori_loop(
                0, k, body,
                (valid_local, partial0, jnp.zeros((k,), jnp.int32),
                 jnp.zeros((k,), jnp.int32)),
            )
        n_valid = jnp.maximum(
            jax.lax.psum(valid_local.sum(dtype=jnp.float32), axes), 1.0)
        return seeds, gains.sum(dtype=jnp.float32) / n_valid, gains

    in_specs = (P(axes, vertex_axis), P(axes))
    out_specs = (P(), P(), P())
    fn = shard_map(
        local_select, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )
    return fn(R, valid)


def greedy_select(R_or_idx, valid, k: int, *, n: int | None = None,
                  representation: str = "bitmap", method: str = "rebuild"):
    """Unified entry point used by the IMM driver."""
    if representation == "bitmap":
        return select_dense(R_or_idx, valid, k, method)
    if representation == "indices":
        assert n is not None
        return select_sparse(R_or_idx, valid, n, k, method)
    raise ValueError(representation)


# ------------------------------------------------- SelectionStrategy API ----
#
# A strategy is ``fn(view, k, **opts) -> (seeds, covered_frac, gains)`` where
# ``view`` is a ``repro.core.store.StoreView`` (duck-typed: .R, .valid, .n).
# The registry is keyed "<method>-<layout>" with method in
# {rebuild, decrement} and layout in {dense, sparse, sharded}.

SELECTION_STRATEGIES = {}


def register_selection(name: str, fn=None):
    """Register a selection strategy; usable as ``@register_selection(name)``."""
    if fn is None:
        def deco(f):
            SELECTION_STRATEGIES[name] = f
            return f
        return deco
    SELECTION_STRATEGIES[name] = fn
    return fn


def get_selection(method: str, layout: str):
    name = f"{method}-{layout}"
    try:
        return SELECTION_STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"no selection strategy {name!r}; registered: "
            f"{sorted(SELECTION_STRATEGIES)}")


def _dense_strategy(method):
    def run(view, k, **_):
        return select_dense(view.R, view.valid, k, method)
    return run


def _sparse_strategy(method):
    def run(view, k, **_):
        return select_sparse(view.R, view.valid, view.n, k, method)
    return run


def _sharded_strategy(method):
    def run(view, k, *, mesh=None, theta_axes=("data",), vertex_axis=None,
            **_):
        if mesh is None:
            raise ValueError("sharded selection needs a mesh")
        return select_dense_sharded(
            mesh, view.R, view.valid, k,
            theta_axes=theta_axes, vertex_axis=vertex_axis, method=method)
    return run


for _m in ("rebuild", "decrement"):
    register_selection(f"{_m}-dense", _dense_strategy(_m))
    register_selection(f"{_m}-sparse", _sparse_strategy(_m))
    register_selection(f"{_m}-sharded", _sharded_strategy(_m))


# ------------------------------------------- Ripples-faithful baseline ----

@partial(jax.jit, static_argnames=("n", "k"))
def select_vertex_partitioned(R_idx, valid, n: int, k: int):
    """The Ripples work pattern the paper profiles (§III Challenge 1):
    vertices are partitioned across workers and every worker BINARY-SEARCHES
    every (sorted) RRRset for its vertices — O(n * theta * log L) loads per
    counter build vs EfficientIMM's O(theta * L) scatter.  Used as the
    memory-traffic baseline in benchmarks/table4_memory.py.

    R_idx: (theta, L) ascending index lists, sentinel ``n`` padding.
    """
    theta, L = R_idx.shape

    def contains_v(v):
        pos = jnp.clip(
            jax.vmap(lambda row: jnp.searchsorted(row, v))(R_idx), 0, L - 1)
        return jnp.take_along_axis(R_idx, pos[:, None], 1)[:, 0] == v

    def counter_of(alive):
        return jax.vmap(
            lambda v: jnp.sum(contains_v(v) & alive, dtype=jnp.float32)
        )(jnp.arange(n))

    counter0 = counter_of(valid)

    def body(i, state):
        alive, counter, seeds, gains = state
        v = jnp.argmax(counter).astype(jnp.int32)
        covered = contains_v(v) & alive
        gain = covered.sum(dtype=jnp.int32)
        # decremental update: re-search every covered set per vertex
        dec = jax.vmap(
            lambda u: jnp.sum(contains_v(u) & covered, dtype=jnp.float32)
        )(jnp.arange(n))
        return (alive & ~covered, counter - dec,
                seeds.at[i].set(v), gains.at[i].set(gain))

    alive, counter, seeds, gains = jax.lax.fori_loop(
        0, k, body,
        (valid, counter0, jnp.zeros((k,), jnp.int32),
         jnp.zeros((k,), jnp.int32)))
    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)
    return seeds, gains.sum(dtype=jnp.float32) / n_valid, gains
