"""Find_Most_Influential_Set (paper Alg. 2) — greedy max-coverage.

Two strategies, both over bitmap ``R (theta, n) uint8`` or index-list
``R_idx (theta, L) int32`` representations:

  * ``method="rebuild"``   — EfficientIMM (paper C5 "adaptive counter
    update"): every round recomputes the counter from the *surviving* sets:
    ``counter = alive @ R`` — on TPU a masked mat-vec that runs on the MXU
    (Pallas kernel: kernels/coverage_matvec.py / fused_select.py).
  * ``method="decrement"`` — Ripples-faithful baseline: keep a running
    counter and subtract the contribution of the sets covered by the newly
    selected seed.

The two are algebraically identical (property-tested); their cost profiles
differ exactly as the paper describes — with skewed graphs most sets contain
the first seeds, so the decremental update touches far more rows.

``select_dense_sharded`` is the multi-device version (paper C1 RRRset
partitioning, end-to-end since the `ShardedStore` rework): the theta axis
of ``R`` is sharded across the mesh and each device reduces over *its own
resident arena shard* — when fed a ``ShardedStore`` view the input specs
match the store's native ``P(theta_axes, None)`` layout, so no arena data
moves on entry.  Per greedy round only reduced quantities cross devices
(the ``(n,)`` counter psum standing in for the paper's atomic adds, and a
scalar gain); arena rows never do.  Both counter-update methods exist as
true implementations here: ``rebuild`` re-reduces the surviving local rows
every round (C5), ``decrement`` keeps a *local partial counter* per shard
and subtracts the covered local rows' contribution — the running-counter
baseline, executed shard-locally.

The `SelectionStrategy` registry at the bottom exposes all of these to the
`InfluenceEngine` as ``(method, layout)`` pairs — rebuild/decrement x
dense/sparse/sharded — so new strategies plug in via ``register_selection``
instead of growing an if/elif ladder in the driver.

Every strategy treats ``valid`` as an *arbitrary* row mask, not a prefix:
``alive`` starts from it, the counter reduction masks by it, and
``covered_frac`` normalizes by its popcount.  The streaming subsystem
(``repro.stream``) leans on exactly this contract — a `GraphDelta` clears
the live bits of stale RRR rows and they drop out of the very next
``select``/``hits`` with no rebuild and no kernel changes here.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.graphs.partition import vertex_partition
from repro.kernels import ops as kops
from repro.sparse.scatter import bincount_weighted


# ---------------------------------------------------------------- dense ----

@partial(jax.jit, static_argnames=("k", "method"))
def select_dense(R, valid, k: int, method: str = "rebuild"):
    """R: (theta, n) uint8 bitmaps; valid: (theta,) bool (generated sets).

    Single-device (arrays replicated / unsharded); ``valid`` may be any
    mask.  Returns (seeds (k,) int32, covered_frac () f32,
    gains (k,) int32).
    """
    theta, n = R.shape
    Rf = R.astype(jnp.float32)
    alive0 = valid

    def rebuild_round(alive):
        counter = alive.astype(jnp.float32) @ Rf            # (n,)
        v = jnp.argmax(counter).astype(jnp.int32)
        covered = (R[:, v] > 0) & alive
        gain = covered.sum(dtype=jnp.int32)
        return v, gain, alive & ~covered, counter

    if method == "rebuild":
        def body(i, state):
            alive, seeds, gains = state
            v, gain, alive, _ = rebuild_round(alive)
            return alive, seeds.at[i].set(v), gains.at[i].set(gain)

        alive, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (alive0, jnp.zeros((k,), jnp.int32), jnp.zeros((k,), jnp.int32)),
        )
    elif method == "decrement":
        counter0 = alive0.astype(jnp.float32) @ Rf

        def body(i, state):
            alive, counter, seeds, gains = state
            v = jnp.argmax(counter).astype(jnp.int32)
            covered = (R[:, v] > 0) & alive
            gain = covered.sum(dtype=jnp.int32)
            counter = counter - covered.astype(jnp.float32) @ Rf
            return (alive & ~covered, counter,
                    seeds.at[i].set(v), gains.at[i].set(gain))

        alive, _, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (alive0, counter0, jnp.zeros((k,), jnp.int32),
             jnp.zeros((k,), jnp.int32)),
        )
    else:
        raise ValueError(f"unknown method {method}")

    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)
    covered_frac = gains.sum(dtype=jnp.float32) / n_valid
    return seeds, covered_frac, gains


# --------------------------------------------------------------- sparse ----

@partial(jax.jit, static_argnames=("n", "k", "method"))
def select_sparse(R_idx, valid, n: int, k: int, method: str = "rebuild"):
    """R_idx: (theta, L) int32 with sentinel ``n`` padding; valid:
    (theta,) bool.  Single-device.  Returns (seeds (k,) int32,
    covered_frac () f32, gains (k,) int32)."""
    theta, L = R_idx.shape

    def counter_of(alive):
        return bincount_weighted(R_idx, alive.astype(jnp.float32)[:, None], n)

    def contains(v):
        return (R_idx == v).any(axis=1)

    if method == "rebuild":
        def body(i, state):
            alive, seeds, gains = state
            counter = counter_of(alive)
            v = jnp.argmax(counter).astype(jnp.int32)
            covered = contains(v) & alive
            gain = covered.sum(dtype=jnp.int32)
            return alive & ~covered, seeds.at[i].set(v), gains.at[i].set(gain)

        alive, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (valid, jnp.zeros((k,), jnp.int32), jnp.zeros((k,), jnp.int32)),
        )
    elif method == "decrement":
        counter0 = counter_of(valid)

        def body(i, state):
            alive, counter, seeds, gains = state
            v = jnp.argmax(counter).astype(jnp.int32)
            covered = contains(v) & alive
            gain = covered.sum(dtype=jnp.int32)
            counter = counter - bincount_weighted(
                R_idx, covered.astype(jnp.float32)[:, None], n)
            return (alive & ~covered, counter,
                    seeds.at[i].set(v), gains.at[i].set(gain))

        alive, _, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (valid, counter0, jnp.zeros((k,), jnp.int32),
             jnp.zeros((k,), jnp.int32)),
        )
    else:
        raise ValueError(f"unknown method {method}")

    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)
    return seeds, gains.sum(dtype=jnp.float32) / n_valid, gains


# -------------------------------------------------------------- sharded ----

def _vertex_sharded_pick(counter, alive, n, vertex_axis, member_local,
                         starts=None):
    """Greedy argmax over a *vertex-sharded* counter -> (v, covered).

    Runs inside shard_map on every (theta, vertex) tile: mask padding
    columns out of the race, take the local argmax, resolve the global
    winner from ``Dv`` all-gathered (value, global id) scalar pairs, then
    test membership of the winner tile-locally — ``member_local(lv)``
    returns the ``(rows_local,) bool`` membership of in-range local id
    ``lv`` (its result is discarded for out-of-block winners) — and
    psum-or the bits over the vertex axis.  Shared by the dense and
    sharded-sparse strategies so their argmax/pad/tie-break semantics can
    never diverge.

    ``starts`` is the replicated ``(Dv + 1,) int32`` block-boundary array
    of the arena's `VertexPartition` (shard ``s`` owns global vertices
    ``[starts[s], starts[s+1])``) — it carries both the local->global id
    offset and the per-shard pad mask, for equal *and* edge-balanced
    layouts.  Because blocks are contiguous ascending runs in both
    layouts, per-shard-first argmax + first-shard-with-max resolution
    equals the unsharded first-argmax exactly, so selections are
    layout-invariant.  ``starts=None`` keeps the legacy arithmetic
    (equal blocks of width ``nloc``, pad mask from ``n``).
    """
    nloc = counter.shape[0]
    shard = jax.lax.axis_index(vertex_axis)
    if starts is not None:
        lo = starts[shard].astype(jnp.int32)
        size = starts[shard + 1].astype(jnp.int32) - lo
    else:
        lo = (shard * nloc).astype(jnp.int32)
        size = (jnp.clip(n - lo, 0, nloc).astype(jnp.int32)
                if n is not None else jnp.int32(nloc))
    counter = jnp.where(jnp.arange(nloc) < size, counter, -1.0)
    vloc = jnp.argmax(counter)
    val = counter[vloc]
    gidx = lo + vloc
    vals = jax.lax.all_gather(val, vertex_axis)
    gidxs = jax.lax.all_gather(gidx, vertex_axis)
    v = gidxs[jnp.argmax(vals)].astype(jnp.int32)
    lv = v - lo
    member = member_local(jnp.clip(lv, 0, nloc - 1))
    member = jnp.where((lv >= 0) & (lv < nloc), member, False)
    member = jax.lax.psum(member.astype(jnp.int32), vertex_axis) > 0
    return v, member & alive


def _starts_for(mesh, vertex_axis, n, partition):
    """Replicated ``(Dv + 1,) int32`` block boundaries for the sharded
    pick, or None when there is no vertex axis (1D layouts never remap
    ids) or no way to build them (``n`` and ``partition`` both absent —
    the legacy unmasked path)."""
    if vertex_axis is None:
        return None
    if partition is None:
        if n is None:
            return None
        partition = vertex_partition(int(n), int(mesh.shape[vertex_axis]))
    return jnp.asarray(partition.starts, jnp.int32)


def select_dense_sharded(mesh, R, valid, k: int, *,
                         theta_axes=("data",), vertex_axis=None,
                         method: str = "rebuild", n: int | None = None,
                         partition=None, codec=None):
    """EfficientIMM selection with the theta axis sharded over ``theta_axes``
    (paper C1) and, optionally, the vertex axis over ``vertex_axis``.

    ``R (theta, n_pad) uint8`` and ``valid (theta,) bool`` enter with
    specs ``P(theta_axes, vertex_axis)`` / ``P(theta_axes)`` — a
    `ShardedStore` view already carries exactly this layout (1D stores
    with ``vertex_axis=None``, 2D stores with the vertex axis resident),
    so its arena tiles are consumed in place; replicated arrays are
    scattered on entry.  ``valid`` may be any mask, not just a prefix —
    sharded stores fill each shard independently.  ``n`` is the real
    vertex count: on 2D layouts the column dimension is padded to
    ``Dv * n_local`` and the pad columns must never win the argmax
    (they are all-zero, but an all-zero round would otherwise pick one).
    ``partition`` is the arena's `VertexPartition` — it must match the
    layout the columns of ``R`` were tiled with (a `ShardedStore` exposes
    it as ``store.partition``); when None the canonical equal-block
    layout for ``n`` is assumed.

    Inside shard_map each device owns a ``(theta_local, n_local)`` tile.
    Per greedy round only reduced quantities cross devices: the counter
    ``psum`` over the theta axis (the paper's atomic global counter,
    staying vertex-sharded), the per-vertex-shard argmax candidates
    (``all_gather`` of ``Dv`` scalars), the covered-rows bits psum-or over
    the vertex axis, and the scalar gain — never arena rows or columns.
    The greedy argmax is computed redundantly on every device (cheap,
    avoids a broadcast).

    ``method="rebuild"`` re-reduces the surviving local rows every round
    (C5).  ``method="decrement"`` is the true decremental update executed
    tile-locally: each device keeps a partial counter over its own rows
    and columns and subtracts the contribution of its newly-covered rows,
    so the running global counter is ``psum`` of partials.  Both are
    exact over integer-valued f32 counts and return identical selections.

    Returns replicated ``(seeds (k,) int32, covered_frac () f32,
    gains (k,) int32)``.
    """
    axes = tuple(theta_axes)
    if method not in ("rebuild", "decrement"):
        raise ValueError(f"unknown method {method}")
    starts_arr = _starts_for(mesh, vertex_axis, n, partition)

    def local_select(R_enc, valid_local, starts=None):
        # IMPack arenas rest encoded: decode each device's tile inside
        # shard_map (a jit temporary — the decoded tile never lands in
        # HBM between rounds) and run the identical greedy body, so
        # selections are bitwise-equal to the bitmap layout
        R_local = (R_enc if codec is None or codec.kind == "bitmap"
                   else codec.decode(R_enc))
        Rf = R_local.astype(jnp.float32)

        def pick(counter, alive):
            """Greedy argmax over the global counter -> (v, covered)."""
            if vertex_axis is not None:
                return _vertex_sharded_pick(
                    counter, alive, n, vertex_axis,
                    lambda lv: R_local[:, lv] > 0, starts)
            v = jnp.argmax(counter).astype(jnp.int32)
            return v, (R_local[:, v] > 0) & alive

        if method == "rebuild":
            def body(i, state):
                alive, seeds, gains = state
                counter = jax.lax.psum(alive.astype(jnp.float32) @ Rf, axes)
                v, covered = pick(counter, alive)
                gain = jax.lax.psum(covered.sum(dtype=jnp.int32), axes)
                return (alive & ~covered,
                        seeds.at[i].set(v), gains.at[i].set(gain))

            alive, seeds, gains = jax.lax.fori_loop(
                0, k, body,
                (valid_local, jnp.zeros((k,), jnp.int32),
                 jnp.zeros((k,), jnp.int32)),
            )
        else:
            partial0 = valid_local.astype(jnp.float32) @ Rf

            def body(i, state):
                alive, partial, seeds, gains = state
                counter = jax.lax.psum(partial, axes)
                v, covered = pick(counter, alive)
                gain = jax.lax.psum(covered.sum(dtype=jnp.int32), axes)
                partial = partial - covered.astype(jnp.float32) @ Rf
                return (alive & ~covered, partial,
                        seeds.at[i].set(v), gains.at[i].set(gain))

            alive, _, seeds, gains = jax.lax.fori_loop(
                0, k, body,
                (valid_local, partial0, jnp.zeros((k,), jnp.int32),
                 jnp.zeros((k,), jnp.int32)),
            )
        n_valid = jnp.maximum(
            jax.lax.psum(valid_local.sum(dtype=jnp.float32), axes), 1.0)
        return seeds, gains.sum(dtype=jnp.float32) / n_valid, gains

    out_specs = (P(), P(), P())
    if starts_arr is None:
        fn = shard_map(
            local_select, mesh=mesh,
            in_specs=(P(axes, vertex_axis), P(axes)), out_specs=out_specs,
        )
        return fn(R, valid)
    fn = shard_map(
        local_select, mesh=mesh,
        in_specs=(P(axes, vertex_axis), P(axes), P()), out_specs=out_specs,
    )
    return fn(R, valid, starts_arr)


def select_sparse_sharded(mesh, R_idx, valid, n: int, k: int, *,
                          theta_axes=("data",), vertex_axis=None,
                          method: str = "rebuild", partition=None):
    """Greedy max-coverage over *sharded index lists* — the C4 sparse
    representation on a 1D or 2D mesh, lifting the old bitmap-only
    restriction of the sharded pipeline.

    ``R_idx (Dt * cap_local, Dv * l_pad) int32`` enters with spec
    ``P(theta_axes, vertex_axis)``: tile ``(t, v)`` holds, for each of
    its rows, the *local* ids (``0 .. n_local-1``, sentinel ``n_local``)
    of the set members that fall inside vertex block ``v`` — exactly what
    `ShardedStore.index_view` emits (each vertex shard applied the C4
    width to its own columns).  ``valid (Dt * cap_local,) bool`` is
    ``P(theta_axes)``.

    Per greedy round each tile bincounts its own lists into an
    ``(n_local,)`` partial; the psum over the theta axis keeps the
    counter vertex-sharded, the argmax crosses the vertex axis as ``Dv``
    (value, index) scalars, and membership of the winner is a tile-local
    list scan psum-or'ed over the vertex axis — reduced quantities only,
    as in the dense strategy.  Selections are identical to the dense
    strategies over the same rows (exact integer counts).

    Returns replicated ``(seeds (k,) int32, covered_frac () f32,
    gains (k,) int32)``.
    """
    axes = tuple(theta_axes)
    if method not in ("rebuild", "decrement"):
        raise ValueError(f"unknown method {method}")
    Dv = int(mesh.shape[vertex_axis]) if vertex_axis else 1
    # the vertex-block layout — must match the tiles
    # ShardedStore.index_view emitted, or local ids mean the wrong vertex
    part = partition if partition is not None else vertex_partition(n, Dv)
    n_local = part.block
    starts_arr = _starts_for(mesh, vertex_axis, n, part)

    def local_select(R_local, valid_local, starts=None):
        def counter_of(alive):
            partial = bincount_weighted(
                R_local, alive.astype(jnp.float32)[:, None], n_local)
            return jax.lax.psum(partial, axes)

        def pick(counter, alive):
            if vertex_axis is not None:
                return _vertex_sharded_pick(
                    counter, alive, n, vertex_axis,
                    lambda lv: (R_local == lv).any(axis=1), starts)
            v = jnp.argmax(counter).astype(jnp.int32)
            return v, ((R_local == v).any(axis=1)) & alive

        def dec_of(covered):
            return bincount_weighted(
                R_local, covered.astype(jnp.float32)[:, None], n_local)

        if method == "rebuild":
            def body(i, state):
                alive, seeds, gains = state
                v, covered = pick(counter_of(alive), alive)
                gain = jax.lax.psum(covered.sum(dtype=jnp.int32), axes)
                return (alive & ~covered,
                        seeds.at[i].set(v), gains.at[i].set(gain))

            alive, seeds, gains = jax.lax.fori_loop(
                0, k, body,
                (valid_local, jnp.zeros((k,), jnp.int32),
                 jnp.zeros((k,), jnp.int32)),
            )
        else:
            partial0 = bincount_weighted(
                R_local, valid_local.astype(jnp.float32)[:, None], n_local)

            def body(i, state):
                alive, partial, seeds, gains = state
                v, covered = pick(jax.lax.psum(partial, axes), alive)
                gain = jax.lax.psum(covered.sum(dtype=jnp.int32), axes)
                partial = partial - dec_of(covered)
                return (alive & ~covered, partial,
                        seeds.at[i].set(v), gains.at[i].set(gain))

            alive, _, seeds, gains = jax.lax.fori_loop(
                0, k, body,
                (valid_local, partial0, jnp.zeros((k,), jnp.int32),
                 jnp.zeros((k,), jnp.int32)),
            )
        n_valid = jnp.maximum(
            jax.lax.psum(valid_local.sum(dtype=jnp.float32), axes), 1.0)
        return seeds, gains.sum(dtype=jnp.float32) / n_valid, gains

    if starts_arr is None:
        fn = shard_map(
            local_select, mesh=mesh,
            in_specs=(P(axes, vertex_axis), P(axes)),
            out_specs=(P(), P(), P()),
        )
        return fn(R_idx, valid)
    fn = shard_map(
        local_select, mesh=mesh,
        in_specs=(P(axes, vertex_axis), P(axes), P()),
        out_specs=(P(), P(), P()),
    )
    return fn(R_idx, valid, starts_arr)


# ---------------------------------------------------------------- fused ----

@partial(jax.jit, static_argnames=("n", "k", "method", "codec", "interpret"))
def select_fused(R, valid, n: int, k: int, method: str = "rebuild", *,
                 codec=None, interpret: bool = False):
    """Greedy selection whose per-round reduction runs through the
    `repro.kernels.ops` dispatch (Pallas on TPU, ``interpret=True`` for
    CPU kernel validation, jnp oracle elsewhere) — the fused counterpart
    of `select_dense`/`select_packed`/`select_compressed`, bitwise-equal
    to all of them over the same rows (exact integer counts in f32, and
    the `fused_select` kernel's tie-break equals ``jnp.argmax``).

    ``R`` is the at-rest arena in the layout ``codec`` names: raw
    ``(theta, n) uint8`` bitmaps when ``codec`` is None/bitmap, encoded
    ``(theta, codec.width)`` tiles otherwise — encoded arenas are
    counted with the decode-and-count kernels, so the decoded
    ``(theta, n)`` block never exists.  For bitmap rebuild rounds the
    `fused_select` kernel returns the winning vertex directly and the
    per-round ``(n,)`` counter is never materialized either.
    """
    kind = "bitmap" if codec is None else codec.kind

    def counter_of(alive):
        a = alive.astype(jnp.float32)
        if kind == "bitmap":
            return kops.coverage_matvec(a, R, interpret=interpret)
        if kind == "packed":
            return kops.packed_count(
                R, a, n=n, interpret=interpret).astype(jnp.float32)
        return kops.token_count(
            R, a, n=n, interpret=interpret).astype(jnp.float32)

    def member(v):
        if kind == "bitmap":
            return R[:, v] > 0
        return codec.decode_cols(R, v.reshape(1))[:, 0]

    if method == "rebuild":
        def body(i, state):
            alive, seeds, gains = state
            if kind == "bitmap":
                _, v = kops.fused_select(
                    alive.astype(jnp.float32), R, interpret=interpret)
                v = v.astype(jnp.int32)
            else:
                v = jnp.argmax(counter_of(alive)).astype(jnp.int32)
            covered = member(v) & alive
            gain = covered.sum(dtype=jnp.int32)
            return alive & ~covered, seeds.at[i].set(v), gains.at[i].set(gain)

        alive, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (valid, jnp.zeros((k,), jnp.int32), jnp.zeros((k,), jnp.int32)))
    elif method == "decrement":
        def body(i, state):
            alive, counter, seeds, gains = state
            v = jnp.argmax(counter).astype(jnp.int32)
            covered = member(v) & alive
            gain = covered.sum(dtype=jnp.int32)
            counter = counter - counter_of(covered)
            return (alive & ~covered, counter,
                    seeds.at[i].set(v), gains.at[i].set(gain))

        alive, _, seeds, gains = jax.lax.fori_loop(
            0, k, body,
            (valid, counter_of(valid), jnp.zeros((k,), jnp.int32),
             jnp.zeros((k,), jnp.int32)))
    else:
        raise ValueError(f"unknown method {method}")

    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)
    return seeds, gains.sum(dtype=jnp.float32) / n_valid, gains


def select_fused_sharded(mesh, R, valid, k: int, *,
                         theta_axes=("data",), vertex_axis=None,
                         method: str = "rebuild", n: int | None = None,
                         partition=None, codec=None,
                         interpret: bool = False):
    """`select_dense_sharded` with every per-tile reduction routed
    through the `repro.kernels.ops` dispatch: bitmap tiles reduce with
    `coverage_matvec`, packed/compressed tiles with the decode-and-count
    kernels — so encoded tiles are *never* whole-tile decoded, per round
    or otherwise (membership of the winner is a one-column
    ``decode_cols``).  Pad-column masking, balanced-partition offsets and
    the argmax tie-break all go through the shared
    `_vertex_sharded_pick`, so selections are bitwise-identical to the
    unfused sharded strategies (and to the single-device ones) on any
    mesh and either column layout.
    """
    axes = tuple(theta_axes)
    if method not in ("rebuild", "decrement"):
        raise ValueError(f"unknown method {method}")
    starts_arr = _starts_for(mesh, vertex_axis, n, partition)
    kind = "bitmap" if codec is None else codec.kind
    n_tile = None if codec is None else codec.n_cols

    def local_select(R_local, valid_local, starts=None):
        def partial_of(alive):
            a = alive.astype(jnp.float32)
            if kind == "bitmap":
                return kops.coverage_matvec(a, R_local, interpret=interpret)
            if kind == "packed":
                return kops.packed_count(
                    R_local, a, n=n_tile,
                    interpret=interpret).astype(jnp.float32)
            return kops.token_count(
                R_local, a, n=n_tile,
                interpret=interpret).astype(jnp.float32)

        def member_local(lv):
            if kind == "bitmap":
                return R_local[:, lv] > 0
            return codec.decode_cols(R_local, lv.reshape(1))[:, 0]

        def pick(counter, alive):
            if vertex_axis is not None:
                return _vertex_sharded_pick(
                    counter, alive, n, vertex_axis, member_local, starts)
            v = jnp.argmax(counter).astype(jnp.int32)
            return v, member_local(v) & alive

        if method == "rebuild":
            def body(i, state):
                alive, seeds, gains = state
                counter = jax.lax.psum(partial_of(alive), axes)
                v, covered = pick(counter, alive)
                gain = jax.lax.psum(covered.sum(dtype=jnp.int32), axes)
                return (alive & ~covered,
                        seeds.at[i].set(v), gains.at[i].set(gain))

            alive, seeds, gains = jax.lax.fori_loop(
                0, k, body,
                (valid_local, jnp.zeros((k,), jnp.int32),
                 jnp.zeros((k,), jnp.int32)),
            )
        else:
            def body(i, state):
                alive, partial, seeds, gains = state
                counter = jax.lax.psum(partial, axes)
                v, covered = pick(counter, alive)
                gain = jax.lax.psum(covered.sum(dtype=jnp.int32), axes)
                partial = partial - partial_of(covered)
                return (alive & ~covered, partial,
                        seeds.at[i].set(v), gains.at[i].set(gain))

            alive, _, seeds, gains = jax.lax.fori_loop(
                0, k, body,
                (valid_local, partial_of(valid_local),
                 jnp.zeros((k,), jnp.int32), jnp.zeros((k,), jnp.int32)),
            )
        n_valid = jnp.maximum(
            jax.lax.psum(valid_local.sum(dtype=jnp.float32), axes), 1.0)
        return seeds, gains.sum(dtype=jnp.float32) / n_valid, gains

    out_specs = (P(), P(), P())
    if starts_arr is None:
        fn = shard_map(
            local_select, mesh=mesh,
            in_specs=(P(axes, vertex_axis), P(axes)), out_specs=out_specs,
        )
        return fn(R, valid)
    fn = shard_map(
        local_select, mesh=mesh,
        in_specs=(P(axes, vertex_axis), P(axes), P()), out_specs=out_specs,
    )
    return fn(R, valid, starts_arr)


def greedy_select(R_or_idx, valid, k: int, *, n: int | None = None,
                  representation: str = "bitmap", method: str = "rebuild"):
    """Unified entry point used by the IMM driver."""
    if representation == "bitmap":
        return select_dense(R_or_idx, valid, k, method)
    if representation == "indices":
        assert n is not None
        return select_sparse(R_or_idx, valid, n, k, method)
    raise ValueError(representation)


# ------------------------------------------------- SelectionStrategy API ----
#
# A strategy is ``fn(view, k, **opts) -> (seeds, covered_frac, gains)`` where
# ``view`` is a ``repro.core.store.StoreView`` (duck-typed: .R, .valid, .n).
# The registry is keyed "<method>-<layout>" with method in
# {rebuild, decrement} and layout in {dense, sparse, sharded}.

SELECTION_STRATEGIES = {}


def register_selection(name: str, fn=None):
    """Register a selection strategy; usable as ``@register_selection(name)``."""
    if fn is None:
        def deco(f):
            SELECTION_STRATEGIES[name] = f
            return f
        return deco
    SELECTION_STRATEGIES[name] = fn
    return fn


def get_selection(method: str, layout: str):
    name = f"{method}-{layout}"
    try:
        return SELECTION_STRATEGIES[name]
    except KeyError:
        raise ValueError(
            f"no selection strategy {name!r}; registered: "
            f"{sorted(SELECTION_STRATEGIES)}")


def _dense_strategy(method):
    def run(view, k, **_):
        return select_dense(view.R, view.valid, k, method)
    return run


def _sparse_strategy(method):
    def run(view, k, **_):
        return select_sparse(view.R, view.valid, view.n, k, method)
    return run


def _sharded_strategy(method):
    def run(view, k, *, mesh=None, theta_axes=("data",), vertex_axis=None,
            partition=None, codec=None, **_):
        if mesh is None:
            raise ValueError("sharded selection needs a mesh")
        return select_dense_sharded(
            mesh, view.R, view.valid, k,
            theta_axes=theta_axes, vertex_axis=vertex_axis, method=method,
            n=view.n, partition=partition, codec=codec)
    return run


def _sharded_sparse_strategy(method):
    def run(view, k, *, mesh=None, theta_axes=("data",), vertex_axis=None,
            partition=None, **_):
        if mesh is None:
            raise ValueError("sharded selection needs a mesh")
        return select_sparse_sharded(
            mesh, view.R, view.valid, view.n, k,
            theta_axes=theta_axes, vertex_axis=vertex_axis, method=method,
            partition=partition)
    return run


def _fused_dense_strategy(method):
    def run(view, k, *, pallas_interpret=False, **_):
        return select_fused(view.R, view.valid, view.n, k, method,
                            interpret=bool(pallas_interpret))
    return run


def _fused_codec_strategy(method):
    def run(view, k, *, codec=None, pallas_interpret=False, **_):
        if codec is None:
            raise ValueError(
                "fused packed/compressed selection needs the store codec")
        return select_fused(view.R, view.valid, view.n, k, method,
                            codec=codec, interpret=bool(pallas_interpret))
    return run


def _fused_sharded_strategy(method):
    def run(view, k, *, mesh=None, theta_axes=("data",), vertex_axis=None,
            partition=None, codec=None, pallas_interpret=False, **_):
        if mesh is None:
            raise ValueError("sharded selection needs a mesh")
        return select_fused_sharded(
            mesh, view.R, view.valid, k,
            theta_axes=theta_axes, vertex_axis=vertex_axis, method=method,
            n=view.n, partition=partition, codec=codec,
            interpret=bool(pallas_interpret))
    return run


for _m in ("rebuild", "decrement"):
    register_selection(f"{_m}-dense", _dense_strategy(_m))
    register_selection(f"{_m}-sparse", _sparse_strategy(_m))
    register_selection(f"{_m}-sharded", _sharded_strategy(_m))
    register_selection(f"{_m}-sharded-sparse", _sharded_sparse_strategy(_m))
    # the fused-kernel strategies (PR 10): selection_method="fused-rebuild"
    # / "fused-decrement" routes every layout's reductions through the
    # kernels/ops dispatch.  Index-list layouts have no Pallas kernel —
    # they delegate to the plain strategies so the C4 adaptive switch
    # under a fused method never dead-ends
    register_selection(f"fused-{_m}-dense", _fused_dense_strategy(_m))
    register_selection(f"fused-{_m}-packed", _fused_codec_strategy(_m))
    register_selection(f"fused-{_m}-compressed", _fused_codec_strategy(_m))
    register_selection(f"fused-{_m}-sharded", _fused_sharded_strategy(_m))
    register_selection(f"fused-{_m}-sparse", _sparse_strategy(_m))
    register_selection(f"fused-{_m}-sharded-sparse",
                       _sharded_sparse_strategy(_m))


# ------------------------------------------- Ripples-faithful baseline ----

@partial(jax.jit, static_argnames=("n", "k"))
def select_vertex_partitioned(R_idx, valid, n: int, k: int):
    """The Ripples work pattern the paper profiles (§III Challenge 1):
    vertices are partitioned across workers and every worker BINARY-SEARCHES
    every (sorted) RRRset for its vertices — O(n * theta * log L) loads per
    counter build vs EfficientIMM's O(theta * L) scatter.  Used as the
    memory-traffic baseline in benchmarks/table4_memory.py.

    R_idx: (theta, L) ascending index lists, sentinel ``n`` padding.
    """
    theta, L = R_idx.shape

    def contains_v(v):
        pos = jnp.clip(
            jax.vmap(lambda row: jnp.searchsorted(row, v))(R_idx), 0, L - 1)
        return jnp.take_along_axis(R_idx, pos[:, None], 1)[:, 0] == v

    def counter_of(alive):
        return jax.vmap(
            lambda v: jnp.sum(contains_v(v) & alive, dtype=jnp.float32)
        )(jnp.arange(n))

    counter0 = counter_of(valid)

    def body(i, state):
        alive, counter, seeds, gains = state
        v = jnp.argmax(counter).astype(jnp.int32)
        covered = contains_v(v) & alive
        gain = covered.sum(dtype=jnp.int32)
        # decremental update: re-search every covered set per vertex
        dec = jax.vmap(
            lambda u: jnp.sum(contains_v(u) & covered, dtype=jnp.float32)
        )(jnp.arange(n))
        return (alive & ~covered, counter - dec,
                seeds.at[i].set(v), gains.at[i].set(gain))

    alive, counter, seeds, gains = jax.lax.fori_loop(
        0, k, body,
        (valid, counter0, jnp.zeros((k,), jnp.int32),
         jnp.zeros((k,), jnp.int32)))
    n_valid = jnp.maximum(valid.sum(dtype=jnp.float32), 1.0)
    return seeds, gains.sum(dtype=jnp.float32) / n_valid, gains
