"""Batched RRR-set samplers (Generate_RRRsets, paper Alg. 3) — composed
from two orthogonal axes instead of a monolithic per-name fork.

A *sampler* answers "draw a batch of reverse-reachable sets"; historically
the registry hard-forked every answer six ways (``IC-dense``,
``IC-sparse``, ``LT`` and their ``-stable`` twins), so each new diffusion
model or execution scheme multiplied the fork count.  This module factors
the fork matrix into the axes that actually vary (the EFFICIENTIMM
observation — and the fused-IM-kernel result of Gökturk & Kaya,
arXiv:2008.03095 — that activation semantics generalize across cascade
models once they are separated from the traversal loop):

  * **DiffusionModel** — *what* the diffusion semantics are.  Two
    families:

      - `CoinModel` ("coins"): edge-factored semantics — each in-edge
        ``u -> v`` is consulted at most once (when ``v`` first enters the
        reverse frontier) and fires an independent Bernoulli coin with a
        model-supplied marginal.  This is Kempe et al.'s triggering model
        restricted to independent inclusion; built-ins: ``IC`` (the
        graph's per-edge probabilities), ``WC`` (weighted cascade,
        ``1/indeg(dst)``), and ``GT`` (generalized triggering with the
        graph's LT triggering weights as independent marginals).
      - `WalkModel` ("walk"): pick-at-most-one semantics — the vertex the
        walk sits at selects a single in-neighbor by weight (or none).
        Built-in: ``LT``.

  * **TraversalBackend** — *how* the traversal executes:

      - ``dense``  — probabilistic reverse BFS as a *log-semiring
        mat-vec* on the dense activation matrix: P(u activated by
        frontier F) = 1 - prod_{v in F} (1 - p_{u->v-reversed}); exact in
        distribution for reachability (DESIGN §2). MXU-friendly.
      - ``sparse`` — per-edge Bernoulli coins + scatter-max frontier
        expansion over the CSC edge list; exact live-edge semantics,
        scales to graphs where the dense matrix does not fit.
      - ``pallas`` — the dense formulation with the frontier step
        executed by the fused Pallas MXU kernel
        ``kernels/ic_frontier.py`` (matmul + Bernoulli sampling + visited
        mask in one VMEM-resident pass).  Dispatch goes through
        ``repro.kernels.ops.ic_frontier_step``: the kernel on TPU, the
        jnp oracle elsewhere — numerically the *same math* as ``dense``,
        so results are bitwise identical off-TPU and on any
        single-k-tile problem.
      - ``walk``   — the random-walk loop (binary search over per-dst
        cumulative weights, CSC layout) for "walk"-family models.

  * **stable** — an orthogonal *flag*, not a source fork: positional
    coins (``uniform(key, shape)`` — fast, but any shape change renumbers
    every coin) vs identity-keyed counter-mode coins (hash of (step key,
    row position, edge/vertex id) — delta-stable and row-subsettable via
    ``positions``, the form streaming refresh requires).

``make_sampler(model, backend, stable=...)`` composes the axes into a
registry-compatible factory; the full matrix is pre-registered under
canonical ``"<model>/<backend>[+stable]"`` names (e.g. ``"WC/sparse"``,
``"IC/pallas+stable"``).  The historical monolithic names resolve as
deprecated aliases that are **seed-for-seed identical** to the
pre-decomposition samplers (goldens pinned in
tests/test_sampler_matrix.py).

Every bound sampler returns the batch as **visited bitmaps** ``(B, n)
uint8`` plus the fused in-place counter contribution (paper C3) and the
batch roots (the sparse backend can alternatively emit index lists
natively — C4 routed per-backend, see ``emit_l``).  Factories accept an
optional ``placement`` (a ``jax.sharding.NamedSharding`` for the
``(B, n)`` output — a `ShardedStore` hands out its ``batch_sharding``):
the constraint is applied to the initial frontier state inside jit, so
GSPMD partitions the whole generation loop over the batch axis — and,
when the placement is 2D (``P(theta_axes, vertex_axis)``), over the
vertex axis too: each device samples exactly the (row block, vertex
block) tile its arena shard will store (paper C1, both axes).  The coin
backends additionally pin their graph tables to the same vertex blocks
(`_shard_cols`): the dense ``logq`` matrix is column-partitioned so each
device expands only its own vertex block from the all-gathered frontier
— the frontier exchange is the only cross-shard traffic in the loop.
PRNG values are position- or identity-keyed, so placement changes layout
only — sampled sets are bitwise identical on any mesh shape.
"""
from __future__ import annotations

import dataclasses
import inspect
import warnings
from functools import partial
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.adaptive import bitmap_to_indices
from repro.core.store import next_pow2
from repro.graphs.csr import Graph, dense_ic_matrix, edge_arrays, wc_edge_probs
from repro.kernels import ops as kops

_LOGQ_CLAMP = -30.0  # exp(-30) ~ 1e-13: treat p=1 edges as prob 1-1e-13


# ------------------------------------------- vertex-partitioned tables ----
#
# With a 2D batch placement (``P(theta_axes, vertex_axis)``, handed out by
# a 2D `ShardedStore`), the traversal state is column-partitioned over the
# vertex axis — so the graph tables the frontier step reads should be too,
# or every step would re-broadcast them.  ``_shard_cols`` pins a table's
# trailing axis to the placement's vertex axis (the same contiguous block
# layout as ``repro.graphs.partition.vertex_partition``, which GSPMD uses
# for trailing-dim shardings): the dense ``logq`` matrix becomes
# column-blocked, so each device computes activations only for its own
# vertex block from the all-gathered frontier — the frontier exchange is
# the only cross-shard traffic in the loop — and the CSC edge arrays
# become contiguous dst-block slabs (CSC order is dst-sorted, so an even
# split of the edge list approximates the dst blocks).  PRNG values are
# position- or identity-keyed, so all of this changes layout only: the
# sampled sets stay bitwise identical on any mesh shape.

def _vertex_axis_of(placement):
    """The vertex (column) mesh axis of a 2D batch placement, or None."""
    if placement is None:
        return None
    spec = tuple(placement.spec)
    return spec[1] if len(spec) > 1 else None


def _shard_cols(x, placement):
    """Constrain a graph table's trailing axis to the placement's vertex
    axis (no-op for 1D/absent placements): ``(n, n)`` tables become
    column-blocked, ``(m,)``/``(n,)`` tables contiguous slabs."""
    vx = _vertex_axis_of(placement)
    if vx is None:
        return x
    spec = PartitionSpec(*((None,) * (x.ndim - 1) + (vx,)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(placement.mesh, spec))


def _pin_replicated(x, placement):
    """Pin a freshly drawn threefry array to the replicated layout under
    a 2D placement.  The container's jax runs the *non-partitionable*
    threefry (``jax_threefry_partitionable=False``), whose generator
    GSPMD may lower differently per sharding context — an unpinned
    ``uniform``/``randint`` inside a vertex-sharded computation produces
    *different values* than the single-device trace, silently breaking
    the layout-independent key stream.  Replicating the draw (generation
    is redundant per device; the masked traversal compute downstream
    stays partitioned) restores the historical stream bitwise.  The
    identity-keyed stable coins never hit this: they are elementwise
    counter-mode hashes of (key, row, vertex/edge id), which partition
    cleanly over both mesh axes with no pin."""
    if _vertex_axis_of(placement) is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(placement.mesh, PartitionSpec()))


# ---------------------------------------------------------------- models ----
#
# A DiffusionModel owns *semantics only*: how an edge (or a visited
# vertex's in-segment) turns randomness into activation.  It supplies the
# per-edge tables a backend consumes; it never owns a traversal loop, so
# adding a model is ~5 lines (see docs/samplers.md) and every compatible
# backend — including the Pallas kernel — works with it immediately.

@dataclasses.dataclass(frozen=True)
class CoinModel:
    """Edge-factored ("coins" family) diffusion semantics.

    ``edge_probs(graph) -> (m,) float32`` returns the CSC-order marginal
    activation probability of each in-edge.  Each edge is consulted at
    most once per RRR traversal — when its destination first enters the
    reverse frontier — and fires independently, which is exactly the
    triggering model with independent inclusion (IC is the instance whose
    marginals are the graph's edge probabilities).
    """
    name: str
    edge_probs: Callable[[Graph], jnp.ndarray]
    family: str = dataclasses.field(default="coins", init=False)


@dataclasses.dataclass(frozen=True)
class WalkModel:
    """Pick-at-most-one ("walk" family) diffusion semantics.

    ``walk_tables(graph) -> (dst_offsets, in_src, cum, total)`` returns
    the CSC segment offsets, in-neighbor ids, within-segment cumulative
    pick weights, and per-vertex total pick probability: one uniform draw
    ``r`` selects the in-neighbor whose cumulative interval contains it
    (or none when ``r >= total``), the Tang'15 LT RRR random walk.
    """
    name: str
    walk_tables: Callable[[Graph], tuple]
    family: str = dataclasses.field(default="walk", init=False)


def _wc_probs(graph: Graph) -> jnp.ndarray:
    """Weighted cascade: p(u -> v) = 1 / indeg(v) (CSC edge order; the
    formula lives in `repro.graphs.csr.wc_edge_probs`)."""
    return jnp.asarray(wc_edge_probs(graph.edge_dst, graph.n), jnp.float32)


def _gt_probs(graph: Graph) -> jnp.ndarray:
    """Generalized triggering: the graph's LT triggering weights as
    *independent* per-edge marginals (CSC order).

    LT and GT share the same per-edge marginals but sit at opposite
    correlation extremes of the triggering framework: LT's triggering set
    includes at most one in-neighbor (mutually exclusive picks), GT's
    includes each in-neighbor independently.  Per-dst LT weights sum to
    <= 1, so every marginal is a valid probability.
    """
    _, _, _, w = edge_arrays(graph)
    return jnp.asarray(np.clip(w, 0.0, 1.0), jnp.float32)


IC = CoinModel("IC", lambda g: g.in_prob)
WC = CoinModel("WC", _wc_probs)
GT = CoinModel("GT", _gt_probs)
LT = WalkModel("LT", lambda g: (g.dst_offsets, g.in_src, g.in_lt_cum,
                                g.in_lt_total))

_MODEL_REGISTRY: dict = {}


def register_model(model) -> None:
    """Register a `CoinModel`/`WalkModel` under its name (overwrites
    silently so experiments can shadow the built-ins).  Registered coin
    models compose with every frontier backend; walk models with the
    walk backend."""
    _MODEL_REGISTRY[model.name] = model


def get_model(name: str):
    try:
        return _MODEL_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown diffusion model {name!r}; registered: "
            f"{sorted(_MODEL_REGISTRY)}")


def registered_models():
    return sorted(_MODEL_REGISTRY)


for _m in (IC, WC, GT, LT):
    register_model(_m)


def logq_from_probs(graph: Graph, probs) -> jnp.ndarray:
    """Dense (n, n) log(1-p) matrix in *reverse-traversal* orientation
    for any per-edge marginal vector: logq[v, u] = log(1 - p_{u->v}) so
    that ``frontier @ logq`` accumulates over frontier nodes v the
    log-survival of u w.r.t. its out-edges into v."""
    P = dense_ic_matrix(graph, probs)
    return jnp.maximum(jnp.log1p(-P.T), _LOGQ_CLAMP)


def make_logq(graph: Graph) -> jnp.ndarray:
    """`logq_from_probs` for the IC model (the historical entry point)."""
    return logq_from_probs(graph, graph.in_prob)


# ------------------------------------------------ the stable-coin machinery ----
#
# The positional loops draw their randomness by *array position*
# (``uniform(key, shape)``): fast, but any change to the edge count
# renumbers every coin, and a batch can only ever be re-generated whole.
# With ``stable=True`` every coin is re-keyed by **identity** — a
# stateless counter-mode hash of (step key, row position, edge/vertex id)
# — which buys the two properties streaming (``repro.stream``) needs:
#
#   * **delta stability**: re-sampling a row with the same key on a
#     mutated graph reproduces it bitwise unless its traversal actually
#     touched a mutated edge's destination — exactly the staleness
#     predicate ``repro.stream.invalidate`` marks;
#   * **row-granular repair**: ``positions`` selects an arbitrary subset
#     of the batch's rows and re-generates *only those* (same coins the
#     full batch would have drawn), so refresh work is proportional to
#     stale rows, not to the batches they happen to live in.
#
# Distribution-wise each coin is still an independent-in-practice uniform;
# only the key-stream mechanism differs, so the stable twins are not
# coin-for-coin identical to their positional twins (they are separate
# registry entries and leave the historical ``imm()`` streams untouched).

def _mix32(x):
    """splitmix-style avalanche on uint32 (stateless counter-mode hash)."""
    x = (x ^ (x >> jnp.uint32(16))) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> jnp.uint32(15))) * jnp.uint32(0x846CA68B)
    return x ^ (x >> jnp.uint32(16))


def _u01(bits):
    """uint32 hash bits -> f32 uniform in [0, 1)."""
    return ((bits >> jnp.uint32(8)).astype(jnp.float32)
            * jnp.float32(1.0 / (1 << 24)))


_GOLD = 0x9E3779B9   # 2**32 / phi — the classic Weyl increment


def _setup(key, batch, n_nodes, positions, placement, stable):
    """Shared traversal preamble: the (kroot, kstep) split, full-batch
    roots, initial visited state, and (stable only) per-row hash lanes.

    The PRNG op sequence is identical for both stability modes — one
    ``split`` plus one ``randint`` — so the root stream of a composed
    sampler matches the historical monolithic samplers bitwise.
    ``positions`` (stable only) gathers a row subset of the full batch.
    """
    kroot, kstep = jax.random.split(key)
    roots_full = _pin_replicated(
        jax.random.randint(kroot, (batch,), 0, n_nodes), placement)
    if not stable:
        if positions is not None:
            raise ValueError(
                "positions-subset resampling needs stable=True "
                "(identity-keyed coins); positional samplers can only "
                "re-generate whole batches")
        roots = roots_full
        visited0 = jax.nn.one_hot(roots, n_nodes, dtype=jnp.bool_)
        if placement is not None:
            visited0 = jax.lax.with_sharding_constraint(visited0, placement)
        return kstep, roots, visited0, None
    pos = (jnp.arange(batch, dtype=jnp.int32) if positions is None
           else jnp.asarray(positions, jnp.int32))
    roots = roots_full[pos]
    visited0 = jax.nn.one_hot(roots, n_nodes, dtype=jnp.bool_)
    if placement is not None and positions is None:
        visited0 = jax.lax.with_sharding_constraint(visited0, placement)
    bb = pos.astype(jnp.uint32)[:, None] * jnp.uint32(_GOLD)
    return kstep, roots, visited0, bb


# --------------------------------------------------------- traversal loops ----
#
# One loop per backend family, written once.  ``stable`` selects the coin
# source; the PRNG split chain (one ``split`` per step) is shared, so the
# positional path reproduces the historical samplers bitwise and the
# stable path reproduces the historical ``-stable`` twins bitwise.

@partial(jax.jit, static_argnames=("batch", "max_steps", "stable", "kernel",
                                   "interpret", "placement", "overlap"))
def _dense_loop(key, logq, positions=None, *, batch: int, max_steps: int = 0,
                stable: bool = False, kernel: bool = False,
                interpret: bool = False, placement=None,
                overlap: bool = False):
    """Dense log-semiring frontier expansion (the ``dense`` and
    ``pallas`` backends; ``kernel=True`` routes the step through
    ``kernels.ops.ic_frontier_step`` — same math, fused on the MXU).

    ``overlap=True`` (2D placements only; a no-op otherwise) double-
    buffers the loop's one collective: the while-loop state carries the
    *vertex-axis-gathered* frontier, so the all-gather that step ``t+1``
    needs is issued at the end of step ``t``'s body — as soon as ``new``
    exists and *decoupled from the step-t matmul*, letting XLA's
    latency-hiding scheduler run the collective behind the local logq
    compute instead of serializing gather -> matmul inside one dot
    lowering.  A pure scheduling change: the gathered operand feeds the
    same full-width local matmul GSPMD lowers for the annotation-free
    path, so sampled sets are bitwise identical with overlap on or off.

    Returns ``(visited (K, n) uint8, counter (n,) int32, roots (K,))``
    where ``K = len(positions)`` (the full batch when ``positions`` is
    None; positional mode requires ``positions is None``).
    """
    n = logq.shape[0]
    max_steps = max_steps or n
    # 2D placement: column-block the activation matrix over the vertex
    # axis once, outside the loop — each device then owns the logq
    # columns of its own vertex block, and the per-step mat-vec needs
    # only the all-gathered frontier (the frontier exchange)
    logq = _shard_cols(logq, placement)
    kstep, roots, visited0, bb = _setup(
        key, batch, n, positions, placement, stable)
    uids = jnp.arange(n, dtype=jnp.uint32)[None, :] if stable else None
    overlap = overlap and _vertex_axis_of(placement) is not None
    if overlap:
        spec = tuple(placement.spec)
        gathered_sh = NamedSharding(placement.mesh,
                                    PartitionSpec(spec[0], None))

    def gather(x):
        """Issue the vertex-axis frontier all-gather (overlap mode)."""
        return (jax.lax.with_sharding_constraint(x, gathered_sh)
                if overlap else x)

    def cond(state):
        step, frontier, visited, _ = state
        return jnp.logical_and(step < max_steps, frontier.any())

    def body(state):
        step, frontier, visited, k = state
        k, sub = jax.random.split(k)
        if stable:
            kd = jnp.asarray(sub, jnp.uint32).reshape(-1)
            coin = _u01(_mix32(_mix32(uids ^ kd[0]) ^ bb ^ kd[1]))
        else:
            coin = _pin_replicated(
                jax.random.uniform(sub, frontier.shape), placement)
        if kernel:
            new = kops.ic_frontier_step(
                frontier, visited, logq, coin,
                interpret=interpret).astype(jnp.bool_)
        else:
            acc = frontier.astype(jnp.float32) @ logq   # (K, n) log-survival
            p_act = -jnp.expm1(acc)                     # 1 - exp(acc)
            new = jnp.logical_and(coin < p_act, ~visited)
        # overlap: kick off step-(t+1)'s frontier collective here, while
        # nothing downstream in this body depends on the gathered copy
        return step + 1, gather(new), jnp.logical_or(visited, new), k

    _, _, visited, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), gather(visited0), visited0, kstep)
    )
    counter = visited.sum(axis=0, dtype=jnp.int32)      # fused count (C3)
    return visited.astype(jnp.uint8), counter, roots


@partial(jax.jit, static_argnames=("n_nodes", "batch", "max_steps", "stable",
                                   "placement", "emit_l"))
def _sparse_loop(key, edge_src, edge_dst, edge_prob, positions=None, *,
                 n_nodes: int, batch: int, max_steps: int = 0,
                 stable: bool = False, placement=None, emit_l: int = 0):
    """CSC edge-list frontier expansion (the ``sparse`` backend).

    An edge ``u -> v`` is consulted when ``v`` is in the reverse
    frontier (each vertex fronts at most once, so each edge gets exactly
    one coin — independent-inclusion triggering, any `CoinModel`).
    Stable coins key on the edge's *identity* ``u * n + v`` rather than
    its list position, so inserts/deletes renumber nothing; padded
    never-firing edges (see `_pad_edges_pow2`) are likewise invisible.

    ``emit_l > 0`` emits the batch *natively as index lists* ``(K,
    emit_l) int32`` (ascending, sentinel ``n_nodes``) instead of
    bitmaps — the C4 representation routed per-backend: the conversion
    fuses into this jit (the transient visited state never round-trips
    through an arena-sized bitmap write), and an `IndexStore` ingests the
    rows as-is (`add_index_batch`).  The coin stream is untouched, so
    emitted rows equal the bitmap rows converted after the fact, bit for
    bit.  Rows with more than ``emit_l`` members are truncated — callers
    grow ``emit_l`` and re-emit when a row comes back full (same key,
    same coins, wider lists).
    """
    m = edge_src.shape[0]
    max_steps = max_steps or n_nodes
    # 2D placement: slab the CSC edge arrays over the vertex axis (CSC
    # order is dst-sorted, so contiguous slabs track the dst blocks)
    edge_src = _shard_cols(edge_src, placement)
    edge_dst = _shard_cols(edge_dst, placement)
    edge_prob = _shard_cols(edge_prob, placement)
    kstep, roots, visited0, bb = _setup(
        key, batch, n_nodes, positions, placement, stable)
    uid = ((edge_src.astype(jnp.uint32) * jnp.uint32(n_nodes)
            + edge_dst.astype(jnp.uint32))[None, :] if stable else None)

    def cond(state):
        step, frontier, visited, _ = state
        return jnp.logical_and(step < max_steps, frontier.any())

    def body(state):
        step, frontier, visited, k = state
        k, sub = jax.random.split(k)
        if stable:
            kd = jnp.asarray(sub, jnp.uint32).reshape(-1)
            coin = _u01(_mix32(_mix32(uid ^ kd[0]) ^ bb ^ kd[1]))
            hit = coin < edge_prob[None, :]
        else:
            hit = _pin_replicated(
                jax.random.uniform(sub, (batch, m)),
                placement) < edge_prob[None, :]
        # reverse traversal: edge u->v is usable when v is in the frontier
        live = frontier[:, edge_dst] & hit & ~visited[:, edge_src]
        # scatter-or into src — the segment_max counter-update pattern (C1)
        new = jnp.zeros_like(visited).at[:, edge_src].max(live)
        new = jnp.logical_and(new, ~visited)
        return step + 1, new, jnp.logical_or(visited, new), k

    _, _, visited, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), visited0, visited0, kstep)
    )
    counter = visited.sum(axis=0, dtype=jnp.int32)
    if emit_l:
        return bitmap_to_indices(visited.astype(jnp.uint8),
                                 emit_l), counter, roots
    return visited.astype(jnp.uint8), counter, roots


@partial(jax.jit, static_argnames=("batch", "max_steps", "max_indeg_log2",
                                   "stable", "placement"))
def _walk_loop(key, dst_offsets, in_src, in_cum, in_total, positions=None, *,
               batch: int, max_steps: int = 0, max_indeg_log2: int = 32,
               stable: bool = False, placement=None):
    """Pick-at-most-one random walk (the ``walk`` backend, `WalkModel`).

    Each step the walk at ``cur`` draws one uniform ``r``: ``r >=
    total(cur)`` stops, otherwise binary search over the per-dst
    cumulative weights selects the in-neighbor; revisits terminate.
    Stable draws key on the row identity so a row's walk is a function
    of itself plus the per-dst segments it visits.

    Under a 2D placement the visited rows are still born as shard-local
    column slices (the ``placement`` constraint partitions the one-hot
    scatter), but the walk tables stay replicated: a walk's next gather
    is data-dependent and uniformly random over vertices, so there is no
    block locality for a column partition to exploit — tables are
    O(m + n) scalars, not O(n^2).
    """
    n = dst_offsets.shape[0] - 1
    max_steps = max_steps or n
    kstep, roots, visited0, bb = _setup(
        key, batch, n, positions, placement, stable)
    brow = bb[:, 0] if stable else None

    def pick_in_neighbor(cur, r):
        """Binary search within CSC segment of ``cur`` for cum >= r."""
        lo = dst_offsets[cur]
        hi = dst_offsets[cur + 1]

        def step_fn(_, lohi):
            lo_, hi_ = lohi
            mid = (lo_ + hi_) // 2
            val = in_cum[jnp.clip(mid, 0, in_cum.shape[0] - 1)]
            go_right = val < r
            return (jnp.where(go_right, mid + 1, lo_),
                    jnp.where(go_right, hi_, mid))

        lo_f, _ = jax.lax.fori_loop(0, max_indeg_log2, step_fn, (lo, hi))
        idx = jnp.clip(lo_f, 0, in_src.shape[0] - 1)
        return in_src[idx]

    def cond(state):
        step, cur, active, visited, _ = state
        return jnp.logical_and(step < max_steps, active.any())

    def body(state):
        step, cur, active, visited, k = state
        k, sub = jax.random.split(k)
        if stable:
            kd = jnp.asarray(sub, jnp.uint32).reshape(-1)
            r = _u01(_mix32(_mix32(brow ^ kd[0]) ^ kd[1]))
        else:
            r = _pin_replicated(jax.random.uniform(sub, (batch,)),
                                placement)
        total = in_total[cur]
        go = jnp.logical_and(active, r < total)
        nxt = jax.vmap(pick_in_neighbor)(cur, r)
        revisit = jnp.take_along_axis(visited, nxt[:, None], axis=1)[:, 0]
        go = jnp.logical_and(go, ~revisit)
        visited = jnp.logical_or(
            visited, jax.nn.one_hot(nxt, visited.shape[1], dtype=jnp.bool_)
            & go[:, None]
        )
        cur = jnp.where(go, nxt, cur)
        return step + 1, cur, go, visited, k

    _, _, _, visited, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), roots, jnp.ones(roots.shape, jnp.bool_),
                     visited0, kstep)
    )
    counter = visited.sum(axis=0, dtype=jnp.int32)
    return visited.astype(jnp.uint8), counter, roots


# ------------------------------------------------- historical entry points ----
#
# The pre-decomposition function API, kept as thin wrappers over the
# unified loops (benchmarks and launch/steps.py call these directly).

def sample_ic_dense(key, logq, *, batch: int, max_steps: int = 0,
                    placement=None):
    """Positional dense log-semiring IC sampling (see `_dense_loop`)."""
    return _dense_loop(key, logq, batch=batch, max_steps=max_steps,
                       placement=placement)


def sample_ic_dense_stable(key, logq, positions=None, *, batch: int,
                           max_steps: int = 0, placement=None):
    """Identity-keyed dense sampling with ``positions`` row subsets."""
    return _dense_loop(key, logq, positions, batch=batch,
                       max_steps=max_steps, stable=True, placement=placement)


def sample_ic_sparse(key, edge_src, edge_dst, edge_prob, *, n_nodes: int,
                     batch: int, max_steps: int = 0, placement=None):
    """Positional edge-list IC sampling (see `_sparse_loop`)."""
    return _sparse_loop(key, edge_src, edge_dst, edge_prob,
                        n_nodes=n_nodes, batch=batch, max_steps=max_steps,
                        placement=placement)


def sample_ic_sparse_stable(key, edge_src, edge_dst, edge_prob,
                            positions=None, *, n_nodes: int, batch: int,
                            max_steps: int = 0, placement=None):
    """Edge-identity-keyed sparse sampling with ``positions`` subsets."""
    return _sparse_loop(key, edge_src, edge_dst, edge_prob, positions,
                        n_nodes=n_nodes, batch=batch, max_steps=max_steps,
                        stable=True, placement=placement)


def sample_lt(key, dst_offsets, in_src, in_lt_cum, in_lt_total, *,
              batch: int, max_steps: int = 0, max_indeg_log2: int = 32,
              placement=None):
    """Positional LT RRR random walk (see `_walk_loop`)."""
    return _walk_loop(key, dst_offsets, in_src, in_lt_cum, in_lt_total,
                      batch=batch, max_steps=max_steps,
                      max_indeg_log2=max_indeg_log2, placement=placement)


def sample_lt_stable(key, dst_offsets, in_src, in_lt_cum, in_lt_total,
                     positions=None, *, batch: int, max_steps: int = 0,
                     max_indeg_log2: int = 32, placement=None):
    """Identity-keyed LT walk with ``positions`` row subsets."""
    return _walk_loop(key, dst_offsets, in_src, in_lt_cum, in_lt_total,
                      positions, batch=batch, max_steps=max_steps,
                      max_indeg_log2=max_indeg_log2, stable=True,
                      placement=placement)


# -------------------------------------------------------------- backends ----

def _pad_edges_pow2(edge_src, edge_dst, edge_prob):
    """Pad CSC edge arrays to the next power of two with never-firing
    edges (prob 0, endpoints 0), so the stable sparse loop is traced per
    pow2 *bucket* of m rather than per exact m — a `GraphDelta` that
    changes the edge count inside the bucket reuses the compiled kernel
    instead of retracing.  Identity-keyed coins make the pad lanes
    invisible: a padded sampler's output is bitwise identical to the
    unpadded one's (pinned in tests/test_sampler_matrix.py)."""
    m = int(edge_src.shape[0])
    m_pad = next_pow2(m, 1)
    if m_pad == m:
        return edge_src, edge_dst, edge_prob
    pad = m_pad - m
    z = jnp.zeros((pad,), edge_src.dtype)
    return (jnp.concatenate([edge_src, z]),
            jnp.concatenate([edge_dst, jnp.zeros((pad,), edge_dst.dtype)]),
            jnp.concatenate([edge_prob, jnp.zeros((pad,), edge_prob.dtype)]))


@dataclasses.dataclass(frozen=True)
class TraversalBackend:
    """One way to execute an RRR traversal.

    ``family`` names the model family it can execute ("coins" or
    "walk"); ``bind(model, graph, cfg, *, stable, placement)`` does the
    per-graph preprocessing once (dense matrix, edge padding, walk
    tables) and returns the bound sampler: a callable of a PRNG key —
    plus a keyword-only ``positions`` row subset when ``stable`` —
    returning ``(visited (B, n) uint8, counter (n,) int32, roots (B,))``.
    """
    name: str
    family: str
    bind: Callable


def _bind_dense(model, graph: Graph, cfg, *, stable, placement,
                kernel=False):
    logq = logq_from_probs(graph, model.edge_probs(graph))
    interpret = bool(getattr(cfg, "pallas_interpret", False))
    # double-buffer the frontier all-gather on 2D placements (config-
    # gated for the overlap-on/off equivalence cells; _dense_loop drops
    # the flag on 1D/absent placements where there is no collective)
    overlap = bool(getattr(cfg, "overlap", True))
    if stable:
        return lambda key, positions=None: _dense_loop(
            key, logq, positions, batch=cfg.batch, stable=True,
            kernel=kernel, interpret=interpret, placement=placement,
            overlap=overlap)
    return lambda key: _dense_loop(
        key, logq, batch=cfg.batch, kernel=kernel, interpret=interpret,
        placement=placement, overlap=overlap)


def _bind_pallas(model, graph: Graph, cfg, *, stable, placement):
    return _bind_dense(model, graph, cfg, stable=stable,
                       placement=placement, kernel=True)


def _bind_sparse(model, graph: Graph, cfg, *, stable, placement):
    src, dst = graph.edge_src, graph.edge_dst
    prob = jnp.asarray(model.edge_probs(graph), jnp.float32)
    if stable:
        # pow2 padding is only bitwise-invisible under identity-keyed
        # coins; the positional coin layout is a function of m, so the
        # positional sampler keeps the exact edge count (seed parity
        # with the historical IC-sparse stream)
        src, dst, prob = _pad_edges_pow2(src, dst, prob)
        fn = lambda key, positions=None, emit_l=0: _sparse_loop(
            key, src, dst, prob, positions, n_nodes=graph.n,
            batch=cfg.batch, stable=True, placement=placement,
            emit_l=emit_l)
    else:
        fn = lambda key, emit_l=0: _sparse_loop(
            key, src, dst, prob, n_nodes=graph.n, batch=cfg.batch,
            placement=placement, emit_l=emit_l)
    # the engine routes C4 per-backend through this tag: an IndexStore
    # asks a tagged sampler for native index rows (`emit_l`) instead of
    # densifying to bitmaps and converting at the arena write
    fn.supports_index_emit = True
    return fn


def _bind_walk(model, graph: Graph, cfg, *, stable, placement):
    tables = model.walk_tables(graph)
    if stable:
        return lambda key, positions=None: _walk_loop(
            key, *tables, positions, batch=cfg.batch, stable=True,
            placement=placement)
    return lambda key: _walk_loop(
        key, *tables, batch=cfg.batch, placement=placement)


DENSE_BACKEND = TraversalBackend("dense", "coins", _bind_dense)
SPARSE_BACKEND = TraversalBackend("sparse", "coins", _bind_sparse)
PALLAS_BACKEND = TraversalBackend("pallas", "coins", _bind_pallas)
WALK_BACKEND = TraversalBackend("walk", "walk", _bind_walk)

_BACKEND_REGISTRY: dict = {}


def register_backend(backend: TraversalBackend) -> None:
    """Register a `TraversalBackend` under its name (overwrites
    silently)."""
    _BACKEND_REGISTRY[backend.name] = backend


def get_backend(name: str) -> TraversalBackend:
    try:
        return _BACKEND_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown traversal backend {name!r}; registered: "
            f"{sorted(_BACKEND_REGISTRY)}")


def registered_backends():
    return sorted(_BACKEND_REGISTRY)


for _b in (DENSE_BACKEND, SPARSE_BACKEND, PALLAS_BACKEND, WALK_BACKEND):
    register_backend(_b)


# ----------------------------------------------------------- composition ----

def _check_family(model, backend) -> None:
    if backend.family != model.family:
        raise ValueError(
            f"backend {backend.name!r} executes {backend.family!r}-family "
            f"models; model {model.name!r} is {model.family!r}-family "
            f"(coin models compose with dense/sparse/pallas, walk models "
            f"with walk)")


def composed_name(model: str, backend: str, stable: bool = False) -> str:
    """Canonical registry spelling of a composition:
    ``"<model>/<backend>"`` plus ``"+stable"`` for the identity-keyed
    form (e.g. ``"WC/sparse"``, ``"IC/pallas+stable"``)."""
    return f"{model}/{backend}" + ("+stable" if stable else "")


def make_sampler(model, backend=None, *, stable: bool = False):
    """Compose a `DiffusionModel` x `TraversalBackend` into a sampler
    factory (registry-compatible: ``factory(graph, cfg, *,
    placement=None) -> bound sampler``).

    ``model``/``backend`` are registry names or instances; ``backend``
    defaults to the model family's reference backend ("dense" for coin
    models, "walk" for walk models).  ``stable=True`` selects
    identity-keyed counter-mode coins with ``positions`` row-subset
    resampling (the delta-stable form streaming refresh requires).
    Incompatible families fail fast::

        make_sampler("WC", "pallas")           # weighted cascade on MXU
        make_sampler("IC", "sparse", stable=True)
        make_sampler(CoinModel("mine", f), "dense")
    """
    m = get_model(model) if isinstance(model, str) else model
    if backend is None:
        backend = "dense" if m.family == "coins" else "walk"
    b = get_backend(backend) if isinstance(backend, str) else backend
    _check_family(m, b)
    model_ref = model if isinstance(model, str) else m
    backend_ref = backend if isinstance(backend, str) else b

    def factory(graph: Graph, cfg, *, placement=None):
        # names re-resolve per bind, so register_model/register_backend
        # shadowing (the documented overwrite contract) reaches factories
        # composed — or cached by get_sampler — before the re-registration
        mm = (get_model(model_ref) if isinstance(model_ref, str)
              else model_ref)
        bb = (get_backend(backend_ref) if isinstance(backend_ref, str)
              else backend_ref)
        _check_family(mm, bb)
        return bb.bind(mm, graph, cfg, stable=stable, placement=placement)

    factory.__name__ = f"sampler_{m.name}_{b.name}" + (
        "_stable" if stable else "")
    factory.model, factory.backend, factory.stable = m, b, stable
    return factory


def sampler_matrix():
    """Every valid (model, backend) composition over the registered
    models and backends, as ``[(model_name, backend_name), ...]`` —
    the docs/tests/benchmarks iterate this instead of hardcoding."""
    cells = []
    for mn in registered_models():
        m = _MODEL_REGISTRY[mn]
        for bn in registered_backends():
            if _BACKEND_REGISTRY[bn].family == m.family:
                cells.append((mn, bn))
    return cells


# ------------------------------------------------------- sampler registry ----
#
# The engine resolves samplers by name so new diffusion models (or tuned
# variants of the built-ins) plug in without touching the driver:
#
#     register_model(CoinModel("mine", edge_prob_fn))   # every backend...
#     register_sampler("mine/dense", make_sampler("mine", "dense"))
#
# or, bypassing the axes entirely (a factory takes (graph, cfg) and
# returns a bound sampler; preprocessing happens once in the factory):
#
#     register_sampler("IC-mykernel", lambda graph, cfg: bound_fn)
#
# Factories may additionally accept a keyword-only ``placement`` (batch
# output sharding, see the module docstring); the engine passes it only
# to factories that declare it (`bind_sampler`), so user-registered
# (graph, cfg) factories keep working unchanged.

_SAMPLER_REGISTRY = {}

# historical monolithic spellings -> canonical compositions.  Resolving
# one emits a DeprecationWarning (once per name per process) pointing at
# the `make_sampler` spelling; results are seed-for-seed identical.
_LEGACY_ALIASES = {
    "IC-dense": "IC/dense",
    "IC-sparse": "IC/sparse",
    "LT": "LT/walk",
    "IC-dense-stable": "IC/dense+stable",
    "IC-sparse-stable": "IC/sparse+stable",
    "LT-stable": "LT/walk+stable",
}
_LEGACY_WARNED: set = set()


def register_sampler(name: str, factory=None):
    """Register a sampler factory under ``name`` (overwrites silently so
    experiments can shadow the built-ins).  Usable as a decorator:
    ``@register_sampler("IC-mykernel")``."""
    if factory is None:
        def deco(f):
            _SAMPLER_REGISTRY[name] = f
            return f
        return deco
    _SAMPLER_REGISTRY[name] = factory
    return factory


def _parse_composed(name: str):
    """``(model, backend, stable)`` when ``name`` is a canonical
    composition over *registered* axes, else None.  This is what lets a
    post-import ``register_model``/``register_backend`` resolve through
    configs immediately — its composed names need no pre-registration."""
    mdl, sep, rest = name.partition("/")
    if not sep:
        return None
    bkd, plus, stb = rest.partition("+")
    if plus and stb != "stable":
        return None
    if mdl in _MODEL_REGISTRY and bkd in _BACKEND_REGISTRY:
        return mdl, bkd, bool(plus)
    return None


def get_sampler(name: str):
    hit = _SAMPLER_REGISTRY.get(name)
    if hit is not None:
        return hit
    alias = _LEGACY_ALIASES.get(name)
    if alias is not None:
        if name not in _LEGACY_WARNED:
            _LEGACY_WARNED.add(name)
            mdl, _, rest = alias.partition("/")
            bkd, _, stb = rest.partition("+")
            spelling = f"make_sampler({mdl!r}, {bkd!r}" + (
                ", stable=True)" if stb else ")")
            warnings.warn(
                f"sampler name {name!r} is a legacy monolithic spelling; "
                f"use {alias!r} (= {spelling}) instead — results are "
                f"seed-for-seed identical",
                DeprecationWarning, stacklevel=2)
        return _SAMPLER_REGISTRY[alias]
    axes = _parse_composed(name)
    if axes is not None:
        # compose (and cache) on demand: models/backends registered
        # after import resolve by canonical name with no extra
        # register_sampler calls; family mismatches fail with
        # make_sampler's explanation
        mdl, bkd, stable = axes
        factory = make_sampler(mdl, bkd, stable=stable)
        _SAMPLER_REGISTRY[name] = factory
        return factory
    raise ValueError(
        f"unknown sampler {name!r}; registered: "
        f"{registered_samplers()}")


def registered_samplers():
    """All resolvable names: the canonical ``model/backend[+stable]``
    matrix, user registrations, and the deprecated legacy aliases."""
    return sorted(set(_SAMPLER_REGISTRY) | set(_LEGACY_ALIASES))


for _mn, _bn in sampler_matrix():
    for _s in (False, True):
        register_sampler(composed_name(_mn, _bn, _s),
                         make_sampler(_mn, _bn, stable=_s))


def default_sampler_name(graph: Graph, cfg) -> str:
    """Resolve ``cfg`` to a canonical composed name: coin models take the
    dense backend below ``cfg.dense_sampler_max_n`` and the edge-list
    backend above it (the historical dispatch), walk models take the
    walk backend; ``cfg.backend`` overrides the backend axis and
    ``cfg.stable`` selects the identity-keyed form."""
    m = get_model(cfg.model)
    backend = getattr(cfg, "backend", None)
    if backend is None:
        if m.family == "walk":
            backend = "walk"
        else:
            backend = ("dense" if graph.n <= cfg.dense_sampler_max_n
                       else "sparse")
    else:
        # fail here with the family explanation, not later with a
        # generic unknown-sampler error from the composed name
        _check_family(m, get_backend(backend))
    return composed_name(m.name, backend, bool(getattr(cfg, "stable",
                                                       False)))


def stable_variant(name: str) -> str:
    """The delta-stable spelling of a sampler name: canonical names gain
    ``+stable``, legacy aliases keep their legacy ``-stable`` spelling,
    and unknown (user-registered) names pass through unchanged — the
    caller keeps whatever row-resample support the custom factory has."""
    if name.endswith("+stable") or name.endswith("-stable"):
        return name
    if name in _LEGACY_ALIASES:
        return f"{name}-stable"
    if (f"{name}+stable" in _SAMPLER_REGISTRY
            or _parse_composed(name) is not None):
        return f"{name}+stable"
    return name


def bind_sampler(factory, graph: Graph, cfg, placement=None):
    """Instantiate a sampler factory, forwarding ``placement`` only when
    the factory declares it (keyword ``placement`` or ``**kwargs``) —
    back-compat with user factories registered as ``(graph, cfg)``."""
    if placement is not None:
        params = inspect.signature(factory).parameters
        takes_kw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                       for p in params.values())
        if "placement" in params or takes_kw:
            return factory(graph, cfg, placement=placement)
    return factory(graph, cfg)
