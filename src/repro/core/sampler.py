"""Batched RRR-set samplers (Generate_RRRsets, paper Alg. 3).

All samplers return the batch as **visited bitmaps** ``(B, n) uint8`` plus the
fused in-place counter contribution (paper C3: counting is folded into
generation, no re-gather pass).  The adaptive layer converts to index lists
when sets are sparse (paper C4).

Every sampler accepts an optional ``placement`` (a
``jax.sharding.NamedSharding`` for the ``(B, n)`` visited output — a
`ShardedStore` hands out its ``batch_sharding``).  When given, the
constraint is applied to the *initial* frontier/visited state inside jit,
so GSPMD partitions the whole generation loop over the batch axis and each
device samples the rows its arena shard will store (paper C1: sampling
writes device-local state).  PRNG values are position-keyed (threefry), so
placement changes layout only — the sampled sets are bitwise identical on
any mesh, which is what keeps sharded runs seed-for-seed equal to
single-device ones.

Three implementations:
  * ``sample_ic_dense``  — probabilistic reverse BFS as a *log-semiring
    mat-vec* on the dense IC matrix: P(u activated by frontier F) =
    1 - prod_{v in F} (1 - p_{u->v-reversed}); exact in distribution for
    reachability (see DESIGN §2).  TPU-native: the expansion runs on the MXU
    (Pallas kernel: kernels/ic_frontier.py).
  * ``sample_ic_sparse`` — per-edge Bernoulli coins + segment_max frontier
    expansion over the CSC edge list; exact live-edge semantics, scales to
    graphs where the dense matrix does not fit.
  * ``sample_lt``        — the LT random walk: each step picks at most one
    in-neighbor with probability proportional to its LT weight (stops with
    prob 1 - sum w), terminating on revisits. Binary search over the
    per-dst cumulative weights (CSC layout).

Each has a ``*-stable`` twin ("IC-dense-stable", "IC-sparse-stable",
"LT-stable") whose randomness is keyed by *identity* (row position,
edge/vertex id) instead of array position — delta-stable and row-
subsettable, the form streaming refresh requires (see the delta-stable
section below).
"""
from __future__ import annotations

import inspect
from functools import partial

import jax
import jax.numpy as jnp

from repro.graphs.csr import Graph, dense_ic_matrix

_LOGQ_CLAMP = -30.0  # exp(-30) ~ 1e-13: treat p=1 edges as prob 1-1e-13


def make_logq(graph: Graph) -> jnp.ndarray:
    """Dense (n, n) log(1-p) matrix in *reverse-traversal* orientation:
    logq[v, u] = log(1 - p_{u->v}) so that ``frontier @ logq`` accumulates
    over frontier nodes v the log-survival of u w.r.t. its out-edges into v.
    """
    P = dense_ic_matrix(graph)  # P[u, v] = p(u -> v)
    return jnp.maximum(jnp.log1p(-P.T), _LOGQ_CLAMP)


@partial(jax.jit, static_argnames=("batch", "max_steps", "placement"))
def sample_ic_dense(key, logq, *, batch: int, max_steps: int = 0,
                    placement=None):
    """Returns (visited (B,n) uint8, counter (n,) int32, roots (B,)).

    ``placement`` (optional ``NamedSharding`` over ``(B, n)``): constrains
    the visited state so the frontier mat-vec loop is partitioned over the
    batch axis and the output lands shard-local to the consuming store.
    """
    n = logq.shape[0]
    max_steps = max_steps or n
    kroot, kstep = jax.random.split(key)
    roots = jax.random.randint(kroot, (batch,), 0, n)
    visited0 = jax.nn.one_hot(roots, n, dtype=jnp.bool_)
    if placement is not None:
        visited0 = jax.lax.with_sharding_constraint(visited0, placement)
    frontier0 = visited0

    def cond(state):
        step, frontier, visited, _ = state
        return jnp.logical_and(step < max_steps, frontier.any())

    def body(state):
        step, frontier, visited, k = state
        k, sub = jax.random.split(k)
        acc = frontier.astype(jnp.float32) @ logq          # (B, n) log-survival
        p_act = -jnp.expm1(acc)                            # 1 - exp(acc)
        coin = jax.random.uniform(sub, p_act.shape)
        new = jnp.logical_and(coin < p_act, ~visited)
        return step + 1, new, jnp.logical_or(visited, new), k

    _, _, visited, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), frontier0, visited0, kstep)
    )
    counter = visited.sum(axis=0, dtype=jnp.int32)          # fused count (C3)
    return visited.astype(jnp.uint8), counter, roots


@partial(jax.jit, static_argnames=("n_nodes", "batch", "max_steps",
                                   "placement"))
def sample_ic_sparse(key, edge_src, edge_dst, edge_prob, *, n_nodes: int,
                     batch: int, max_steps: int = 0, placement=None):
    """Edge-list frontier expansion with per-edge coins.

    edge_* are CSC-ordered (sorted by dst) but any order works.
    Returns (visited, counter, roots).  ``placement`` as in
    `sample_ic_dense`: batch-axis partitioning of the expansion loop.
    """
    m = edge_src.shape[0]
    max_steps = max_steps or n_nodes
    kroot, kstep = jax.random.split(key)
    roots = jax.random.randint(kroot, (batch,), 0, n_nodes)
    visited0 = jax.nn.one_hot(roots, n_nodes, dtype=jnp.bool_)
    if placement is not None:
        visited0 = jax.lax.with_sharding_constraint(visited0, placement)

    def cond(state):
        step, frontier, visited, _ = state
        return jnp.logical_and(step < max_steps, frontier.any())

    def body(state):
        step, frontier, visited, k = state
        k, sub = jax.random.split(k)
        coin = jax.random.uniform(sub, (batch, m)) < edge_prob[None, :]
        # reverse traversal: edge u->v is usable when v is in the frontier
        live = frontier[:, edge_dst] & coin & ~visited[:, edge_src]
        # scatter-or into src — the segment_max counter-update pattern (C1)
        new = jnp.zeros_like(visited).at[:, edge_src].max(live)
        new = jnp.logical_and(new, ~visited)
        return step + 1, new, jnp.logical_or(visited, new), k

    _, _, visited, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), visited0, visited0, kstep)
    )
    counter = visited.sum(axis=0, dtype=jnp.int32)
    return visited.astype(jnp.uint8), counter, roots


# -------------------------------------------------- delta-stable samplers ----
#
# The positional samplers above draw their randomness by *array position*
# (``uniform(key, (batch, m))``): fast, but any change to the edge count
# renumbers every coin, and a batch can only ever be re-generated whole.
# The ``*-stable`` samplers below re-key every coin by **identity** — a
# stateless counter-mode hash of (step key, row position, edge/vertex id)
# — which buys the two properties streaming (``repro.stream``) needs:
#
#   * **delta stability**: re-sampling a row with the same key on a
#     mutated graph reproduces it bitwise unless its traversal actually
#     touched a mutated edge's destination — exactly the staleness
#     predicate ``repro.stream.invalidate`` marks;
#   * **row-granular repair**: ``positions`` selects an arbitrary subset
#     of the batch's rows and re-generates *only those* (same coins the
#     full batch would have drawn), so refresh work is proportional to
#     stale rows, not to the batches they happen to live in.
#
# Distribution-wise each coin is still an independent-in-practice uniform;
# only the key-stream mechanism differs, so the stable samplers are not
# coin-for-coin identical to their positional twins (they are separate
# registry entries and leave the historical ``imm()`` streams untouched).

def _mix32(x):
    """splitmix-style avalanche on uint32 (stateless counter-mode hash)."""
    x = (x ^ (x >> jnp.uint32(16))) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> jnp.uint32(15))) * jnp.uint32(0x846CA68B)
    return x ^ (x >> jnp.uint32(16))


def _u01(bits):
    """uint32 hash bits -> f32 uniform in [0, 1)."""
    return ((bits >> jnp.uint32(8)).astype(jnp.float32)
            * jnp.float32(1.0 / (1 << 24)))


_GOLD = 0x9E3779B9   # 2**32 / phi — the classic Weyl increment


def _stable_setup(key, batch, n_nodes, positions, placement):
    """Shared preamble: full-batch roots (positional randint, gathered at
    ``positions``), initial visited state, per-row hash lanes, step key."""
    kroot, kstep = jax.random.split(key)
    roots_full = jax.random.randint(kroot, (batch,), 0, n_nodes)
    pos = (jnp.arange(batch, dtype=jnp.int32) if positions is None
           else jnp.asarray(positions, jnp.int32))
    roots = roots_full[pos]
    visited0 = jax.nn.one_hot(roots, n_nodes, dtype=jnp.bool_)
    if placement is not None and positions is None:
        visited0 = jax.lax.with_sharding_constraint(visited0, placement)
    bb = pos.astype(jnp.uint32)[:, None] * jnp.uint32(_GOLD)
    return kstep, roots, visited0, bb


@partial(jax.jit, static_argnames=("batch", "max_steps", "placement"))
def sample_ic_dense_stable(key, logq, positions=None, *, batch: int,
                           max_steps: int = 0, placement=None):
    """`sample_ic_dense` with identity-keyed coins: the coin for (row b,
    vertex u, step t) hashes (step key, b, u), so it survives edge
    mutations (the dense matrix keeps its shape; only ``logq`` entries
    move) and row subsets re-generate exactly.  Returns
    ``(visited (K, n) uint8, counter (n,) int32, roots (K,))`` where
    ``K = len(positions)`` (the full batch when ``positions`` is None).
    """
    n = logq.shape[0]
    max_steps = max_steps or n
    kstep, roots, visited0, bb = _stable_setup(
        key, batch, n, positions, placement)
    uids = jnp.arange(n, dtype=jnp.uint32)[None, :]

    def cond(state):
        step, frontier, visited, _ = state
        return jnp.logical_and(step < max_steps, frontier.any())

    def body(state):
        step, frontier, visited, k = state
        k, sub = jax.random.split(k)
        kd = jnp.asarray(sub, jnp.uint32).reshape(-1)
        acc = frontier.astype(jnp.float32) @ logq
        p_act = -jnp.expm1(acc)
        coin = _u01(_mix32(_mix32(uids ^ kd[0]) ^ bb ^ kd[1]))
        new = jnp.logical_and(coin < p_act, ~visited)
        return step + 1, new, jnp.logical_or(visited, new), k

    _, _, visited, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), visited0, visited0, kstep)
    )
    counter = visited.sum(axis=0, dtype=jnp.int32)
    return visited.astype(jnp.uint8), counter, roots


@partial(jax.jit, static_argnames=("n_nodes", "batch", "max_steps",
                                   "placement"))
def sample_ic_sparse_stable(key, edge_src, edge_dst, edge_prob,
                            positions=None, *, n_nodes: int, batch: int,
                            max_steps: int = 0, placement=None):
    """`sample_ic_sparse` with **edge-identity-keyed** coins: the coin for
    (row b, edge u->v, step t) hashes (step key, b, u * n + v) — a
    function of the edge's identity, not its position in the edge list —
    so inserts/deletes renumber nothing and ``positions`` re-generates
    row subsets exactly (see the section comment above)."""
    max_steps = max_steps or n_nodes
    kstep, roots, visited0, bb = _stable_setup(
        key, batch, n_nodes, positions, placement)
    # stable per-edge identity: unique for n < 2**16, a well-mixed hash
    # input beyond that (uniqueness is a quality nicety, not correctness)
    uid = (edge_src.astype(jnp.uint32) * jnp.uint32(n_nodes)
           + edge_dst.astype(jnp.uint32))[None, :]

    def cond(state):
        step, frontier, visited, _ = state
        return jnp.logical_and(step < max_steps, frontier.any())

    def body(state):
        step, frontier, visited, k = state
        k, sub = jax.random.split(k)
        kd = jnp.asarray(sub, jnp.uint32).reshape(-1)
        coin = _u01(_mix32(_mix32(uid ^ kd[0]) ^ bb ^ kd[1]))
        hit = coin < edge_prob[None, :]
        live = frontier[:, edge_dst] & hit & ~visited[:, edge_src]
        new = jnp.zeros_like(visited).at[:, edge_src].max(live)
        new = jnp.logical_and(new, ~visited)
        return step + 1, new, jnp.logical_or(visited, new), k

    _, _, visited, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), visited0, visited0, kstep)
    )
    counter = visited.sum(axis=0, dtype=jnp.int32)
    return visited.astype(jnp.uint8), counter, roots


@partial(jax.jit, static_argnames=("batch", "max_steps", "max_indeg_log2",
                                   "placement"))
def sample_lt_stable(key, dst_offsets, in_src, in_lt_cum, in_lt_total,
                     positions=None, *, batch: int, max_steps: int = 0,
                     max_indeg_log2: int = 32, placement=None):
    """`sample_lt` with identity-keyed step draws: the walk draw for
    (row b, step t) hashes (step key, b), so a row's walk is a function
    of its own identity plus the per-dst LT segments it visits — stable
    across deltas that avoid those dsts, and subsettable via
    ``positions``."""
    n = dst_offsets.shape[0] - 1
    max_steps = max_steps or n
    kstep, roots, visited0, bb = _stable_setup(
        key, batch, n, positions, placement)
    brow = bb[:, 0]

    def pick_in_neighbor(cur, r):
        lo = dst_offsets[cur]
        hi = dst_offsets[cur + 1]

        def step_fn(_, lohi):
            lo_, hi_ = lohi
            mid = (lo_ + hi_) // 2
            val = in_lt_cum[jnp.clip(mid, 0, in_lt_cum.shape[0] - 1)]
            go_right = val < r
            return (jnp.where(go_right, mid + 1, lo_),
                    jnp.where(go_right, hi_, mid))

        lo_f, _ = jax.lax.fori_loop(0, max_indeg_log2, step_fn, (lo, hi))
        idx = jnp.clip(lo_f, 0, in_src.shape[0] - 1)
        return in_src[idx]

    def cond(state):
        step, cur, active, visited, _ = state
        return jnp.logical_and(step < max_steps, active.any())

    def body(state):
        step, cur, active, visited, k = state
        k, sub = jax.random.split(k)
        kd = jnp.asarray(sub, jnp.uint32).reshape(-1)
        r = _u01(_mix32(_mix32(brow ^ kd[0]) ^ kd[1]))
        total = in_lt_total[cur]
        go = jnp.logical_and(active, r < total)
        nxt = jax.vmap(pick_in_neighbor)(cur, r)
        revisit = jnp.take_along_axis(visited, nxt[:, None], axis=1)[:, 0]
        go = jnp.logical_and(go, ~revisit)
        visited = jnp.logical_or(
            visited, jax.nn.one_hot(nxt, visited.shape[1], dtype=jnp.bool_)
            & go[:, None]
        )
        cur = jnp.where(go, nxt, cur)
        return step + 1, cur, go, visited, k

    _, _, _, visited, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), roots, jnp.ones(roots.shape, jnp.bool_),
                     visited0, kstep)
    )
    counter = visited.sum(axis=0, dtype=jnp.int32)
    return visited.astype(jnp.uint8), counter, roots


@partial(jax.jit, static_argnames=("batch", "max_steps", "max_indeg_log2",
                                   "placement"))
def sample_lt(key, dst_offsets, in_src, in_lt_cum, in_lt_total, *,
              batch: int, max_steps: int = 0, max_indeg_log2: int = 32,
              placement=None):
    """LT-model RRR walk. Returns (visited (B,n) uint8, counter, roots).
    ``placement`` as in `sample_ic_dense`: the walk batch partitions over
    the mesh so each device generates its store shard's rows."""
    n = dst_offsets.shape[0] - 1
    max_steps = max_steps or n
    kroot, kstep = jax.random.split(key)
    roots = jax.random.randint(kroot, (batch,), 0, n)
    visited0 = jax.nn.one_hot(roots, n, dtype=jnp.bool_)
    if placement is not None:
        visited0 = jax.lax.with_sharding_constraint(visited0, placement)

    def pick_in_neighbor(cur, r):
        """Binary search within CSC segment of ``cur`` for lt_cum >= r."""
        lo = dst_offsets[cur]
        hi = dst_offsets[cur + 1]

        def step_fn(_, lohi):
            lo_, hi_ = lohi
            mid = (lo_ + hi_) // 2
            val = in_lt_cum[jnp.clip(mid, 0, in_lt_cum.shape[0] - 1)]
            go_right = val < r
            return (jnp.where(go_right, mid + 1, lo_),
                    jnp.where(go_right, hi_, mid))

        lo_f, _ = jax.lax.fori_loop(0, max_indeg_log2, step_fn, (lo, hi))
        idx = jnp.clip(lo_f, 0, in_src.shape[0] - 1)
        return in_src[idx]

    def cond(state):
        step, cur, active, visited, _ = state
        return jnp.logical_and(step < max_steps, active.any())

    def body(state):
        step, cur, active, visited, k = state
        k, sub = jax.random.split(k)
        r = jax.random.uniform(sub, (batch,))
        total = in_lt_total[cur]
        go = jnp.logical_and(active, r < total)
        nxt = jax.vmap(pick_in_neighbor)(cur, r)
        revisit = jnp.take_along_axis(visited, nxt[:, None], axis=1)[:, 0]
        go = jnp.logical_and(go, ~revisit)
        visited = jnp.logical_or(
            visited, jax.nn.one_hot(nxt, visited.shape[1], dtype=jnp.bool_)
            & go[:, None]
        )
        cur = jnp.where(go, nxt, cur)
        return step + 1, cur, go, visited, k

    _, _, _, visited, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(0), roots, jnp.ones((batch,), jnp.bool_),
                     visited0, kstep)
    )
    counter = visited.sum(axis=0, dtype=jnp.int32)
    return visited.astype(jnp.uint8), counter, roots


# ------------------------------------------------------- sampler registry ----
#
# The engine resolves samplers by name so new diffusion models (or tuned
# variants of the built-ins) plug in without touching the driver:
#
#     register_sampler("IC-mykernel", lambda graph, cfg: bound_fn)
#
# A factory takes (graph, cfg) and returns a bound sampler: a callable of a
# PRNG key returning (visited (B, n) uint8, counter (n,) int32, roots (B,)).
# Preprocessing (e.g. the dense log-survival matrix) happens once in the
# factory, not per batch.  Factories may additionally accept a keyword-only
# ``placement`` (batch output sharding, see the module docstring); the
# engine passes it only to factories that declare it (`bind_sampler`), so
# user-registered (graph, cfg) factories keep working unchanged.

_SAMPLER_REGISTRY = {}


def register_sampler(name: str, factory=None):
    """Register a sampler factory under ``name`` (overwrites silently so
    experiments can shadow the built-ins).  Usable as a decorator:
    ``@register_sampler("IC-dense")``."""
    if factory is None:
        def deco(f):
            _SAMPLER_REGISTRY[name] = f
            return f
        return deco
    _SAMPLER_REGISTRY[name] = factory
    return factory


def get_sampler(name: str):
    try:
        return _SAMPLER_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown sampler {name!r}; registered: "
            f"{sorted(_SAMPLER_REGISTRY)}")


def registered_samplers():
    return sorted(_SAMPLER_REGISTRY)


def default_sampler_name(graph: Graph, cfg) -> str:
    """The historical dispatch: dense log-semiring IC below
    ``dense_sampler_max_n``, edge-list IC above it, LT walk otherwise."""
    if cfg.model == "IC":
        if graph.n <= cfg.dense_sampler_max_n:
            return "IC-dense"
        return "IC-sparse"
    if cfg.model == "LT":
        return "LT"
    raise ValueError(f"unknown diffusion model {cfg.model!r}")


def bind_sampler(factory, graph: Graph, cfg, placement=None):
    """Instantiate a sampler factory, forwarding ``placement`` only when
    the factory declares it (keyword ``placement`` or ``**kwargs``) —
    back-compat with user factories registered as ``(graph, cfg)``."""
    if placement is not None:
        params = inspect.signature(factory).parameters
        takes_kw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                       for p in params.values())
        if "placement" in params or takes_kw:
            return factory(graph, cfg, placement=placement)
    return factory(graph, cfg)


@register_sampler("IC-dense")
def _ic_dense_factory(graph: Graph, cfg, *, placement=None):
    logq = make_logq(graph)
    return lambda key: sample_ic_dense(
        key, logq, batch=cfg.batch, placement=placement)


@register_sampler("IC-sparse")
def _ic_sparse_factory(graph: Graph, cfg, *, placement=None):
    return lambda key: sample_ic_sparse(
        key, graph.edge_src, graph.edge_dst, graph.in_prob,
        n_nodes=graph.n, batch=cfg.batch, placement=placement)


@register_sampler("IC-dense-stable")
def _ic_dense_stable_factory(graph: Graph, cfg, *, placement=None):
    logq = make_logq(graph)
    return lambda key, positions=None: sample_ic_dense_stable(
        key, logq, positions, batch=cfg.batch, placement=placement)


@register_sampler("IC-sparse-stable")
def _ic_sparse_stable_factory(graph: Graph, cfg, *, placement=None):
    return lambda key, positions=None: sample_ic_sparse_stable(
        key, graph.edge_src, graph.edge_dst, graph.in_prob, positions,
        n_nodes=graph.n, batch=cfg.batch, placement=placement)


@register_sampler("LT-stable")
def _lt_stable_factory(graph: Graph, cfg, *, placement=None):
    return lambda key, positions=None: sample_lt_stable(
        key, graph.dst_offsets, graph.in_src, graph.in_lt_cum,
        graph.in_lt_total, positions, batch=cfg.batch, placement=placement)


@register_sampler("LT")
def _lt_factory(graph: Graph, cfg, *, placement=None):
    return lambda key: sample_lt(
        key, graph.dst_offsets, graph.in_src, graph.in_lt_cum,
        graph.in_lt_total, batch=cfg.batch, placement=placement)
