"""Streaming influence subsystem: dynamic graphs over a resident RRR store.

The static pipeline samples once and answers queries forever; real
campaigns run on networks that change under them.  This package layers a
delta/invalidate/refresh cycle on the `InfluenceEngine`:

  * `repro.stream.delta`      — `GraphDelta` edge batches (insert /
    delete / reweight) and their application to dense and CSR graphs;
  * `repro.stream.invalidate` — the vertex -> RRR-row reverse-touch
    queries that mark exactly the stale resident sets after a delta;
  * `repro.stream.engine`     — `StreamEngine`: ``apply_delta`` /
    ``refresh(budget)`` / epoch-tagged ``select``/``influence`` with
    bounded-memory eviction via `repro.core.store.StorePressurePolicy`.

See docs/streaming.md for the delta model, staleness semantics and the
epoch-consistency contract.
"""
from repro.stream.delta import GraphDelta, canonicalize, random_delta
from repro.stream.invalidate import invalidate, rows_touching
from repro.stream.engine import StreamEngine, StreamSelection

__all__ = [
    "GraphDelta",
    "canonicalize",
    "random_delta",
    "invalidate",
    "rows_touching",
    "StreamEngine",
    "StreamSelection",
]
