"""GraphDelta — batched dynamic-graph mutations for streaming IMM.

A delta is an ordered batch of edge operations (insert / delete /
reweight) applied atomically between serving epochs.  Vertices are a
fixed universe (``n`` never changes — appearing vertices are modeled as
vertices gaining their first edges); edges are identified by their
``(src, dst)`` pair.

Semantics (strict, so streams are deterministic and bugs fail loudly):

  * ``insert``   — the edge must not exist; it is added with the given IC
    probability.  Its LT weight is ``p * (1 - total(dst))`` where
    ``total(dst)`` is the destination's current LT in-weight — a
    deterministic rule that keeps every per-dst total < 1 (the LT model
    invariant) without touching any *other* edge's weight.
  * ``delete``   — the edge must exist; it is removed (its LT weight
    leaves the dst total; remaining weights are untouched).
  * ``reweight`` — the edge must exist; its IC probability is replaced.
    The LT weight is kept (reweighting is an IC-strength change; LT
    structure follows insert/delete).
  * Later operations in one delta see the effects of earlier ones.

Untouched dst segments keep **bit-identical** LT cumulative weights and
IC probabilities across `apply` (see `repro.graphs.csr.edge_arrays`),
which is what lets `repro.stream.invalidate` bound staleness to the rows
whose traversal touched a mutated edge's destination.

`apply` rebuilds the CSR/CSC `Graph` (O(m + |delta|) host work — the
representation the samplers traverse); `apply_dense` updates an ``(n, n)``
dense IC matrix in O(|delta|) device work (the representation
``sample_ic_dense`` consumes via its precomputed log-survival matrix).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.graphs.csr import Graph, build_graph, edge_arrays

OP_INSERT = 0
OP_DELETE = 1
OP_REWEIGHT = 2
_OP_NAMES = {OP_INSERT: "insert", OP_DELETE: "delete",
             OP_REWEIGHT: "reweight"}


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """An ordered batch of edge mutations.

    ``src``/``dst`` are ``(E,) int32`` endpoints, ``prob`` the ``(E,)
    float32`` IC probabilities (ignored for deletes), ``op`` the ``(E,)
    int8`` opcode per entry (`OP_INSERT` / `OP_DELETE` / `OP_REWEIGHT`).
    """
    src: np.ndarray
    dst: np.ndarray
    prob: np.ndarray
    op: np.ndarray

    def __post_init__(self):
        object.__setattr__(self, "src", np.asarray(self.src, np.int32))
        object.__setattr__(self, "dst", np.asarray(self.dst, np.int32))
        object.__setattr__(self, "prob", np.asarray(self.prob, np.float32))
        object.__setattr__(self, "op", np.asarray(self.op, np.int8))
        e = self.src.shape[0]
        if not (self.dst.shape[0] == self.prob.shape[0]
                == self.op.shape[0] == e):
            raise ValueError("GraphDelta arrays must share one length")
        if e and not np.isin(self.op, list(_OP_NAMES)).all():
            raise ValueError(f"unknown opcode in {np.unique(self.op)}")
        needs_p = self.op != OP_DELETE
        if needs_p.any():
            p = self.prob[needs_p]
            if not (np.isfinite(p).all() and (p >= 0).all()
                    and (p <= 1).all()):
                raise ValueError(
                    "insert/reweight probabilities must lie in [0, 1]")

    # -------------------------------------------------------- construction

    @classmethod
    def inserts(cls, src, dst, prob) -> "GraphDelta":
        src = np.asarray(src)
        return cls(src, dst, prob, np.full(src.shape[0], OP_INSERT))

    @classmethod
    def deletes(cls, src, dst) -> "GraphDelta":
        src = np.asarray(src)
        return cls(src, dst, np.zeros(src.shape[0]),
                   np.full(src.shape[0], OP_DELETE))

    @classmethod
    def reweights(cls, src, dst, prob) -> "GraphDelta":
        src = np.asarray(src)
        return cls(src, dst, prob, np.full(src.shape[0], OP_REWEIGHT))

    @classmethod
    def concat(cls, deltas) -> "GraphDelta":
        """One delta applying ``deltas`` in order."""
        return cls(np.concatenate([d.src for d in deltas]),
                   np.concatenate([d.dst for d in deltas]),
                   np.concatenate([d.prob for d in deltas]),
                   np.concatenate([d.op for d in deltas]))

    def __len__(self) -> int:
        return int(self.src.shape[0])

    # --------------------------------------------------------- staleness

    def touched_vertices(self) -> np.ndarray:
        """The vertices whose mutation can change a resident RRR set:
        the *destinations* of mutated edges.

        RRR traversal is reverse: an edge ``u -> v`` is only consulted
        when ``v`` is already in the set (IC expands from ``v`` to ``u``;
        the LT walk picks an in-neighbor while sitting at ``v``).  A row
        that never visited any mutated ``v`` therefore re-samples
        bitwise-identically on the mutated graph under a delta-stable
        sampler — so marking rows that touch these vertices is a
        *conservative and sufficient* staleness predicate.
        """
        return np.unique(self.dst).astype(np.int32)

    # ------------------------------------------------------------- apply

    def apply(self, graph: Graph) -> Graph:
        """Rebuild ``graph`` with this delta applied (CSR/CSC path).

        Strict: inserting an existing edge, or deleting/reweighting a
        missing one, raises ``ValueError`` naming the offending entry.
        """
        n = graph.n
        if len(self) and ((self.src < 0).any() or (self.src >= n).any()
                          or (self.dst < 0).any() or (self.dst >= n).any()):
            raise ValueError(f"delta endpoints out of range for n={n}")
        src, dst, prob, w = edge_arrays(graph)
        prob = prob.astype(np.float32).copy()
        w = w.copy()
        alive = np.ones(src.shape[0], bool)
        keys = src.astype(np.int64) * n + dst
        table = {int(k): i for i, k in enumerate(keys)}
        totals = np.zeros(n, np.float64)
        np.add.at(totals, dst, w)
        app_src, app_dst, app_prob, app_w = [], [], [], []
        app_table: dict[int, int] = {}

        for i in range(len(self)):
            u, v, p, o = (int(self.src[i]), int(self.dst[i]),
                          float(self.prob[i]), int(self.op[i]))
            k = u * n + v
            pos = table.get(k)
            exists_orig = pos is not None and alive[pos]
            jpos = app_table.get(k)
            exists_new = jpos is not None
            if o == OP_INSERT:
                if exists_orig or exists_new:
                    raise ValueError(
                        f"delta[{i}]: insert of existing edge {u}->{v}")
                wi = p * max(0.0, 1.0 - float(totals[v]))
                app_table[k] = len(app_src)
                app_src.append(u)
                app_dst.append(v)
                app_prob.append(p)
                app_w.append(wi)
                totals[v] += wi
            elif o == OP_DELETE:
                if exists_orig:
                    alive[pos] = False
                    totals[v] -= w[pos]
                elif exists_new:
                    totals[v] -= app_w[jpos]
                    del app_table[k]
                    app_w[jpos] = 0.0
                    app_prob[jpos] = -1.0     # tombstone, filtered below
                else:
                    raise ValueError(
                        f"delta[{i}]: delete of missing edge {u}->{v}")
            else:  # OP_REWEIGHT
                if exists_orig:
                    prob[pos] = np.float32(p)
                elif exists_new:
                    app_prob[jpos] = p
                else:
                    raise ValueError(
                        f"delta[{i}]: reweight of missing edge {u}->{v}")

        live_new = [j for j, p in enumerate(app_prob) if p >= 0.0]
        new_src = np.concatenate(
            [src[alive], np.asarray([app_src[j] for j in live_new],
                                    np.int32)])
        new_dst = np.concatenate(
            [dst[alive], np.asarray([app_dst[j] for j in live_new],
                                    np.int32)])
        new_prob = np.concatenate(
            [prob[alive], np.asarray([app_prob[j] for j in live_new],
                                     np.float32)])
        new_w = np.concatenate(
            [w[alive], np.asarray([app_w[j] for j in live_new],
                                  np.float64)])
        return build_graph(new_src, new_dst, n, ic_prob=new_prob,
                           lt_weight=new_w)

    def apply_dense(self, P) -> jnp.ndarray:
        """Apply to a dense ``(n, n)`` IC matrix (``P[u, v] = p(u->v)``)
        in one scatter: deletes zero the entry, inserts/reweights set it
        (last operation on an edge wins).  The fast path for callers that
        mirror `repro.graphs.csr.dense_ic_matrix`; existence is *not*
        validated here — `apply` on the `Graph` is the strict source of
        truth."""
        if not len(self):
            return jnp.asarray(P)
        final: dict[tuple[int, int], float] = {}
        for i in range(len(self)):
            u, v = int(self.src[i]), int(self.dst[i])
            final[(u, v)] = (0.0 if int(self.op[i]) == OP_DELETE
                             else float(self.prob[i]))
        uu = np.asarray([k[0] for k in final], np.int32)
        vv = np.asarray([k[1] for k in final], np.int32)
        pp = np.asarray(list(final.values()), np.float32)
        return jnp.asarray(P).at[uu, vv].set(pp)


def canonicalize(graph: Graph) -> Graph:
    """Round-trip a graph through `edge_arrays`/`build_graph` once.

    The rebuilt graph is delta-stable: further rebuilds (every
    `GraphDelta.apply`) reproduce untouched edges' IC probabilities, LT
    cumulative weights *and* LT totals bit-for-bit, so resident RRR sets
    that avoided mutated vertices stay exactly re-sampleable.
    `StreamEngine` applies this before its first sample.
    """
    src, dst, prob, w = edge_arrays(graph)
    return build_graph(src, dst, graph.n, ic_prob=prob, lt_weight=w)


def random_delta(graph: Graph, rng, *, inserts: int = 0, deletes: int = 0,
                 reweights: int = 0,
                 max_dst_indeg: int | None = None) -> GraphDelta:
    """A valid random delta for ``graph``: deletes/reweights drawn from
    distinct existing edges, inserts from absent pairs (rejection
    sampled), probabilities U(0, 1).  Deterministic in ``rng``.

    ``max_dst_indeg`` restricts mutated destinations to vertices with at
    most that in-degree — the long-tail churn pattern of real evolving
    networks (hub edges are stable, fringe edges come and go), and the
    regime where invalidation pays: a hub destination sits in most RRR
    sets, so mutating it stales most of the store no matter how precise
    the reverse-touch marking is.
    """
    n = graph.n
    src = np.asarray(graph.in_src)
    dst = np.asarray(graph.edge_dst)
    indeg = np.bincount(dst, minlength=n)
    if max_dst_indeg is not None:
        edge_pool = np.flatnonzero(indeg[dst] <= max_dst_indeg)
        vert_pool = np.flatnonzero(indeg < max_dst_indeg)
        if edge_pool.size < deletes + reweights or not vert_pool.size:
            raise ValueError(
                f"max_dst_indeg={max_dst_indeg} leaves too few candidate "
                f"edges/vertices")
    else:
        edge_pool = np.arange(src.shape[0])
        vert_pool = np.arange(n)
    existing = set((src.astype(np.int64) * n + dst).tolist())
    parts = []
    if deletes or reweights:
        take = edge_pool[rng.choice(edge_pool.shape[0],
                                    size=deletes + reweights,
                                    replace=False)]
        if deletes:
            d = take[:deletes]
            parts.append(GraphDelta.deletes(src[d], dst[d]))
        if reweights:
            r = take[deletes:]
            parts.append(GraphDelta.reweights(
                src[r], dst[r], rng.uniform(0.0, 1.0, size=reweights)))
    if inserts:
        pairs = []
        seen = set(existing)
        while len(pairs) < inserts:
            u = int(rng.integers(n))
            v = int(vert_pool[rng.integers(vert_pool.shape[0])])
            k = u * n + v
            if u == v or k in seen:
                continue
            seen.add(k)
            pairs.append((u, v))
        uu = np.asarray([p[0] for p in pairs], np.int32)
        vv = np.asarray([p[1] for p in pairs], np.int32)
        parts.append(GraphDelta.inserts(
            uu, vv, rng.uniform(0.0, 1.0, size=inserts)))
    if not parts:
        raise ValueError("random_delta needs at least one operation")
    return GraphDelta.concat(parts)
