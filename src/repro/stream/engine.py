"""StreamEngine — influence serving on a graph that changes underneath.

Wraps an `InfluenceEngine` with the streaming cycle:

    stream = StreamEngine(graph, IMMConfig(...), policy=...)
    stream.extend(4096)              # sample the resident store
    stream.apply_delta(delta)        # edges change; stale rows die NOW
    stream.select(k)                 # serves immediately (live rows only)
    stream.refresh(budget=1024)      # repair stale rows incrementally
    stream.refresh()                 # ... until stream.stale == 0

Semantics:

  * **apply_delta** applies a `GraphDelta` to the graph, rebinds the
    sampler, and kills exactly the resident RRR sets whose traversal
    touched a mutated edge's destination (`repro.stream.invalidate`).
    The store version bump invalidates the engine's select memoization,
    so queries can never mix pre- and post-delta rows.  Each call opens a
    new **epoch**.
  * **refresh(budget)** repairs staleness in row-budgeted slices: stale
    rows are re-sampled *with their original batch keys* against the
    current graph and written back in place (``replace_rows``); rows lost
    to eviction/compaction are topped up with fresh keys drawn from the
    same per-engine key stream `InfluenceEngine.extend` uses — the seed
    stream is layout-independent, so a mesh-sharded stream refreshes to
    the same rows as a single-device one.
  * **Equivalence invariant** (tested in tests/test_stream.py): with an
    unbounded store and a delta-stable sampler, refreshing until
    ``stale == 0`` leaves the store holding *exactly* the multiset of
    rows a fresh `InfluenceEngine` would sample on the post-delta graph
    with the same seed and theta — surviving rows re-sample identically
    (they avoided all mutated destinations), repaired rows are taken
    from the very re-sample the fresh engine would draw.  Selection is
    permutation-invariant over rows, so ``select(k)`` matches
    seed-for-seed.
  * **Bounded memory**: pass a `StorePressurePolicy` and the arena never
    outgrows its row cap on an indefinite delta stream — dead rows are
    compacted away first, then the oldest live rows are evicted
    (staleness-first victim order); ``refresh`` tops back up to the cap.

`StreamEngine` canonicalizes the input graph once
(`repro.stream.delta.canonicalize`) so every delta rebuild reproduces
untouched edges bit-for-bit, and upgrades the positional ``IC-sparse``
sampler to the edge-identity-keyed ``IC-sparse-stable`` (the positional
coin layout would decorrelate every row on any edge-count change).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import jax.numpy as jnp

from repro.core.engine import IMMConfig, InfluenceEngine, Selection
from repro.core.sampler import default_sampler_name
from repro.core.store import StorePressurePolicy, make_store, next_pow2
from repro.graphs.csr import Graph
from repro.stream.delta import GraphDelta, canonicalize
from repro.stream.invalidate import invalidate


@dataclasses.dataclass(frozen=True)
class StreamSelection(Selection):
    """A `Selection` tagged with the stream epoch it answered in and the
    staleness backlog at answer time (``stale == 0`` means the answer is
    indistinguishable from a fresh engine on the current graph)."""
    epoch: int = -1
    stale: int = 0


class StreamEngine:
    """Dynamic-graph influence serving over a resident, repairable store.

    Parameters
    ----------
    graph, cfg : as `InfluenceEngine` (``cfg.sampler == "IC-sparse"`` is
        upgraded to the delta-stable ``"IC-sparse-stable"``).
    mesh, theta_axes, vertex_axis : mesh sharding, as `InfluenceEngine`.
    policy : optional `StorePressurePolicy` — bounded-memory mode.

    The wrapped engine is exposed as ``.engine``; ``select`` /
    ``influence`` / ``influences`` delegate to it (same memoization,
    correctly keyed across deltas by the store version).
    """

    def __init__(self, graph: Graph, cfg: IMMConfig = None, *,
                 mesh=None, theta_axes=("data",), vertex_axis=None,
                 policy: StorePressurePolicy | None = None):
        cfg = cfg if cfg is not None else IMMConfig()
        name = cfg.sampler or default_sampler_name(graph, cfg)
        # the positional samplers can only re-generate whole batches and
        # (IC-sparse) decorrelate entirely when the edge count changes —
        # upgrade to the delta-stable, row-subsettable twins
        name = {"IC-dense": "IC-dense-stable",
                "IC-sparse": "IC-sparse-stable",
                "LT": "LT-stable"}.get(name, name)
        cfg = dataclasses.replace(cfg, sampler=name)
        graph = canonicalize(graph)
        if mesh is not None:
            if cfg.store not in ("auto", "sharded"):
                raise ValueError(
                    "streaming on a mesh requires the sharded bitmap "
                    "store (cfg.store='auto')")
            store = make_store("sharded", graph.n, mesh=mesh,
                               theta_axes=theta_axes, policy=policy)
        else:
            kind = "bitmap" if cfg.store in ("auto", "sharded") else cfg.store
            store = make_store(kind, graph.n, policy=policy)
        store.track_remaps = True
        self.engine = InfluenceEngine(
            graph, cfg, store=store, mesh=mesh, theta_axes=theta_axes,
            vertex_axis=vertex_axis)
        self.policy = policy
        self.epoch = 0
        self.deltas_applied = 0
        self.target_theta = 0
        self._batch_keys: list[np.ndarray] = []
        # slot provenance: which (batch id, in-batch position) produced
        # the row living in each arena slot (-1 = unknown/empty)
        self._slot_batch = np.full(store.capacity, -1, np.int64)
        self._slot_pos = np.full(store.capacity, -1, np.int64)

    # -------------------------------------------------------- bookkeeping

    @property
    def graph(self) -> Graph:
        return self.engine.graph

    @property
    def cfg(self) -> IMMConfig:
        return self.engine.cfg

    @property
    def store(self):
        return self.engine.store

    @property
    def theta(self) -> int:
        """Live resident RRR sets (the effective serving theta)."""
        return self.store.live_count

    @property
    def _effective_target(self) -> int:
        cap = self.store.row_cap
        return (self.target_theta if cap is None
                else min(self.target_theta, cap))

    @property
    def stale(self) -> int:
        """Rows `refresh` still owes: dead-in-place stale rows plus any
        eviction deficit below the (cap-clamped) target theta."""
        return max(0, self._effective_target - self.store.live_count)

    @property
    def consistent(self) -> bool:
        """True when serving state equals a fresh engine on the current
        graph (no staleness backlog) — an epoch-consistent snapshot."""
        return self.stale == 0

    def _sync_layout(self):
        """Chase store-side slot moves (compaction, per-shard growth)
        through the provenance arrays."""
        store = self.store
        cap = store.capacity
        for remap in store.drain_remaps():
            nb = np.full(cap, -1, np.int64)
            npos = np.full(cap, -1, np.int64)
            old = min(remap.shape[0], self._slot_batch.shape[0])
            r = remap[:old]
            kept = r >= 0
            nb[r[kept]] = self._slot_batch[:old][kept]
            npos[r[kept]] = self._slot_pos[:old][kept]
            self._slot_batch, self._slot_pos = nb, npos
        if self._slot_batch.shape[0] < cap:
            pad = cap - self._slot_batch.shape[0]
            self._slot_batch = np.concatenate(
                [self._slot_batch, np.full(pad, -1, np.int64)])
            self._slot_pos = np.concatenate(
                [self._slot_pos, np.full(pad, -1, np.int64)])

    def _record(self, slots: np.ndarray, bid: int):
        self._slot_batch[slots] = bid
        self._slot_pos[slots] = np.arange(slots.shape[0])

    def _add_recorded_batch(self) -> int:
        """Draw one batch from the engine's key stream, store it, and
        record its provenance.  Returns rows written."""
        key, visited, counter = self.engine.sample_batch()
        bid = len(self._batch_keys)
        self._batch_keys.append(key)
        slots = self.store.add_batch(visited, counter)
        self._sync_layout()
        self._record(slots, bid)
        return slots.shape[0]

    # ----------------------------------------------------------- sampling

    def extend(self, theta: int) -> int:
        """Sample until the store holds >= ``theta`` *live* rows (clamped
        to the policy row cap), recording every batch's key for later
        same-key repair.  Returns the live count."""
        cap = self.store.row_cap
        target = theta if cap is None else min(int(theta), cap)
        while self.store.live_count < target:
            self._add_recorded_batch()
        self.target_theta = max(self.target_theta, target)
        return self.store.live_count

    # ------------------------------------------------------------- deltas

    def apply_delta(self, delta: GraphDelta) -> int:
        """Apply a `GraphDelta`: mutate the graph, rebind the sampler,
        and kill exactly the resident rows whose traversal touched a
        mutated edge's destination.  Opens a new epoch; serving continues
        immediately on the surviving rows.  Returns the number of rows
        that went stale."""
        new_graph = delta.apply(self.graph)
        stale = invalidate(self.store, delta.touched_vertices())
        self.engine.rebind_graph(new_graph)
        self.epoch += 1
        self.deltas_applied += 1
        return stale

    def refresh(self, budget: int | None = None) -> int:
        """Repair up to ``budget`` rows (None = everything) and return
        the remaining staleness backlog.

        Order of work (batch-granular, so a budget is approximate):
        (1) stale rows whose batch key is known are re-sampled with that
        key on the current graph and replaced in place; (2) stale slots
        with unknown provenance are compacted away; (3) any live deficit
        below the target theta (evictions, dropped slots) is topped up
        with fresh batches from the engine's key stream.
        """
        if budget is not None and int(budget) < 1:
            raise ValueError(
                f"refresh budget must be >= 1 row (got {budget}); a "
                f"zero budget can never drain the backlog")
        store = self.store
        if store.dead == 0 and self.stale == 0:
            return 0     # steady state: skip the live-mask gather entirely
        self._sync_layout()
        left = math.inf if budget is None else int(budget)

        dead_slots = np.flatnonzero(~np.asarray(store.live_mask()))
        by_bid: dict[int, list[int]] = {}
        for s in dead_slots:
            by_bid.setdefault(int(self._slot_batch[s]), []).append(int(s))
        orphans = by_bid.pop(-1, [])
        row_repair = self.engine.supports_row_resample
        for bid in sorted(by_bid):
            if left <= 0:
                break
            slots = np.asarray(by_bid[bid], np.int64)
            # pad the repair batch to a power of two (-1 targets are
            # dropped by the store) so the sampler/scatter kernels retrace
            # O(log batch) times, not once per distinct staleness count
            k = slots.shape[0]
            width = next_pow2(k, 1)
            idx = np.full(width, -1, np.int64)
            idx[:k] = slots
            pos = np.zeros(width, np.int64)
            pos[:k] = self._slot_pos[slots]
            if row_repair:
                # stable sampler: re-generate ONLY the stale rows of the
                # batch — repair work scales with staleness, not batches
                rows, _ = self.engine.resample(self._batch_keys[bid],
                                               positions=pos)
            else:
                visited, _ = self.engine.resample(self._batch_keys[bid])
                rows = jnp.take(visited, jnp.asarray(pos, jnp.int32),
                                axis=0)
            store.replace_rows(idx, rows)
            left -= k

        if orphans and left > 0:
            store.compact()
            self._sync_layout()

        while self.store.live_count < self._effective_target and left > 0:
            left -= self._add_recorded_batch()
        return self.stale

    # ------------------------------------------------------------ queries

    def select(self, k: int = None, *, method: str = None) -> StreamSelection:
        """Greedy top-k over the current live rows, tagged with the
        epoch and staleness backlog it was answered under.  Memoized by
        the wrapped engine; any delta bumps the store version, so a
        post-delta call can never return a pre-delta answer."""
        sel = self.engine.select(k, method=method)
        return StreamSelection(
            seeds=sel.seeds, covered_frac=sel.covered_frac,
            influence=sel.influence, gains=sel.gains,
            representation=sel.representation, theta=self.theta,
            epoch=self.epoch, stale=self.stale)

    def influences(self, seed_sets) -> np.ndarray:
        """Batched sigma(S) against the live rows of the current epoch."""
        return self.engine.influences(seed_sets)

    def influence(self, seed_set) -> float:
        """sigma(S) against the live rows of the current epoch."""
        return self.engine.influence(seed_set)
