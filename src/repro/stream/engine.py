"""StreamEngine — influence serving on a graph that changes underneath.

Wraps an `InfluenceEngine` with the streaming cycle:

    stream = StreamEngine(graph, IMMConfig(...), policy=...)
    stream.extend(4096)              # sample the resident store
    stream.apply_delta(delta)        # edges change; stale rows die NOW
    stream.select(k)                 # serves immediately (live rows only)
    stream.refresh(budget=1024)      # repair stale rows incrementally
    stream.refresh()                 # ... until stream.stale == 0

Semantics:

  * **apply_delta** applies a `GraphDelta` to the graph, rebinds the
    sampler, and kills exactly the resident RRR sets whose traversal
    touched a mutated edge's destination (`repro.stream.invalidate`).
    The store version bump invalidates the engine's select memoization,
    so queries can never mix pre- and post-delta rows.  Each call opens a
    new **epoch**.
  * **refresh(budget)** repairs staleness in row-budgeted slices: stale
    rows are re-sampled *with their original batch keys* against the
    current graph and written back in place (``replace_rows``); rows lost
    to eviction/compaction are topped up with fresh keys drawn from the
    same per-engine key stream `InfluenceEngine.extend` uses — the seed
    stream is layout-independent, so a mesh-sharded stream refreshes to
    the same rows as a single-device one.
  * **Equivalence invariant** (tested in tests/test_stream.py): with an
    unbounded store and a delta-stable sampler, refreshing until
    ``stale == 0`` leaves the store holding *exactly* the multiset of
    rows a fresh `InfluenceEngine` would sample on the post-delta graph
    with the same seed and theta — surviving rows re-sample identically
    (they avoided all mutated destinations), repaired rows are taken
    from the very re-sample the fresh engine would draw.  Selection is
    permutation-invariant over rows, so ``select(k)`` matches
    seed-for-seed.
  * **Bounded memory**: pass a `StorePressurePolicy` and the arena never
    outgrows its row cap on an indefinite delta stream — dead rows are
    compacted away first, then the oldest live rows are evicted
    (staleness-first victim order); ``refresh`` tops back up to the cap.

`StreamEngine` canonicalizes the input graph once
(`repro.stream.delta.canonicalize`) so every delta rebuild reproduces
untouched edges bit-for-bit, and upgrades the configured sampler to its
delta-stable form (``repro.core.sampler.stable_variant`` — the
positional coin layouts would decorrelate every row on any edge-count
change).  ``snapshot``/``restore`` persist the batch-key repair
provenance alongside the engine state, so a restored stream same-key
repairs instead of topping up.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.checkpoint import store as ckpt
from repro.core.engine import IMMConfig, InfluenceEngine, Selection
from repro.core.sampler import default_sampler_name, stable_variant
from repro.core.store import StorePressurePolicy, make_store, next_pow2
from repro.graphs.csr import Graph, edge_arrays
from repro.graphs.partition import resolve_partition
from repro.stream.delta import GraphDelta, canonicalize
from repro.stream.invalidate import invalidate


def _graph_fingerprint(graph: Graph) -> str:
    """Content hash of a (canonicalized) graph's edge set and weights —
    identical iff resident RRR rows sampled on one graph are valid
    against the other."""
    src, dst, prob, w = edge_arrays(graph)
    h = hashlib.sha256()
    for a in (src, dst, prob, np.asarray(w, np.float64)):
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class StreamSelection(Selection):
    """A `Selection` tagged with the stream epoch it answered in and the
    staleness backlog at answer time (``stale == 0`` means the answer is
    indistinguishable from a fresh engine on the current graph)."""
    epoch: int = -1
    stale: int = 0


class StreamEngine:
    """Dynamic-graph influence serving over a resident, repairable store.

    Parameters
    ----------
    graph, cfg : as `InfluenceEngine` (the resolved sampler is upgraded
        to its delta-stable form, e.g. ``"IC/sparse"`` ->
        ``"IC/sparse+stable"``).
    mesh, theta_axes, vertex_axis : mesh sharding, as `InfluenceEngine`.
    policy : optional `StorePressurePolicy` — bounded-memory mode.

    The wrapped engine is exposed as ``.engine``; ``select`` /
    ``influence`` / ``influences`` delegate to it (same memoization,
    correctly keyed across deltas by the store version).
    """

    def __init__(self, graph: Graph, cfg: IMMConfig = None, *,
                 mesh=None, theta_axes=("data",), vertex_axis=None,
                 policy: StorePressurePolicy | None = None):
        cfg = cfg if cfg is not None else IMMConfig()
        name = cfg.sampler or default_sampler_name(graph, cfg)
        # the positional coin layouts can only re-generate whole batches
        # and (sparse backends) decorrelate entirely when the edge count
        # changes — upgrade any composed or legacy name to its
        # delta-stable, row-subsettable form
        name = stable_variant(name)
        cfg = dataclasses.replace(cfg, sampler=name)
        graph = canonicalize(graph)
        if mesh is not None:
            if cfg.store not in ("auto", "sharded", "packed", "compressed"):
                raise ValueError(
                    "streaming on a mesh requires a sharded dense-at-rest "
                    "store: cfg.store='auto' (sharded bitmap), 'packed', "
                    "or 'compressed'")
            # balanced boundaries are derived from the *initial* graph
            # and stay fixed across deltas — a snapshot/restore (or a
            # fresh stream on the mutated graph) re-partitions, the
            # resident rows re-tile through the store's global-order
            # snapshot contract
            part = None
            if vertex_axis is not None:
                part = resolve_partition(
                    getattr(cfg, "partition", "equal"), graph.n,
                    int(mesh.shape[vertex_axis]), dst=graph.edge_dst)
            codec = ("bitmap" if cfg.store in ("auto", "sharded")
                     else cfg.store)
            store = make_store("sharded", graph.n, mesh=mesh,
                               theta_axes=theta_axes,
                               vertex_axis=vertex_axis, policy=policy,
                               partition=part, codec=codec)
        else:
            kind = "bitmap" if cfg.store in ("auto", "sharded") else cfg.store
            store = make_store(kind, graph.n, policy=policy)
        store.track_remaps = True
        self.engine = InfluenceEngine(
            graph, cfg, store=store, mesh=mesh, theta_axes=theta_axes,
            vertex_axis=vertex_axis)
        self.policy = policy
        self.epoch = 0
        self.deltas_applied = 0
        self.target_theta = 0
        # per-slice repair accounting (read by the serving tier's
        # SLO-aware refresh scheduler): how many refresh slices ran, how
        # many rows they repaired in total, and the last slice's yield
        self.refreshes = 0
        self.rows_repaired = 0
        self.last_repair = 0
        self._batch_keys: list[np.ndarray] = []
        # slot provenance: which (batch id, in-batch position) produced
        # the row living in each arena slot (-1 = unknown/empty)
        self._slot_batch = np.full(store.capacity, -1, np.int64)
        self._slot_pos = np.full(store.capacity, -1, np.int64)

    # -------------------------------------------------------- bookkeeping

    @property
    def graph(self) -> Graph:
        return self.engine.graph

    @property
    def cfg(self) -> IMMConfig:
        return self.engine.cfg

    @property
    def store(self):
        return self.engine.store

    @property
    def theta(self) -> int:
        """Live resident RRR sets (the effective serving theta)."""
        return self.store.live_count

    @property
    def _effective_target(self) -> int:
        cap = self.store.row_cap
        return (self.target_theta if cap is None
                else min(self.target_theta, cap))

    @property
    def stale(self) -> int:
        """Rows `refresh` still owes: dead-in-place stale rows plus any
        eviction deficit below the (cap-clamped) target theta."""
        return max(0, self._effective_target - self.store.live_count)

    @property
    def consistent(self) -> bool:
        """True when serving state equals a fresh engine on the current
        graph (no staleness backlog) — an epoch-consistent snapshot."""
        return self.stale == 0

    @property
    def backlog(self) -> int:
        """Staleness-backlog size: dead-in-place rows awaiting same-key
        repair plus the live deficit below the target theta.  The
        quantity the serving tier's refresh scheduler allocates the
        global budget against (``stale`` spelled for schedulers)."""
        return self.stale

    def _sync_layout(self):
        """Chase store-side slot moves (compaction, per-shard growth)
        through the provenance arrays."""
        store = self.store
        cap = store.capacity
        for remap in store.drain_remaps():
            nb = np.full(cap, -1, np.int64)
            npos = np.full(cap, -1, np.int64)
            old = min(remap.shape[0], self._slot_batch.shape[0])
            r = remap[:old]
            kept = r >= 0
            nb[r[kept]] = self._slot_batch[:old][kept]
            npos[r[kept]] = self._slot_pos[:old][kept]
            self._slot_batch, self._slot_pos = nb, npos
        if self._slot_batch.shape[0] < cap:
            pad = cap - self._slot_batch.shape[0]
            self._slot_batch = np.concatenate(
                [self._slot_batch, np.full(pad, -1, np.int64)])
            self._slot_pos = np.concatenate(
                [self._slot_pos, np.full(pad, -1, np.int64)])

    def _record(self, slots: np.ndarray, bid: int):
        self._slot_batch[slots] = bid
        self._slot_pos[slots] = np.arange(slots.shape[0])

    def _add_recorded_batch(self) -> int:
        """Draw one batch from the engine's key stream, store it, and
        record its provenance.  Returns rows written."""
        key, visited, counter = self.engine.sample_batch()
        bid = len(self._batch_keys)
        self._batch_keys.append(key)
        slots = self.store.add_batch(visited, counter)
        self._sync_layout()
        self._record(slots, bid)
        return slots.shape[0]

    # ----------------------------------------------------------- sampling

    def extend(self, theta: int) -> int:
        """Sample until the store holds >= ``theta`` *live* rows (clamped
        to the policy row cap), recording every batch's key for later
        same-key repair.  Returns the live count."""
        cap = self.store.row_cap
        target = theta if cap is None else min(int(theta), cap)
        while self.store.live_count < target:
            self._add_recorded_batch()
        self.target_theta = max(self.target_theta, target)
        return self.store.live_count

    # ------------------------------------------------------------- deltas

    def apply_delta(self, delta: GraphDelta) -> int:
        """Apply a `GraphDelta`: mutate the graph, rebind the sampler,
        and kill exactly the resident rows whose traversal touched a
        mutated edge's destination.  Opens a new epoch; serving continues
        immediately on the surviving rows.  Returns the number of rows
        that went stale."""
        with obs.span("delta", tier="stream", epoch=self.epoch + 1):
            new_graph = delta.apply(self.graph)
            stale = invalidate(self.store, delta.touched_vertices())
            self.engine.rebind_graph(new_graph)
        self.epoch += 1
        self.deltas_applied += 1
        obs.counter("stream.deltas").add(1)
        obs.counter("stream.rows_invalidated").add(stale)
        obs.gauge("stream.backlog").set(self.stale)
        return stale

    def refresh(self, budget: int | None = None) -> int:
        """Repair up to ``budget`` rows (None = everything) and return
        the remaining staleness backlog.

        Order of work (batch-granular, so a budget is approximate):
        (1) stale rows whose batch key is known are re-sampled with that
        key on the current graph and replaced in place; (2) stale slots
        with unknown provenance are compacted away; (3) any live deficit
        below the target theta (evictions, dropped slots) is topped up
        with fresh batches from the engine's key stream.
        """
        if budget is not None and int(budget) < 1:
            raise ValueError(
                f"refresh budget must be >= 1 row (got {budget}); a "
                f"zero budget can never drain the backlog")
        store = self.store
        if store.dead == 0 and self.stale == 0:
            return 0     # steady state: skip the live-mask gather entirely
        with obs.span("refresh", tier="stream",
                      budget=-1 if budget is None else int(budget)):
            self._sync_layout()
            left = math.inf if budget is None else int(budget)
            repaired = 0

            dead_slots = np.flatnonzero(~np.asarray(store.live_mask()))
            by_bid: dict[int, list[int]] = {}
            for s in dead_slots:
                by_bid.setdefault(int(self._slot_batch[s]), []).append(int(s))
            orphans = by_bid.pop(-1, [])
            row_repair = self.engine.supports_row_resample
            for bid in sorted(by_bid):
                if left <= 0:
                    break
                slots = np.asarray(by_bid[bid], np.int64)
                # pad the repair batch to a power of two (-1 targets are
                # dropped by the store) so the sampler/scatter kernels
                # retrace O(log batch) times, not once per distinct
                # staleness count
                k = slots.shape[0]
                width = next_pow2(k, 1)
                idx = np.full(width, -1, np.int64)
                idx[:k] = slots
                pos = np.zeros(width, np.int64)
                pos[:k] = self._slot_pos[slots]
                if row_repair:
                    # stable sampler: re-generate ONLY the stale rows of
                    # the batch — repair work scales with staleness, not
                    # batches
                    rows, _ = self.engine.resample(self._batch_keys[bid],
                                                   positions=pos)
                else:
                    visited, _ = self.engine.resample(self._batch_keys[bid])
                    rows = jnp.take(visited, jnp.asarray(pos, jnp.int32),
                                    axis=0)
                store.replace_rows(idx, rows)
                left -= k
                repaired += k

            if orphans and left > 0:
                store.compact()
                self._sync_layout()

            while self.store.live_count < self._effective_target and left > 0:
                got = self._add_recorded_batch()
                left -= got
                repaired += got
        self.refreshes += 1
        self.rows_repaired += repaired
        self.last_repair = repaired
        obs.counter("stream.refreshes").add(1)
        obs.counter("stream.rows_repaired").add(repaired)
        obs.gauge("stream.backlog").set(self.stale)
        return self.stale

    # ------------------------------------------------------- checkpointing

    def snapshot(self, directory: str, *, tag: str = "stream") -> str:
        """Persist the wrapped engine's state *plus* the stream's repair
        provenance — the per-batch PRNG keys and the (batch, position)
        that produced every resident row — so a restored stream same-key
        repairs future staleness instead of topping up with fresh keys
        (which would break the refresh-until-consistent equivalence with
        a fresh engine).  One atomic file via `checkpoint.store`.

        Row provenance is saved aligned with the store snapshot's row
        order: full-arena order for a `BitmapStore` (dead rows keep
        their provenance — a restored stream can finish an in-flight
        repair), compacted live-row order for a `ShardedStore`.
        """
        self._sync_layout()
        store = self.store
        if hasattr(store, "_filled_host"):          # ShardedStore layout
            keep = store._filled_host() & store._live_host
            slot_batch = self._slot_batch[keep]
            slot_pos = self._slot_pos[keep]
        else:
            slot_batch, slot_pos = self._slot_batch, self._slot_pos
        keys = (np.stack([np.asarray(k) for k in self._batch_keys])
                if self._batch_keys else np.zeros((0, 2), np.uint32))
        tree = {
            "engine": self.engine.snapshot_tree(),
            "stream": {
                "batch_keys": keys,
                "slot_batch": np.asarray(slot_batch, np.int64),
                "slot_pos": np.asarray(slot_pos, np.int64),
                "batch": np.int64(self.cfg.batch),
                "graph_sha": np.asarray(_graph_fingerprint(self.graph)),
                "target_theta": np.int64(self.target_theta),
                "epoch": np.int64(self.epoch),
                "deltas_applied": np.int64(self.deltas_applied),
            },
        }
        return ckpt.save_named(directory, tag, tree)

    def restore(self, directory: str, *, tag: str = "stream") -> bool:
        """Resume from `snapshot`; returns False when none exists.

        The engine restores elastically across store layouts (any mesh
        or none); the stream then re-derives its slot -> (batch,
        position) provenance through the restored store's snapshot-row
        placement (``_restore_slots``), so every surviving row keeps its
        original batch key and the next delta repairs it in place with
        the same coins the saved stream would have used.
        """
        tree = ckpt.load_named(directory, tag)
        if tree is None:
            return False
        # the saved batch keys only reproduce their rows under the very
        # sampler and batch width that drew them — a mismatched restore
        # would silently corrupt same-key repair (positions gathers from
        # a different-width batch), so fail loudly instead
        saved_sampler = str(np.asarray(tree["engine"]["meta"]["sampler"]))
        if saved_sampler != self.engine.sampler_name:
            raise ValueError(
                f"snapshot was sampled with {saved_sampler!r}, this "
                f"stream resolves {self.engine.sampler_name!r}; same-key "
                f"repair needs the identical sampler composition")
        saved_batch = int(tree["stream"]["batch"])
        if saved_batch != self.cfg.batch:
            raise ValueError(
                f"snapshot was sampled with batch={saved_batch}, this "
                f"stream has batch={self.cfg.batch}; same-key repair "
                f"needs the identical batch width")
        saved_graph = str(np.asarray(tree["stream"]["graph_sha"]))
        if saved_graph != _graph_fingerprint(self.graph):
            raise ValueError(
                "snapshot was taken against a different graph (edge "
                "set/weights differ); its resident rows and batch keys "
                "are not valid here — construct the stream with the "
                "snapshot's graph, then apply further deltas through "
                "apply_delta")
        self.engine.restore_tree(tree["engine"])
        store = self.store
        store.track_remaps = True
        store.policy = self.policy      # restore drops it; re-arm the cap
        st = tree["stream"]
        keys = np.asarray(st["batch_keys"])
        self._batch_keys = [keys[i] for i in range(keys.shape[0])]
        self.target_theta = int(st["target_theta"])
        self.epoch = int(st["epoch"])
        self.deltas_applied = int(st["deltas_applied"])
        prov_b = np.asarray(st["slot_batch"], np.int64)
        prov_p = np.asarray(st["slot_pos"], np.int64)
        self._slot_batch = np.full(store.capacity, -1, np.int64)
        self._slot_pos = np.full(store.capacity, -1, np.int64)
        slots = getattr(store, "_restore_slots", None)
        if slots is None:
            # same-layout single-device restore: snapshot rows *are* the
            # arena slots (dead rows included)
            k = min(store.capacity, prov_b.shape[0])
            self._slot_batch[:k] = prov_b[:k]
            self._slot_pos[:k] = prov_p[:k]
            return True
        snap_store = tree["engine"]["store"]
        if str(np.asarray(snap_store["kind"])) != "sharded":
            # a full-arena snapshot restored through row re-adding keeps
            # live rows only — apply the same filter to the provenance
            count = int(snap_store["count"])
            prov_b, prov_p = prov_b[:count], prov_p[:count]
            if "live" in snap_store:
                live = np.asarray(snap_store["live"])[:count].astype(bool)
                prov_b, prov_p = prov_b[live], prov_p[live]
        self._slot_batch[slots] = prov_b[:slots.shape[0]]
        self._slot_pos[slots] = prov_p[:slots.shape[0]]
        return True

    # ------------------------------------------------------------ queries

    def select(self, k: int = None, *, method: str = None) -> StreamSelection:
        """Greedy top-k over the current live rows, tagged with the
        epoch and staleness backlog it was answered under.  Memoized by
        the wrapped engine; any delta bumps the store version, so a
        post-delta call can never return a pre-delta answer."""
        sel = self.engine.select(k, method=method)
        return StreamSelection(
            seeds=sel.seeds, covered_frac=sel.covered_frac,
            influence=sel.influence, gains=sel.gains,
            representation=sel.representation, theta=self.theta,
            epoch=self.epoch, stale=self.stale)

    def influences(self, seed_sets) -> np.ndarray:
        """Batched sigma(S) against the live rows of the current epoch."""
        return self.engine.influences(seed_sets)

    def influence(self, seed_set) -> float:
        """sigma(S) against the live rows of the current epoch."""
        return self.engine.influence(seed_set)
