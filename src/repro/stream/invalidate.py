"""Vertex -> RRR-row reverse-touch queries: which resident sets go stale.

The key observation is that the store's arena *is* the reverse-touch
index, and it is maintained at write time for free: column ``v`` of a
bitmap arena lists exactly the rows whose traversal touched ``v`` (the
sampler wrote the bit the moment the traversal activated ``v``), and an
`IndexStore` row is literally the list of touched vertices.  So the
"index update" happens inside ``add_batch``'s existing write, and a
staleness query after a `GraphDelta` is a masked column reduction — no
separate structure to build, grow, or keep consistent.

For a `ShardedStore` the query is shard-local by construction: the
touched-vertex list is tiny and replicated, each device reduces over its
own arena block, and the resulting stale mask stays sharded
``P(theta_axes)`` — nothing row-sized crosses devices.  Which columns a
device owns is the store's `VertexPartition` contract (equal or
edge-balanced blocks): each tile resolves the touched vertices against
its own block-start offsets, so the query answers identically under any
column layout.

``invalidate(store, vertices)`` marks the touched rows dead through the
store's ``kill_rows`` primitive: they leave ``view().valid``, ``hits``
and the fused counter immediately (the masked valid bit already flows
through fused counting and every selection strategy), so serving
continues on the surviving rows with no rebuild while
`repro.stream.engine.StreamEngine.refresh` repairs in the background.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.store import next_pow2


@jax.jit
def _touched_bitmap(R, verts, vmask):
    """Rows of ``R (cap, n)`` with a set bit in any masked ``verts``
    column.  Runs shard-local on a sharded arena (columns are
    replicated)."""
    memb = jnp.take(R, verts, axis=1) > 0                 # (cap, V)
    return (memb & vmask[None, :]).any(axis=1)


@partial(jax.jit, static_argnames=("codec",))
def _touched_codec(R, verts, vmask, *, codec):
    """Encoded-arena version (IMPack packed/compressed rows): membership
    of the touched columns is decoded in place — a byte gather + shift
    for packed rows, a token comparison for compressed ones — the
    encoded arena never expands."""
    memb = codec.decode_cols(R, verts)                    # (cap, V) bool
    return (memb & vmask[None, :]).any(axis=1)


@jax.jit
def _touched_indices(R_idx, verts, vmask):
    """Index-list version: rows containing any masked vertex (the rows
    are the touch lists themselves)."""
    def one(args):
        v, ok = args
        return (R_idx == v).any(axis=1) & ok

    hit = jax.lax.map(one, (verts, vmask))                # (V, cap)
    return hit.any(axis=0)


def _padded_vertices(vertices, n: int):
    """Unique in-range vertices padded to a power of two (bounds jit
    retraces to O(log n) distinct query widths); pad entries are masked
    out and point at vertex 0 to stay gather-safe."""
    verts = np.unique(np.asarray(vertices, np.int32))
    if verts.size and ((verts < 0).any() or (verts >= n).any()):
        raise ValueError(f"touched vertices out of range for n={n}")
    V = next_pow2(max(int(verts.size), 1), 1)
    padded = np.zeros(V, np.int32)
    padded[:verts.size] = verts
    vmask = np.zeros(V, bool)
    vmask[:verts.size] = True
    return jnp.asarray(padded), jnp.asarray(vmask)


def rows_touching(store, vertices) -> jnp.ndarray:
    """``(capacity,) bool`` mask of arena rows whose RRR traversal
    touched any of ``vertices`` (unfilled/padding rows are all-zero /
    all-sentinel, so they never match).  Sharded stores answer through
    their own tile-local kernel (`ShardedStore.rows_touching_cols`): each
    (theta, vertex) tile scans the touched vertices inside its own column
    block against its own rows, and only per-row hit bits cross the
    vertex axis — shard-local in both mesh axes."""
    verts, vmask = _padded_vertices(vertices, store.n)
    sharded = getattr(store, "rows_touching_cols", None)
    if sharded is not None:
        return sharded(verts, vmask)
    if store.representation in ("packed", "compressed"):
        return _touched_codec(store.R, verts, vmask, codec=store.codec)
    if store.representation == "bitmap":
        return _touched_bitmap(store.R, verts, vmask)
    return _touched_indices(store.R, verts, vmask)


def invalidate(store, vertices) -> int:
    """Mark every resident RRR set that touched ``vertices`` as stale
    (dead): the conservative staleness set for a `GraphDelta` whose
    mutated-edge destinations are ``vertices`` (see
    `repro.stream.delta.GraphDelta.touched_vertices`).  Returns the
    number of newly stale rows."""
    return store.kill_rows(rows_touching(store, vertices))
