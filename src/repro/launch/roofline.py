"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS §Roofline):

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = wire_bytes_per_device / ICI_link_bw

``cost_analysis`` of the compiled executable is already per-device (the
SPMD-partitioned program), so dividing by per-chip peaks is equivalent to
the global form HLO_FLOPs / (chips x peak).

collective bytes are NOT in cost_analysis: we parse the optimized HLO and
sum wire bytes of every collective op with ring-algorithm conventions:
  all-reduce X bytes      -> 2X on the wire per device (reduce-scatter +
                             all-gather phases, (G-1)/G ~ 1)
  all-gather out X        -> X   (each device receives X(G-1)/G)
  reduce-scatter in X     -> X
  all-to-all X            -> X
  collective-permute X    -> X
``-start`` async forms are counted; ``-done`` forms are skipped.
"""
from __future__ import annotations

import dataclasses
import re

from repro.launch.mesh import TPU_V5E


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all arrays in an HLO type string like
    'f32[128,1024]{1,0}' or '(f32[8], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    wire_bytes: float

    def as_dict(self):
        return {"counts": self.counts, "bytes_by_kind": self.bytes_by_kind,
                "wire_bytes": self.wire_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts = {k: 0 for k in _COLLECTIVE_KINDS}
    bytes_by_kind = {k: 0 for k in _COLLECTIVE_KINDS}
    wire = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%name = TYPE kind(" — the op kind follows the '=' and type
        m = re.search(r"=\s+(\S.*?)\s+([a-z0-9-]+)\(", s)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        base = op
        if base.endswith("-start"):
            base = base[:-6]
        elif base.endswith("-done") or base.endswith("-update"):
            continue
        if base not in _COLLECTIVE_KINDS:
            continue
        nbytes = _shape_bytes(type_str)
        counts[base] += 1
        bytes_by_kind[base] += nbytes
        if base == "all-reduce":
            wire += 2.0 * nbytes
        else:
            wire += float(nbytes)
    return CollectiveStats(counts, bytes_by_kind, wire)


def roofline_terms(flops: float, bytes_acc: float, wire_bytes: float,
                   model_flops_global: float, n_devices: int,
                   hw: dict = TPU_V5E, extra: dict | None = None) -> dict:
    """All inputs are PER-DEVICE (the compiled module is the per-device
    program); model_flops_global is the whole-step analytic count."""
    t_compute = flops / hw["peak_flops_bf16"]
    t_memory = bytes_acc / hw["hbm_bytes_per_s"]
    t_collective = wire_bytes / hw["ici_bytes_per_s"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    hlo_flops_global = flops * n_devices
    return {
        **terms,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "wire_bytes_per_device": wire_bytes,
        "model_flops_global": model_flops_global,
        "useful_flops_ratio": (model_flops_global / hlo_flops_global
                               if hlo_flops_global else 0.0),
        "roofline_fraction": (
            (model_flops_global / n_devices / hw["peak_flops_bf16"])
            / terms[dominant] if terms[dominant] > 0 else 0.0),
        **(extra or {}),
    }
