"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS §Roofline):

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = wire_bytes_per_device / ICI_link_bw

``cost_analysis`` of the compiled executable is already per-device (the
SPMD-partitioned program), so dividing by per-chip peaks is equivalent to
the global form HLO_FLOPs / (chips x peak).

collective bytes are NOT in cost_analysis: we parse the optimized HLO and
sum wire bytes of every collective op with ring-algorithm conventions:
  all-reduce X bytes      -> 2X on the wire per device (reduce-scatter +
                             all-gather phases, (G-1)/G ~ 1)
  all-gather out X        -> X   (each device receives X(G-1)/G)
  reduce-scatter in X     -> X
  all-to-all X            -> X
  collective-permute X    -> X
``-start`` async forms are counted; ``-done`` forms are skipped.
"""
from __future__ import annotations

import dataclasses
import re

from repro.launch.mesh import TPU_V5E


# ------------------------------------------------- device peak table ----
#
# Peaks keyed by ``device_kind`` (what ``jax.devices()[0].platform`` /
# benchmarks._emit.device_kind() report).  The TPU row is the v5e the
# production mesh targets (launch/mesh.py); the GPU row is an A100-class
# part (dense bf16 tensor-core peak, HBM2e, NVLink per direction); the
# CPU row is a deliberately round-number server-class socket estimate
# (AVX-512 F32 throughput, dual-channel-ish DRAM) so CPU BENCH rows get
# an order-of-magnitude achieved fraction rather than a meaningless one.
# The "unknown" fallback is tiny on purpose: an unrecognized platform
# reports achieved_frac ~ 1.0-clamped garbage loudly instead of quietly
# flattering numbers.

HW_PEAKS = {
    "tpu": TPU_V5E,
    "gpu": {
        "name": "A100-40G class",
        "peak_flops_bf16": 312e12,
        "hbm_bytes_per_s": 1.555e12,
        "ici_bytes_per_s": 300e9,
        "hbm_bytes": 40 * 2**30,
    },
    "cpu": {
        "name": "server CPU (estimate)",
        "peak_flops_bf16": 1e12,
        "hbm_bytes_per_s": 5e10,
        "ici_bytes_per_s": 1e10,
        "hbm_bytes": 64 * 2**30,
    },
    "unknown": {
        "name": "unknown device",
        "peak_flops_bf16": 1e9,
        "hbm_bytes_per_s": 1e9,
        "ici_bytes_per_s": 1e9,
        "hbm_bytes": 1 * 2**30,
    },
}


def peaks_for(device_kind: str | None = None) -> dict:
    """The `HW_PEAKS` row for ``device_kind`` (auto-detected from the
    default jax backend when None; anything unrecognized gets the
    explicit "unknown" fallback, never a KeyError)."""
    if device_kind is None:
        try:
            import jax
            device_kind = jax.devices()[0].platform
        except Exception:
            device_kind = "unknown"
    return HW_PEAKS.get(str(device_kind), HW_PEAKS["unknown"])


# --------------------------------------------- per-kernel cost models ----
#
# Analytic (flops, bytes) estimates for the Pallas kernels in
# ``repro.kernels`` — the *useful* work, not what a given impl happens
# to execute, so ``achieved_frac`` compares impls against the same
# yardstick.  Shapes are the kwargs each entry names; counts assume f32
# accumulation (2 flops per MAC) and one HBM touch per logical input and
# output byte.

KERNEL_COST_MODELS = {
    # masked counter rebuild: (theta,) x (theta, n) mat-vec
    "coverage_matvec": lambda theta, n: (
        2.0 * theta * n, theta * n + 4.0 * theta + 4.0 * n),
    # same reduction fused with the argmax (outputs are scalars)
    "fused_select": lambda theta, n: (
        2.0 * theta * n + n, theta * n + 4.0 * theta),
    # one probabilistic-BFS step: frontier @ logq + activation test
    "ic_frontier_step": lambda B, n: (
        2.0 * B * n * n + 4.0 * B * n,
        4.0 * n * n + 3.0 * B * n),
    # encode + column-count over one sampled batch (the commit tail of
    # the fused chain): bitmap stores B*n bytes back, packed B*n/8
    "arena_commit": lambda B, n, kind="bitmap": (
        (2.0 if kind == "packed" else 1.0) * B * n,
        B * n + (B * n / 8.0 if kind == "packed" else B * n) + 4.0 * n),
    # decode-and-count over a bit-packed arena
    "packed_count": lambda theta, n: (
        3.0 * theta * n, theta * n / 8.0 + 4.0 * theta + 4.0 * n),
    # decode-and-count over token rows (s_pad int32 tokens per row)
    "token_count": lambda theta, n, s_pad=8: (
        3.0 * theta * n, 4.0 * theta * s_pad + 4.0 * theta + 4.0 * n),
    # the full fused sample->write->count chain: `steps` frontier
    # passes + the commit (BENCH_10's kernel row)
    "sample_write_count": lambda B, n, steps=4, kind="bitmap": tuple(
        a + b for a, b in zip(
            tuple(x * steps for x in
                  KERNEL_COST_MODELS["ic_frontier_step"](B=B, n=n)),
            KERNEL_COST_MODELS["arena_commit"](B=B, n=n, kind=kind))),
}


def kernel_cost(kernel: str, **shape) -> tuple[float, float]:
    """(flops, bytes) of ``kernel`` at ``shape`` per
    `KERNEL_COST_MODELS`; raises KeyError for an unmodeled kernel so a
    bench cannot silently report a cost of zero."""
    return KERNEL_COST_MODELS[kernel](**shape)


def achieved_frac(kernel: str, wall_s: float, *,
                  device_kind: str | None = None, **shape) -> float:
    """Achieved fraction of the roofline bound: the kernel's analytic
    best-case time on ``device_kind`` (max of its compute and memory
    terms against `peaks_for`) divided by the measured ``wall_s``,
    clamped to [0, 1].  This is an *estimate* keyed by the cost model —
    its job in BENCH_10 is comparing fused vs unfused on the same
    yardstick, not absolute attainment."""
    if wall_s <= 0.0:
        return 0.0
    flops, bytes_acc = kernel_cost(kernel, **shape)
    hw = peaks_for(device_kind)
    t_bound = max(flops / hw["peak_flops_bf16"],
                  bytes_acc / hw["hbm_bytes_per_s"])
    return min(t_bound / wall_s, 1.0)


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all arrays in an HLO type string like
    'f32[128,1024]{1,0}' or '(f32[8], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    wire_bytes: float

    def as_dict(self):
        return {"counts": self.counts, "bytes_by_kind": self.bytes_by_kind,
                "wire_bytes": self.wire_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts = {k: 0 for k in _COLLECTIVE_KINDS}
    bytes_by_kind = {k: 0 for k in _COLLECTIVE_KINDS}
    wire = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%name = TYPE kind(" — the op kind follows the '=' and type
        m = re.search(r"=\s+(\S.*?)\s+([a-z0-9-]+)\(", s)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        base = op
        if base.endswith("-start"):
            base = base[:-6]
        elif base.endswith("-done") or base.endswith("-update"):
            continue
        if base not in _COLLECTIVE_KINDS:
            continue
        nbytes = _shape_bytes(type_str)
        counts[base] += 1
        bytes_by_kind[base] += nbytes
        if base == "all-reduce":
            wire += 2.0 * nbytes
        else:
            wire += float(nbytes)
    return CollectiveStats(counts, bytes_by_kind, wire)


def roofline_terms(flops: float, bytes_acc: float, wire_bytes: float,
                   model_flops_global: float, n_devices: int,
                   hw: dict = TPU_V5E, extra: dict | None = None) -> dict:
    """All inputs are PER-DEVICE (the compiled module is the per-device
    program); model_flops_global is the whole-step analytic count."""
    t_compute = flops / hw["peak_flops_bf16"]
    t_memory = bytes_acc / hw["hbm_bytes_per_s"]
    t_collective = wire_bytes / hw["ici_bytes_per_s"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    dominant = max(terms, key=terms.get)
    hlo_flops_global = flops * n_devices
    return {
        **terms,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_acc,
        "wire_bytes_per_device": wire_bytes,
        "model_flops_global": model_flops_global,
        "useful_flops_ratio": (model_flops_global / hlo_flops_global
                               if hlo_flops_global else 0.0),
        "roofline_fraction": (
            (model_flops_global / n_devices / hw["peak_flops_bf16"])
            / terms[dominant] if terms[dominant] > 0 else 0.0),
        **(extra or {}),
    }
