"""Per-cell step builders: (arch x shape x mesh) -> lowered-compatible fn +
ShapeDtypeStruct inputs + shardings.

This is the distribution heart of the framework: every assigned cell (40
total) plus the IMM production cells map here onto the fixed production mesh
(launch/mesh.py).  Policies live in launch/shardings.py; model math stays in
repro.models / repro.core.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.configs._gnn_common import minibatch_subgraph_dims
from repro.launch import shardings as sh
from repro.launch.mesh import dp_axes
from repro.models.transformer import (
    LMConfig, init_lm, lm_loss, prefill, prefill_chunked, decode_step,
)
from repro.models.gnn import graphcast as m_graphcast
from repro.models.gnn import equiformer as m_equiformer
from repro.models.gnn import egnn as m_egnn
from repro.models.gnn import graphsage as m_sage
from repro.models.recsys import fm as m_fm
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm
from repro.sparse.embedding_bag import sharded_embedding_lookup


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    step_fn: Callable
    input_specs: tuple               # positional ShapeDtypeStructs
    in_shardings: Any
    out_shardings: Any               # None -> let GSPMD choose
    model_flops: float               # analytic "useful" flops (global)
    note: str = ""
    # ideal HBM traffic of a fused (Pallas flash) attention, GLOBAL bytes:
    # the jnp blockwise path materializes score tensors at fusion
    # boundaries that the TPU kernel keeps in VMEM; §Roofline reports the
    # memory term both raw and kernel-adjusted using this value.
    attention_ideal_bytes: float = 0.0


def _lm_attention_ideal_bytes(cfg: LMConfig, kind: str, batch: int,
                              q_len: int, kv_len: int) -> float:
    """Q/K/V/O HBM traffic of a fused attention kernel, all layers, bytes.

    fwd: read Q,K,V + write O; bwd: read Q,K,V,O,dO + write dQ,dK,dV;
    remat adds one extra fwd. bf16 elements.
    """
    hd = cfg.head_dim
    qo = batch * q_len * cfg.n_heads * hd
    kv = batch * kv_len * cfg.n_kv_heads * hd
    fwd = 2.0 * (qo * 2 + kv * 2)
    if kind == "train":
        bwd = 2.0 * (qo * 3 + kv * 4)
        per_layer = 2 * fwd + bwd          # fwd + remat-fwd + bwd
    else:
        per_layer = fwd
    return cfg.n_layers * per_layer


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _dp(mesh):
    return dp_axes(mesh)


# =========================================================== LM family ====

def _lm_state_specs(cfg: LMConfig, mesh, opt_cfg: AdamWConfig):
    policy = sh.LM_POLICY[cfg.name] if cfg.name in sh.LM_POLICY else "tp"
    p_shapes = jax.eval_shape(partial(init_lm, cfg=cfg), jax.random.PRNGKey(0))
    o_shapes = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), p_shapes)
    p_specs = sh.lm_param_specs(p_shapes, policy, mesh)
    o_specs = {
        "mu": p_specs, "nu": p_specs, "step": P(),
    }
    return ({"params": p_shapes, "opt": o_shapes},
            {"params": p_specs, "opt": o_specs})


def _lm_model_flops(cfg: LMConfig, kind: str, tokens: int) -> float:
    n_active = cfg.active_param_count()
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_active * tokens


def make_lm_train_step(cfg: LMConfig, opt_cfg: AdamWConfig,
                       microbatches: int):
    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(lm_loss)(
                params, cfg, batch["tokens"], batch["labels"])
        else:
            B = batch["tokens"].shape[0]
            mb = B // microbatches
            toks = batch["tokens"].reshape(microbatches, mb, -1)
            labs = batch["labels"].reshape(microbatches, mb, -1)

            def mb_body(carry, tl):
                g_acc, l_acc = carry
                loss_i, grads_i = jax.value_and_grad(lm_loss)(
                    params, cfg, tl[0], tl[1])
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), g_acc, grads_i)
                return (g_acc, l_acc + loss_i), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
            (g_acc, l_sum), _ = jax.lax.scan(
                mb_body, (g0, jnp.float32(0.0)), (toks, labs))
            grads = jax.tree.map(lambda g: g / microbatches, g_acc)
            loss = l_sum / microbatches
            # pin the optimizer phase AFTER the microbatch loop: without
            # this XLA hoists the loop-invariant f32 upcasts of params and
            # moments above the scan, threading f32 weight copies through
            # the carry (+9 GB/device at grok scale — EXPERIMENTS §Perf)
            grads, params, opt = jax.lax.optimization_barrier(
                (grads, params, opt))
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_opt = adamw_update(params, grads, opt, opt_cfg)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, "grad_norm": gnorm})

    return train_step


def _build_lm_cell(arch, shape, mesh) -> Cell:
    cfg: LMConfig = arch.config
    dims = shape.dims
    dp = _dp(mesh)
    B, S = dims["global_batch"], dims["seq_len"]
    policy = sh.LM_POLICY[cfg.name]
    big = cfg.name in ("grok-1-314b", "moonshot-v1-16b-a3b")
    opt_cfg = AdamWConfig(moment_dtype="bfloat16" if big else "float32")

    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    # Megatron-style vocab padding so embed/lm_head always shard evenly
    cfg = dataclasses.replace(
        cfg, vocab=_pad_up(cfg.vocab, mesh.shape["model"]))
    if cfg.n_experts:
        from repro.models import moe_sharded
        moe_sharded.MESH = mesh
        cfg = dataclasses.replace(
            cfg, moe_shard_axes=tuple(dp),
            moe_partition="ep" if policy == "moe_ep" else "tpe",
            # train: explicit all-to-all MoE pipeline + seq-parallel
            # activations (remat stacks otherwise pick up whatever
            # sharding GSPMD propagates)
            moe_impl="shard_map" if shape.kind == "train" else "dense",
            act_batch_axes=tuple(dp) if shape.kind == "train" else (),
            act_seq_axis="model" if shape.kind == "train" else "")
    else:
        # dense archs: sequence-parallel activation constraints
        if shape.kind in ("train", "prefill"):
            cfg = dataclasses.replace(
                cfg, act_batch_axes=tuple(dp), act_seq_axis="model")

    if shape.kind == "train":
        mbs = sh.LM_TRAIN_MICROBATCHES[cfg.name]
        if mbs == "auto":
            mbs = max(B // dp_size, 1)
        state_shapes, state_specs = _lm_state_specs(cfg, mesh, opt_cfg)
        batch_shapes = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
        # dense archs: sequence parallelism (activations sharded over
        # "model" on the seq axis — keeps attention scores and remat
        # carries per-device-small); MoE archs keep seq unsharded and
        # bound buffers via microbatching + capacity sharding instead.
        seq_axis = None if cfg.n_experts else "model"
        batch_specs = {"tokens": P(dp, seq_axis),
                       "labels": P(dp, seq_axis)}
        step = make_lm_train_step(cfg, opt_cfg, mbs)
        metrics_specs = {"loss": P(), "grad_norm": P()}
        return Cell(
            arch.arch_id, shape.name, "train", step,
            (state_shapes, batch_shapes),
            _named(mesh, (state_specs, batch_specs)),
            _named(mesh, (state_specs, metrics_specs)),
            _lm_model_flops(cfg, "train", B * S),
            note=f"policy={policy} microbatches={mbs}",
            attention_ideal_bytes=_lm_attention_ideal_bytes(
                cfg, "train", B, S, S))

    p_shapes = jax.eval_shape(partial(init_lm, cfg=cfg), jax.random.PRNGKey(0))
    p_specs = sh.lm_param_specs(p_shapes, policy, mesh)

    if shape.kind == "prefill":
        chunk = sh.LM_PREFILL_CHUNK.get(cfg.name)
        if chunk:
            def step(params, tokens):
                return prefill_chunked(params, cfg, tokens, chunk=chunk)
            tok_spec = P(dp, None)     # chunked: seq sliced dynamically
        else:
            def step(params, tokens):
                return prefill(params, cfg, tokens)
            tok_spec = P(dp, "model")  # dense: sequence parallelism
        cache_spec = sh.kv_cache_spec(cfg.n_kv_heads, mesh, batch=B)
        out_specs = (P(dp, None),
                     {"k": cache_spec, "v": cache_spec, "len": P()})
        return Cell(
            arch.arch_id, shape.name, "prefill", step,
            (p_shapes, _sds((B, S), jnp.int32)),
            _named(mesh, (p_specs, tok_spec)),
            _named(mesh, out_specs),
            _lm_model_flops(cfg, "prefill", B * S),
            note=f"policy={policy}"
                 + (f" chunked_prefill={chunk}" if chunk else " seq-parallel"),
            attention_ideal_bytes=_lm_attention_ideal_bytes(
                cfg, "prefill", B, S, S))

    # decode: cache length = window for SWA archs (ring buffer), else context
    cache_len = cfg.window if cfg.window > 0 else S
    cache_spec = sh.kv_cache_spec(cfg.n_kv_heads, mesh, batch=B)
    cache_shapes = {
        "k": _sds((cfg.n_layers, B, cfg.n_kv_heads, cache_len,
                   cfg.head_dim), jnp.bfloat16),
        "v": _sds((cfg.n_layers, B, cfg.n_kv_heads, cache_len,
                   cfg.head_dim), jnp.bfloat16),
        "len": _sds((), jnp.int32),
    }
    cache_specs = {"k": cache_spec, "v": cache_spec, "len": P()}
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tok_spec = P(dp if B % dp_size == 0 and B >= dp_size else None, None)

    def step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens)

    return Cell(
        arch.arch_id, shape.name, "decode", step,
        (p_shapes, cache_shapes, _sds((B, 1), jnp.int32)),
        _named(mesh, (p_specs, cache_specs, tok_spec)),
        _named(mesh, (tok_spec, cache_specs)),
        _lm_model_flops(cfg, "decode", B)
        + 2.0 * B * cfg.n_layers * 2 * cfg.n_kv_heads * cache_len
        * cfg.head_dim,                                 # cache attention
        note=f"policy={policy} cache_len={cache_len}",
        attention_ideal_bytes=_lm_attention_ideal_bytes(
            cfg, "decode", B, 1, cache_len))


# ========================================================== GNN family ====

# edge chunk length for the chunked-equiformer path (global)
_EQUI_EDGE_CHUNK = 524_288


def _gnn_edge_spec(mesh):
    """Edges sharded over every mesh axis (flat edge parallelism)."""
    return P(tuple(mesh.axis_names))


def _gnn_cell_config(arch, shape, mesh):
    """Specialize the arch config to the cell's feature width + mesh."""
    dims = shape.dims
    d_feat = dims.get("d_feat", 227)
    dp = tuple(dp_axes(mesh))
    all_axes = tuple(mesh.axis_names)
    big = dims.get("n_edges", 0) > 1_000_000
    if arch.arch_id == "graphcast":
        return dataclasses.replace(
            arch.config, n_vars=d_feat,
            dtype="bfloat16" if big else "float32",
            remat_group=4 if big else 1,
            node_axes=dp, edge_axes=all_axes)
    if arch.arch_id == "equiformer-v2":
        return dataclasses.replace(
            arch.config, d_feat=d_feat,
            dtype="bfloat16" if big else "float32",
            node_axes=dp, channel_axis="model" if big else "")
    if arch.arch_id == "egnn":
        return dataclasses.replace(arch.config, d_feat=d_feat)
    if arch.arch_id == "graphsage-reddit":
        return dataclasses.replace(
            arch.config, d_feat=d_feat,
            n_classes=dims.get("n_classes", arch.config.n_classes))
    raise KeyError(arch.arch_id)


def _pad_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _gnn_graph_dims(shape, mesh):
    """(n_nodes, n_edges) of the per-step graph, padded to mesh multiples
    (jit in_shardings require divisible dims; pad nodes/edges carry the
    sentinel id and drop out of every segment reduction)."""
    dims = shape.dims
    if shape.name == "minibatch_lg":
        n, e = minibatch_subgraph_dims(dims["batch_nodes"], dims["fanout"])
    elif shape.name == "molecule":
        n, e = dims["n_nodes"] * dims["batch"], dims["n_edges"] * dims["batch"]
    else:
        n, e = dims["n_nodes"], dims["n_edges"]
    dp_size = 1
    for a in dp_axes(mesh):
        dp_size *= mesh.shape[a]
    total = dp_size * mesh.shape["model"]
    return _pad_up(n, dp_size), _pad_up(e, total)


def _gnn_loss_fn(arch_id, cfg):
    if arch_id == "graphcast":
        return m_graphcast.loss_edges
    if arch_id == "equiformer-v2":
        return m_equiformer.loss_edges
    if arch_id == "egnn":
        return m_egnn.loss_edges
    if arch_id == "graphsage-reddit":
        return m_sage.loss_edges
    raise KeyError(arch_id)


def _gnn_model_flops(arch_id, cfg, n_nodes, n_edges):
    """Analytic MAC*2 counts of the dominant ops (forward), x3 for train
    (fwd + bwd ~ 2x)."""
    if arch_id == "graphcast":
        d = cfg.d_hidden
        per_layer = n_edges * (3 * d * d + d * d) * 2 \
            + n_nodes * (2 * d * d + d * d) * 2
        f = cfg.n_layers * per_layer
    elif arch_id == "equiformer-v2":
        S = (cfg.l_max + 1) ** 2
        C = cfg.d_hidden
        n_l = cfg.l_max + 1
        so2 = sum(2 * ((cfg.l_max + 1 - m) * C) ** 2 *
                  (1 if m == 0 else 4) for m in range(cfg.m_max + 1))
        rot = 2 * sum((2 * l + 1) ** 2 * C for l in range(n_l)) * 2
        mix = 2 * S * C * C * 3
        f = cfg.n_layers * n_edges * (so2 + rot + mix)
    elif arch_id == "egnn":
        d = cfg.d_hidden
        f = cfg.n_layers * n_edges * (2 * (2 * d + 1) * d + 2 * d * d) * 2
    elif arch_id == "graphsage-reddit":
        d = cfg.d_hidden
        f = cfg.n_layers * n_nodes * (2 * cfg.d_feat * d) * 2 \
            + n_edges * cfg.d_feat * 2
    else:
        raise KeyError(arch_id)
    return 3.0 * f     # train: fwd + ~2x bwd


def make_gnn_train_step(arch_id, cfg, loss_fn, opt_cfg, extra):
    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        loss, grads = jax.value_and_grad(loss_fn)(
            params, cfg, *batch, **extra)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        new_params, new_opt = adamw_update(params, grads, opt, opt_cfg)
        return ({"params": new_params, "opt": new_opt},
                {"loss": loss, "grad_norm": gnorm})
    return train_step


def _build_gnn_cell(arch, shape, mesh) -> Cell:
    dims = shape.dims
    dp = _dp(mesh)
    opt_cfg = AdamWConfig()
    cfg = _gnn_cell_config(arch, shape, mesh)
    n_nodes, n_edges = _gnn_graph_dims(shape, mesh)
    edge_spec = _gnn_edge_spec(mesh)

    # graphsage minibatch keeps its native sampled-block form
    if arch.arch_id == "graphsage-reddit" and shape.name == "minibatch_lg":
        B = dims["batch_nodes"]
        f1, f2 = dims["fanout"]
        F = dims["d_feat"]
        p_shapes = jax.eval_shape(
            partial(m_sage.init_sage, cfg=cfg), jax.random.PRNGKey(0))
        o_shapes = jax.eval_shape(
            partial(adamw_init, cfg=opt_cfg), p_shapes)
        p_specs = sh.gnn_param_specs(p_shapes, mesh)
        state_shapes = {"params": p_shapes, "opt": o_shapes}
        state_specs = {"params": p_specs,
                       "opt": {"mu": p_specs, "nu": p_specs, "step": P()}}

        def loss_fn(params, cfg, x_seed, x_n1, x_n2, labels):
            return m_sage.loss_blocks(params, cfg, x_seed, x_n1, x_n2, labels)

        step = make_gnn_train_step(
            arch.arch_id, cfg, loss_fn, opt_cfg, {})
        batch_shapes = (
            _sds((B, F), jnp.float32),
            _sds((B, f1, F), jnp.float32),
            _sds((B * f1, f2, F), jnp.float32),
            _sds((B,), jnp.int32),
        )
        batch_specs = (P(dp, None), P(dp, None, None),
                       P(dp, None, None), P(dp))
        flops = _gnn_model_flops(
            arch.arch_id, cfg, B * (1 + f1), B * f1 * (1 + f2))
        return Cell(
            arch.arch_id, shape.name, "train", step,
            (state_shapes, batch_shapes),
            _named(mesh, (state_specs, batch_specs)),
            _named(mesh, (state_specs, {"loss": P(), "grad_norm": P()})),
            flops, note="sampled-block mode (native GraphSAGE)")

    F = dims.get("d_feat", 227)
    p_shapes = jax.eval_shape(
        partial(arch.init_fn, cfg=cfg), jax.random.PRNGKey(0))
    o_shapes = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), p_shapes)
    p_specs = sh.gnn_param_specs(p_shapes, mesh)
    state_shapes = {"params": p_shapes, "opt": o_shapes}
    state_specs = {"params": p_specs,
                   "opt": {"mu": p_specs, "nu": p_specs, "step": P()}}

    loss_fn = _gnn_loss_fn(arch.arch_id, cfg)
    extra = {"n_nodes": n_nodes}

    # per-arch batch pytrees (edge lists; equiformer chunks the edge axis —
    # its per-edge (chunk, 49, C) irrep tensors are the memory hot spot)
    if arch.arch_id == "equiformer-v2" and n_edges > 100_000:
        chunk = min(_EQUI_EDGE_CHUNK,
                    _pad_up(-(-n_edges // 4),
                            len(mesh.devices.flatten())))
        n_chunks = -(-n_edges // chunk)
        e_shape = (n_chunks, chunk)
        # edges over every mesh axis (an edges-over-dp-only variant was
        # tried and REVERTED: 2x worse peak memory — EXPERIMENTS §Perf)
        e_spec = P(None, tuple(mesh.axis_names))
    else:
        e_shape = (n_edges,)
        e_spec = edge_spec

    if arch.arch_id == "graphcast":
        # production path: dst-partitioned shard_map processor (paper C2) —
        # edges arrive pre-partitioned by dst block (graphs/partition.py)
        def loss_fn(params, cfg_, nf, ef, es, edl, targets, n_nodes):
            return m_graphcast.loss_edges_dst_partitioned(
                params, cfg_, nf, ef, es, edl, targets, n_nodes,
                mesh=mesh)

        batch_shapes = (
            _sds((n_nodes, F), jnp.float32),
            _sds((n_edges, cfg.d_edge_in), jnp.float32),
            _sds((n_edges,), jnp.int32),
            _sds((n_edges,), jnp.int32),
            _sds((n_nodes, F), jnp.float32),
        )
        batch_specs = (P(dp, None), P(edge_spec[0], None),
                       edge_spec, edge_spec, P(dp, None))
    elif arch.arch_id == "equiformer-v2":
        batch_shapes = (
            _sds((n_nodes, F), jnp.float32),
            _sds((n_nodes, 3), jnp.float32),
            _sds(e_shape, jnp.int32),
            _sds(e_shape, jnp.int32),
            _sds((n_nodes, cfg.n_out), jnp.float32),
        )
        batch_specs = (P(dp, None), P(dp, None), e_spec, e_spec,
                       P(dp, None))
    elif arch.arch_id == "egnn":
        batch_shapes = (
            _sds((n_nodes, F), jnp.float32),
            _sds((n_nodes, 3), jnp.float32),
            _sds((n_edges,), jnp.int32),
            _sds((n_edges,), jnp.int32),
            _sds((n_nodes, 3), jnp.float32),
        )
        batch_specs = (P(dp, None), P(dp, None), edge_spec, edge_spec,
                       P(dp, None))
    elif arch.arch_id == "graphsage-reddit":
        batch_shapes = (
            _sds((n_nodes, F), jnp.float32),
            _sds((n_edges,), jnp.int32),
            _sds((n_edges,), jnp.int32),
            _sds((n_nodes,), jnp.int32),
        )
        batch_specs = (P(dp, None), edge_spec, edge_spec, P(dp))
    else:
        raise KeyError(arch.arch_id)

    step = make_gnn_train_step(arch.arch_id, cfg, loss_fn, opt_cfg, extra)
    flops = _gnn_model_flops(arch.arch_id, cfg, n_nodes, n_edges)
    return Cell(
        arch.arch_id, shape.name, "train", step,
        (state_shapes, batch_shapes),
        _named(mesh, (state_specs, batch_specs)),
        _named(mesh, (state_specs, {"loss": P(), "grad_norm": P()})),
        flops,
        note=f"edge-parallel over {mesh.axis_names}"
             + (" + edge-chunked scan" if len(e_shape) == 2 else ""))


# ======================================================== recsys family ====

def make_fm_sharded_logits(cfg, mesh):
    """FM logits with the paper-technique lookup: row-sharded table, local
    partial gathers, psum combine (EfficientIMM partial counters, DESIGN §4).
    """
    dp = _dp(mesh)
    model_size = mesh.shape["model"]
    shard_rows = -(-cfg.total_rows // model_size)

    def local_fn(v, w, b, idx):
        rows = idx + cfg.field_offsets()[None, :]
        emb = sharded_embedding_lookup(
            v, rows, axis_name="model", shard_rows=shard_rows)
        wrow = sharded_embedding_lookup(
            w[:, None], rows, axis_name="model", shard_rows=shard_rows)[..., 0]
        s = emb.sum(axis=1)
        s2 = (emb * emb).sum(axis=1)
        pair = 0.5 * (s * s - s2).sum(axis=-1)
        return b + wrow.sum(axis=-1) + pair

    return shard_map(
        local_fn, mesh=mesh,
        in_specs=(P("model", None), P("model"), P(), P(dp, None)),
        out_specs=P(dp))


def _build_fm_cell(arch, shape, mesh) -> Cell:
    cfg: m_fm.FMConfig = arch.config
    dims = shape.dims
    dp = _dp(mesh)
    opt_cfg = AdamWConfig()
    p_shapes = jax.eval_shape(
        partial(m_fm.init_fm, cfg=cfg), jax.random.PRNGKey(0))
    p_specs = sh.fm_param_specs(p_shapes, mesh)
    logits_fn = make_fm_sharded_logits(cfg, mesh)

    if shape.kind == "train":
        B = dims["batch"]
        o_shapes = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), p_shapes)
        state_shapes = {"params": p_shapes, "opt": o_shapes}
        state_specs = {"params": p_specs,
                       "opt": {"mu": p_specs, "nu": p_specs, "step": P()}}

        def loss_fn(params, idx, labels):
            logits = logits_fn(
                params["v"], params["w"], params["b"], idx).astype(jnp.float32)
            return jnp.mean(
                jnp.maximum(logits, 0) - logits * labels
                + jnp.log1p(jnp.exp(-jnp.abs(logits))))

        def step(state, batch):
            idx, labels = batch
            loss, grads = jax.value_and_grad(loss_fn)(
                state["params"], idx, labels)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            params, opt = adamw_update(
                state["params"], grads, state["opt"], opt_cfg)
            return ({"params": params, "opt": opt},
                    {"loss": loss, "grad_norm": gnorm})

        batch_shapes = (_sds((B, cfg.n_sparse), jnp.int32),
                        _sds((B,), jnp.float32))
        batch_specs = (P(dp, None), P(dp))
        flops = 3.0 * B * cfg.n_sparse * cfg.embed_dim * 4
        return Cell(
            arch.arch_id, shape.name, "train", step,
            (state_shapes, batch_shapes),
            _named(mesh, (state_specs, batch_specs)),
            _named(mesh, (state_specs, {"loss": P(), "grad_norm": P()})),
            flops, note="sharded-lookup (paper-technique) path")

    if shape.name == "retrieval_cand":
        C = dims["n_candidates"]
        n_user_fields = 4
        model_size = mesh.shape["model"]
        shard_rows = -(-cfg.total_rows // model_size)

        def local_score(v, w, b, user_idx, cand):
            user_rows = user_idx + cfg.field_offsets()[:n_user_fields]
            vu = sharded_embedding_lookup(
                v, user_rows, axis_name="model", shard_rows=shard_rows)
            wu = sharded_embedding_lookup(
                w[:, None], user_rows, axis_name="model",
                shard_rows=shard_rows)[..., 0]
            su = vu.sum(axis=0)
            s2 = (vu * vu).sum(axis=0)
            const = b + wu.sum() + 0.5 * ((su * su) - s2).sum()
            vc = sharded_embedding_lookup(
                v, cand, axis_name="model", shard_rows=shard_rows)
            wc = sharded_embedding_lookup(
                w[:, None], cand, axis_name="model",
                shard_rows=shard_rows)[..., 0]
            return const + wc + vc @ su

        step = shard_map(
            local_score, mesh=mesh,
            in_specs=(P("model", None), P("model"), P(), P(), P(dp)),
            out_specs=P(dp))
        specs = (p_shapes["v"], p_shapes["w"], p_shapes["b"],
                 _sds((n_user_fields,), jnp.int32), _sds((C,), jnp.int32))
        in_specs = (p_specs["v"], p_specs["w"], p_specs["b"], P(), P(dp))
        flops = C * cfg.embed_dim * 2
        return Cell(
            arch.arch_id, shape.name, "serve", step, specs,
            _named(mesh, in_specs), _named(mesh, P(dp)), flops,
            note="one query vs 1M candidates, single batched mat-vec")

    B = dims["batch"]

    def step(v, w, b, idx):
        return logits_fn(v, w, b, idx)

    specs = (p_shapes["v"], p_shapes["w"], p_shapes["b"],
             _sds((B, cfg.n_sparse), jnp.int32))
    in_specs = (p_specs["v"], p_specs["w"], p_specs["b"], P(dp, None))
    flops = B * cfg.n_sparse * cfg.embed_dim * 4
    return Cell(
        arch.arch_id, shape.name, "serve", step, specs,
        _named(mesh, in_specs), _named(mesh, P(dp)), flops,
        note="sharded-lookup serve path")


# ============================================================= IMM cells ====

def build_imm_cell(cell_name: str, spec: dict, mesh) -> Cell:
    """Production-scale IMM cells (DESIGN §2): sharded selection + sampling."""
    from repro.core.selection import select_dense_sharded
    from repro.core.sampler import sample_ic_sparse

    dp = _dp(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if cell_name.startswith("imm_select"):
        theta, k = spec["theta"], spec["k"]
        # pad the vertex axis to the counter-shard multiple (pad vertices
        # never appear in any RRRset -> counter 0, never selected)
        n = _pad_up(spec["n"], mesh.shape["model"] * dp_size)

        def step(R, valid):
            return select_dense_sharded(
                mesh, R, valid, k, theta_axes=dp, vertex_axis="model")

        specs = (_sds((theta, n), jnp.uint8), _sds((theta,), jnp.bool_))
        in_specs = (P(dp, "model"), P(dp))
        out_specs = (P(), P(), P())
        flops = 2.0 * k * theta * n        # k rounds of masked mat-vec
        return Cell("imm", cell_name, "select", step, specs,
                    _named(mesh, in_specs), _named(mesh, out_specs), flops,
                    note=spec.get("note", ""))

    # sampling cell: fixed-step sparse IC frontier expansion
    n = _pad_up(spec["n"], mesh.shape["model"] * dp_size)
    m = _pad_up(spec["m"], mesh.shape["model"] * dp_size)
    batch = spec["batch"]
    steps = spec["bfs_steps"]

    def step(key, edge_src, edge_dst, edge_prob):
        return sample_ic_sparse(
            key, edge_src, edge_dst, edge_prob, n_nodes=n, batch=batch,
            max_steps=steps)

    specs = (_sds((2,), jnp.uint32), _sds((m,), jnp.int32),
             _sds((m,), jnp.int32), _sds((m,), jnp.float32))
    in_specs = (P(), P("model"), P("model"), P("model"))
    out_specs = (P(dp, None), P(None), P(dp))
    flops = 2.0 * batch * m * steps / 8    # expected frontier work
    return Cell("imm", cell_name, "sample", step, specs,
                _named(mesh, in_specs), _named(mesh, out_specs), flops,
                note=spec.get("note", ""))


# ============================================================ dispatcher ====

def build_cell(arch_id: str, shape_name: str, mesh) -> Cell:
    arch = get_arch(arch_id)
    shape = arch.shape(shape_name)
    if shape.skip:
        raise ValueError(
            f"cell ({arch_id}, {shape_name}) is skipped: {shape.skip_reason}")
    if arch.family == "lm":
        return _build_lm_cell(arch, shape, mesh)
    if arch.family == "gnn":
        return _build_gnn_cell(arch, shape, mesh)
    if arch.family == "recsys":
        return _build_fm_cell(arch, shape, mesh)
    raise KeyError(arch.family)
