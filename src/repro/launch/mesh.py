"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before the first jax device query, while smoke
tests/benches must keep seeing 1 CPU device.

Mesh shapes (TPU v5e):
  single-pod : (16, 16)    axes ("data", "model")       — 256 chips
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") — 512 chips

IMM shards the RRRset (theta) axis over ("pod","data") and the vertex axis
over "model" (DESIGN §2); LMs put batch on ("pod","data") and TP/experts on
"model".
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests/benchmarks on CPU)."""
    n = len(jax.devices())
    if shape is None:
        shape = (n, 1)
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh ('pod' included)."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"


TPU_V5E = {
    "name": "TPU v5e",
    "peak_flops_bf16": 197e12,      # per chip
    "hbm_bytes_per_s": 819e9,       # per chip
    "ici_bytes_per_s": 50e9,        # per link (~4 links/chip usable)
    "hbm_bytes": 16 * 2**30,
}
