"""Trip-count-aware analysis of optimized HLO.

``compiled.cost_analysis()`` counts every while-loop body ONCE — for
scan-over-layers programs that under-counts flops/bytes by ~n_layers and,
worse, misses per-layer collectives entirely.  This analyzer re-walks the
scheduled HLO text multiplying loop bodies by their ``known_trip_count``:

  * flops          — dot ops (2 x out_elems x contracted_elems), including
                     dots inside fusion computations
  * bytes          — operand + output bytes at fusion/op boundaries (the
                     HBM-traffic model for a TPU-like memory hierarchy:
                     fusions stream internally, boundaries hit HBM)
  * collectives    — per-kind counts + wire-byte model (ring conventions:
                     all-reduce 2x, others 1x), trip-multiplied

Loops with data-dependent conditions have no known_trip_count; they count
once and are reported in ``unknown_trip_loops`` (the dry-run cells are built
with fixed trip counts so this stays 0).
"""
from __future__ import annotations

import dataclasses
import json
import re


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_ARRAY_RE = re.compile(r"(\w+)\[([0-9,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# ops whose boundary IO we do NOT count as memory traffic (views/control)
_VIEW_OPS = {
    "get-tuple-element", "tuple", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "domain", "opt-barrier",
}
# ops where we count output bytes only (no real operand reads)
_OUT_ONLY_OPS = {"broadcast", "iota", "rng", "rng-bit-generator"}


def _type_dims(type_str: str):
    """All arrays in a type string -> [(dtype, [dims])]."""
    out = []
    for m in _ARRAY_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((dt, dims))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _type_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    out_type: str
    operands: list
    line: str

    @property
    def op_name(self) -> str:
        m = re.search(r'op_name="([^"]*)"', self.line)
        return m.group(1) if m else ""


def _parse_op_line(line: str):
    s = line.strip()
    m = re.match(r"(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$", s)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    # type: parenthesized tuple or single token
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        out_type = rest[: i + 1]
        rest2 = rest[i + 1:].strip()
    else:
        sp = rest.find(" ")
        out_type = rest[:sp]
        rest2 = rest[sp + 1:].strip()
    m2 = re.match(r"([a-z0-9\-]+)\(", rest2)
    if not m2:
        return None
    opcode = m2.group(1)
    # operand names: %refs inside the top-level call parens
    depth = 0
    start = rest2.find("(")
    operands = []
    for i in range(start, len(rest2)):
        ch = rest2[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
    call_str = rest2[start: i + 1]
    operands = re.findall(r"%([\w.\-]+)", call_str)
    return Op(name, opcode, out_type, operands, s)


def parse_module(hlo_text: str):
    """-> (computations: dict name -> [Op], types: dict name -> type str,
    entry_name)."""
    computations = {}
    types = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        if raw.startswith("ENTRY ") or (raw.startswith("%")
                                        and raw.rstrip().endswith("{")):
            m = re.match(r"(ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->", raw)
            if m:
                cur = m.group(2)
                computations[cur] = []
                if m.group(1):
                    entry = cur
                # parameter types from the signature
                sig = m.group(3)
                for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^()]*\))|"
                                      r"(?:\w+\[[0-9,]*\](?:\{[^}]*\})?))",
                                      sig):
                    types[pm.group(1)] = pm.group(2)
            continue
        if raw.startswith("}"):
            cur = None
            continue
        if cur is None or not raw.strip().startswith(("%", "ROOT")):
            continue
        op = _parse_op_line(raw)
        if op is None:
            continue
        computations[cur].append(op)
        types[op.name] = op.out_type
    return computations, types, entry


def _dot_flops(op: Op, types: dict) -> float:
    out_elems = 1
    arrs = _type_dims(op.out_type)
    if arrs:
        for d in arrs[0][1]:
            out_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    lhs_type = types.get(op.operands[0], "") if op.operands else ""
    lhs_arrs = _type_dims(lhs_type)
    contracted = 1
    if m and m.group(1) and lhs_arrs:
        dims = lhs_arrs[0][1]
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(dims):
                contracted *= dims[i]
    return 2.0 * out_elems * contracted


def _called_comps(op: Op, line: str):
    """Computation names referenced via calls=/to_apply=/body=/condition=
    or branch_computations."""
    out = {}
    for key in ("calls", "to_apply", "body", "condition"):
        m = re.search(key + r"=%([\w.\-]+)", line)
        if m:
            out[key] = m.group(1)
    m = re.search(r"branch_computations=\{([^}]*)\}", line)
    if m:
        out["branches"] = re.findall(r"%([\w.\-]+)", m.group(1))
    return out


def _trip_count(line: str):
    m = re.search(r'known_trip_count[^0-9]*"?n"?[^0-9]*([0-9]+)', line)
    return int(m.group(1)) if m else None


@dataclasses.dataclass
class HLOCounts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    unknown_trip_loops: int = 0
    # attribution (metadata op_name substring -> accumulated cost)
    bytes_by_tag: dict = dataclasses.field(default_factory=dict)
    wire_by_tag: dict = dataclasses.field(default_factory=dict)
    top_collectives: list = dataclasses.field(default_factory=list)
    tag_patterns: tuple = ()

    def _tag(self, op_name: str) -> str:
        for p in self.tag_patterns:
            if p in op_name:
                return p
        return "other"

    def add_bytes(self, op: Op, nbytes: float):
        self.bytes += nbytes
        t = self._tag(op.op_name)
        self.bytes_by_tag[t] = self.bytes_by_tag.get(t, 0.0) + nbytes

    def add_wire(self, op: Op, kind: str, wire: float, total: float):
        self.collective_wire_bytes += wire
        t = self._tag(op.op_name)
        self.wire_by_tag[t] = self.wire_by_tag.get(t, 0.0) + wire
        self.top_collectives.append(
            (wire, kind, op.op_name[-120:] if op.op_name else op.name))
        if len(self.top_collectives) > 200:
            self.top_collectives.sort(reverse=True)
            del self.top_collectives[30:]

    def as_dict(self):
        d = dataclasses.asdict(self)
        d["top_collectives"] = sorted(
            self.top_collectives, reverse=True)[:15]
        d.pop("tag_patterns", None)
        return d


def _op_io_bytes(op: Op, types: dict) -> float:
    total = _type_bytes(op.out_type)
    if op.opcode in _OUT_ONLY_OPS:
        return float(total)
    for o in op.operands:
        t = types.get(o)
        if t:
            total += _type_bytes(t)
    return float(total)


def _flops_only(comp_name, computations, types, mult, acc: HLOCounts,
                default_trip):
    for op in computations.get(comp_name, ()):  # dots inside fusions etc.
        if op.opcode == "dot":
            acc.flops += mult * _dot_flops(op, types)
        refs = _called_comps(op, op.line)
        for key, val in refs.items():
            if key == "branches":
                for b in val:
                    _flops_only(b, computations, types, mult, acc,
                                default_trip)
            else:
                sub_mult = mult
                if op.opcode == "while" and key == "body":
                    tc = _trip_count(op.line)
                    sub_mult = mult * (tc if tc else default_trip)
                _flops_only(val, computations, types, sub_mult, acc,
                            default_trip)


def _walk(comp_name, computations, types, mult, acc: HLOCounts,
          default_trip, seen_fusion_flops):
    for op in computations.get(comp_name, ()):
        base = op.opcode
        if base.endswith("-start"):
            base = base[:-6]
        if base in _COLLECTIVES:
            nbytes = _type_bytes(op.out_type)
            acc.collective_counts[base] += int(mult)
            acc.collective_bytes[base] += mult * nbytes
            acc.add_wire(op, base,
                         mult * nbytes * (2.0 if base == "all-reduce"
                                          else 1.0),
                         mult * nbytes)
            acc.add_bytes(op, mult * _op_io_bytes(op, types))
            continue
        if op.opcode.endswith("-done") or op.opcode.endswith("-update"):
            continue
        if op.opcode == "while":
            tc = _trip_count(op.line)
            if tc is None:
                acc.unknown_trip_loops += 1
                tc = default_trip
            refs = _called_comps(op, op.line)
            if "body" in refs:
                _walk(refs["body"], computations, types, mult * tc, acc,
                      default_trip, seen_fusion_flops)
            if "condition" in refs:
                _walk(refs["condition"], computations, types, mult * tc,
                      acc, default_trip, seen_fusion_flops)
            continue
        if op.opcode in ("call", "async-start"):
            refs = _called_comps(op, op.line)
            for key in ("to_apply", "calls"):
                if key in refs:
                    _walk(refs[key], computations, types, mult, acc,
                          default_trip, seen_fusion_flops)
            continue
        if op.opcode == "conditional":
            refs = _called_comps(op, op.line)
            for b in refs.get("branches", []):
                _walk(b, computations, types, mult, acc, default_trip,
                      seen_fusion_flops)
            acc.add_bytes(op, mult * _op_io_bytes(op, types))
            continue
        if op.opcode == "fusion":
            acc.add_bytes(op, mult * _op_io_bytes(op, types))
            refs = _called_comps(op, op.line)
            if "calls" in refs:
                _flops_only(refs["calls"], computations, types, mult, acc,
                            default_trip)
            continue
        if op.opcode == "dot":
            acc.flops += mult * _dot_flops(op, types)
            acc.add_bytes(op, mult * _op_io_bytes(op, types))
            continue
        if op.opcode in _VIEW_OPS:
            continue
        acc.add_bytes(op, mult * _op_io_bytes(op, types))


DEFAULT_TAGS = (
    "blockwise_attention", "attention_ref", "flash", "apply_rope",
    "_moe_ffn", "_dense_ffn", "lm_head", "embed", "logsumexp",
    "adamw", "clip", "segment_sum", "scatter", "take", "top_k", "cumsum",
)

ATTENTION_TAGS = ("blockwise_attention", "attention_ref", "flash")


def analyze_module(hlo_text: str, default_trip: int = 1,
                   tag_patterns: tuple = DEFAULT_TAGS) -> HLOCounts:
    computations, types, entry = parse_module(hlo_text)
    acc = HLOCounts(tag_patterns=tuple(tag_patterns))
    if entry is None:
        return acc
    _walk(entry, computations, types, 1.0, acc, default_trip, set())
    return acc
