"""Serving driver: batched prefill + decode with a KV cache.

CPU-runnable smoke serving (examples/serve_lm.py); the production decode
cells in launch/steps.py lower the same decode_step onto the 256/512-chip
meshes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.transformer import (
    LMConfig, init_lm, prefill, decode_step, init_kv_cache,
)


class LMServer:
    """Minimal batched server: submit token prompts, get continuations."""

    def __init__(self, cfg: LMConfig, params=None, *, max_len: int = 256,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params if params is not None else init_lm(
            jax.random.PRNGKey(seed), cfg)
        self.max_len = max_len
        self._prefill = jax.jit(lambda p, t: prefill(p, cfg, t))
        self._decode = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))

    def generate(self, prompts, n_tokens: int = 16):
        """prompts: (B, S) int32 -> (B, n_tokens) greedy continuation."""
        prompts = jnp.asarray(prompts)
        B, S = prompts.shape
        cache_len = self.cfg.window if self.cfg.window > 0 else self.max_len
        logits, pcache = self._prefill(self.params, prompts)
        # seed the decode cache by replaying the prompt (simple + correct
        # ring-buffer handling for SWA archs)
        cache = init_kv_cache(self.cfg, B, cache_len)
        for i in range(S):
            _, cache = self._decode(self.params, cache, prompts[:, i:i + 1])
        out = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(prompts.dtype)
        for _ in range(n_tokens):
            out.append(tok)
            tok, cache = self._decode(self.params, cache, tok)
        return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.smoke_config
    server = LMServer(cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = server.generate(prompts, args.gen)
    dt = time.time() - t0
    print(f"[serve] {args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(out[0])


if __name__ == "__main__":
    main()
