"""Serving drivers.

Two workloads share this module:
  * ``LMServer`` — batched prefill + decode with a KV cache (CPU-runnable
    smoke serving; the production decode cells in launch/steps.py lower the
    same decode_step onto the 256/512-chip meshes).
  * ``IMServer`` — influence-query serving over one shared
    `InfluenceEngine`: clients submit sigma(S) queries for arbitrary seed
    sets, the server coalesces everything pending into a single fused
    membership kernel over the resident RRR store (no re-sampling per
    query), and seed-selection queries hit the engine's memoized
    ``select``.  This is the multi-query regime the store redesign exists
    for: sampling once amortizes across an entire campaign of queries.
    ``--mesh N`` serves the same workload from a mesh-sharded RRR store
    (paper C1): the resident arena is partitioned across devices, so the
    served theta scales with device count — answers are seed-for-seed
    identical to the single-device store.

    PYTHONPATH=src python -m repro.launch.serve --workload im \
        --graph com-Amazon --queries 64 --mesh auto
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.transformer import (
    LMConfig, init_lm, prefill, decode_step, init_kv_cache,
)


class LMServer:
    """Minimal batched server: submit token prompts, get continuations."""

    def __init__(self, cfg: LMConfig, params=None, *, max_len: int = 256,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params if params is not None else init_lm(
            jax.random.PRNGKey(seed), cfg)
        self.max_len = max_len
        self._prefill = jax.jit(lambda p, t: prefill(p, cfg, t))
        self._decode = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))

    def generate(self, prompts, n_tokens: int = 16):
        """prompts: (B, S) int32 -> (B, n_tokens) greedy continuation."""
        prompts = jnp.asarray(prompts)
        B, S = prompts.shape
        cache_len = self.cfg.window if self.cfg.window > 0 else self.max_len
        logits, pcache = self._prefill(self.params, prompts)
        # seed the decode cache by replaying the prompt (simple + correct
        # ring-buffer handling for SWA archs)
        cache = init_kv_cache(self.cfg, B, cache_len)
        for i in range(S):
            _, cache = self._decode(self.params, cache, prompts[:, i:i + 1])
        out = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(prompts.dtype)
        for _ in range(n_tokens):
            out.append(tok)
            tok, cache = self._decode(self.params, cache, tok)
        return jnp.concatenate(out, axis=1)


class IMServer:
    """Batches concurrent influence queries against a shared engine.

    ``submit`` enqueues a sigma(S) query and returns a ticket; ``flush``
    answers every pending ticket with one fused store pass (seed sets are
    padded to shared power-of-two shapes inside the engine, so mixed query
    sizes don't fragment compilation).  ``select`` serves top-k queries
    from the engine's memoized selection — repeated k values are free.
    """

    def __init__(self, engine, *, max_batch: int = 256):
        self.engine = engine
        self.max_batch = max_batch
        self._pending = []          # list[(ticket, seed_set)]
        self._next_ticket = 0
        self.queries_served = 0

    @property
    def pending(self) -> int:
        return len(self._pending)

    def submit(self, seed_set) -> int:
        """Enqueue one sigma(S) query; returns its ticket id."""
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, np.asarray(seed_set, np.int32)))
        return ticket

    def flush(self) -> dict:
        """Answer all pending queries; returns {ticket: influence}."""
        results = {}
        while self._pending:
            chunk = self._pending[:self.max_batch]
            self._pending = self._pending[self.max_batch:]
            vals = self.engine.influences([s for _, s in chunk])
            results.update(
                {t: float(v) for (t, _), v in zip(chunk, vals)})
        self.queries_served += len(results)
        return results

    def influence(self, seed_set) -> float:
        """Convenience single-query path (submit + flush)."""
        ticket = self.submit(seed_set)
        return self.flush()[ticket]

    def select(self, k: int):
        """Top-k seed-selection query (memoized by the engine)."""
        return self.engine.select(k)


def _main_lm(args):
    arch = get_arch(args.arch)
    cfg = arch.smoke_config
    server = LMServer(cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = server.generate(prompts, args.gen)
    dt = time.time() - t0
    print(f"[serve] {args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(out[0])


def _main_im(args):
    from repro.configs.imm_snap import IMM_EXPERIMENTS, make_theta_mesh
    from repro.core.engine import InfluenceEngine, IMMConfig
    from repro.graphs.datasets import scaled_snap

    exp = IMM_EXPERIMENTS[args.graph]
    scale = exp.bench_scale if args.scale is None else args.scale
    g = scaled_snap(args.graph, scale, seed=0)
    mesh = make_theta_mesh(args.mesh)
    engine = InfluenceEngine(
        g, IMMConfig(k=args.k, model=args.model, max_theta=args.max_theta),
        mesh=mesh)
    t0 = time.time()
    engine.extend(args.max_theta)
    t_sample = time.time() - t0
    server = IMServer(engine)
    if mesh is not None:
        print(f"[serve-im] sharded store: theta axis over "
              f"{engine.store.D} device shard(s), "
              f"cap_local={engine.store.cap_local}")

    # a realistic mixed workload: top-k selections of several sizes plus a
    # burst of random candidate-set influence queries, all from one store
    t0 = time.time()
    sels = {kk: server.select(kk) for kk in (5, args.k // 2 or 1, args.k)}
    rng = np.random.default_rng(0)
    tickets = [server.submit(rng.choice(g.n, size=rng.integers(1, 9),
                                        replace=False))
               for _ in range(args.queries)]
    answers = server.flush()
    dt = time.time() - t0
    n_q = len(sels) + len(tickets)
    print(f"[serve-im] {args.graph} n={g.n:,} theta={engine.theta}: "
          f"sampled in {t_sample:.2f}s, answered {n_q} queries in {dt:.2f}s "
          f"({n_q / max(dt, 1e-9):.1f} q/s)")
    for kk, s in sorted(sels.items()):
        print(f"  select(k={kk}): influence={s.influence:.1f} "
              f"seeds={[int(v) for v in s.seeds[:5]]}...")
    vals = [answers[t] for t in tickets[:4]]
    print(f"  sample influence answers: {[round(v, 1) for v in vals]}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=("lm", "im"))
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--graph", default="com-Amazon")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--model", default="IC", choices=("IC", "LT"))
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--max-theta", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--mesh", default=None,
                    help="theta shards for the IM store: int, 'auto', or "
                         "omit for single-device")
    args = ap.parse_args(argv)
    if args.workload == "im":
        _main_im(args)
    else:
        _main_lm(args)


if __name__ == "__main__":
    main()
