"""Serving drivers.

Two workloads share this module:
  * ``LMServer`` — batched prefill + decode with a KV cache (CPU-runnable
    smoke serving; the production decode cells in launch/steps.py lower the
    same decode_step onto the 256/512-chip meshes).
  * ``IMServer`` — influence-query serving over one shared
    `InfluenceEngine`: clients submit sigma(S) queries for arbitrary seed
    sets, the server coalesces everything pending into a single fused
    membership kernel over the resident RRR store (no re-sampling per
    query), and seed-selection queries hit the engine's memoized
    ``select``.  This is the multi-query regime the store redesign exists
    for: sampling once amortizes across an entire campaign of queries.
    ``--mesh N`` serves the same workload from a mesh-sharded RRR store
    (paper C1): the resident arena is partitioned across devices, so the
    served theta scales with device count — answers are seed-for-seed
    identical to the single-device store.  ``--deltas N`` switches to the
    dynamic-graph regime: the server runs a `StreamEngine`, random edge
    deltas land between query bursts, and up to ``--refresh-budget`` rows
    of stale-RRR repair run between flushes while every flush stays
    epoch-consistent (see docs/streaming.md).  ``--async-refresh`` moves
    the repair onto a background worker thread that drains the backlog
    continuously between flushes instead of only inside them.
    ``--mesh RxC`` (e.g. ``2x4``) serves from a 2D theta x vertex store:
    per-device memory is ``theta/R x n/C``, so resident theta *and* graph
    size scale with the mesh.

    PYTHONPATH=src python -m repro.launch.serve --workload im \
        --graph com-Amazon --queries 64 --mesh auto --deltas 4

A third workload, ``--workload tier``, is a thin CLI over the
**multi-tenant serving tier** (`repro.serve.IMServe` — engine pools,
admission control + DRR fairness, the epoch-keyed sigma(S) cache,
replica read scaling, SLO-aware refresh scheduling; docs/serving.md):
it registers ``--tenants`` campaigns (static and streaming, one
relaxed-SLO tenant with ``--replicas``), generates a Zipf-skewed
arrival-process trace interleaved with GraphDeltas
(`repro.serve.trace`), and replays it with the refresh worker running:

    PYTHONPATH=src python -m repro.launch.serve --workload tier \
        --tenants 4 --qps 256 --duration 1.0 --mesh auto
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.configs import get_arch
from repro.models.transformer import (
    LMConfig, init_lm, prefill, decode_step, init_kv_cache,
)


class LMServer:
    """Minimal batched server: submit token prompts, get continuations."""

    def __init__(self, cfg: LMConfig, params=None, *, max_len: int = 256,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params if params is not None else init_lm(
            jax.random.PRNGKey(seed), cfg)
        self.max_len = max_len
        self._prefill = jax.jit(lambda p, t: prefill(p, cfg, t))
        self._decode = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))

    def generate(self, prompts, n_tokens: int = 16):
        """prompts: (B, S) int32 -> (B, n_tokens) greedy continuation."""
        prompts = jnp.asarray(prompts)
        B, S = prompts.shape
        cache_len = self.cfg.window if self.cfg.window > 0 else self.max_len
        logits, pcache = self._prefill(self.params, prompts)
        # seed the decode cache by replaying the prompt (simple + correct
        # ring-buffer handling for SWA archs)
        cache = init_kv_cache(self.cfg, B, cache_len)
        for i in range(S):
            _, cache = self._decode(self.params, cache, prompts[:, i:i + 1])
        out = []
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(prompts.dtype)
        for _ in range(n_tokens):
            out.append(tok)
            tok, cache = self._decode(self.params, cache, tok)
        return jnp.concatenate(out, axis=1)


class IMServer:
    """Batches concurrent influence queries against a shared engine.

    ``submit`` enqueues a sigma(S) query and returns a ticket; ``flush``
    answers every pending ticket with one fused store pass (seed sets are
    padded to shared power-of-two shapes inside the engine, so mixed query
    sizes don't fragment compilation).  ``select`` serves top-k queries
    from the engine's memoized selection — repeated k values are free.

    **Background-refresh mode** (dynamic graphs): construct with a
    `repro.stream.StreamEngine` and a ``refresh_budget``.  ``apply_delta``
    forwards graph mutations to the stream (stale RRR rows leave serving
    immediately), and every ``flush`` first answers *all* pending tickets
    against one consistent store state — the epoch recorded in
    ``served_epoch`` — and only then spends up to ``refresh_budget`` rows
    of repair between flushes (cooperative backgrounding: the refresh
    never interleaves with answering, so a flush can never mix rows from
    two epochs — no torn reads across ``apply_delta``).

    **Async-refresh mode** (``async_refresh=True``) upgrades the
    cooperative scheme to a real worker thread: the worker drains the
    staleness backlog in ``refresh_budget``-row slices *continuously*,
    not just once per flush — repair overlaps the server's host-side
    work (request intake, batch assembly, idle gaps between bursts)
    instead of waiting for it.  Engine access stays serialized by one
    lock: stores donate their arena buffers on every repair write, so a
    query racing a refresh would read a deleted buffer — the lock is the
    epoch-consistency guarantee (every flush answers against exactly one
    store state; tested in tests/test_stream.py).  ``close`` (or the
    context manager) stops the worker.
    """

    def __init__(self, engine, *, max_batch: int = 256,
                 refresh_budget: int | None = None,
                 async_refresh: bool = False):
        self.engine = engine
        self.max_batch = max_batch
        self.refresh_budget = refresh_budget
        if refresh_budget is not None and not hasattr(engine, "refresh"):
            raise ValueError(
                "refresh_budget needs a StreamEngine (got a static "
                "engine with nothing to refresh)")
        if refresh_budget is not None and refresh_budget < 1:
            raise ValueError(
                f"refresh_budget must be >= 1 row (got {refresh_budget})")
        if async_refresh and refresh_budget is None:
            raise ValueError(
                "async_refresh needs a refresh_budget (the worker "
                "repairs in budget-row slices)")
        self._pending = []          # list[(ticket, seed_set)]
        self._next_ticket = 0
        self.queries_served = 0
        self.served_epoch = getattr(engine, "epoch", None)
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._worker: threading.Thread | None = None
        self.refreshes_run = 0      # worker repair slices completed
        if async_refresh:
            self.start_refresh_worker()

    # ------------------------------------------------- async refresh ----

    def start_refresh_worker(self) -> None:
        """Start the background repair worker.  Idempotent: a second
        call while the worker is alive is a no-op, and a stopped server
        (``stop_refresh_worker``/``close``/``__exit__``) can be
        restarted by calling this again."""
        if self.refresh_budget is None:
            raise ValueError(
                "the refresh worker needs a refresh_budget (it repairs "
                "in budget-row slices)")
        if self._worker is not None and self._worker.is_alive():
            return
        self._stop.clear()
        self._worker = threading.Thread(
            target=self._refresh_loop, name="im-refresh", daemon=True)
        self._worker.start()

    def stop_refresh_worker(self) -> None:
        """Stop the worker and join it.  Safe to call any number of
        times, in any state — twice, after ``close``, after the context
        manager has already exited, or with no worker ever started —
        and safe from the worker thread itself (no self-join)."""
        self._stop.set()
        worker, self._worker = self._worker, None
        if worker is not None and worker is not threading.current_thread():
            worker.join()

    close = stop_refresh_worker

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.stop_refresh_worker()

    @property
    def async_refreshing(self) -> bool:
        return self._worker is not None and self._worker.is_alive()

    def _refresh_loop(self):
        while not self._stop.is_set():
            did = False
            with self._lock:
                if getattr(self.engine, "stale", 0) > 0:
                    self.engine.refresh(self.refresh_budget)
                    self.refreshes_run += 1
                    did = True
            if did:
                # Python locks are not fair: without an explicit yield
                # between slices the worker can win the lock re-acquire
                # race repeatedly and starve a blocked flush()/submit()
                # for the whole drain — give waiters a real window
                time.sleep(1e-4)
            else:
                # backlog drained: sleep until the next delta (re-checked
                # on a short tick; apply_delta wakes work implicitly)
                self._stop.wait(0.002)

    # ------------------------------------------------------- queries ----

    @property
    def pending(self) -> int:
        return len(self._pending)

    def submit(self, seed_set) -> int:
        """Enqueue one sigma(S) query; returns its ticket id."""
        with self._lock:
            ticket = self._next_ticket
            self._next_ticket += 1
            self._pending.append((ticket, np.asarray(seed_set, np.int32)))
        return ticket

    def apply_delta(self, delta) -> int:
        """Forward a `GraphDelta` to the underlying stream engine; the
        next flush answers from the new epoch (the async worker starts
        repairing it immediately).  Returns the number of resident rows
        that went stale."""
        if not hasattr(self.engine, "apply_delta"):
            raise ValueError("apply_delta needs a StreamEngine")
        with self._lock:
            return self.engine.apply_delta(delta)

    def flush(self) -> dict:
        """Answer all pending queries; returns {ticket: influence}.

        Every ticket in one flush is answered against the same store
        state (the engine lock is held across the whole flush, so
        neither ``apply_delta`` nor any refresh slice can interleave) —
        the results are epoch-consistent even when deltas land between
        submits.  In cooperative background-refresh mode (no worker),
        repair work runs *after* the answers, bounded by
        ``refresh_budget`` rows; in async mode the worker owns repair
        and the flush does none.
        """
        results = {}
        with obs.span("flush", tier="serve"), self._lock:
            while self._pending:
                chunk = self._pending[:self.max_batch]
                self._pending = self._pending[self.max_batch:]
                vals = self.engine.influences([s for _, s in chunk])
                results.update(
                    {t: float(v) for (t, _), v in zip(chunk, vals)})
            self.queries_served += len(results)
            self.served_epoch = getattr(self.engine, "epoch", None)
            if self.refresh_budget is not None and not self.async_refreshing:
                self.engine.refresh(self.refresh_budget)
        return results

    def influence(self, seed_set) -> float:
        """Convenience single-query path (submit + flush)."""
        ticket = self.submit(seed_set)
        return self.flush()[ticket]

    def select(self, k: int):
        """Top-k seed-selection query (memoized by the engine)."""
        with self._lock:
            return self.engine.select(k)

    def metrics(self) -> dict:
        """The obs metrics-registry snapshot (empty maps unless
        ``repro.obs`` is enabled — see docs/observability.md)."""
        return obs.snapshot()

    def drain(self, timeout: float | None = 30.0) -> bool:
        """Block until the staleness backlog is fully repaired (True) or
        ``timeout`` seconds elapse (False); ``timeout=None`` waits
        forever.  With a live async worker this waits on it; otherwise
        it refreshes inline in budget-row slices, re-checking the
        deadline between slices so a finite timeout is honored on the
        inline path too (a backlog bigger than the time allows returns
        False with partial progress kept)."""
        deadline = (None if timeout is None
                    else time.monotonic() + float(timeout))
        while True:
            with self._lock:
                if getattr(self.engine, "stale", 0) == 0:
                    return True
                if not self.async_refreshing:
                    self.engine.refresh(self.refresh_budget)
                    continue_inline = True
                else:
                    continue_inline = False
            if deadline is not None and time.monotonic() > deadline:
                with self._lock:
                    if getattr(self.engine, "stale", 0) == 0:
                        return True
                return False
            if not continue_inline:
                time.sleep(0.002)


def _main_lm(args):
    arch = get_arch(args.arch)
    cfg = arch.smoke_config
    server = LMServer(cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = server.generate(prompts, args.gen)
    dt = time.time() - t0
    print(f"[serve] {args.arch}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(out[0])


def _main_im(args):
    from repro.configs.imm_snap import (
        IMM_EXPERIMENTS, make_im_mesh, mesh_engine_kwargs,
    )
    from repro.core.engine import InfluenceEngine, IMMConfig
    from repro.graphs.datasets import scaled_snap

    exp = IMM_EXPERIMENTS[args.graph]
    scale = exp.bench_scale if args.scale is None else args.scale
    g = scaled_snap(args.graph, scale, seed=0)
    mesh = make_im_mesh(args.mesh)
    mesh_kw = mesh_engine_kwargs(mesh)
    cfg = IMMConfig(k=args.k, model=args.model, backend=args.backend,
                    sampler=args.sampler, max_theta=args.max_theta,
                    store=args.store)
    if args.deltas:
        from repro.stream import StreamEngine
        engine = StreamEngine(g, cfg, **mesh_kw)
    else:
        engine = InfluenceEngine(g, cfg, **mesh_kw)
    t0 = time.time()
    engine.extend(args.max_theta)
    t_sample = time.time() - t0
    server = IMServer(
        engine,
        refresh_budget=args.refresh_budget if args.deltas else None,
        async_refresh=bool(args.deltas and args.async_refresh))
    if mesh is not None:
        print(f"[serve-im] sharded store: theta axis over "
              f"{engine.store.D} shard(s) x vertex axis over "
              f"{getattr(engine.store, 'Dv', 1)} shard(s), "
              f"cap_local={engine.store.cap_local}, "
              f"n_local={getattr(engine.store, 'n_local', g.n)}")

    # a realistic mixed workload: top-k selections of several sizes plus a
    # burst of random candidate-set influence queries, all from one store
    t0 = time.time()
    sels = {kk: server.select(kk) for kk in (5, args.k // 2 or 1, args.k)}
    rng = np.random.default_rng(0)
    tickets = [server.submit(rng.choice(g.n, size=rng.integers(1, 9),
                                        replace=False))
               for _ in range(args.queries)]
    answers = server.flush()
    dt = time.time() - t0
    n_q = len(sels) + len(tickets)
    print(f"[serve-im] {args.graph} n={g.n:,} theta={engine.theta}: "
          f"sampled in {t_sample:.2f}s, answered {n_q} queries in {dt:.2f}s "
          f"({n_q / max(dt, 1e-9):.1f} q/s)")
    for kk, s in sorted(sels.items()):
        print(f"  select(k={kk}): influence={s.influence:.1f} "
              f"seeds={[int(v) for v in s.seeds[:5]]}...")
    vals = [answers[t] for t in tickets[:4]]
    print(f"  sample influence answers: {[round(v, 1) for v in vals]}")

    if args.deltas:
        from repro.stream import random_delta
        drng = np.random.default_rng(7)
        probe = engine.select(args.k).seeds
        for i in range(args.deltas):
            d = random_delta(engine.graph, drng, inserts=4, deletes=4,
                             reweights=4)
            stale = server.apply_delta(d)
            tickets = [server.submit(probe) for _ in range(8)]
            ans = server.flush()      # consistent answers + budgeted repair
            sig = ans[tickets[0]]
            print(f"  delta {i}: {len(d)} edge ops, {stale} rows stale, "
                  f"epoch {server.served_epoch}, sigma(probe)={sig:.1f}, "
                  f"backlog {engine.stale}")
        if server.async_refreshing:
            if not server.drain(timeout=120.0):
                print(f"  WARNING: async drain timed out with "
                      f"{engine.stale} rows still stale; finishing "
                      f"inline")
                while engine.stale:
                    engine.refresh(args.refresh_budget)
            server.stop_refresh_worker()
            print(f"  async worker ran {server.refreshes_run} repair "
                  f"slice(s)")
        else:
            while engine.stale:
                engine.refresh(args.refresh_budget)
        final = engine.select(args.k)
        print(f"  drained: epoch {engine.epoch} consistent, "
              f"select(k={args.k}) influence={final.influence:.1f}")


def _main_tier(args):
    """Thin CLI over the `repro.serve.IMServe` tier: N tenants (static
    and streaming alternating, one relaxed-SLO tenant with replicas when
    ``--replicas`` > 0), a Zipf-skewed Poisson query trace interleaved
    with GraphDeltas, replayed in arrival order with the SLO-aware
    refresh worker running in the background."""
    import numpy as np
    from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
    from repro.core.engine import IMMConfig
    from repro.graphs import rmat_graph
    from repro.serve import (
        IMServe, TenantSpec, make_trace, replay, trace_summary, zipf_rates,
    )

    mesh_kw = mesh_engine_kwargs(make_im_mesh(args.mesh))
    cfg = IMMConfig(k=args.k, batch=min(args.max_theta, 256),
                    max_theta=max(args.max_theta, 1 << 20), seed=0,
                    store=args.store)
    tier = IMServe(quantum=args.quantum, refresh_budget=args.refresh_budget,
                   mesh_kwargs=mesh_kw)
    graphs, stream_map = {}, {}
    for i in range(args.tenants):
        name = f"tenant{i}"
        streaming = i % 2 == 1
        relaxed = args.replicas > 0 and i == 2 % max(args.tenants, 1)
        g = rmat_graph(args.tier_n, args.tier_n * 8, seed=10 + i,
                       weighted_ic="wc")
        tier.register(TenantSpec(
            name, graph=g, cfg=cfg, theta=args.max_theta,
            streaming=streaming,
            slo="relaxed" if relaxed else "strict",
            replicas=args.replicas if relaxed else 0,
            max_pending=args.max_pending))
        graphs[name], stream_map[name] = g, streaming
    print(f"[serve-tier] {args.tenants} tenants x n={args.tier_n} "
          f"(theta={args.max_theta}, mesh={args.mesh or 1}) registered")

    events = make_trace(
        graphs, duration=args.duration,
        qps=zipf_rates(sorted(graphs), args.qps, args.skew,
                       np.random.default_rng(1)),
        streaming=stream_map, delta_period=args.duration / 4,
        seed=2)
    print(f"[serve-tier] trace: {len(events)} events "
          f"{trace_summary(events)}")
    tier.start_refresh_worker()
    t0 = time.time()
    answered, rejected = replay(tier, events, pump_every=args.quantum * 2)
    wall = time.time() - t0
    drained = tier.drain(timeout=60.0)
    tier.close()
    lat = sorted(tier.result(t).latency_s for t in answered)
    stats = tier.stats()
    print(f"[serve-tier] {len(answered)} answered / {rejected} rejected "
          f"in {wall:.2f}s ({len(answered) / max(wall, 1e-9):.1f} q/s), "
          f"p50={lat[len(lat) // 2] * 1e3:.1f}ms "
          f"p99={lat[int(len(lat) * 0.99)] * 1e3:.1f}ms")
    print(f"[serve-tier] cache {stats['cache']}, "
          f"refresh {stats.get('refresh')}, drained={drained}")
    for name, ts in sorted(stats["tenants"].items()):
        print(f"  {name}: served={ts['served']} rejected={ts['rejected']} "
              f"cache_hits={ts['cache_hits']} epoch={ts['epoch']} "
              f"refreshes={ts['refreshes']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="lm", choices=("lm", "im", "tier"))
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--graph", default="com-Amazon")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--model", default="IC",
                    choices=("IC", "WC", "GT", "LT"))
    ap.add_argument("--backend", default=None,
                    choices=("dense", "sparse", "pallas", "walk"),
                    help="traversal backend (default: auto by model/n)")
    ap.add_argument("--sampler", default=None,
                    help="full sampler-name override, e.g. 'WC/pallas'")
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--max-theta", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--deltas", type=int, default=0,
                    help="IM workload: apply N random graph deltas and "
                         "serve through them (StreamEngine)")
    ap.add_argument("--refresh-budget", type=int, default=1024,
                    help="stale rows repaired between flushes in "
                         "--deltas mode")
    ap.add_argument("--async-refresh", action="store_true",
                    help="--deltas mode: repair on a background worker "
                         "thread instead of cooperatively inside flush")
    ap.add_argument("--store", default="auto",
                    choices=("auto", "bitmap", "indices", "packed",
                             "compressed", "sharded"),
                    help="IM arena at-rest representation ('packed'/"
                         "'compressed' = IMPack encoded tiles; results "
                         "are bitwise-identical to 'bitmap')")
    ap.add_argument("--mesh", default=None,
                    help="IM store mesh: int or 'auto' (1D theta "
                         "sharding), 'RxC' e.g. '2x4' (2D theta x "
                         "vertex), or omit for single-device")
    ap.add_argument("--tenants", type=int, default=4,
                    help="tier workload: campaigns to register")
    ap.add_argument("--tier-n", type=int, default=512,
                    help="tier workload: vertices per tenant graph")
    ap.add_argument("--duration", type=float, default=1.0,
                    help="tier workload: trace length (virtual seconds)")
    ap.add_argument("--qps", type=float, default=256.0,
                    help="tier workload: total query arrival rate")
    ap.add_argument("--skew", type=float, default=1.0,
                    help="tier workload: Zipf exponent of per-tenant "
                         "traffic shares")
    ap.add_argument("--quantum", type=int, default=8,
                    help="tier workload: DRR quantum per round")
    ap.add_argument("--replicas", type=int, default=1,
                    help="tier workload: read replicas for the "
                         "relaxed-SLO tenant (0 disables)")
    ap.add_argument("--max-pending", type=int, default=1024,
                    help="tier workload: per-tenant admission queue cap")
    ap.add_argument("--metrics-out", default=None,
                    help="enable repro.obs and write the metrics-registry "
                         "JSON snapshot here at exit")
    ap.add_argument("--trace-out", default=None,
                    help="enable repro.obs and write the Chrome "
                         "trace-event JSON (Perfetto-loadable) here")
    args = ap.parse_args(argv)
    if args.metrics_out or args.trace_out:
        obs.enable()
    if args.workload == "tier":
        _main_tier(args)
    elif args.workload == "im":
        _main_im(args)
    else:
        _main_lm(args)
    if args.metrics_out:
        print(f"[obs] metrics -> {obs.write_metrics(args.metrics_out)}")
    if args.trace_out:
        print(f"[obs] trace -> {obs.write_trace(args.trace_out)}")


if __name__ == "__main__":
    main()
