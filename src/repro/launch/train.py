"""Training driver: wires an ArchDef + TrainLoop + CheckpointManager.

Runs REAL steps on whatever devices exist (CPU here, a pod in production:
the same cell builders produce the production shardings when given the
production mesh).  Used by examples/train_lm.py and the integration tests;
``--steps``/sizes stay CPU-friendly by default.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --smoke --steps 50 --checkpoint-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.tokens import TokenPipeline
from repro.models.transformer import LMConfig, init_lm, lm_loss
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.clip import clip_by_global_norm
from repro.optim.schedule import wsd_schedule, cosine_schedule
from repro.runtime.loop import TrainLoop, LoopConfig


def make_step(cfg: LMConfig, opt_cfg: AdamWConfig, schedule_fn):
    @jax.jit
    def step_fn(state, batch):
        tokens, labels = batch

        def loss_fn(p):
            return lm_loss(p, cfg, tokens, labels)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"])
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr_scale = schedule_fn(state["opt"]["step"])
        params, opt = adamw_update(state["params"], grads, state["opt"],
                                   opt_cfg, lr_scale)
        return ({"params": params, "opt": opt},
                {"loss": loss, "grad_norm": gnorm})

    return step_fn


def train_lm(arch_id: str, *, smoke: bool = True, steps: int = 100,
             batch: int = 8, seq_len: int = 128,
             checkpoint_dir: str = "/tmp/repro_ck", save_every: int = 50,
             seed: int = 0, log=print):
    arch = get_arch(arch_id)
    cfg = arch.smoke_config if smoke else arch.config
    opt_cfg = AdamWConfig(lr=1e-3)
    use_wsd = arch_id == "minicpm-2b"       # the WSD schedule arch
    if use_wsd:
        schedule_fn = lambda s: wsd_schedule(   # noqa: E731
            s, warmup=steps // 10 + 1, stable=int(steps * 0.6),
            decay=max(int(steps * 0.3), 1))
    else:
        schedule_fn = lambda s: cosine_schedule(  # noqa: E731
            s, warmup=steps // 10 + 1, total=steps)

    pipe = TokenPipeline(vocab=cfg.vocab, batch=batch, seq_len=seq_len,
                         seed=seed)

    def batch_fn(step):
        t, l = pipe.batch_at(step)
        return jnp.asarray(t), jnp.asarray(l)

    def init_fn():
        params = init_lm(jax.random.PRNGKey(seed), cfg)
        return {"params": params, "opt": adamw_init(params, opt_cfg)}

    step_fn = make_step(cfg, opt_cfg, schedule_fn)
    loop = TrainLoop(
        LoopConfig(total_steps=steps, checkpoint_dir=checkpoint_dir,
                   save_every=save_every),
        step_fn, batch_fn, init_fn)
    t0 = time.time()
    state = loop.run()
    losses = [float(r.metrics["loss"]) for r in loop.history]
    if losses:
        log(f"[train] {arch_id}: steps={len(loop.history)} "
            f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
            f"({time.time()-t0:.1f}s, recoveries={loop.recoveries})")
    return state, losses, loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ck")
    args = ap.parse_args(argv)
    train_lm(args.arch, smoke=args.smoke, steps=args.steps,
             batch=args.batch, seq_len=args.seq_len,
             checkpoint_dir=args.checkpoint_dir)


if __name__ == "__main__":
    main()
