"""Per-architecture sharding policies for the production mesh.

Axis roles (DESIGN §3):
  ("pod","data") — batch / RRRset-theta / edge-parallel axes
  "model"        — tensor/expert/vocab/vertex-counter axis

LM policies (chosen per arch; see EXPERIMENTS §Dry-run for the resulting
memory/collective profile):
  * "tp"        — Megatron tensor parallel on heads/ffn/vocab; params
                  replicated over data (small archs: qwen, danube).
  * "row"       — row-parallel attention (head-count agnostic: minicpm's 36
                  heads don't divide 16) + TP ffn; FSDP-style vocab shard.
  * "moe_ep"    — experts over "model" (E % 16 == 0: moonshot 64e) + FSDP
                  storage shard of the expert d axis over "data".
  * "moe_tpe"   — TP inside experts over "model" (grok 8e) + FSDP storage
                  shard over "data"; XLA re-gathers the stored shard
                  per layer inside the scan (ZeRO-3 pattern).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes


LM_POLICY = {
    "qwen1.5-0.5b": "tp",
    "h2o-danube-3-4b": "tp",
    "minicpm-2b": "row",
    "moonshot-v1-16b-a3b": "moe_ep",
    "grok-1-314b": "moe_tpe",
}

# grad-accumulation microbatches for train_4k (bounds MoE dispatch buffers
# and activation residency — DESIGN §4); "auto" -> one dp-row of sequences
# per microbatch (B/dp_size), the per-device-minimal setting grok needs
LM_TRAIN_MICROBATCHES = {
    "grok-1-314b": "auto",
    "moonshot-v1-16b-a3b": 8,
    "minicpm-2b": 1,
    "h2o-danube-3-4b": 1,
    "qwen1.5-0.5b": 1,
}

# chunked prefill for MoE archs (bounds per-chunk dispatch size)
LM_PREFILL_CHUNK = {
    "grok-1-314b": 2048,     # 4096 leaves single-pod ~240 MB over HBM
    "moonshot-v1-16b-a3b": 4096,
}


def _lm_layer_spec(name: str, ndim: int, policy: str, dp: tuple):
    """PartitionSpec for a stacked (L, ...) layer param by name."""
    m = "model"
    d = dp[-1] if dp else None          # "data" (storage/FSDP axis)
    if name in ("ln1", "ln2"):
        return P(None, None)
    if policy in ("tp", "row"):
        row = policy == "row"
        table = {
            "wq": P(None, "model", None) if row else P(None, None, m),
            "wk": P(None, "model", None) if row else P(None, None, m),
            "wv": P(None, "model", None) if row else P(None, None, m),
            "wo": P(None, None, "model") if row else P(None, m, None),
            "bq": P(None, None) if row else P(None, m),
            "bk": P(None, None) if row else P(None, m),
            "bv": P(None, None) if row else P(None, m),
            "w_gate_up": P(None, None, m),
            "w_down": P(None, m, None),
            "router": P(None, None, None),
        }
        return table[name]
    if policy == "moe_ep":
        table = {
            "wq": P(None, None, m),
            "wk": P(None, None, m),
            "wv": P(None, None, m),
            "wo": P(None, m, None),
            "bq": P(None, m), "bk": P(None, m), "bv": P(None, m),
            "router": P(None, None, None),
            # (L, E, d, 2ff): experts over model, d over data (storage)
            "w_gate_up": P(None, m, d, None),
            # (L, E, ff, d): experts over model, ff over data (storage)
            "w_down": P(None, m, d, None),
        }
        return table[name]
    if policy == "moe_tpe":
        table = {
            # grok: q heads 48/16 ok; kv heads 8 stay unsharded
            "wq": P(None, d, m),
            "wk": P(None, d, None),
            "wv": P(None, d, None),
            "wo": P(None, m, d),
            "bq": P(None, m), "bk": P(None, None), "bv": P(None, None),
            "router": P(None, None, None),
            # (L, E, d, 2ff): TP on ff over model, storage shard d over data
            "w_gate_up": P(None, None, d, m),
            # (L, E, ff, d): TP on ff (row-parallel) over model, d over data
            "w_down": P(None, None, m, d),
        }
        return table[name]
    raise ValueError(policy)


def lm_param_specs(params_shape, policy: str, mesh):
    """Pytree of PartitionSpec matching an init_lm param tree."""
    dp = dp_axes(mesh)
    m = "model"

    def spec_of(path, leaf):
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        if keys[0] == "embed":
            # vocab padded to a 16-multiple by launch/steps.py (Megatron-
            # style) so odd vocabs (minicpm 122753) still row-shard
            return P(m, None)
        if keys[0] == "lm_head":
            return P(None, m)
        if keys[0] == "ln_f":
            return P(None)
        if keys[0] == "layers":
            return _lm_layer_spec(keys[1], leaf.ndim, policy, dp)
        raise KeyError(keys)

    return jax.tree_util.tree_map_with_path(spec_of, params_shape)


def gnn_param_specs(params_shape, mesh):
    """GNN weights are small: replicated (baseline; EXPERIMENTS §Perf
    evaluates feature-dim sharding as a hillclimb)."""
    return jax.tree.map(lambda leaf: P(*([None] * leaf.ndim)), params_shape)


def fm_param_specs(params_shape, mesh):
    """Row-shard the embedding tables over "model" (paper C2 analogue)."""
    def spec_of(path, leaf):
        keys = [getattr(k, "key", None) for k in path]
        if keys[0] in ("v",):
            return P("model", None)
        if keys[0] in ("w",):
            return P("model")
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(spec_of, params_shape)


def opt_state_specs(param_specs):
    """AdamW moments shard exactly like their parameters."""
    return {
        "mu": jax.tree.map(lambda s: s, param_specs),
        "nu": jax.tree.map(lambda s: s, param_specs),
        "step": P(),
    }


def kv_cache_spec(n_kv_heads: int, mesh, *, batch: int):
    """(L, B, Hkv, S, hd): batch over dp when it divides; heads over model
    when divisible, else the sequence axis."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b_axis = dp if batch % dp_size == 0 and batch >= dp_size else None
    if n_kv_heads % mesh.shape["model"] == 0:
        return P(None, b_axis, "model", None, None)
    return P(None, b_axis, None, "model", None)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
