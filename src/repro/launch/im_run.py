"""IMM end-to-end driver (the paper's workload), on the InfluenceEngine.

    PYTHONPATH=src python -m repro.launch.im_run --graph com-Amazon \
        --scale 0.01 --model IC --k 50

Runs Algorithm 1 with EfficientIMM defaults (rebuild selection + fused
counters + adaptive representation) or the Ripples-style baseline
(--baseline), on a synthetic SNAP stand-in (hermetic container: see
graphs/datasets.py).  Because the engine keeps its sampled RRR store,
``--select-k`` answers extra campaign queries from the same store for free,
and ``--snapshot-dir`` persists the store for later resumption.

``--mesh N`` (or ``--mesh auto``) shards the RRR store's theta axis across
N devices; ``--mesh RxC`` (e.g. ``--mesh 2x4``) makes the mesh genuinely
2D — R theta shards x C vertex shards, so theta *and* the graph's vertex
dimension scale with device count (paper C1 end-to-end: device-local
sampling writes over both axes, sharded selection).  Results are
seed-for-seed identical to the single-device default; on one device any
flag degrades gracefully to a 1-tile mesh.
"""
from __future__ import annotations

import argparse
import json
import time

from repro import obs
from repro.configs.imm_snap import (
    IMM_EXPERIMENTS, make_im_mesh, mesh_engine_kwargs,
)
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.graphs.datasets import scaled_snap, synthetic_snap


def run(graph: str, *, scale: float = None, model: str = "IC", k: int = 50,
        eps: float = 0.5, baseline: bool = False, seed: int = 0,
        max_theta: int = 1 << 14, select_ks=(), snapshot_dir: str = None,
        mesh=None, backend: str = None, sampler: str = None,
        store: str = "auto", metrics_out: str = None, trace_out: str = None,
        log=print):
    if metrics_out or trace_out:
        obs.enable()
    exp = IMM_EXPERIMENTS[graph]
    scale = exp.bench_scale if scale is None else scale
    t0 = time.time()
    g = scaled_snap(graph, scale, seed=seed) if scale < 1.0 else \
        synthetic_snap(graph, seed=seed)
    t_graph = time.time() - t0

    cfg = IMMConfig(
        k=k, eps=eps, model=model, backend=backend, sampler=sampler,
        max_theta=max_theta, seed=seed, store=store,
        selection_method="decrement" if baseline else "rebuild",
        adaptive_representation=not baseline,
    )
    mesh = make_im_mesh(mesh)
    engine = InfluenceEngine(g, cfg, **mesh_engine_kwargs(mesh))
    if snapshot_dir:
        engine.restore(snapshot_dir)       # resume if a snapshot exists
    t0 = time.time()
    res = engine.run()
    t_imm = time.time() - t0

    # extra (k, influence) campaign queries — same store, no re-sampling
    t0 = time.time()
    queries = {
        int(q): {"influence": engine.select(int(q)).influence,
                 "seeds": [int(s) for s in engine.select(int(q)).seeds[:10]]}
        for q in select_ks
    }
    t_queries = time.time() - t0

    if snapshot_dir:
        engine.snapshot(snapshot_dir)

    out = {
        "graph": graph, "scale": scale, "n": g.n, "m": g.m, "model": model,
        "sampler": engine.sampler_name,
        "k": k, "mode": "ripples-style" if baseline else "efficientimm",
        "mesh_shards": None if mesh is None else int(
            engine.store.D if hasattr(engine.store, "D") else 1),
        "vertex_shards": None if mesh is None else int(
            getattr(engine.store, "Dv", 1)),
        "influence": res.influence, "covered_frac": res.covered_frac,
        "theta": res.theta, "representation": res.representation,
        "graph_s": round(t_graph, 3), "imm_s": round(t_imm, 3),
        "seeds": [int(s) for s in res.seeds[:10]],
    }
    if queries:
        out["queries"] = queries
        out["queries_s"] = round(t_queries, 3)
    if metrics_out:
        out["metrics_out"] = obs.write_metrics(metrics_out)
    if trace_out:
        out["trace_out"] = obs.write_trace(trace_out)
    log(json.dumps(out))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="com-Amazon",
                    choices=sorted(IMM_EXPERIMENTS))
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--model", default="IC",
                    choices=("IC", "WC", "GT", "LT"),
                    help="diffusion model: IC (per-edge probs), WC "
                         "(weighted cascade), GT (generalized triggering),"
                         " LT (linear threshold walk)")
    ap.add_argument("--backend", default=None,
                    choices=("dense", "sparse", "pallas", "walk"),
                    help="traversal backend (default: auto by model/n; "
                         "'pallas' drives the fused MXU ic_frontier "
                         "kernel, falling back to the jnp oracle off-TPU)")
    ap.add_argument("--sampler", default=None,
                    help="full sampler-name override, e.g. "
                         "'WC/pallas+stable' (wins over --model/--backend)")
    ap.add_argument("--k", type=int, default=50)
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--baseline", action="store_true")
    ap.add_argument("--max-theta", type=int, default=1 << 14)
    ap.add_argument("--select-k", type=int, action="append", default=[],
                    help="extra seed-set sizes to answer from the same "
                         "sampled store (repeatable)")
    ap.add_argument("--snapshot-dir", default=None,
                    help="resume from / persist the engine store here")
    ap.add_argument("--store", default="auto",
                    choices=("auto", "bitmap", "indices", "packed",
                             "compressed", "sharded"),
                    help="RRR arena at-rest representation: 'packed' "
                         "(bit-packed, 8x smaller) and 'compressed' "
                         "(token lists) are the IMPack formats; all are "
                         "seed-for-seed identical to 'bitmap'")
    ap.add_argument("--mesh", default=None,
                    help="RRR store mesh: an int or 'auto' (1D theta "
                         "sharding), 'RxC' e.g. '2x4' (2D theta x vertex "
                         "sharding), or omit for single-device")
    ap.add_argument("--metrics-out", default=None,
                    help="enable repro.obs and write the metrics-registry "
                         "JSON snapshot here at exit")
    ap.add_argument("--trace-out", default=None,
                    help="enable repro.obs and write the Chrome "
                         "trace-event JSON (Perfetto-loadable) here")
    args = ap.parse_args(argv)
    run(args.graph, scale=args.scale, model=args.model, k=args.k,
        eps=args.eps, baseline=args.baseline, max_theta=args.max_theta,
        select_ks=args.select_k, snapshot_dir=args.snapshot_dir,
        mesh=args.mesh, backend=args.backend, sampler=args.sampler,
        store=args.store, metrics_out=args.metrics_out,
        trace_out=args.trace_out)


if __name__ == "__main__":
    main()
