"""IMM end-to-end driver (the paper's workload).

    PYTHONPATH=src python -m repro.launch.im_run --graph com-Amazon \
        --scale 0.01 --model IC --k 50

Runs Algorithm 1 with EfficientIMM defaults (rebuild selection + fused
counters + adaptive representation) or the Ripples-style baseline
(--baseline), on a synthetic SNAP stand-in (hermetic container: see
graphs/datasets.py).
"""
from __future__ import annotations

import argparse
import json
import time

from repro.configs.imm_snap import IMM_EXPERIMENTS
from repro.core.imm import imm, IMMConfig
from repro.graphs.datasets import scaled_snap, synthetic_snap


def run(graph: str, *, scale: float = None, model: str = "IC", k: int = 50,
        eps: float = 0.5, baseline: bool = False, seed: int = 0,
        max_theta: int = 1 << 14, log=print):
    exp = IMM_EXPERIMENTS[graph]
    scale = exp.bench_scale if scale is None else scale
    t0 = time.time()
    g = scaled_snap(graph, scale, seed=seed) if scale < 1.0 else \
        synthetic_snap(graph, seed=seed)
    t_graph = time.time() - t0

    cfg = IMMConfig(
        k=k, eps=eps, model=model, max_theta=max_theta, seed=seed,
        selection_method="decrement" if baseline else "rebuild",
        adaptive_representation=not baseline,
    )
    t0 = time.time()
    res = imm(g, cfg)
    t_imm = time.time() - t0
    out = {
        "graph": graph, "scale": scale, "n": g.n, "m": g.m, "model": model,
        "k": k, "mode": "ripples-style" if baseline else "efficientimm",
        "influence": res.influence, "covered_frac": res.covered_frac,
        "theta": res.theta, "representation": res.representation,
        "graph_s": round(t_graph, 3), "imm_s": round(t_imm, 3),
        "seeds": [int(s) for s in res.seeds[:10]],
    }
    log(json.dumps(out))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="com-Amazon",
                    choices=sorted(IMM_EXPERIMENTS))
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--model", default="IC", choices=("IC", "LT"))
    ap.add_argument("--k", type=int, default=50)
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--baseline", action="store_true")
    ap.add_argument("--max-theta", type=int, default=1 << 14)
    args = ap.parse_args(argv)
    run(args.graph, scale=args.scale, model=args.model, k=args.k,
        eps=args.eps, baseline=args.baseline, max_theta=args.max_theta)


if __name__ == "__main__":
    main()
