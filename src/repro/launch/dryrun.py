"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be invoked as its own process (the XLA_FLAGS above take effect only
before jax initializes — which is why they are the first lines of this
module, before any other import).

Usage:
  python -m repro.launch.dryrun --arch grok-1-314b --shape train_4k \
      --mesh single --out experiments/cells/grok_train_single.json
  python -m repro.launch.dryrun --all --mesh both      # everything, in-proc
  python -m repro.launch.dryrun --imm --mesh single    # IMM cells

Per cell this prints/records:
  * compiled.memory_analysis()  — proves the cell fits per-device HBM
  * compiled.cost_analysis()    — FLOPs / bytes for §Roofline
  * collective op census parsed from the optimized HLO (§Roofline)
"""
import os
os.environ["XLA_FLAGS"] = (                       # noqa: E402 — MUST precede
    "--xla_force_host_platform_device_count=512 "  # any jax import/init
    + os.environ.get("XLA_FLAGS", ""))

import argparse                                    # noqa: E402
import json                                        # noqa: E402
import sys                                         # noqa: E402
import time                                        # noqa: E402
import traceback                                   # noqa: E402


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             keep_hlo: bool = False) -> dict:
    import jax
    from repro.launch.mesh import make_production_mesh, TPU_V5E
    from repro.launch.steps import build_cell, build_imm_cell
    from repro.launch.roofline import parse_collectives, roofline_terms
    from repro.configs import IMM_DRYRUN_CELLS

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = len(mesh.devices.flatten())
    t0 = time.time()
    if arch_id == "imm":
        cell = build_imm_cell(shape_name, IMM_DRYRUN_CELLS[shape_name], mesh)
    else:
        cell = build_cell(arch_id, shape_name, mesh)

    # donate the state (train) / cache (decode): realistic in-place update
    donate = (0,) if cell.kind == "train" else \
             ((1,) if cell.kind == "decode" else ())
    with mesh:
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*cell.input_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax wraps the dict per-device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # trip-count-corrected flop/byte/collective census (hlo_analysis.py) —
    # compiled.cost_analysis() counts while-loop bodies once (scan!)
    from repro.launch.hlo_analysis import analyze_module, ATTENTION_TAGS
    counts = analyze_module(hlo)
    # kernel-adjusted memory: the jnp blockwise-attention path materializes
    # score tensors at fusion boundaries; the production TPU path is the
    # Pallas flash kernel whose HBM traffic is just Q/K/V/O (+grads).
    attn_boundary = sum(counts.bytes_by_tag.get(t, 0.0)
                        for t in ATTENTION_TAGS)
    bytes_adjusted = (counts.bytes - attn_boundary
                      + cell.attention_ideal_bytes / n_dev)
    from repro.launch.mesh import TPU_V5E as HW
    terms = roofline_terms(
        counts.flops, counts.bytes, counts.collective_wire_bytes,
        cell.model_flops, n_dev,
        extra={
            "memory_adjusted_s": bytes_adjusted / HW["hbm_bytes_per_s"],
            "hlo_bytes_adjusted": bytes_adjusted,
            "attention_boundary_bytes": attn_boundary,
            "collective_counts": counts.collective_counts,
            "collective_bytes": counts.collective_bytes,
            "bytes_by_tag": {k: v for k, v in sorted(
                counts.bytes_by_tag.items(), key=lambda kv: -kv[1])[:8]},
            "wire_by_tag": {k: v for k, v in sorted(
                counts.wire_by_tag.items(), key=lambda kv: -kv[1])[:8]},
            "top_collectives": sorted(
                counts.top_collectives, reverse=True)[:10],
            "unknown_trip_loops": counts.unknown_trip_loops,
            "raw_cost_analysis_flops": float(cost.get("flops", 0.0)),
            "raw_cost_analysis_bytes": float(
                cost.get("bytes accessed", 0.0)),
        })
    adj = {"compute_s": terms["compute_s"],
           "memory_s": terms["memory_adjusted_s"],
           "collective_s": terms["collective_s"]}
    terms["dominant_adjusted"] = max(adj, key=adj.get)
    ideal = cell.model_flops / n_dev / HW["peak_flops_bf16"]
    terms["roofline_fraction_adjusted"] = (
        ideal / adj[terms["dominant_adjusted"]]
        if adj[terms["dominant_adjusted"]] > 0 else 0.0)

    mem_dict = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_dict[k] = int(v)
    # arguments are aliased (donated state) in spirit; peak residency proxy:
    live = (mem_dict.get("argument_size_in_bytes", 0)
            + mem_dict.get("temp_size_in_bytes", 0)
            + mem_dict.get("output_size_in_bytes", 0)
            - mem_dict.get("alias_size_in_bytes", 0))
    result = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": cell.kind,
        "note": cell.note,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_dict,
        "bytes_per_device": live,
        "fits_hbm": bool(live <= TPU_V5E["hbm_bytes"]),
        "cost_analysis": {k: float(cost[k]) for k in
                          ("flops", "bytes accessed")
                          if k in cost},
        "roofline": terms,
    }
    if keep_hlo:
        result["hlo_len"] = len(hlo)
    print(compiled.memory_analysis())
    print({k: cost.get(k) for k in ("flops", "bytes accessed")})
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="all assigned cells (in-process)")
    ap.add_argument("--imm", action="store_true", help="IMM cells")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.configs import all_cells, IMM_DRYRUN_CELLS

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    todo = []
    if args.all:
        todo = list(all_cells())
    elif args.imm:
        todo = [("imm", name) for name in IMM_DRYRUN_CELLS]
    elif args.arch and args.shape:
        todo = [(args.arch, args.shape)]
    else:
        ap.error("need --arch+--shape, --all, or --imm")

    results = []
    n_fail = 0
    for arch_id, shape_name in todo:
        for mp in meshes:
            tag = f"{arch_id}/{shape_name}/{'multi' if mp else 'single'}"
            print(f"=== dryrun {tag} ===", flush=True)
            try:
                res = run_cell(arch_id, shape_name, mp)
            except Exception as e:  # noqa: BLE001 — record + continue
                traceback.print_exc()
                res = {"arch": arch_id, "shape": shape_name,
                       "mesh": "2x16x16" if mp else "16x16",
                       "ok": False, "error": f"{type(e).__name__}: {e}"}
                n_fail += 1
            results.append(res)
            print(json.dumps(
                {k: res.get(k) for k in
                 ("arch", "shape", "mesh", "ok", "bytes_per_device",
                  "fits_hbm", "compile_s")}), flush=True)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
