"""Viral campaign on an *evolving* network — the StreamEngine scenario.

A campaign team plans seed sets on a social graph that keeps changing
under them: fringe follow edges appear and disappear every tick, edge
strengths drift.  The static workflow (examples/influence_campaign.py)
would re-sample the whole RRR store per change; here the `StreamEngine`
keeps the store resident and repairs only what each delta actually
staled:

  * tick loop: apply a `GraphDelta`, serve top-k + what-if queries
    immediately from the surviving rows (epoch-tagged answers), then
    `refresh` — stale rows re-sample with their original keys, so after
    the repair the store is *identical* to a fresh engine's;
  * bounded memory: the same stream under a `StorePressurePolicy` row
    cap, evicting oldest rows instead of growing — the indefinite-stream
    deployment mode;
  * the final tick cross-checks the streamed store against a from-scratch
    engine on the final graph (the equivalence invariant, live).

    PYTHONPATH=src python examples/streaming_campaign.py [--ticks 6]
"""
import argparse
import time

import numpy as np

from repro.core.engine import InfluenceEngine, IMMConfig
from repro.core.store import StorePressurePolicy
from repro.graphs import rmat_graph
from repro.stream import StreamEngine, random_delta


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ticks", type=int, default=6)
    ap.add_argument("--n", type=int, default=768)
    ap.add_argument("--theta", type=int, default=2048)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args(argv)

    print(f"building evolving network (n={args.n})...")
    g = rmat_graph(args.n, args.n * 8, seed=0, weighted_ic="wc")
    cfg = IMMConfig(k=args.k, batch=256, max_theta=1 << 20, seed=0)
    stream = StreamEngine(g, cfg)
    t0 = time.time()
    stream.extend(args.theta)
    print(f"  resident store: theta={stream.theta} "
          f"(sampled in {time.time() - t0:.1f}s, "
          f"sampler={stream.cfg.sampler})")

    rng = np.random.default_rng(1)
    campaign = stream.select(args.k).seeds
    for tick in range(args.ticks):
        delta = random_delta(stream.graph, rng, inserts=4, deletes=4,
                             reweights=4, max_dst_indeg=8)
        t0 = time.time()
        stale = stream.apply_delta(delta)
        # serve immediately from the survivors (degraded-fidelity answers
        # are tagged with their staleness backlog)...
        sel = stream.select(args.k)
        sigma_old = stream.influence(campaign)
        # ...then repair exactly the stale rows
        stream.refresh()
        sigma_new = stream.influence(campaign)
        dt = time.time() - t0
        print(f"  tick {tick}: {len(delta)} edge ops -> {stale:4d} stale "
              f"rows, epoch {sel.epoch}, sigma(campaign) "
              f"{sigma_old:7.1f} -> {sigma_new:7.1f} repaired, "
              f"select(k) influence {sel.influence:7.1f}  [{dt:.2f}s]")
        campaign = stream.select(args.k).seeds

    print("cross-checking against a from-scratch engine on the final "
          "graph...")
    fresh = InfluenceEngine(stream.graph, stream.cfg)
    fresh.extend(stream.theta)
    same = np.array_equal(fresh.select(args.k).seeds, campaign)
    print(f"  seed-for-seed identical: {same}")

    cap = args.theta // 2
    print(f"replaying under a max_rows={cap} memory cap...")
    bounded = StreamEngine(g, cfg, policy=StorePressurePolicy(max_rows=cap))
    bounded.extend(args.theta)
    rng = np.random.default_rng(1)
    for _ in range(args.ticks):
        bounded.apply_delta(random_delta(
            bounded.graph, rng, inserts=4, deletes=4, reweights=4,
            max_dst_indeg=8))
        bounded.refresh()
    assert bounded.store.capacity <= cap
    sb = bounded.select(args.k)
    sigma_b, sigma_u = stream.influences(
        [sb.seeds, campaign]).tolist()
    print(f"  arena capped at {bounded.store.capacity} rows "
          f"(theta {bounded.theta}); seed quality "
          f"{sigma_b / max(sigma_u, 1e-9) * 100:.1f}% of the unbounded "
          f"stream's")


if __name__ == "__main__":
    main()
