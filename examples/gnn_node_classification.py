"""GraphSAGE node classification on a planted-partition graph with REAL
neighbor sampling (the minibatch_lg training pattern at CPU scale).

    PYTHONPATH=src python examples/gnn_node_classification.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data.graph_feats import synthetic_node_features
from repro.graphs import rmat_graph
from repro.graphs.sampler import neighbor_sampler
from repro.models.gnn.graphsage import (
    SageConfig, init_sage, forward_blocks, loss_blocks,
)
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main(n_nodes=2_000, n_edges=16_000, n_classes=5, d_feat=32,
         steps=150, batch=64):
    g = rmat_graph(n_nodes, n_edges, seed=0)
    feats_np, labels_np = synthetic_node_features(
        g.n, d_feat, n_classes, seed=0, noise=1.5)
    feats = jnp.asarray(feats_np)
    labels = jnp.asarray(labels_np)

    cfg = SageConfig(n_layers=2, d_hidden=64, d_feat=d_feat,
                     n_classes=n_classes, sample_sizes=(10, 5))
    params = init_sage(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)
    f1, f2 = cfg.sample_sizes

    @jax.jit
    def step(params, opt, key, seeds):
        k1, k2 = jax.random.split(key)
        n1 = neighbor_sampler(k1, g.dst_offsets, g.in_src, seeds, f1)
        n2 = neighbor_sampler(k2, g.dst_offsets, g.in_src,
                              n1.reshape(-1), f2)
        pad = jnp.zeros((1, d_feat), feats.dtype)
        table = jnp.concatenate([feats, pad])          # sentinel row n
        x_seed = table[seeds]
        x_n1 = table[n1]
        x_n2 = table[n2]
        loss, grads = jax.value_and_grad(loss_blocks)(
            params, cfg, x_seed, x_n1, x_n2, labels[seeds])
        params, opt = adamw_update(params, grads, opt, opt_cfg)
        logits = forward_blocks(params, cfg, x_seed, x_n1, x_n2)
        acc = jnp.mean(jnp.argmax(logits, -1) == labels[seeds])
        return params, opt, loss, acc

    key = jax.random.PRNGKey(1)
    accs, losses = [], []
    for i in range(steps):
        key, k1, k2 = jax.random.split(key, 3)
        seeds = jax.random.randint(k1, (batch,), 0, g.n)
        params, opt, loss, acc = step(params, opt, k2, seeds)
        losses.append(float(loss))
        accs.append(float(acc))
        if i % 30 == 0:
            print(f"step {i:4d}  loss {loss:.4f}  minibatch acc {acc:.3f}")
    first, last = np.mean(accs[:10]), np.mean(accs[-10:])
    print(f"[gnn] minibatch accuracy {first:.3f} -> {last:.3f}")
    assert last > first + 0.1, "accuracy did not improve"
    print("[gnn] OK")


if __name__ == "__main__":
    main()
