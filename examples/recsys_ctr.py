"""FM CTR training + the three serving modes (p99 / bulk / retrieval).

    PYTHONPATH=src python examples/recsys_ctr.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.clicks import synthetic_click_batches
from repro.models.recsys.fm import (
    FMConfig, init_fm, fm_logits, fm_loss, fm_retrieval_scores,
)
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main(steps=200):
    cfg = FMConfig(n_sparse=8, embed_dim=8, vocab_per_field=500)
    params = init_fm(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=0.01, weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)

    @jax.jit
    def train_step(params, opt, idx, labels):
        loss, grads = jax.value_and_grad(fm_loss)(params, cfg, idx, labels)
        params, opt = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    losses = []
    for idx, labels in synthetic_click_batches(
            cfg.n_sparse, cfg.vocab_per_field, 1024, steps, dim=4, seed=0):
        params, opt, loss = train_step(
            params, opt, jnp.asarray(idx), jnp.asarray(labels))
        losses.append(float(loss))
    print(f"[recsys] CTR loss {np.mean(losses[:10]):.4f} -> "
          f"{np.mean(losses[-10:]):.4f}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10])

    # --- serving modes (the assigned shape set, CPU scale) ---
    serve = jax.jit(lambda p, idx: fm_logits(p, cfg, idx))
    for name, B in (("serve_p99", 512), ("serve_bulk", 8192)):
        idx = jax.random.randint(jax.random.PRNGKey(1),
                                 (B, cfg.n_sparse), 0, cfg.vocab_per_field)
        serve(params, idx).block_until_ready()       # compile
        t0 = time.perf_counter()
        serve(params, idx).block_until_ready()
        dt = time.perf_counter() - t0
        print(f"[recsys] {name}: batch {B} in {dt*1e3:.2f} ms "
              f"({B/dt:.0f} preds/s)")

    # retrieval: one user context against many candidates as one mat-vec
    n_cand = 100_000
    cands = jax.random.randint(jax.random.PRNGKey(2), (n_cand,), 0,
                               cfg.total_rows)
    ret = jax.jit(lambda p, u, c: fm_retrieval_scores(p, cfg, u, c))
    user = jnp.array([3, 77, 150, 9], jnp.int32)
    ret(params, user, cands).block_until_ready()
    t0 = time.perf_counter()
    scores = ret(params, user, cands).block_until_ready()
    dt = time.perf_counter() - t0
    top = np.argsort(np.asarray(scores))[-5:][::-1]
    print(f"[recsys] retrieval_cand: {n_cand:,} candidates in "
          f"{dt*1e3:.2f} ms; top-5 rows {top.tolist()}")
    print("[recsys] OK")


if __name__ == "__main__":
    main()
