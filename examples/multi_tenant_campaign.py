"""Two campaigns, one serving tier — the IMServe multi-tenant scenario.

A brand team runs a *static* influence campaign (fixed network, heavy
dashboard traffic re-asking the same seed sets) while a second team runs
a *streaming* campaign on an evolving network (follow edges churn every
tick).  Instead of one server per team, both register as tenants of a
single `IMServe` tier and get the shared-deployment behaviours:

  * **admission control** — a dashboard flood past the tenant's
    ``max_pending`` cap is rejected at the door, not queued into
    everyone's latency;
  * **DRR fairness** — the flooding tenant cannot starve the other:
    every scheduling round serves each backlogged tenant its weighted
    share, as one fused sigma(S) kernel call;
  * **epoch-keyed result cache** — repeated dashboard queries hit the
    ``(tenant, epoch, frozenset(S))`` cache and return bitwise-identical
    answers for free; the streaming tenant's entries die the moment its
    served epoch advances past a delta;
  * **SLO-aware refresh** — one global repair budget flows to the tenant
    whose graph actually changed (the static tenant never has backlog);
  * **engine pools** — a third what-if tenant plans against the *same*
    network as the static campaign via ``share_engine_with``: no second
    store is sampled, but its admission queue, fairness share, and cache
    namespace stay its own.

    PYTHONPATH=src python examples/multi_tenant_campaign.py [--ticks 4]
"""
import argparse
import time

import numpy as np

from repro.core.engine import IMMConfig
from repro.graphs import rmat_graph
from repro.serve import AdmissionError, IMServe, TenantSpec
from repro.stream import random_delta


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ticks", type=int, default=4)
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--theta", type=int, default=1024)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args(argv)

    print(f"registering tenants (n={args.n}, theta={args.theta})...")
    cfg = IMMConfig(k=args.k, batch=256, max_theta=1 << 20, seed=0)
    t0 = time.time()
    tier = IMServe(quantum=8, refresh_budget=256)
    tier.register(TenantSpec(
        "brand-a", graph=rmat_graph(args.n, args.n * 8, seed=0,
                                    weighted_ic="wc"),
        cfg=cfg, theta=args.theta, weight=1.0, max_pending=32))
    tier.register(TenantSpec(
        "brand-b", graph=rmat_graph(args.n, args.n * 8, seed=1,
                                    weighted_ic="wc"),
        cfg=cfg, theta=args.theta, streaming=True, weight=2.0))
    # what-if analysts share brand-a's engine slot: same store, own
    # admission queue / fairness share / cache namespace
    tier.register(TenantSpec("whatif-a", share_engine_with="brand-a",
                             weight=0.5))
    print(f"  3 tenants up in {time.time() - t0:.1f}s "
          f"(whatif-a shares brand-a's engine: "
          f"{tier.tenants['whatif-a'].engine is tier.tenants['brand-a'].engine})")

    rng = np.random.default_rng(2)
    camp_a = np.asarray(tier.select("brand-a", args.k).seeds)
    camp_b = np.asarray(tier.select("brand-b", args.k).seeds)

    with tier:
        tier.start_refresh_worker()
        for tick in range(args.ticks):
            # brand-b's network churns; its epoch advances mid-traffic
            delta = random_delta(tier.tenants["brand-b"].graph, rng,
                                 inserts=4, deletes=4, reweights=4,
                                 max_dst_indeg=8)
            stale = tier.apply_delta("brand-b", delta)

            # brand-a's dashboard re-asks the same seed set (cache food),
            # brand-b asks post-delta, whatif-a probes a variation
            ta = [tier.submit("brand-a", camp_a) for _ in range(3)]
            tb = tier.submit("brand-b", camp_b)
            tw = tier.submit("whatif-a", camp_a[: args.k // 2])
            tier.flush()
            ra = [tier.result(t) for t in ta]
            rb, rw = tier.result(tb), tier.result(tw)
            assert len({r.value for r in ra}) == 1   # hits == recompute
            print(f"  tick {tick}: {len(delta)} ops -> {stale:3d} stale; "
                  f"brand-a sigma {ra[0].value:7.1f} "
                  f"(cached {sum(r.cached for r in ra)}/3), "
                  f"brand-b sigma {rb.value:7.1f} @epoch {rb.epoch}, "
                  f"whatif {rw.value:6.1f}")
        drained = tier.drain(timeout=60.0)

    # admission control: a dashboard flood bounces off brand-a's cap
    admitted = rejected = 0
    try:
        for _ in range(100):
            tier.submit("brand-a", camp_a)
            admitted += 1
    except AdmissionError:
        rejected = 100 - admitted
    tier.flush()
    print(f"flood of 100: {admitted} admitted (cap "
          f"{tier.tenants['brand-a'].spec.max_pending}), first of "
          f"{rejected} rejections raised AdmissionError")

    s = tier.stats()
    print(f"drained={drained}; cache hit rate "
          f"{s['cache']['hit_rate']:.2f} "
          f"({s['cache']['invalidations']} entries invalidated by epoch "
          f"advances); refresh granted {s['refresh']['rows_granted']} "
          f"rows over {s['refresh']['steps']} steps, all to brand-b "
          f"(brand-a backlog stayed "
          f"{s['tenants']['brand-a']['backlog']})")


if __name__ == "__main__":
    main()
