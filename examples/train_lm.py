"""End-to-end LM training driver: ~100M-parameter qwen-family model for a
few hundred steps with checkpoint/restart and the WSD/cosine schedule.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --tiny     # smoke scale
"""
import argparse
import dataclasses

import jax

from repro.configs import get_arch
from repro.launch.train import train_lm
from repro.models.transformer import LMConfig
import repro.configs.base as cfg_base
from repro.configs.base import ArchDef
from repro.configs._lm_common import lm_shapes, lm_smoke_step
from repro.models.transformer import init_lm


def register_100m():
    """A ~100M-parameter member of the qwen family (same code path as the
    full assigned configs)."""
    cfg = LMConfig(
        name="qwen-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=8, d_ff=1408, vocab=32_000, qkv_bias=True)
    arch = ArchDef(
        arch_id="qwen-100m", family="lm", source="examples/train_lm.py",
        config=cfg, smoke_config=cfg, shapes=lm_shapes(),
        init_fn=init_lm, smoke_step=lm_smoke_step)
    cfg_base.register(arch)
    print(f"[train_lm] params: {cfg.param_count()/1e6:.1f}M")
    return arch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.tiny:
        steps = args.steps or 60
        state, losses, loop = train_lm(
            "qwen1.5-0.5b", smoke=True, steps=steps, batch=8, seq_len=64,
            checkpoint_dir=args.checkpoint_dir)
    else:
        register_100m()
        steps = args.steps or 200
        state, losses, loop = train_lm(
            "qwen-100m", smoke=True, steps=steps, batch=8, seq_len=256,
            checkpoint_dir=args.checkpoint_dir, save_every=50)
    print(f"[train_lm] first-10 loss {sum(losses[:10])/10:.4f} -> "
          f"last-10 loss {sum(losses[-10:])/10:.4f}")
    assert sum(losses[-10:]) < sum(losses[:10]), "loss did not improve"
    print("[train_lm] OK — loss improved; checkpoints in",
          args.checkpoint_dir)


if __name__ == "__main__":
    main()
