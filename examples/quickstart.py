"""Quickstart: influence maximization with EfficientIMM in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import imm, IMMConfig
from repro.graphs import rmat_graph

# a power-law social graph (synthetic stand-in for a SNAP graph)
graph = rmat_graph(n=2_000, m=16_000, seed=0)

# EfficientIMM defaults: fused counting (C3), RRRset-partitioned rebuild
# selection (C1+C5), adaptive representation (C4)
result = imm(graph, IMMConfig(k=10, eps=0.5, model="IC", max_theta=4096))

print(f"graph: n={graph.n} m={graph.m}")
print(f"seeds: {list(result.seeds)}")
print(f"estimated influence: {result.influence:.1f} nodes "
      f"({100 * result.covered_frac:.1f}% RRR coverage)")
print(f"RRR sets sampled: {result.theta}  "
      f"(representation: {result.representation})")

# the Ripples-style baseline is one flag away (paper comparison)
baseline = imm(graph, IMMConfig(
    k=10, eps=0.5, model="IC", max_theta=4096,
    selection_method="decrement", adaptive_representation=False))
print(f"baseline influence (identical math): {baseline.influence:.1f}")
