"""End-to-end influence-maximization campaign on the InfluenceEngine.

Plans a viral campaign on a YouTube-scale synthetic network the way a real
campaign tool would: sample the RRR store ONCE per diffusion model, then
answer a whole sweep of questions from it —

  * budget sweep: best seed sets for several campaign sizes k
    (``engine.select(k)``, no re-sampling between queries);
  * what-if queries: sigma(S) for hand-picked candidate seed sets
    (``engine.influence``), batched through one fused membership kernel;
  * resumability: snapshot the sampled store, restore it in a fresh
    engine, and keep querying (``engine.snapshot``/``restore``);

and finally Monte-Carlo-validates the IC influence estimate by simulating
the diffusion forward from the chosen seeds.

``--mesh N`` (or ``auto``) runs the whole campaign against a mesh-sharded
RRR store (paper C1) — same answers, theta partitioned across devices; on
a single device it defaults to no mesh.

    PYTHONPATH=src python examples/influence_campaign.py [--mesh auto]
"""
import argparse
import tempfile
import time

import numpy as np

from repro.core import InfluenceEngine, IMMConfig
from repro.configs.imm_snap import (
    CAMPAIGN_KS, make_im_mesh, mesh_engine_kwargs,
)
from repro.graphs.datasets import scaled_snap


def simulate_ic(graph, seeds, n_trials: int = 50, seed: int = 1):
    """Forward Monte-Carlo IC simulation (independent check of sigma(S))."""
    rng = np.random.default_rng(seed)
    src = np.asarray(graph.edge_src)
    dst = np.asarray(graph.edge_dst)
    prob = np.asarray(graph.in_prob)
    total = 0
    for _ in range(n_trials):
        live = rng.random(graph.m) < prob
        active = np.zeros(graph.n, bool)
        active[list(seeds)] = True
        while True:
            # forward edges whose src is active & live
            mask = live & active[src] & ~active[dst]
            nxt = np.unique(dst[mask])
            if nxt.size == 0:
                break
            active[nxt] = True
        total += active.sum()
    return total / n_trials


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mesh", default=None,
                    help="RRR store mesh: int or 'auto' (1D theta "
                         "sharding), 'RxC' e.g. '2x4' (2D theta x "
                         "vertex), or omit for single-device")
    args = ap.parse_args(argv)
    mesh = make_im_mesh(args.mesh)

    print("building YouTube-scale synthetic network (replica)...")
    g = scaled_snap("com-YouTube", 0.004)
    print(f"  n={g.n:,} m={g.m:,}")
    if mesh is not None:
        print(f"  RRR store sharded over {mesh.devices.size} device(s)")

    ks = [k for k in CAMPAIGN_KS if k <= 20]
    for model in ("IC", "LT"):
        engine = InfluenceEngine(
            g, IMMConfig(k=max(ks), eps=0.5, model=model, max_theta=8192),
            **mesh_engine_kwargs(mesh))
        t0 = time.time()
        res = engine.run()
        t_solve = time.time() - t0
        print(f"\n[{model}] solved in {t_solve:.1f}s  theta={res.theta}  "
              f"rep={res.representation}")

        # --- budget sweep: every k answered from the same sampled store ---
        t0 = time.time()
        for k in ks:
            sel = engine.select(k)
            print(f"  k={k:>3}: influence={sel.influence:8.0f}  "
                  f"seeds={[int(v) for v in sel.seeds[:6]]}")
        print(f"  (budget sweep over {len(ks)} campaign sizes: "
              f"{time.time() - t0:.2f}s, zero extra sampling)")

        # --- what-if: compare the solver's picks against naive candidates ---
        top = engine.select(ks[-1])
        degree_hubs = np.argsort(np.asarray(engine.store.counter))[-ks[-1]:]
        sigma_opt, sigma_hub = engine.influences(
            [top.seeds, degree_hubs]).tolist()
        print(f"  what-if: greedy seeds -> {sigma_opt:.0f}, "
              f"top-counter hubs -> {sigma_hub:.0f}")

        if model == "IC":
            mc = simulate_ic(g, top.seeds, n_trials=20)
            print(f"  Monte-Carlo validation: {mc:.0f} nodes "
                  f"({abs(mc - top.influence) / max(mc, 1) * 100:.1f}% gap)")

        # --- resumability: a fresh engine picks up the sampled store ---
        if model == "IC":
            with tempfile.TemporaryDirectory() as ckpt_dir:
                engine.snapshot(ckpt_dir)
                engine2 = InfluenceEngine(
                    g, IMMConfig(k=max(ks), model=model, max_theta=8192),
                    mesh=mesh)
                engine2.restore(ckpt_dir)
                sel2 = engine2.select(ks[0])
                same = list(sel2.seeds) == list(engine.select(ks[0]).seeds)
                print(f"  snapshot/restore: restored theta={engine2.theta}, "
                      f"select(k={ks[0]}) identical: {same}")


if __name__ == "__main__":
    main()
