"""End-to-end influence-maximization campaign (the paper's workload kind).

Picks seed users for a viral campaign on a YouTube-scale synthetic network,
under both diffusion models, then Monte-Carlo-validates the influence
estimate by simulating the IC diffusion from the chosen seeds.

    PYTHONPATH=src python examples/influence_campaign.py
"""
import time

import numpy as np

from repro.core import imm, IMMConfig
from repro.graphs.datasets import scaled_snap


def simulate_ic(graph, seeds, n_trials: int = 50, seed: int = 1):
    """Forward Monte-Carlo IC simulation (independent check of sigma(S))."""
    rng = np.random.default_rng(seed)
    src = np.asarray(graph.edge_src)
    dst = np.asarray(graph.edge_dst)
    prob = np.asarray(graph.in_prob)
    total = 0
    for _ in range(n_trials):
        live = rng.random(graph.m) < prob
        active = np.zeros(graph.n, bool)
        active[list(seeds)] = True
        frontier = list(seeds)
        while frontier:
            # forward edges whose src is active & live
            mask = live & active[src] & ~active[dst]
            nxt = np.unique(dst[mask])
            if nxt.size == 0:
                break
            active[nxt] = True
            frontier = nxt
        total += active.sum()
    return total / n_trials


def main():
    print("building YouTube-scale synthetic network (1% replica)...")
    g = scaled_snap("com-YouTube", 0.004)
    print(f"  n={g.n:,} m={g.m:,}")

    for model in ("IC", "LT"):
        t0 = time.time()
        res = imm(g, IMMConfig(k=20, eps=0.5, model=model,
                               max_theta=8192))
        dt = time.time() - t0
        print(f"\n[{model}] {dt:.1f}s  theta={res.theta}  "
              f"rep={res.representation}")
        print(f"  top seeds: {list(res.seeds[:8])}")
        print(f"  estimated influence: {res.influence:.0f} nodes")
        if model == "IC":
            mc = simulate_ic(g, res.seeds, n_trials=20)
            print(f"  Monte-Carlo validation: {mc:.0f} nodes "
                  f"({abs(mc - res.influence) / max(mc, 1) * 100:.1f}% gap)")


if __name__ == "__main__":
    main()
