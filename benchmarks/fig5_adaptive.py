"""Paper Fig. 5: runtime w/ and w/o the adaptive counter update.

The effect is graph-skewness dependent: with dense, overlapping RRRsets
(the IC + SCC regime) the first seeds cover most sets, so decremental
updates touch nearly every set repeatedly while the rebuild path shrinks
its work each round.  We measure both selection strategies on skewed
(rmat) and near-uniform (erdos) replicas at matched sizes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._util import print_table, save_results, timeit
from repro.core.selection import select_dense
from repro.core.sampler import make_logq, sample_ic_dense
from repro.graphs import rmat_graph, erdos_graph


def run(n: int = 2048, m: int = 16384, theta: int = 2048, k: int = 20,
        log=print):
    rows, payload = [], {}
    for gname, g in (("rmat (skewed)", rmat_graph(n, m, seed=0)),
                     ("erdos (uniform)", erdos_graph(n, m, seed=0))):
        logq = make_logq(g)
        R, _, _ = sample_ic_dense(jax.random.PRNGKey(0), logq, batch=theta)
        valid = jnp.ones((theta,), bool)
        coverage = float(jnp.mean(R.sum(1) / g.n))
        f_re = jax.jit(lambda R_, v_: select_dense(R_, v_, k, "rebuild"))
        f_de = jax.jit(lambda R_, v_: select_dense(R_, v_, k, "decrement"))
        t_re = timeit(f_re, R, valid)
        t_de = timeit(f_de, R, valid)
        payload[gname] = {"avg_coverage": coverage,
                          "adaptive_rebuild_s": t_re,
                          "decrement_s": t_de,
                          "speedup": t_de / max(t_re, 1e-9)}
        rows.append([gname, f"{coverage*100:.1f}%",
                     f"{t_de*1e3:.1f}", f"{t_re*1e3:.1f}",
                     f"{t_de/max(t_re,1e-9):.2f}x"])
    print_table("Fig 5 analogue: adaptive counter update",
                ["graph", "avg coverage", "decrement ms",
                 "rebuild ms", "speedup"], rows)
    save_results("fig5_adaptive", payload)
    return payload


if __name__ == "__main__":
    run()
