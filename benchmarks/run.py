"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all, CPU-scale
    PYTHONPATH=src python -m benchmarks.run table3 fig5
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (
    table1_coverage, table2_layout, table3_runtime, table4_memory,
    fig5_adaptive, fig67_scaling,
)

ALL = {
    "table1": table1_coverage.run,
    "table2": table2_layout.run,
    "table3": table3_runtime.run,
    "table4": table4_memory.run,
    "fig5": fig5_adaptive.run,
    "fig67": fig67_scaling.run,
}


def main(argv=None):
    names = (argv if argv is not None else sys.argv[1:]) or list(ALL)
    failures = []
    for name in names:
        print(f"\n########## {name} ##########", flush=True)
        t0 = time.time()
        try:
            ALL[name]()
            print(f"[{name}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print("FAILED:", failures)
        return 1
    print("\nall benchmarks complete")
    return 0


if __name__ == "__main__":
    sys.exit(main())
