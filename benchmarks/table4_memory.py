"""Paper Table IV: cache misses -> memory-traffic proxy.

The paper profiles L1+L2 misses of Find_Most_Influential_Set; on TPU the
analogue is HBM bytes accessed.  We compare the two selection strategies'
HLO byte traffic (trip-count-corrected, launch/hlo_analysis.py) on the same
RRRset matrix:

  * vertex-partitioned decremental baseline (Ripples work pattern): every
    round touches the full bitmap twice (counter matvec + decrement pass);
  * EfficientIMM RRRset-partitioned rebuild: one masked matvec per round
    over surviving sets only;
  * the IMPack at-rest formats on the same rebuild pattern: ``packed``
    (bit-packed arena, decoded once inside the fused selection) and
    ``compressed`` (token lists counted by the decode-and-count kernel).
    The numbers are honest about dispatch: on TPU the Pallas kernel reads
    tokens only, while the off-TPU jnp oracle materializes per-round
    decode temporaries, so the ``compressed`` column measured on CPU is
    an upper bound that the fused kernel does not pay.

Also reports measured wall-time per selection on CPU as a secondary signal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import print_table, save_results, timeit
from repro.core.selection import select_dense, select_vertex_partitioned
from repro.core.adaptive import bitmap_to_indices
from repro.core.pack.codec import (
    MIN_TOKEN_PAD, pack_bits, token_encode, tokens_needed,
)
from repro.core.pack.selection import select_compressed, select_packed
from repro.core.sampler import make_logq, sample_ic_dense
from repro.core.store import next_pow2
from repro.configs.imm_snap import IMM_EXPERIMENTS
from repro.graphs.datasets import scaled_snap
from repro.launch.hlo_analysis import analyze_module

GRAPHS = ["com-Amazon", "web-Google", "soc-Pokec", "com-YouTube", "com-LJ"]


def _traffic(R, valid, k, method, n=None):
    if method == "ripples":
        # the faithful Ripples pattern: vertex partitioning + binary search
        # over sorted index lists (paper §III Challenge 1)
        l_max = int(np.asarray(R.sum(1)).max())
        R_idx = bitmap_to_indices(R, l_max)
        fn = jax.jit(lambda R_, v_: select_vertex_partitioned(
            R_, v_, n, k))
        compiled = fn.lower(R_idx, valid).compile()
        counts = analyze_module(compiled.as_text())
        secs = timeit(fn, R_idx, valid)
        return counts.bytes, secs
    if method == "packed":
        Rp = pack_bits(R.astype(jnp.uint8))
        fn = jax.jit(lambda R_, v_: select_packed(R_, v_, n, k))
        compiled = fn.lower(Rp, valid).compile()
        counts = analyze_module(compiled.as_text())
        secs = timeit(fn, Rp, valid)
        return counts.bytes, secs
    if method == "compressed":
        bits = R.astype(jnp.uint8)
        s_pad = next_pow2(
            max(int(tokens_needed(bits).max()), MIN_TOKEN_PAD), 1)
        T = token_encode(bits, s_pad)
        fn = jax.jit(lambda T_, v_: select_compressed(T_, v_, n, k))
        compiled = fn.lower(T, valid).compile()
        counts = analyze_module(compiled.as_text())
        secs = timeit(fn, T, valid)
        return counts.bytes, secs
    fn = jax.jit(lambda R_, v_: select_dense(R_, v_, k, method))
    compiled = fn.lower(R, valid).compile()
    counts = analyze_module(compiled.as_text())
    secs = timeit(fn, R, valid)
    return counts.bytes, secs


def run(theta: int = 1024, k: int = 10, log=print):
    rows, payload = [], {}
    for name in GRAPHS:
        exp = IMM_EXPERIMENTS[name]
        g = scaled_snap(name, exp.bench_scale, seed=0)
        if g.n > 2048:
            g = scaled_snap(name, exp.bench_scale * 2048 / g.n, seed=0)
        logq = make_logq(g)
        R, _, _ = sample_ic_dense(jax.random.PRNGKey(0), logq, batch=theta)
        valid = jnp.ones((theta,), bool)
        b_rip, t_rip = _traffic(R, valid, k, "ripples", n=g.n)
        b_dec, t_dec = _traffic(R, valid, k, "decrement")
        b_eff, t_eff = _traffic(R, valid, k, "rebuild")
        b_pck, t_pck = _traffic(R, valid, k, "packed", n=g.n)
        b_cmp, t_cmp = _traffic(R, valid, k, "compressed", n=g.n)
        payload[name] = {
            "n": g.n, "theta": theta,
            "bytes_ripples_vp": b_rip, "bytes_decremental": b_dec,
            "bytes_efficientimm": b_eff,
            "bytes_packed": b_pck, "bytes_compressed": b_cmp,
            "reduction_vs_ripples": b_rip / max(b_eff, 1),
            "reduction_vs_decremental": b_dec / max(b_eff, 1),
            "time_ripples_vp_s": t_rip, "time_decremental_s": t_dec,
            "time_efficientimm_s": t_eff,
            "time_packed_s": t_pck, "time_compressed_s": t_cmp,
        }
        rows.append([name, g.n,
                     f"{b_rip/1e6:.1f}", f"{b_dec/1e6:.1f}",
                     f"{b_eff/1e6:.1f}",
                     f"{b_pck/1e6:.1f}", f"{b_cmp/1e6:.1f}",
                     f"{b_rip/max(b_eff,1):.1f}x",
                     f"{t_rip*1e3:.0f}", f"{t_eff*1e3:.0f}"])
    print_table(
        "Table IV analogue: selection memory traffic (MB accessed) + time",
        ["graph", "n", "MB ripples(vp)", "MB decr", "MB eff",
         "MB packed", "MB compr",
         "reduction", "ms ripples", "ms eff"], rows)
    save_results("table4_memory", payload)
    return payload


if __name__ == "__main__":
    run()
