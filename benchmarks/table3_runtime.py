"""Paper Table III: EfficientIMM vs Ripples-style best runtime (IC + LT).

CPU-scale replicas of the SNAP graphs (hermetic container).  The
"ripples-style" baseline uses decremental counter updates + no adaptive
representation (the paper's characterization of the original framework's
work pattern); EfficientIMM uses fused counting + rebuild + adaptive
representation.  Relative speedups are the reproduction target — absolute
times are CPU-container numbers.  Both paths run through the
`InfluenceEngine` API (repro.core.engine) over preallocated RRR arenas;
``--mesh N`` (or ``auto``) runs both over a mesh-sharded RRR store
(paper C1) — results are seed-for-seed identical, so speedup ratios stay
comparable across layouts.  On one device the default is no mesh.
"""
from __future__ import annotations

import argparse
import time

from benchmarks._util import print_table, save_results
from repro.configs.imm_snap import (
    IMM_EXPERIMENTS, make_im_mesh, mesh_engine_kwargs,
)
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.graphs.datasets import scaled_snap

GRAPHS = ["com-Amazon", "com-DBLP", "com-YouTube", "as-Skitter",
          "web-Google", "soc-Pokec", "com-LJ"]        # Twitter7: in --full


def _run_one(g, model, method, adaptive, k, max_theta, seed=0, mesh=None):
    cfg = IMMConfig(k=k, model=model, selection_method=method,
                    adaptive_representation=adaptive,
                    max_theta=max_theta, batch=256, seed=seed)
    t0 = time.perf_counter()
    # engine construction stays inside the timed window: it runs sampler
    # preprocessing (e.g. the dense logq build) that imm() always included
    engine = InfluenceEngine(g, cfg, **mesh_engine_kwargs(mesh))
    res = engine.run()
    return time.perf_counter() - t0, res


def run(k: int = 20, max_theta: int = 4096, full: bool = False, mesh=None,
        log=print):
    mesh = make_im_mesh(mesh)
    graphs = GRAPHS + (["Twitter7"] if full else [])
    rows, payload = [], {}
    for name in graphs:
        exp = IMM_EXPERIMENTS[name]
        g = scaled_snap(name, exp.bench_scale, seed=0)
        entry = {"n": g.n, "m": g.m,
                 "mesh_shards": None if mesh is None else mesh.devices.size}
        for model in ("IC", "LT"):
            # warm compile both paths on the same graph
            t_eff, r_eff = _run_one(g, model, "rebuild", True, k, max_theta,
                                    mesh=mesh)
            t_eff, r_eff = _run_one(g, model, "rebuild", True, k, max_theta,
                                    mesh=mesh)
            t_rip, r_rip = _run_one(g, model, "decrement", False, k,
                                    max_theta, mesh=mesh)
            entry[model] = {
                "efficientimm_s": t_eff, "ripples_style_s": t_rip,
                "speedup": t_rip / max(t_eff, 1e-9),
                "influence_eff": r_eff.influence,
                "influence_rip": r_rip.influence,
            }
        payload[name] = entry
        rows.append([
            name, g.n,
            f"{entry['IC']['ripples_style_s']:.2f}",
            f"{entry['IC']['efficientimm_s']:.2f}",
            f"{entry['IC']['speedup']:.2f}x",
            f"{entry['LT']['ripples_style_s']:.2f}",
            f"{entry['LT']['efficientimm_s']:.2f}",
            f"{entry['LT']['speedup']:.2f}x",
        ])
    print_table(
        "Table III (scaled replicas): best runtime (s)",
        ["graph", "n", "IC base", "IC eff", "IC speedup",
         "LT base", "LT eff", "LT speedup"], rows)
    save_results("table3_runtime", payload)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--k", type=int, default=20)
    ap.add_argument("--max-theta", type=int, default=4096)
    ap.add_argument("--full", action="store_true",
                    help="include Twitter7 (slow)")
    ap.add_argument("--mesh", default=None,
                    help="RRR store mesh: int or 'auto' (1D theta), "
                         "'RxC' (2D theta x vertex), or omit for "
                         "single-device")
    a = ap.parse_args()
    run(k=a.k, max_theta=a.max_theta, full=a.full, mesh=a.mesh)
