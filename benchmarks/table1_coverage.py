"""Paper Table I: input graphs + RRRset coverage characteristics (IC,
eps=0.5).  CPU-scale replicas of the 8 SNAP graphs; validates the paper's observation
that social graphs' SCC structure yields dense RRRsets (avg coverage >30%
for community graphs) while road-like topologies stay sparse.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks._util import print_table, save_results
from repro.configs.imm_snap import IMM_EXPERIMENTS
from repro.core.sampler import make_logq, sample_ic_dense
from repro.graphs.datasets import scaled_snap


def run(theta: int = 512, log=print):
    rows, payload = [], {}
    for name, exp in IMM_EXPERIMENTS.items():
        g = scaled_snap(name, exp.bench_scale, seed=0)
        if g.n > 4096:
            g = scaled_snap(name, exp.bench_scale * 2048 / g.n, seed=0)
        logq = make_logq(g)
        visited, _, _ = sample_ic_dense(
            jax.random.PRNGKey(0), logq, batch=theta)
        sizes = np.asarray(visited).sum(axis=1) / g.n
        rows.append([name, g.n, g.m,
                     f"{sizes.mean() * 100:.1f}%",
                     f"{sizes.max() * 100:.1f}%"])
        payload[name] = {"n": g.n, "m": g.m,
                         "avg_coverage": float(sizes.mean()),
                         "max_coverage": float(sizes.max())}
    print_table("Table I (scaled replicas): RRRset coverage under IC",
                ["graph", "nodes", "edges", "avg RRR cov", "max RRR cov"],
                rows)
    save_results("table1_coverage", payload)
    return payload


if __name__ == "__main__":
    run()
