"""Serving-tier benchmark: multi-tenant latency, throughput, and cache
behaviour under a trace-driven load (BENCH_6).

Drives an `IMServe` tier with >= 4 tenants — alternating static and
streaming campaigns, one relaxed-SLO tenant reading from replicas, one
tenant sharing another's engine — through a Zipf-skewed Poisson query
trace with `GraphDelta` batches interleaved mid-stream, while the
SLO-aware refresh worker repairs staleness in the background.

The full (non ``--tiny``) run models a million-user-scale universe:
``--users`` is each tenant's campaign population (default 262144, so 4
tenants span a 2^20-user universe) and — following the repo's Table III
convention for the paper's SNAP graphs — each campaign executes as a
density-preserving scaled RMAT replica of that population
(``n = users * scale``; absolute times are CPU-container numbers, the
latency/throughput/hit-rate *structure* is the reproduction target).
``--scale 1`` runs the universe at full size if you have the hardware.

Reported per run and per tenant:

  * ``p50_ms`` / ``p99_ms`` — end-to-end query latency (submit ->
    answered, queueing under DRR included);
  * ``qps`` — answered throughput over the serving wall-clock;
  * ``cache_hit_rate`` — fraction of queries answered from the
    epoch-keyed sigma cache (the trace's hot pools make this non-zero);
  * ``refreshes`` — engine refresh slices run by the scheduler.

Emits machine-readable ``BENCH_6.json`` rows
``{name, mesh, n, theta, wall_s}`` + the extras above (shared
`benchmarks._emit` schema) next to a human table.

    PYTHONPATH=src python -m benchmarks.serve_tier [--tiny] [--mesh M]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks._emit import bench_row, mesh_tag, write_bench
from benchmarks._util import print_table
from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
from repro.core.engine import IMMConfig
from repro.graphs import rmat_graph
from repro.serve import (
    IMServe, TenantSpec, make_trace, replay, trace_summary, zipf_rates,
)


def _percentiles_ms(latencies: list[float]) -> tuple[float, float]:
    if not latencies:
        return 0.0, 0.0
    arr = np.asarray(latencies)
    return (float(np.percentile(arr, 50)) * 1e3,
            float(np.percentile(arr, 99)) * 1e3)


def _specs(names, n, m, theta, replicas, max_pending, seed):
    """The tenant mix the tier exists for: alternating static/streaming,
    one relaxed-SLO replicated reader, one shared-engine slot (the last
    tenant plans against the first's network)."""
    specs = []
    for i, name in enumerate(names):
        g = rmat_graph(n, m, seed=seed + 10 + i, weighted_ic="wc")
        cfg = IMMConfig(k=10, batch=max(theta // 4, 64),
                        max_theta=max(theta, 1 << 20), seed=seed + i)
        streaming = i % 2 == 1
        relaxed = i == 2 and replicas > 0
        share = (names[0] if i == len(names) - 1 and len(names) >= 5
                 else None)
        if share is not None:
            specs.append(TenantSpec(name, share_engine_with=share,
                                    weight=0.5, max_pending=max_pending))
        else:
            specs.append(TenantSpec(
                name, graph=g, cfg=cfg, theta=theta, streaming=streaming,
                slo="relaxed" if relaxed else "strict",
                replicas=replicas if relaxed else 0,
                weight=2.0 if i == 0 else 1.0, max_pending=max_pending))
    return specs


def run(tenants=4, users=16384, scale=1.0, theta=1024, duration=1.0,
        qps=256.0, skew=1.0, quantum=8, refresh_budget=512, replicas=1,
        max_pending=4096, mesh=None, seed=0, log=print):
    n = max(int(users * scale), 256)
    names = [f"campaign-{i}" for i in range(tenants)]
    specs = _specs(names, n, n * 8, theta, replicas, max_pending, seed)

    tier = IMServe(quantum=quantum, refresh_budget=refresh_budget,
                   mesh_kwargs=mesh_engine_kwargs(mesh))
    t0 = time.perf_counter()
    for spec in specs:
        tier.register(spec)
    t_register = time.perf_counter() - t0

    graphs = {t.name: t.graph for t in tier.tenants.values()}
    streaming = {t.name: t.streaming and t.owns_engine
                 for t in tier.tenants.values()}
    trace = make_trace(
        graphs, duration=duration,
        qps=zipf_rates(names, qps * tenants, skew,
                       np.random.default_rng(seed)),
        streaming=streaming, delta_period=duration / 4, delta_ops=4,
        seed=seed + 1)
    summary = trace_summary(trace)

    with tier:
        tier.start_refresh_worker()
        t0 = time.perf_counter()
        answered, rejected = replay(tier, trace)
        drained = tier.drain(timeout=60.0)
    wall = time.perf_counter() - t0

    stats = tier.stats()
    rows, bench = [], []

    def record(name, graph_n, lat_ms, served_qps, hit_rate, refreshes,
               wall_s, extra=""):
        p50, p99 = lat_ms
        bench.append(bench_row(
            name, n=graph_n, theta=theta, wall_s=wall_s, mesh=mesh,
            tenants=tenants, users=users, scale=scale,
            qps=round(served_qps, 2),
            p50_ms=round(p50, 3), p99_ms=round(p99, 3),
            refreshes=refreshes, cache_hit_rate=round(hit_rate, 4)))
        rows.append([name, graph_n, f"{served_qps:.1f}", f"{p50:.2f}",
                     f"{p99:.2f}", f"{hit_rate:.3f}", refreshes, extra])

    per_tenant_lat = {name: [] for name in names}
    for tid in answered:
        r = tier.result(tid)
        per_tenant_lat[r.tenant].append(r.latency_s)
    all_lat = [v for ls in per_tenant_lat.values() for v in ls]

    total_refreshes = sum(
        ts.get("refreshes", 0) for ts in stats["tenants"].values()
        if not ts["shared_engine"])
    record("serve-tier", n * tenants, _percentiles_ms(all_lat),
           len(answered) / max(wall, 1e-9),
           stats["cache"]["hit_rate"], total_refreshes, wall,
           f"rejected={rejected} drained={drained}")
    for name in names:
        ts = stats["tenants"][name]
        hits = ts["cache_hits"] / max(ts["served"], 1)
        record(f"tenant:{name}", n, _percentiles_ms(per_tenant_lat[name]),
               len(per_tenant_lat[name]) / max(wall, 1e-9), hits,
               0 if ts["shared_engine"] else ts.get("refreshes", 0), wall,
               f"{summary[name]['queries']}q/"
               f"{summary[name]['deltas']}d"
               + (" shared" if ts["shared_engine"] else "")
               + (" relaxed" if ts["slo"] == "relaxed" else ""))

    print_table(
        f"IMServe tier ({tenants} tenants x {users} users @ scale "
        f"{scale:g} -> n={n}, theta={theta}, {len(trace)} events, "
        f"mesh={mesh_tag(mesh)})",
        ["name", "n", "qps", "p50_ms", "p99_ms", "hit_rate", "refreshes",
         "notes"], rows)
    log(f"register {t_register:.2f}s; serve {wall:.2f}s; "
        f"{len(answered)} answered, {rejected} rejected, "
        f"cache hit rate {stats['cache']['hit_rate']:.3f}, "
        f"{total_refreshes} refresh slices "
        f"({stats.get('refresh', {}).get('rows_granted', 0)} rows); "
        f"drained={drained}")
    assert drained, "refresh scheduler failed to drain the backlog"
    return bench


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny graphs, short trace")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--users", type=int, default=262144,
                    help="campaign population per tenant (4 x 262144 = "
                         "a 2^20-user universe)")
    ap.add_argument("--scale", type=float, default=1.0 / 16,
                    help="density-preserving replica factor the campaign "
                         "executes at (Table III convention)")
    ap.add_argument("--theta", type=int, default=1024)
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--qps", type=float, default=96.0,
                    help="mean per-tenant query rate (Zipf-skewed)")
    ap.add_argument("--skew", type=float, default=1.0)
    ap.add_argument("--refresh-budget", type=int, default=512)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--mesh", default=None,
                    help="engine mesh for every tenant: N, 'auto', "
                         "or 'RxC' (see configs.imm_snap.make_im_mesh)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_6.json",
                    help="machine-readable output path")
    args = ap.parse_args(argv)
    mesh = make_im_mesh(args.mesh)
    if args.tiny:
        bench = run(tenants=4, users=192, scale=1.0, theta=256,
                    duration=0.25, qps=64.0, refresh_budget=256,
                    replicas=args.replicas, mesh=mesh, seed=args.seed)
    else:
        bench = run(tenants=args.tenants, users=args.users,
                    scale=args.scale, theta=args.theta,
                    duration=args.duration, qps=args.qps, skew=args.skew,
                    refresh_budget=args.refresh_budget,
                    replicas=args.replicas, mesh=mesh, seed=args.seed)
    write_bench(args.out, bench)


if __name__ == "__main__":
    main()
