"""Fused RRR pipeline: what does the sample->write->count chain buy?

Times `InfluenceEngine.extend(theta)` twice per arena cell — once with
``fused_pipeline="off"`` (the legacy sample-jit -> add_batch-jit path,
where every batch exists as a separate ``(B, n)`` device array between
the two calls) and once with ``"auto"`` (one jit per batch: the bound
sampler inlined ahead of the ``kernels/commit.py`` arena-commit kernel,
buffers donated, no intermediate handoff).  Both engines are built from
the *same* ``IMMConfig.seed``, so the PRNG streams are identical by
construction; the emitter then **asserts** — not just reports — that the
per-vertex counters, the selected seed sets, ``covered_frac``, and
``influence`` are bitwise identical before any row is written.  A BENCH
file from this emitter is therefore a pure execution-strategy diff.

Emits machine-readable ``BENCH_10.json`` rows

    {name, mesh, n, theta, wall_s, kernel, fused, store, impl,
     achieved_frac[, speedup]}

where ``impl`` is the ``kernels/ops.py`` dispatch outcome
(``pallas``/``interpret``/``oracle``; sharded cells always report
``oracle`` — the mesh write body is the jnp oracle inside ``shard_map``,
never the single-device Pallas kernel) and ``achieved_frac`` is the
per-batch roofline fraction from ``repro.launch.roofline`` for the
``sample_write_count`` cost model on this ``device_kind``.

The real-hardware section (raw ``arena_commit`` kernel, pallas vs
oracle) runs only when the default backend is an accelerator; on CPU it
skips with a message rather than timing the interpreter.

    PYTHONPATH=src python -m benchmarks.kernel_pipeline [--tiny]
        [--mesh RxC] [--out F] [--require-speedup X]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks._emit import bench_row, device_kind, write_bench
from benchmarks._util import block, print_table, timeit
from repro.core.engine import IMMConfig, InfluenceEngine
from repro.graphs import rmat_graph
from repro.kernels import ops as kops
from repro.launch.roofline import achieved_frac

# small n + many batches on purpose: the fused chain removes per-batch
# dispatch + the (B, n) handoff, which is exactly the regime where that
# fixed cost dominates the arithmetic
CELLS = {
    "default": dict(n=256, m=2048, theta=16384, batch=64, seed=0, k=4),
    "tiny": dict(n=128, m=1024, theta=512, batch=64, seed=0, k=4),
}
STORES = ("auto", "packed")  # bitmap arena + bit-packed arena


def _engine(g, cfg, mesh):
    if mesh is None:
        return InfluenceEngine(g, cfg)
    from repro.configs.imm_snap import mesh_engine_kwargs
    return InfluenceEngine(g, cfg, **mesh_engine_kwargs(mesh))


def _timed_extend(g, cfg, theta, mesh):
    """(engine, wall_s) for extend(theta) after warming the engine's own
    first batch.  The warmup is the engine itself (not a throwaway, as
    in sampler_matrix): the fused chain jit closes over the per-engine
    bound sampler, so only a same-engine batch pre-compiles it — and
    running the identical warmup on the unfused engine keeps the two
    PRNG streams aligned batch-for-batch for the bitwise asserts."""
    engine = _engine(g, cfg, mesh)
    engine.extend(cfg.batch)
    block(engine.store.counter)
    t0 = time.perf_counter()
    engine.extend(theta)
    block(engine.store.counter)
    return engine, time.perf_counter() - t0


def _assert_bitwise(off, on, k):
    """Fused and legacy engines must agree bitwise before a row is
    emitted — counters, then the full selection answer."""
    assert off.cfg.seed == on.cfg.seed, "emitter bug: seeds differ"
    np.testing.assert_array_equal(
        np.asarray(off.store.counter), np.asarray(on.store.counter),
        err_msg="fused vs unfused per-vertex counters diverged")
    s_off, s_on = off.select(k), on.select(k)
    np.testing.assert_array_equal(
        np.asarray(s_off.seeds), np.asarray(s_on.seeds),
        err_msg="fused vs unfused seed sets diverged")
    assert float(s_off.covered_frac) == float(s_on.covered_frac), (
        f"covered_frac diverged: {s_off.covered_frac} vs "
        f"{s_on.covered_frac}")
    assert float(s_off.influence) == float(s_on.influence), (
        f"influence diverged: {s_off.influence} vs {s_on.influence}")
    return s_on


def run(n, m, theta, batch, seed, k, mesh=None, log=print):
    g = rmat_graph(n, m, seed=seed)
    batches = -(-theta // batch)
    # what the dispatch layer would pick for the single-device commit
    # kernel here; sharded cells use the jnp oracle inside shard_map
    impl = "oracle" if mesh is not None else kops.resolve_impl()
    rows, bench = [], []
    for store in STORES:
        kind = "packed" if store == "packed" else "bitmap"
        base = dict(model="IC", batch=batch, max_theta=max(theta, 1 << 20),
                    seed=seed, k=k, store=store)
        off, w_off = _timed_extend(
            g, IMMConfig(fused_pipeline="off", **base), theta, mesh)
        on, w_on = _timed_extend(
            g, IMMConfig(fused_pipeline="auto", **base), theta, mesh)
        sel = _assert_bitwise(off, on, k)
        speedup = w_off / w_on if w_on > 0 else 0.0
        for fused, wall in ((False, w_off), (True, w_on)):
            af = achieved_frac("sample_write_count", wall / batches,
                               B=batch, n=n, kind=kind)
            extra = dict(kernel="sample_write_count", fused=fused,
                         store=store, impl=impl,
                         achieved_frac=round(af, 6))
            if fused:
                extra["speedup"] = round(speedup, 3)
            bench.append(bench_row(
                f"kernel_pipeline/{store}/"
                f"{'fused' if fused else 'unfused'}",
                n=n, theta=theta, wall_s=wall, mesh=mesh, **extra))
            rows.append([store, fused, f"{wall:.3f}", impl, f"{af:.4f}",
                         f"{speedup:.2f}x" if fused else "-"])
        log(f"[kernel-pipeline] store={store}: unfused {w_off:.3f}s, "
            f"fused {w_on:.3f}s ({speedup:.2f}x), influence "
            f"{sel.influence:.1f} bitwise-equal")
    print_table(
        f"Fused RRR pipeline (n={n}, m={m}, theta={theta}, batch={batch},"
        f" mesh={'1' if mesh is None else 'x'.join(map(str, mesh.devices.shape))})",
        ["store", "fused", "wall_s", "impl", "achieved_frac", "speedup"],
        rows)
    return bench


def run_hw(n, batch, seed, log=print):
    """Raw arena-commit kernel, pallas vs oracle, on real hardware only.

    The interpreter is not hardware — timing it says nothing about the
    MXU path — so off-accelerator this section skips cleanly."""
    dk = device_kind()
    if dk not in ("tpu", "gpu"):
        log(f"[kernel-pipeline] device_kind={dk}: skipping the raw "
            "arena_commit hardware section (needs tpu/gpu)")
        return []
    import jax
    rng = np.random.default_rng(seed)
    rows_np = (rng.random((batch, n)) < 0.25).astype(np.uint8)
    bench = []
    for kind in ("bitmap", "packed"):
        for use_pallas in (False, True):
            fn = jax.jit(lambda r, up=use_pallas, kd=kind: kops.arena_commit(
                r, kind=kd, use_pallas=up))
            wall = timeit(fn, jax.numpy.asarray(rows_np))
            impl = "pallas" if use_pallas else "oracle"
            bench.append(bench_row(
                f"arena_commit/{kind}/{impl}", n=n, theta=batch,
                wall_s=wall, kernel="arena_commit", fused=False,
                store=kind, impl=impl,
                achieved_frac=round(achieved_frac(
                    "arena_commit", wall, B=batch, n=n, kind=kind), 6)))
            log(f"[kernel-pipeline] arena_commit {kind}/{impl}: "
                f"{wall * 1e3:.3f}ms")
    return bench


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small cell, same asserts")
    ap.add_argument("--mesh", default=None,
                    help="run the cells on a device mesh (e.g. 2x2)")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--theta", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--out", default="BENCH_10.json",
                    help="machine-readable output path")
    ap.add_argument("--require-speedup", type=float, default=None,
                    help="fail unless some fused cell hits this speedup")
    args = ap.parse_args(argv)
    cell = dict(CELLS["tiny" if args.tiny else "default"])
    for key in ("n", "theta", "batch"):
        if getattr(args, key) is not None:
            cell[key] = getattr(args, key)
    mesh = None
    if args.mesh is not None:
        from repro.configs.imm_snap import make_im_mesh
        mesh = make_im_mesh(args.mesh)
    bench = run(mesh=mesh, **cell)
    bench += run_hw(cell["n"], cell["batch"], cell["seed"])
    if args.require_speedup is not None:
        best = max((r.get("speedup", 0.0) for r in bench), default=0.0)
        assert best >= args.require_speedup, (
            f"best fused speedup {best:.2f}x < required "
            f"{args.require_speedup:.2f}x")
    write_bench(args.out, bench)


if __name__ == "__main__":
    main()
