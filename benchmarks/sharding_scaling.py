"""2D sharding scaling: what does each mesh layout buy per device?

Runs the same IMM workload — ``extend(theta)`` + ``select(k)`` through
the `InfluenceEngine` — on every store layout the available devices
support: single-device, the 1D theta mesh, and every 2D ``Dt x Dv``
factorization of the device count (``make_im_mesh``), each vertex-sharded
layout in both its **equal** (canonical contiguous blocks) and
**edge-balanced** (``IMMConfig.partition="balanced"``, tagged ``+bal``)
column layouts.  For each layout it reports:

  * ``wall_s`` and ``bytes_per_device`` — the resident arena bytes on one
    device, the quantity the 2D refactor exists to shrink: a ``Dt x Dv``
    mesh holds ``ceil(theta / Dt)`` rows x one vertex block of columns
    per device, so theta scales with the theta axis and graph size with
    the vertex axis *simultaneously*.
  * ``imbalance`` — per-tile dst-edge imbalance (max/mean edges per
    vertex block; 1.0 is perfect).  On rmat graphs the balanced layout
    must come out no worse than equal blocks — asserted below, strictly
    better whenever equal blocks are meaningfully skewed.
  * ``collective_s`` / ``compute_s`` — per-step frontier cost split: the
    vertex-axis all-gather the traversal double-buffers vs the local
    logq matmul it hides behind (``0.0`` collective when the layout has
    no vertex axis).

Answers are asserted seed-for-seed identical across every layout *and*
both column layouts before anything is emitted — the bench doubles as
the equivalence gate on real multi-device buffers.

Emits ``BENCH_5.json`` rows ``{name, mesh, n, theta, wall_s,
bytes_per_device, imbalance, collective_s, compute_s}`` (the shared
`benchmarks._emit` schema) next to a human table.

    PYTHONPATH=src python -m benchmarks.sharding_scaling [--tiny] [--out F]

CI runs the ``--tiny`` smoke under a forced 8-device host platform so
the 2x4 / 4x2 / 8x1 / 1x8 layouts all execute with real device buffers,
then asserts the breakdown keys are present in every row (scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from benchmarks._emit import bench_row, mesh_tag, span_median_s, write_bench
from benchmarks._util import block, print_table
from repro import obs
from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.graphs import balance_report, resolve_partition, rmat_graph


def _layouts():
    """Every mesh layout the local devices support: None, the 1D mesh,
    and each 2D factorization Dt x Dv of the device count."""
    d = jax.device_count()
    yield None
    yield make_im_mesh(d)
    for dv in range(1, d + 1):
        if d % dv == 0:
            yield make_im_mesh((d // dv, dv))


def _variants(mesh):
    """Vertex-column layout variants of one mesh: the canonical equal
    blocks always, plus edge-balanced blocks whenever the mesh actually
    shards the vertex axis (on ``Dv == 1`` the two layouts coincide)."""
    yield "equal", ""
    kw = mesh_engine_kwargs(mesh) if mesh is not None else {}
    vx = kw.get("vertex_axis")
    if vx is not None and int(mesh.shape[vx]) > 1:
        yield "balanced", "+bal"


def _arena_bytes_per_device(store) -> int:
    """Resident arena bytes on one device (max over devices: uneven
    theta fills are possible mid-growth)."""
    R = getattr(store, "R", None)
    shards = getattr(R, "addressable_shards", None)
    if not shards:
        return int(R.nbytes)
    return max(int(s.data.nbytes) for s in shards)


def _imbalance(g, mesh, kw, partition) -> float:
    """Per-tile dst-edge imbalance (max edges per vertex block over the
    mean) of this layout — 1.0 is perfect balance; equal blocks on a
    power-law rmat graph typically land well above it."""
    vx = kw.get("vertex_axis")
    if mesh is None or vx is None:
        return 1.0
    dv = int(mesh.shape[vx])
    if dv == 1:
        return 1.0
    part = resolve_partition(partition, g.n, dv, dst=g.edge_dst)
    rep = balance_report(g.edge_dst, g.n, dv, partition=part)
    return float(rep["imbalance"])


_STEP_ITERS = 3


def _timed_span(name, fn, *args):
    """Median seconds of ``fn(*args)`` over ``_STEP_ITERS`` blocked
    iterations, each recorded as an obs span (tier ``bench``), read back
    from the tracer — so the number in the BENCH row is the same
    measurement a ``--trace-out`` timeline would show.  One untimed
    warmup absorbs compilation."""
    block(fn(*args))
    for _ in range(_STEP_ITERS):
        with obs.span(name, tier="bench"):
            block(fn(*args))
    return span_median_s(name, tier="bench", last=_STEP_ITERS)


def _step_breakdown(g, mesh, kw, batch):
    """Median per-step frontier cost split ``(collective_s, compute_s)``.

    ``collective_s`` times the vertex-axis frontier collective the
    traversal loop double-buffers: resharding a ``(B, n)`` frontier from
    ``P(theta, vertex)`` tiles to vertex-replicated (the all-gather that
    overlap issues for step t+1 while step t computes).  ``compute_s``
    times the work it hides behind — the full-width local logq matmul
    producing the next tiled frontier.  Layouts with no vertex axis
    (single device, 1D theta meshes, ``Dv == 1``) have no frontier
    collective: ``collective_s == 0.0``.  Both are measured through obs
    spans (phases ``collective`` / ``compute``), so the trace timeline
    and the BENCH row agree by construction.
    """
    n = g.n
    rng = np.random.default_rng(7)
    frontier = jnp.asarray(rng.random((batch, n)), jnp.float32)
    logq = jnp.asarray(-rng.random((n, n)), jnp.float32)
    matmul = jax.jit(lambda f, w: f @ w)
    vx = kw.get("vertex_axis")
    if mesh is None or vx is None or int(mesh.shape[vx]) == 1:
        return 0.0, _timed_span("compute", matmul, frontier, logq)
    axes = tuple(kw["theta_axes"])
    tiled = NamedSharding(mesh, PartitionSpec(axes, vx))
    gathered = NamedSharding(mesh, PartitionSpec(axes, None))
    f_tiled = jax.device_put(frontier, tiled)
    gather = jax.jit(lambda x: x, out_shardings=gathered)
    f_gathered = block(gather(f_tiled))
    # logq column-sharded over the vertex axis: each device's matmul is
    # (B/Dt, n) @ (n, block) -> its own tile of the next frontier
    w_cols = jax.device_put(logq, NamedSharding(mesh, PartitionSpec(None, vx)))
    return (_timed_span("collective", gather, f_tiled),
            _timed_span("compute", matmul, f_gathered, w_cols))


def run(n=1024, m=8192, theta=4096, k=10, batch=256, seed=0, log=print):
    obs.enable()          # the step breakdown is measured through spans
    g = rmat_graph(n, m, seed=seed)
    base = IMMConfig(k=k, batch=batch, max_theta=max(theta, 1 << 20),
                     seed=seed)
    rows, bench, seeds_ref = [], [], None
    imb_by_tag = {}
    for mesh in _layouts():
        kw = mesh_engine_kwargs(mesh)
        # the breakdown depends on the mesh, not the column layout (the
        # traversal frontier keeps equal GSPMD tiling either way)
        collective_s, compute_s = _step_breakdown(g, mesh, kw, batch)
        for partition, suffix in _variants(mesh):
            tag = mesh_tag(mesh) + suffix
            cfg = dataclasses.replace(base, partition=partition)
            # compile warmup on a throwaway engine (module-level jit
            # caches are shared), so the timed run samples all theta
            # rows from zero
            warm = InfluenceEngine(g, cfg, **kw)
            warm.extend(batch)
            block(warm.select(k).seeds)
            engine = InfluenceEngine(g, cfg, **kw)
            t0 = time.perf_counter()
            engine.extend(theta)
            sel = engine.select(k)
            block(engine.store.counter)
            wall = time.perf_counter() - t0
            if seeds_ref is None:
                seeds_ref = np.asarray(sel.seeds)
            else:
                # the equivalence gate: every layout — mesh shape,
                # column partition, all of them — must answer identically
                np.testing.assert_array_equal(seeds_ref,
                                              np.asarray(sel.seeds))
            per_dev = _arena_bytes_per_device(engine.store)
            imb = _imbalance(g, mesh, kw, partition)
            imb_by_tag[tag] = imb
            bench.append(bench_row(
                "sharding-scaling", mesh=tag, n=n, theta=theta,
                wall_s=wall, bytes_per_device=per_dev, imbalance=imb,
                collective_s=collective_s, compute_s=compute_s))
            shape = ("replicated" if mesh is None else
                     f"{getattr(engine.store, 'cap_local', theta)} rows x "
                     f"{getattr(engine.store, 'n_local', n)} cols/dev")
            rows.append([tag, n, theta, f"{wall:.3f}", f"{per_dev:,}",
                         f"{imb:.3f}", f"{collective_s * 1e3:.2f}",
                         f"{compute_s * 1e3:.2f}", shape])
            log(f"[sharding-scaling] mesh={tag}: {wall:.3f}s, "
                f"{per_dev:,} arena B/device, imbalance {imb:.3f}, "
                f"step {collective_s * 1e3:.2f}ms coll / "
                f"{compute_s * 1e3:.2f}ms comp")
    # balanced blocks must never be worse than equal blocks, and must be
    # strictly better whenever equal blocks are meaningfully skewed (an
    # rmat degree distribution always is once Dv >= 2)
    for tag, bal in imb_by_tag.items():
        if not tag.endswith("+bal"):
            continue
        eq = imb_by_tag[tag[: -len("+bal")]]
        assert bal <= eq + 1e-9, \
            f"balanced layout {tag} is MORE imbalanced: {bal} > {eq}"
        if eq > 1.1:
            assert bal < eq, \
                f"balanced layout {tag} did not improve on equal: " \
                f"{bal} vs {eq}"
    print_table(
        f"2D sharding scaling (n={n}, m={m}, theta={theta}, k={k}, "
        f"{jax.device_count()} device(s); identical seeds asserted)",
        ["mesh", "n", "theta", "wall_s", "arena B/dev", "imbal",
         "coll ms", "comp ms", "per-device tile"],
        rows)
    return bench


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small graph, small theta")
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--m", type=int, default=8192)
    ap.add_argument("--theta", type=int, default=4096)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--out", default="BENCH_5.json",
                    help="machine-readable output path")
    args = ap.parse_args(argv)
    if args.tiny:
        bench = run(n=192, m=1024, theta=256, k=4, batch=64)
    else:
        bench = run(n=args.n, m=args.m, theta=args.theta, k=args.k,
                    batch=args.batch)
    write_bench(args.out, bench)


if __name__ == "__main__":
    main()
