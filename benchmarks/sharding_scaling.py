"""2D sharding scaling: what does each mesh layout buy per device?

Runs the same IMM workload — ``extend(theta)`` + ``select(k)`` through
the `InfluenceEngine` — on every store layout the available devices
support: single-device, the 1D theta mesh, and every 2D ``Dt x Dv``
factorization of the device count (``make_im_mesh``).  For each layout it
reports wall time and **bytes_per_device** — the resident arena bytes on
one device, the quantity the 2D refactor exists to shrink: a ``Dt x Dv``
mesh holds ``ceil(theta / Dt)`` rows x ``ceil(n / Dv)`` vertex columns
per device, so theta scales with the theta axis and graph size with the
vertex axis *simultaneously*.  Answers are asserted seed-for-seed
identical across every layout before anything is emitted — the bench
doubles as the equivalence gate on real multi-device buffers.

Emits ``BENCH_5.json`` rows
``{name, mesh, n, theta, wall_s, bytes_per_device}`` (the shared
`benchmarks._emit` schema) next to a human table.

    PYTHONPATH=src python -m benchmarks.sharding_scaling [--tiny] [--out F]

CI runs the ``--tiny`` smoke under a forced 8-device host platform so
the 2x4 / 4x2 / 8x1 / 1x8 layouts all execute with real device buffers
(see scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from benchmarks._emit import bench_row, mesh_tag, write_bench
from benchmarks._util import block, print_table
from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.graphs import rmat_graph


def _layouts():
    """Every mesh layout the local devices support: None, the 1D mesh,
    and each 2D factorization Dt x Dv of the device count."""
    d = jax.device_count()
    yield None
    yield make_im_mesh(d)
    for dv in range(1, d + 1):
        if d % dv == 0:
            yield make_im_mesh((d // dv, dv))


def _arena_bytes_per_device(store) -> int:
    """Resident arena bytes on one device (max over devices: uneven
    theta fills are possible mid-growth)."""
    R = getattr(store, "R", None)
    shards = getattr(R, "addressable_shards", None)
    if not shards:
        return int(R.nbytes)
    return max(int(s.data.nbytes) for s in shards)


def run(n=1024, m=8192, theta=4096, k=10, batch=256, seed=0, log=print):
    g = rmat_graph(n, m, seed=seed)
    cfg = IMMConfig(k=k, batch=batch, max_theta=max(theta, 1 << 20),
                    seed=seed)
    rows, bench, seeds_ref = [], [], None
    for mesh in _layouts():
        tag = mesh_tag(mesh)
        kw = mesh_engine_kwargs(mesh)
        # compile warmup on a throwaway engine (module-level jit caches
        # are shared), so the timed run samples all theta rows from zero
        warm = InfluenceEngine(g, cfg, **kw)
        warm.extend(batch)
        block(warm.select(k).seeds)
        engine = InfluenceEngine(g, cfg, **kw)
        t0 = time.perf_counter()
        engine.extend(theta)
        sel = engine.select(k)
        block(engine.store.counter)
        wall = time.perf_counter() - t0
        if seeds_ref is None:
            seeds_ref = np.asarray(sel.seeds)
        else:
            # the equivalence gate: every layout must answer identically
            np.testing.assert_array_equal(seeds_ref, np.asarray(sel.seeds))
        per_dev = _arena_bytes_per_device(engine.store)
        bench.append(bench_row(
            "sharding-scaling", mesh=tag, n=n, theta=theta, wall_s=wall,
            bytes_per_device=per_dev))
        shape = ("replicated" if mesh is None else
                 f"{getattr(engine.store, 'cap_local', theta)} rows x "
                 f"{getattr(engine.store, 'n_local', n)} cols/dev")
        rows.append([tag, n, theta, f"{wall:.3f}", f"{per_dev:,}", shape])
        log(f"[sharding-scaling] mesh={tag}: {wall:.3f}s, "
            f"{per_dev:,} arena B/device")
    print_table(
        f"2D sharding scaling (n={n}, m={m}, theta={theta}, k={k}, "
        f"{jax.device_count()} device(s); identical seeds asserted)",
        ["mesh", "n", "theta", "wall_s", "arena B/dev", "per-device tile"],
        rows)
    return bench


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small graph, small theta")
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--m", type=int, default=8192)
    ap.add_argument("--theta", type=int, default=4096)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--out", default="BENCH_5.json",
                    help="machine-readable output path")
    args = ap.parse_args(argv)
    if args.tiny:
        bench = run(n=192, m=1024, theta=256, k=4, batch=64)
    else:
        bench = run(n=args.n, m=args.m, theta=args.theta, k=args.k,
                    batch=args.batch)
    write_bench(args.out, bench)


if __name__ == "__main__":
    main()
