"""IMPack memory: RRR bytes-at-rest and quality-per-byte across codecs.

The IMPack claim is twofold and this bench gates both:

* **Unchanged answers, fewer bytes.**  At a fixed theta the packed
  (bit-packed, 8 vertices/byte) and compressed (token-list) arenas hold
  exactly the same RRR sets as the uint8 bitmap — selections are
  seed-for-seed identical — in a fraction of the resident bytes.  The
  bench runs the same IMM workload through all three at-rest formats
  (plus every mesh layout when multiple devices are available), asserts
  identical seeds, and asserts the headline: packed spends **>= 4x**
  fewer ``bytes_per_device`` than bitmap at identical quality (it is
  8x by construction; compressed must come in under bitmap too, and
  under packed when the rows are sparse — the default rmat parameters
  keep RRR rows sparse so the token lists win).

* **More quality per byte.**  Holding the byte budget fixed instead of
  theta, a denser format fits more RRR sets per device, and more sets
  mean better influence estimates.  The bench grows each store through
  geometric theta checkpoints and emits ``(bytes_per_device,
  influence)`` curve rows per format — at any byte level the packed and
  compressed curves sit at or above bitmap's.

Emits ``BENCH_9.json`` rows (shared `benchmarks._emit` schema):

    {"name": "pack-fixed-theta"|"pack-curve", "mesh", "n", "theta",
     "wall_s", "store", "bytes_per_device", "influence", "covered_frac"}

    PYTHONPATH=src python -m benchmarks.pack_memory [--tiny] [--out F]

CI runs the ``--tiny`` smoke (scripts/ci.sh); the forced-8-device pass
picks up the mesh cells, so the equivalence and byte gates execute on
real multi-device buffers.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from benchmarks._emit import bench_row, mesh_tag, write_bench
from benchmarks._util import block, print_table
from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.graphs import rmat_graph

STORES = ("bitmap", "packed", "compressed")


def _bytes_per_device(store) -> int:
    """Physical resident arena bytes on one device (max over shards)."""
    R = store.R
    shards = getattr(R, "addressable_shards", None)
    if not shards:
        return int(R.nbytes)
    return max(int(s.data.nbytes) for s in shards)


def _layouts():
    """None (single device) plus, with multiple devices, the 1D theta
    mesh and — when the count allows it — a genuinely 2D theta x vertex
    mesh, so the encoded tiles exercise both arena axes."""
    d = jax.device_count()
    yield None
    if d > 1:
        yield make_im_mesh(d)
        if d % 4 == 0 and d > 4:
            yield make_im_mesh((d // 4, 4))


def _cell(g, cfg, mesh, kw, theta, k):
    """One (layout, store) cell: extend + select, timed after a
    throwaway compile warmup; returns (wall_s, bytes/device, result)."""
    warm = InfluenceEngine(g, cfg, **kw)
    warm.extend(min(theta, cfg.batch))
    block(warm.select(k).seeds)
    engine = InfluenceEngine(g, cfg, **kw)
    t0 = time.perf_counter()
    engine.extend(theta)
    res = engine.select(k)
    block(engine.store.counter)
    wall = time.perf_counter() - t0
    return wall, _bytes_per_device(engine.store), res


def run(n=1024, m=4096, theta=2048, k=10, batch=256, seed=0, log=print):
    # low average degree keeps RRR rows sparse — the regime where the
    # compressed token lists undercut even the packed bytes
    g = rmat_graph(n, m, seed=seed)
    bench, rows, seeds_ref = [], [], None
    bytes_at = {}                      # (mesh_tag, store) -> bytes/device
    for mesh in _layouts():
        kw = mesh_engine_kwargs(mesh)
        tag = mesh_tag(mesh)
        for kind in STORES:
            # on a mesh, "auto" is the sharded bitmap arena — the
            # baseline the encoded tiles are measured against
            store = ("auto" if (mesh is not None and kind == "bitmap")
                     else kind)
            cfg = IMMConfig(k=k, batch=batch, store=store, seed=seed,
                            max_theta=max(theta, 1 << 20))
            wall, per_dev, res = _cell(g, cfg, mesh, kw, theta, k)
            if seeds_ref is None:
                seeds_ref = np.asarray(res.seeds)
            else:
                # the equivalence gate: every at-rest format on every
                # layout answers bit-identically
                np.testing.assert_array_equal(seeds_ref,
                                              np.asarray(res.seeds))
            bytes_at[(tag, kind)] = per_dev
            bench.append(bench_row(
                "pack-fixed-theta", mesh=tag, n=n, theta=theta,
                wall_s=wall, store=kind, bytes_per_device=per_dev,
                influence=res.influence, covered_frac=res.covered_frac))
            rows.append([tag, kind, theta, f"{wall:.3f}", f"{per_dev:,}",
                         f"{bytes_at[(tag, 'bitmap')] / per_dev:.1f}x",
                         f"{res.influence:.1f}"])
            log(f"[pack-memory] mesh={tag} store={kind}: {wall:.3f}s, "
                f"{per_dev:,} B/device, influence {res.influence:.1f}")
    # the headline byte gates, on every layout that ran
    for (tag, kind), per_dev in bytes_at.items():
        base = bytes_at[(tag, "bitmap")]
        if kind == "packed":
            assert per_dev * 4 <= base, \
                f"packed arena on mesh={tag} is only " \
                f"{base / per_dev:.1f}x smaller than bitmap (need >= 4x)"
        elif kind == "compressed":
            assert per_dev < base, \
                f"compressed arena on mesh={tag} ({per_dev} B) did not " \
                f"beat bitmap ({base} B)"

    # quality-per-byte curves: same workload, geometric theta
    # checkpoints, each store growing in place (single device — the
    # per-row byte ratios are layout-independent)
    checkpoints = [theta >> s for s in (3, 2, 1, 0) if theta >> s >= k]
    for kind in STORES:
        cfg = IMMConfig(k=k, batch=batch, store=kind, seed=seed,
                        max_theta=max(theta, 1 << 20))
        engine = InfluenceEngine(g, cfg)
        seen = set()
        for t in checkpoints:
            engine.extend(t)           # grows to >= t in batch multiples
            t_actual = engine.store.count
            if t_actual in seen:
                continue
            seen.add(t_actual)
            res = engine.select(k)
            per_dev = _bytes_per_device(engine.store)
            bench.append(bench_row(
                "pack-curve", mesh="1", n=n, theta=t_actual, wall_s=0.0,
                store=kind, bytes_per_device=per_dev,
                influence=res.influence, covered_frac=res.covered_frac))
            log(f"[pack-curve] store={kind} theta={t_actual}: "
                f"{per_dev:,} B, influence {res.influence:.1f}")
    print_table(
        f"IMPack bytes at rest (n={n}, m={m}, theta={theta}, k={k}, "
        f"{jax.device_count()} device(s); identical seeds asserted)",
        ["mesh", "store", "theta", "wall_s", "arena B/dev", "vs bitmap",
         "influence"], rows)
    return bench


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small graph, small theta")
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--m", type=int, default=4096)
    ap.add_argument("--theta", type=int, default=2048)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--out", default="BENCH_9.json",
                    help="machine-readable output path")
    args = ap.parse_args(argv)
    if args.tiny:
        bench = run(n=192, m=768, theta=256, k=4, batch=64)
    else:
        bench = run(n=args.n, m=args.m, theta=args.theta, k=args.k,
                    batch=args.batch)
    write_bench(args.out, bench)


if __name__ == "__main__":
    main()
