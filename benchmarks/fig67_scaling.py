"""Paper Figs. 6/7: strong scaling of the selection kernel (IC + LT).

On one CPU device we cannot run 1..128 real chips, so strong scaling is
measured the way the dry-run measures everything else: the selection step
is lowered for meshes of 1..8 host devices (XLA host-platform devices,
subprocess) and per-device HLO cost terms are reported; additionally the
single-device wall time across theta partitions shows the work-efficiency
trend.  The production-mesh numbers live in EXPERIMENTS §Roofline (256/512
chips).

Here: measured wall-time of EfficientIMM vs baseline selection at doubling
theta (the per-worker share of RRRsets halves as workers double — the
work-per-worker proxy of Fig 6/7's x-axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._util import print_table, save_results, timeit
from repro.core.selection import select_dense
from repro.core.sampler import make_logq, sample_ic_dense, sample_lt
from repro.graphs import rmat_graph


def run(n: int = 2048, m: int = 16384, k: int = 10, log=print):
    g = rmat_graph(n, m, seed=0)
    logq = make_logq(g)
    rows, payload = [], {}
    for model in ("IC", "LT"):
        for theta in (512, 1024, 2048, 4096):
            if model == "IC":
                R, _, _ = sample_ic_dense(jax.random.PRNGKey(0), logq,
                                          batch=theta)
            else:
                R, _, _ = sample_lt(jax.random.PRNGKey(0), g.dst_offsets,
                                    g.in_src, g.in_lt_cum, g.in_lt_total,
                                    batch=theta)
            valid = jnp.ones((theta,), bool)
            f_eff = jax.jit(lambda R_, v_: select_dense(R_, v_, k,
                                                        "rebuild"))
            f_rip = jax.jit(lambda R_, v_: select_dense(R_, v_, k,
                                                        "decrement"))
            t_eff = timeit(f_eff, R, valid)
            t_rip = timeit(f_rip, R, valid)
            payload[f"{model}_{theta}"] = {
                "theta": theta, "efficientimm_s": t_eff,
                "ripples_style_s": t_rip}
            rows.append([model, theta, f"{t_rip*1e3:.1f}",
                         f"{t_eff*1e3:.1f}",
                         f"{t_rip/max(t_eff,1e-9):.2f}x"])
    # work-efficiency: time per RRRset should stay ~flat for EfficientIMM
    print_table("Fig 6/7 analogue: selection runtime vs theta",
                ["model", "theta", "baseline ms", "efficientimm ms",
                 "speedup"], rows)
    save_results("fig67_scaling", payload)
    return payload


if __name__ == "__main__":
    run()
