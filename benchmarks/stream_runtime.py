"""Streaming vs re-sampling: what does a graph delta really cost?

Drives a synthetic evolving network (RMAT replica with long-tail churn:
every tick a `GraphDelta` of fringe-edge inserts/deletes/reweights lands)
through two serving strategies:

  * ``stream-refresh``  — `StreamEngine`: apply the delta, invalidate the
    touched resident RRR rows, and `refresh()` only those (same-key
    repair against the mutated graph);
  * ``full-resample``   — the static baseline: rebuild a fresh
    `InfluenceEngine` on the post-delta graph and re-sample all of theta.

Both end in the *identical* store (the streaming equivalence invariant),
so the wall-clock ratio is pure work saved.  A third row reports the
bounded-memory mode (``max_rows`` eviction/compaction) and its selection
quality relative to the unbounded store.

Emits machine-readable ``BENCH_3.json`` rows
``{name, mesh, n, theta, wall_s}`` (the shared `benchmarks._emit`
schema) next to a human table.

    PYTHONPATH=src python -m benchmarks.stream_runtime [--tiny] [--out F]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks._emit import bench_row, write_bench
from benchmarks._util import block, print_table
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.core.store import StorePressurePolicy
from repro.graphs import rmat_graph
from repro.stream import StreamEngine, random_delta


def _deltas_for(stream, ticks, rng, ops):
    """Pre-generate the tick deltas against the evolving graph."""
    deltas = []
    g = stream.graph
    for _ in range(ticks):
        d = random_delta(g, rng, inserts=ops, deletes=ops, reweights=ops,
                         max_dst_indeg=8)
        deltas.append(d)
        g = d.apply(g)
    return deltas


def run(n=1024, m=8192, theta=4096, k=10, batch=256, ticks=5, ops=4,
        cap_frac=0.5, seed=0, log=print):
    cfg = IMMConfig(k=k, batch=batch, max_theta=max(theta, 1 << 20),
                    seed=seed)
    # weighted-cascade probabilities: the realistic small-RRR-set regime
    # (uniform U(0,1) probs make nearly every set span the giant SCC, so
    # *any* delta invalidates everything and no incremental scheme can win)
    g = rmat_graph(n, m, seed=seed, weighted_ic="wc")
    rows, bench = [], []

    def record(name, wall, extra=""):
        bench.append(bench_row(name, n=n, theta=theta, wall_s=wall))
        rows.append([name, n, theta, f"{wall:.3f}", extra])

    # ---- streaming: invalidate + same-key repair per tick -----------------
    stream = StreamEngine(g, cfg)
    t0 = time.perf_counter()
    stream.extend(theta)
    block(stream.store.counter)
    record("initial-sample", time.perf_counter() - t0)

    deltas = _deltas_for(stream, ticks, np.random.default_rng(seed + 1), ops)
    stale_total = 0
    t0 = time.perf_counter()
    for d in deltas:
        stale_total += stream.apply_delta(d)
        stream.refresh()
    block(stream.store.counter)
    t_stream = time.perf_counter() - t0
    record("stream-refresh", t_stream,
           f"{stale_total} rows repaired over {ticks} deltas")

    # ---- baseline: fresh engine + full re-sample per tick -----------------
    graphs, gg = [], g
    from repro.stream.delta import canonicalize
    gg = canonicalize(g)
    for d in deltas:
        gg = d.apply(gg)
        graphs.append(gg)
    t0 = time.perf_counter()
    for gg in graphs:
        # same (delta-stable) sampler as the stream, so the two
        # strategies do identical per-row work and end in identical stores
        fresh = InfluenceEngine(gg, stream.cfg)
        fresh.extend(theta)
    block(fresh.store.counter)
    t_full = time.perf_counter() - t0
    record("full-resample", t_full, f"{ticks} full re-samples")

    # equivalence sanity: both strategies end in the same store
    assert stream.stale == 0
    np.testing.assert_array_equal(np.asarray(stream.store.counter),
                                  np.asarray(fresh.store.counter))

    # ---- bounded-memory mode ---------------------------------------------
    cap = max(int(theta * cap_frac) // batch * batch, batch)
    bounded = StreamEngine(g, cfg, policy=StorePressurePolicy(max_rows=cap))
    bounded.extend(theta)
    t0 = time.perf_counter()
    for d in deltas:
        bounded.apply_delta(d)
        bounded.refresh()
    block(bounded.store.counter)
    t_bound = time.perf_counter() - t0
    assert bounded.store.capacity <= cap
    sb = bounded.select(k)
    su = stream.select(k)
    sigma_b, sigma_u = stream.influences([sb.seeds, su.seeds])
    quality = float(sigma_b) / max(float(sigma_u), 1e-9)
    record("stream-bounded", t_bound,
           f"cap={cap} rows, quality {quality * 100:.1f}% of unbounded")

    print_table(
        f"Streaming vs re-sample (n={n}, theta={theta}, {ticks} deltas "
        f"x {3 * ops} ops)",
        ["strategy", "n", "theta", "wall_s", "notes"], rows)
    log(f"speedup (full-resample / stream-refresh): "
        f"{t_full / max(t_stream, 1e-9):.2f}x; bounded quality "
        f"{quality * 100:.1f}%")
    return bench, quality


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: small graph, few ticks")
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--m", type=int, default=8192)
    ap.add_argument("--theta", type=int, default=4096)
    ap.add_argument("--ticks", type=int, default=5)
    ap.add_argument("--out", default="BENCH_3.json",
                    help="machine-readable output path")
    args = ap.parse_args(argv)
    if args.tiny:
        bench, _ = run(n=192, m=1024, theta=512, batch=128, ticks=2, ops=2)
    else:
        bench, _ = run(n=args.n, m=args.m, theta=args.theta,
                       ticks=args.ticks)
    write_bench(args.out, bench)


if __name__ == "__main__":
    main()
