"""Paper Table II: NUMA-aware data placement -> bitmap-check overhead.

TPU adaptation: the analogue of "checking the visited bitmap" during BFS is
the frontier-expansion step's memory traffic; the analogue of NUMA-aware
placement is the dense log-semiring formulation whose bitmap reads are
MXU-tiled (kernels/ic_frontier.py) versus the edge-list scatter whose reads
are random-access.  We compare the HLO byte traffic per BFS step of the two
samplers at matched (n, m) and report the fraction of step traffic spent on
the visited/bitmap data structures (tagged).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._util import print_table, save_results
from repro.core.sampler import make_logq, sample_ic_dense, sample_ic_sparse
from repro.configs.imm_snap import IMM_EXPERIMENTS
from repro.graphs.datasets import scaled_snap
from repro.launch.hlo_analysis import analyze_module

GRAPHS = ["com-Amazon", "com-YouTube", "soc-Pokec", "com-LJ", "web-Google"]


def run(batch: int = 256, log=print):
    rows, payload = [], {}
    for name in GRAPHS:
        exp = IMM_EXPERIMENTS[name]
        g = scaled_snap(name, exp.bench_scale, seed=0)
        if g.n > 2048:
            g = scaled_snap(name, exp.bench_scale * 2048 / g.n, seed=0)
        logq = make_logq(g)
        c_dense = jax.jit(
            lambda key: sample_ic_dense(key, logq, batch=batch,
                                        max_steps=8)
        ).lower(jax.random.PRNGKey(0)).compile()
        c_sparse = jax.jit(
            lambda key: sample_ic_sparse(
                key, g.edge_src, g.edge_dst, g.in_prob, n_nodes=g.n,
                batch=batch, max_steps=8)
        ).lower(jax.random.PRNGKey(0)).compile()
        # data-dependent while conditions -> per-step traffic via
        # default_trip=8 (matched across both paths)
        b_dense = analyze_module(c_dense.as_text(), default_trip=8).bytes
        b_sparse = analyze_module(c_sparse.as_text(), default_trip=8).bytes
        payload[name] = {
            "n": g.n, "m": g.m,
            "bytes_mxu_layout": b_dense, "bytes_scatter_layout": b_sparse,
            "improvement": 1.0 - b_dense / max(b_sparse, 1),
        }
        rows.append([name, g.n, f"{b_sparse/1e6:.1f}",
                     f"{b_dense/1e6:.1f}",
                     f"{100*(1-b_dense/max(b_sparse,1)):.0f}%"])
    print_table(
        "Table II analogue: BFS-step traffic, scatter vs MXU layout (MB)",
        ["graph", "n", "scatter MB", "mxu MB", "improvement"], rows)
    save_results("table2_layout", payload)
    return payload


if __name__ == "__main__":
    run()
