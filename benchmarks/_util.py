"""Shared benchmark utilities: timing, table printing, result registry."""
from __future__ import annotations

import json
import os
import time

import jax


RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "bench")


def block(x):
    return jax.tree.map(
        lambda a: a.block_until_ready()
        if hasattr(a, "block_until_ready") else a, x)


def timeit(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    """Median wall time of fn(*args) with device sync."""
    for _ in range(warmup):
        block(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def print_table(title: str, headers, rows):
    print(f"\n== {title} ==")
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows
              else len(str(h)) for i, h in enumerate(headers)]
    line = "  ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))


def save_results(name: str, payload):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path
