"""Sampler matrix: what does each traversal backend cost per model?

Times `InfluenceEngine.extend(theta)` — graph preprocessing excluded,
sampling + store writes included — for every coin model (IC, WC, GT)
across the three frontier backends (``dense`` log-semiring mat-vec,
``sparse`` CSC edge-list expansion, ``pallas`` — the fused MXU
``kernels/ic_frontier.py`` step on TPU, its bitwise-equivalent jnp
oracle elsewhere via ``kernels/ops.py`` dispatch), plus the LT walk row.
Every backend samples the same distribution per model (dense and pallas
are coin-for-coin identical), so the wall-clock spread is pure execution
strategy.

Emits machine-readable ``BENCH_4.json`` rows
``{name, mesh, n, theta, wall_s, model, backend}`` (the shared
`benchmarks._emit` schema; ``name`` is the composed ``model/backend``)
next to a human table.

    PYTHONPATH=src python -m benchmarks.sampler_matrix [--tiny] [--out F]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks._emit import bench_row, write_bench
from benchmarks._util import block, print_table
from repro.configs.imm_snap import (
    SAMPLER_MATRIX_BACKENDS, SAMPLER_MATRIX_CELLS,
)
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.core.sampler import sampler_matrix
from repro.graphs import rmat_graph


def _cells():
    """Every registered matrix cell whose backend is in the bench grid
    (plus walk rows) — a model added via `register_model` before this
    runs shows up in BENCH_4 automatically."""
    for model, backend in sampler_matrix():
        if backend in SAMPLER_MATRIX_BACKENDS or backend == "walk":
            yield model, backend


def run(n=1024, m=8192, theta=4096, batch=256, seed=0, log=print):
    # default U(0,1) edge probabilities (the paper's IC setup): every
    # model row then times a *distinct* workload — with weighted_ic="wc"
    # the IC rows would duplicate the WC rows coin-for-coin
    g = rmat_graph(n, m, seed=seed)
    rows, bench = [], []
    for model, backend in _cells():
        cfg = IMMConfig(model=model, backend=backend, batch=batch,
                        max_theta=max(theta, 1 << 20), seed=seed)
        # compile warmup on a throwaway engine (module-level jit caches
        # are shared), so the timed run samples all theta rows from zero
        warm = InfluenceEngine(g, cfg)
        warm.extend(batch)
        block(warm.store.counter)
        engine = InfluenceEngine(g, cfg)
        t0 = time.perf_counter()
        engine.extend(theta)
        block(engine.store.counter)
        wall = time.perf_counter() - t0
        mean_size = float(np.asarray(engine.store.sizes)
                          [:engine.store.count].mean())
        bench.append(bench_row(
            f"{model}/{backend}", n=n, theta=theta, wall_s=wall,
            model=model, backend=backend))
        rows.append([model, backend, n, theta, f"{wall:.3f}",
                     f"mean |RRR| {mean_size:.1f}"])
        log(f"[sampler-matrix] {engine.sampler_name}: {wall:.3f}s "
            f"to theta={theta}")
    print_table(
        f"Sampler matrix (n={n}, m={m}, theta={theta}, batch={batch})",
        ["model", "backend", "n", "theta", "wall_s", "notes"], rows)
    return bench


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: the 'tiny' cell from "
                         "configs/imm_snap.SAMPLER_MATRIX_CELLS")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--theta", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--out", default="BENCH_4.json",
                    help="machine-readable output path")
    args = ap.parse_args(argv)
    cell = dict(SAMPLER_MATRIX_CELLS["tiny" if args.tiny else "default"])
    for k in ("n", "m", "theta", "batch"):
        if getattr(args, k) is not None:
            cell[k] = getattr(args, k)
    bench = run(**cell)
    write_bench(args.out, bench)


if __name__ == "__main__":
    main()
