"""One schema for every BENCH_*.json emitter.

Every benchmark in this repo reports machine-readable rows with the same
five core keys —

    {"name": ..., "mesh": ..., "n": ..., "theta": ..., "wall_s": ...}

— plus two provenance keys stamped automatically at write time —

    {"git_sha": ..., "device_kind": ...}

— plus bench-specific extras (``model``/``backend`` for the sampler
matrix, ``bytes_per_device`` for the sharding scaling bench,
``p50_ms``/``p99_ms``/``cache_hit_rate`` for the serving tier, ...), so
the benchmark-trajectory tooling can diff any two BENCH files without
per-bench parsers.  ``mesh`` is the layout tag: ``"1"`` for
single-device, ``"R"`` for a 1D theta mesh, ``"RxC"`` for a 2D
theta x vertex mesh (`mesh_tag` derives it from a ``jax.sharding.Mesh``).
``git_sha`` is the commit the numbers were measured at and
``device_kind`` the platform they were measured on (``cpu``/``tpu``/
``gpu``) — committed BENCH files are only comparable when both match.

Two *optional* cross-bench keys exist beyond the extras free-for-all
(PR 10): ``impl`` — which kernel implementation actually ran
(``pallas``/``interpret``/``oracle``, as proven by the
``kernels.dispatch`` obs counter rather than inferred from
``device_kind``) — and ``achieved_frac`` — the measured fraction of the
roofline bound per ``repro.launch.roofline.achieved_frac``.  They are
validated *when present* (`OPTIONAL_KEYS`), so BENCH files written
before they existed still pass the schema gate unchanged.

Use `bench_row` to build rows and `write_bench` to emit the file — both
validate the schema, so a bench cannot silently drop a core key.
"""
from __future__ import annotations

import json
import statistics
import subprocess

SCHEMA_KEYS = ("name", "mesh", "n", "theta", "wall_s")
STAMP_KEYS = ("git_sha", "device_kind")
# optional cross-bench keys: validators run only when the key is present,
# so rows (and whole files) written before a key existed still validate
OPTIONAL_KEYS = {
    "impl": lambda v: v in ("pallas", "interpret", "oracle"),
    "achieved_frac": lambda v: (isinstance(v, (int, float))
                                and 0.0 <= float(v) <= 1.0),
}


def git_sha() -> str:
    """Short commit sha of the working tree, with a ``-dirty`` suffix
    when it carries uncommitted changes ("unknown" outside git)."""
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty"],
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:
        # no git binary, no checkout, an unreadable .git, a sandboxed
        # interpreter without subprocess — a bench must still emit,
        # just unstamped
        return "unknown"


def span_median_s(name: str, tier: str = None, last: int = None) -> float:
    """Median duration (seconds) of the completed ``repro.obs`` spans
    named ``name`` — the tracer-backed replacement for hand-rolled
    timer lists, so a BENCH row and a ``--trace-out`` timeline report
    the same measurement.  ``last`` keeps only the most recent N spans
    (repeated measurements in one process would otherwise mix);
    returns 0.0 when nothing was recorded."""
    from repro import obs
    durs = obs.get_tracer().durations_s(name, tier)
    if last is not None:
        durs = durs[-int(last):]
    if not durs:
        return 0.0
    return float(statistics.median(durs))


def snapshot_scalar(snapshot: dict, name: str, default: float = 0.0):
    """Pull one scalar out of a ``repro.obs`` registry snapshot by
    series key: counters return their count, gauges their last value,
    histograms their p50 — so BENCH emitters can lift columns straight
    from the runtime telemetry instead of keeping parallel counters."""
    if name in snapshot.get("counters", {}):
        return snapshot["counters"][name]
    if name in snapshot.get("gauges", {}):
        return snapshot["gauges"][name]["value"]
    if name in snapshot.get("histograms", {}):
        return snapshot["histograms"][name]["p50"]
    return default


def device_kind() -> str:
    """Accelerator platform of device 0 (``cpu``/``gpu``/``tpu``)."""
    try:
        import jax
        return jax.devices()[0].platform
    except Exception:
        return "unknown"


def mesh_tag(mesh) -> str:
    """Layout tag for a mesh: ``"1"`` (None), ``"R"`` (1D), ``"RxC"``
    (2D, theta x vertex axis order as built by
    ``configs.imm_snap.make_im_mesh``)."""
    if mesh is None:
        return "1"
    sizes = tuple(int(mesh.shape[a]) for a in mesh.axis_names)
    return "x".join(str(s) for s in sizes)


def bench_row(name: str, *, n: int, theta: int, wall_s: float,
              mesh=None, **extra) -> dict:
    """One schema-conformant benchmark row.  ``mesh`` may be None, a
    ``jax.sharding.Mesh``, or a pre-built tag string; ``extra`` keys ride
    along after the core five.  Provenance (`STAMP_KEYS`) is stamped by
    `write_bench`."""
    tag = mesh if isinstance(mesh, str) else mesh_tag(mesh)
    row = {"name": str(name), "mesh": tag, "n": int(n),
           "theta": int(theta), "wall_s": round(float(wall_s), 4)}
    for k, v in extra.items():
        if k in row:
            raise ValueError(f"extra key {k!r} collides with the schema")
        row[k] = v
    return row


def write_bench(path: str, rows: list[dict]) -> str:
    """Validate, stamp provenance (``git_sha``, ``device_kind`` — once
    per file, identical on every row), and write BENCH rows; returns
    ``path``."""
    stamp = {"git_sha": git_sha(), "device_kind": device_kind()}
    for i, row in enumerate(rows):
        missing = [k for k in SCHEMA_KEYS if k not in row]
        if missing:
            raise ValueError(f"bench row {i} is missing {missing}: {row}")
        for k, ok in OPTIONAL_KEYS.items():
            if k in row and not ok(row[k]):
                raise ValueError(
                    f"bench row {i} has malformed optional key "
                    f"{k}={row[k]!r}: {row}")
        for k in STAMP_KEYS:
            row.setdefault(k, stamp[k])
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {path} ({len(rows)} rows)")
    return path
