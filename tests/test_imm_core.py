"""IMM core: martingale bounds, samplers, selection, Algorithm-1 driver."""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import martingale as mg
from repro.core.imm import imm, IMMConfig
from repro.core.sampler import (
    make_logq, sample_ic_dense, sample_ic_sparse, sample_lt,
)
from repro.core.selection import select_dense, select_sparse
from repro.core.adaptive import (
    choose_representation, bitmap_to_indices, indices_to_bitmap,
)
from repro.graphs import star_graph, path_graph, rmat_graph, erdos_graph


# ------------------------------------------------------------ martingale ----

def test_bounds_monotone_in_eps():
    b1 = mg.compute_bounds(10_000, 50, 0.5)
    b2 = mg.compute_bounds(10_000, 50, 0.25)
    assert b2.lam_prime > b1.lam_prime       # smaller eps -> more samples
    assert b2.lam_star > b1.lam_star


def test_round_theta_doubles():
    b = mg.compute_bounds(10_000, 50, 0.5)
    assert mg.round_theta(b, 2) == pytest.approx(
        2 * mg.round_theta(b, 1), rel=0.01)


def test_theta_from_lb_decreases_with_lb():
    b = mg.compute_bounds(10_000, 50, 0.5)
    assert mg.theta_from_lb(b, 1000.0) < mg.theta_from_lb(b, 100.0)


def test_tang15_formula_spotcheck():
    """lambda' literal recomputation (Tang'15 Eq. in §4.2)."""
    n, k, eps = 1000, 10, 0.5
    b = mg.compute_bounds(n, k, eps)
    ell = 1.0 * (1 + math.log(2) / math.log(n))
    epsp = math.sqrt(2) * eps
    expect = ((2 + 2 / 3 * epsp)
              * (mg.log_comb(n, k) + ell * math.log(n)
                 + math.log(max(math.log2(n), 1)))
              * n / epsp ** 2)
    assert b.lam_prime == pytest.approx(expect, rel=1e-9)


# -------------------------------------------------------------- samplers ----

def test_ic_dense_star_closed_form():
    """Star 0->i with prob p: RRR(root=i) contains 0 w.p. p."""
    p = 0.7
    g = star_graph(64, p=p)
    logq = make_logq(g)
    hits, tot = 0, 0
    for s in range(6):
        visited, counter, roots = sample_ic_dense(
            jax.random.PRNGKey(s), logq, batch=512)
        spoke = np.asarray(roots) != 0
        hits += int(np.asarray(visited)[spoke, 0].sum())
        tot += int(spoke.sum())
    assert hits / tot == pytest.approx(p, abs=0.03)


def test_ic_dense_vs_sparse_distribution():
    """Dense (log-semiring) and sparse (per-edge coin) samplers agree in
    expected RRR size on the same graph."""
    g = rmat_graph(128, 1024, seed=3)
    logq = make_logq(g)
    v1, c1, _ = sample_ic_dense(jax.random.PRNGKey(0), logq, batch=1024)
    v2, c2, _ = sample_ic_sparse(
        jax.random.PRNGKey(1), g.edge_src, g.edge_dst, g.in_prob,
        n_nodes=g.n, batch=1024)
    s1 = float(np.asarray(v1).sum(1).mean())
    s2 = float(np.asarray(v2).sum(1).mean())
    assert s1 == pytest.approx(s2, rel=0.12), (s1, s2)


def test_ic_sparse_path_reachability():
    """Path 0->1->...->n-1 with p=1: RRR(root) = {0..root}."""
    g = path_graph(16, p=1.0)
    visited, _, roots = sample_ic_sparse(
        jax.random.PRNGKey(0), g.edge_src, g.edge_dst, g.in_prob,
        n_nodes=g.n, batch=64)
    v = np.asarray(visited)
    r = np.asarray(roots)
    for b in range(64):
        expect = np.zeros(16, np.uint8)
        expect[: r[b] + 1] = 1
        np.testing.assert_array_equal(v[b], expect)


def test_lt_walk_is_path_and_counter_fused():
    g = rmat_graph(128, 1024, seed=4)
    visited, counter, roots = sample_lt(
        jax.random.PRNGKey(0), g.dst_offsets, g.in_src, g.in_lt_cum,
        g.in_lt_total, batch=256)
    v = np.asarray(visited)
    # root always in the set; counter equals fused column sums (paper C3)
    assert (v[np.arange(256), np.asarray(roots)] == 1).all()
    np.testing.assert_array_equal(np.asarray(counter), v.sum(0))


def test_rrrsets_contain_root_ic():
    g = rmat_graph(64, 256, seed=5)
    logq = make_logq(g)
    visited, _, roots = sample_ic_dense(jax.random.PRNGKey(2), logq,
                                        batch=128)
    v = np.asarray(visited)
    assert (v[np.arange(128), np.asarray(roots)] == 1).all()


# -------------------------------------------------------------- selection ----

def _numpy_greedy(R, valid, k):
    """Brute-force greedy max-coverage oracle."""
    R = np.asarray(R).astype(bool)
    alive = np.asarray(valid).copy()
    seeds, gains = [], []
    for _ in range(k):
        counter = R[alive].sum(axis=0)
        v = int(np.argmax(counter))
        covered = alive & R[:, v]
        seeds.append(v)
        gains.append(int(covered.sum()))
        alive = alive & ~R[:, v]
    return seeds, gains


@pytest.mark.parametrize("method", ["rebuild", "decrement"])
def test_select_dense_matches_numpy_greedy(method):
    rng = np.random.default_rng(0)
    R = (rng.random((80, 40)) < 0.2).astype(np.uint8)
    valid = np.ones(80, bool)
    valid[70:] = False
    seeds, frac, gains = select_dense(jnp.asarray(R), jnp.asarray(valid),
                                      5, method)
    ref_seeds, ref_gains = _numpy_greedy(R, valid, 5)
    np.testing.assert_array_equal(np.asarray(gains), ref_gains)
    # seeds may differ on argmax ties only; gains equality is the guarantee
    assert float(frac) == pytest.approx(sum(ref_gains) / 70.0)


def test_rebuild_equals_decrement():
    """Paper C5: the adaptive rebuild is algebraically identical to the
    decremental baseline."""
    rng = np.random.default_rng(1)
    R = (rng.random((120, 64)) < 0.15).astype(np.uint8)
    valid = jnp.ones((120,), bool)
    s1, f1, g1 = select_dense(jnp.asarray(R), valid, 8, "rebuild")
    s2, f2, g2 = select_dense(jnp.asarray(R), valid, 8, "decrement")
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    assert float(f1) == pytest.approx(float(f2))


def test_select_sparse_matches_dense():
    rng = np.random.default_rng(2)
    R = (rng.random((60, 32)) < 0.25).astype(np.uint8)
    valid = jnp.ones((60,), bool)
    R_idx = bitmap_to_indices(jnp.asarray(R), 16)
    sd, fd, gd = select_dense(jnp.asarray(R), valid, 4)
    ss, fs, gs = select_sparse(R_idx, valid, 32, 4)
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(gs))


def test_greedy_gains_non_increasing():
    """Submodularity: marginal gains decrease."""
    rng = np.random.default_rng(3)
    R = (rng.random((100, 50)) < 0.3).astype(np.uint8)
    _, _, gains = select_dense(jnp.asarray(R), jnp.ones((100,), bool), 10)
    g = np.asarray(gains)
    assert (g[:-1] >= g[1:]).all()


# -------------------------------------------------------------- adaptive ----

def test_bitmap_index_roundtrip():
    rng = np.random.default_rng(4)
    R = (rng.random((30, 25)) < 0.3).astype(np.uint8)
    l_max = int(R.sum(1).max())
    idx = bitmap_to_indices(jnp.asarray(R), l_max)
    R2 = indices_to_bitmap(idx, 25)
    np.testing.assert_array_equal(np.asarray(R2), R)


def test_choose_representation_thresholds():
    assert choose_representation(0.5, 1000, 100) == "bitmap"
    assert choose_representation(0.001, 100_000, 10) == "indices"
    # long index lists force bitmap regardless of coverage
    assert choose_representation(0.001, 1000, 900) == "bitmap"


# ------------------------------------------------------------ driver ----

@pytest.mark.parametrize("model", ["IC", "LT"])
def test_imm_end_to_end(model):
    g = rmat_graph(256, 2048, seed=1)
    res = imm(g, IMMConfig(k=5, model=model, batch=128, max_theta=1024))
    assert len(res.seeds) == 5
    assert len(set(int(s) for s in res.seeds)) == 5   # distinct seeds
    assert 0.0 < res.covered_frac <= 1.0
    assert res.influence == pytest.approx(res.covered_frac * g.n)


def test_imm_star_picks_hub():
    g = star_graph(64, p=0.9)
    res = imm(g, IMMConfig(k=1, batch=256, max_theta=2048))
    assert res.seeds[0] == 0


def test_imm_baseline_equals_efficient():
    """Paper-faithful baseline and EfficientIMM path give identical
    coverage on the same sample stream (same seed)."""
    g = rmat_graph(200, 1600, seed=7)
    r1 = imm(g, IMMConfig(k=4, batch=128, max_theta=512, seed=3,
                          selection_method="rebuild"))
    r2 = imm(g, IMMConfig(k=4, batch=128, max_theta=512, seed=3,
                          selection_method="decrement",
                          adaptive_representation=False))
    assert r1.covered_frac == pytest.approx(r2.covered_frac)
    assert r1.theta == r2.theta


def test_imm_influence_monotone_in_k():
    g = rmat_graph(200, 1600, seed=8)
    infl = [imm(g, IMMConfig(k=k, batch=128, max_theta=512)).influence
            for k in (1, 4, 8)]
    assert infl[0] <= infl[1] <= infl[2]
