"""Sharded selection on local meshes, hlo_analysis, training integration,
and a subprocess production dry-run sanity cell."""
import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.selection import select_dense, select_dense_sharded
from repro.launch.hlo_analysis import analyze_module, parse_module


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_select_dense_sharded_equals_local():
    """The psum-combined sharded selection (paper C1) == single-device."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rng = np.random.default_rng(0)
    R = jnp.asarray((rng.random((64, 32)) < 0.3).astype(np.uint8))
    valid = jnp.ones((64,), bool)
    s1, f1, g1 = select_dense(R, valid, 5)
    s2, f2, g2 = select_dense_sharded(mesh, R, valid, 5,
                                      theta_axes=("data",),
                                      vertex_axis="model")
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    assert float(f1) == pytest.approx(float(f2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


# ----------------------------------------------------------- hlo analysis ----

def test_hlo_analyzer_scan_trip_count():
    def f(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, ws)[0]

    ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    c = jax.jit(f).lower(ws, x).compile()
    counts = analyze_module(c.as_text())
    assert counts.flops == 8 * 2 * 32 * 64 * 64
    assert counts.unknown_trip_loops == 0


def test_hlo_analyzer_nested_and_tags():
    def f(ws, x):
        def outer(x, _):
            def inner(x, w):
                return x @ w, None
            return jax.lax.scan(inner, x, ws)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    ws = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    c = jax.jit(f).lower(ws, x).compile()
    counts = analyze_module(c.as_text())
    assert counts.flops == 3 * 4 * 2 * 16 * 32 * 32
    assert counts.bytes > 0


def test_hlo_parse_module_entry():
    c = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    comps, types, entry = parse_module(c.as_text())
    assert entry is not None and entry in comps


# ---------------------------------------------------------- train integr. ----

def test_train_loop_lm_loss_decreases():
    from repro.launch.train import train_lm
    with tempfile.TemporaryDirectory() as d:
        state, losses, loop = train_lm(
            "qwen1.5-0.5b", smoke=True, steps=40, batch=8, seq_len=32,
            checkpoint_dir=d, save_every=20, log=lambda *a: None)
        assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_train_resume_from_checkpoint():
    from repro.launch.train import train_lm
    with tempfile.TemporaryDirectory() as d:
        _, losses1, _ = train_lm(
            "qwen1.5-0.5b", smoke=True, steps=10, batch=4, seq_len=32,
            checkpoint_dir=d, save_every=5, log=lambda *a: None)
        # second run resumes at step 10 and continues to 20
        _, losses2, loop2 = train_lm(
            "qwen1.5-0.5b", smoke=True, steps=20, batch=4, seq_len=32,
            checkpoint_dir=d, save_every=5, log=lambda *a: None)
        assert loop2.history[0].step == 10


def test_serve_generates():
    from repro.launch.serve import LMServer
    from repro.configs import get_arch
    cfg = get_arch("qwen1.5-0.5b").smoke_config
    server = LMServer(cfg, max_len=64)
    prompts = jax.random.randint(jax.random.PRNGKey(0), (2, 8), 0, cfg.vocab)
    out = server.generate(prompts, 4)
    assert out.shape == (2, 4)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab).all()


def test_im_run_end_to_end():
    from repro.launch.im_run import run
    out = run("com-Amazon", scale=0.002, model="IC", k=5,
              max_theta=512, log=lambda *a: None)
    assert out["influence"] > 0
    assert len(out["seeds"]) >= 5


# ------------------------------------------------- production cell (slow) ----

@pytest.mark.slow
def test_production_dryrun_subprocess_cell():
    """One cheap production cell end-to-end in a fresh process (512 fake
    devices): proves the make_production_mesh + lower + compile path."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "cell.json")
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "fm", "--shape", "serve_p99",
             "--mesh", "both", "--out", out],
            env=env, capture_output=True, text=True, timeout=540)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
        res = json.load(open(out))
        assert len(res) == 2 and all(c["ok"] for c in res)
        assert {c["mesh"] for c in res} == {"16x16", "2x16x16"}
