"""Fused sample->write->count chain and fused selection vs the legacy
two-call path: every comparison here is bitwise (exact array equality,
exact float equality), because the fused pipeline's contract is
seed-for-seed identity, not statistical agreement."""
import numpy as np
import jax
import pytest

from repro.core.engine import IMMConfig, InfluenceEngine
from repro.core.selection import get_selection
from repro.graphs import rmat_graph

N, M, K, BATCH, THETA = 128, 1024, 4, 64, 192


def _graph():
    return rmat_graph(N, M, seed=5)


def _pair(store="auto", mesh_kwargs=None, theta=THETA, **cfg_kw):
    """(legacy engine, fused engine) extended with identical seeds."""
    g = _graph()
    engines = []
    for fp in ("off", "auto"):
        cfg = IMMConfig(k=K, batch=BATCH, max_theta=1024, seed=3,
                        store=store, fused_pipeline=fp, **cfg_kw)
        e = InfluenceEngine(g, cfg, **(mesh_kwargs or {}))
        e.extend(theta)
        engines.append(e)
    return engines


def _assert_bitwise(off, on):
    assert off.store.count == on.store.count
    np.testing.assert_array_equal(np.asarray(off.store.counter),
                                  np.asarray(on.store.counter))
    np.testing.assert_array_equal(
        np.asarray(off.store.sizes)[:off.store.count],
        np.asarray(on.store.sizes)[:on.store.count])
    s_off, s_on = off.select(K), on.select(K)
    np.testing.assert_array_equal(np.asarray(s_off.seeds),
                                  np.asarray(s_on.seeds))
    assert float(s_off.covered_frac) == float(s_on.covered_frac)
    assert float(s_off.influence) == float(s_on.influence)
    # the PRNG stream stayed aligned batch-for-batch
    np.testing.assert_array_equal(np.asarray(off.key), np.asarray(on.key))


# ------------------------------------------------------ single-device chain


@pytest.mark.parametrize("store", ["auto", "packed"])
def test_fused_matches_legacy(store):
    off, on = _pair(store=store)
    _assert_bitwise(off, on)


@pytest.mark.parametrize("model", ["WC", "GT"])
def test_fused_matches_legacy_models(model):
    off, on = _pair(model=model)
    _assert_bitwise(off, on)


@pytest.mark.parametrize("store", ["auto", "packed"])
def test_fused_matches_legacy_interpret(store):
    """cfg.pallas_interpret routes the chain's arena_commit through the
    Pallas interpreter on CPU — still bitwise-equal to the legacy path."""
    off, on = _pair(store=store, pallas_interpret=True)
    _assert_bitwise(off, on)


def test_compressed_store_falls_back_bitwise():
    """Token-compressed tiles are outside the chain; the extender must
    decline and hand the SAME batch key to the legacy path, so the
    stream is preserved across the fused/unfused boundary."""
    off, on = _pair(store="compressed")
    assert on._fused is not None  # built, but declining per batch
    _assert_bitwise(off, on)


def test_fused_pipeline_off_builds_no_extender():
    g = _graph()
    e = InfluenceEngine(g, IMMConfig(k=K, batch=BATCH, max_theta=1024,
                                     fused_pipeline="off"))
    assert e._fused is None


# ----------------------------------------------------------- fused selection


@pytest.mark.parametrize("store", ["auto", "packed", "compressed"])
@pytest.mark.parametrize("method", ["rebuild", "decrement"])
def test_fused_selection_matches_baseline(store, method):
    g = _graph()
    e = InfluenceEngine(g, IMMConfig(k=K, batch=BATCH, max_theta=1024,
                                     seed=3, store=store))
    e.extend(THETA)
    base = e.select(K, method=method)
    fused = e.select(K, method=f"fused-{method}")
    np.testing.assert_array_equal(np.asarray(base.seeds),
                                  np.asarray(fused.seeds))
    assert float(base.covered_frac) == float(fused.covered_frac)
    np.testing.assert_array_equal(np.asarray(base.gains),
                                  np.asarray(fused.gains))


@pytest.mark.parametrize("method", ["rebuild", "decrement"])
def test_fused_selection_interpret(method):
    g = _graph()
    e = InfluenceEngine(g, IMMConfig(k=K, batch=BATCH, max_theta=1024,
                                     seed=3, pallas_interpret=True))
    e.extend(THETA)
    base = e.select(K, method=method)
    fused = e.select(K, method=f"fused-{method}")
    np.testing.assert_array_equal(np.asarray(base.seeds),
                                  np.asarray(fused.seeds))


def test_fused_selection_registry_complete():
    """Every layout a legacy method serves, the fused spelling serves
    too — including the sparse delegations the C4 adaptive switch needs."""
    for method in ("fused-rebuild", "fused-decrement"):
        for layout in ("dense", "packed", "compressed", "sharded",
                       "sparse", "sharded-sparse"):
            assert callable(get_selection(method, layout))


# ------------------------------------------------------------- meshed chain


needs_mesh = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=4)")


@needs_mesh
@pytest.mark.parametrize("store", ["auto", "packed"])
@pytest.mark.parametrize("partition", ["equal", "balanced"])
def test_fused_matches_legacy_sharded(store, partition):
    from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
    mk = mesh_engine_kwargs(make_im_mesh("2x2"))
    off, on = _pair(store=store, mesh_kwargs=mk, partition=partition)
    _assert_bitwise(off, on)


@needs_mesh
def test_fused_sharded_matches_single_device():
    """The meshed fused chain reproduces the single-device stream —
    sharding is layout, never sampling semantics."""
    from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
    _, local = _pair()
    mk = mesh_engine_kwargs(make_im_mesh("2x2"))
    _, meshed = _pair(mesh_kwargs=mk)
    np.testing.assert_array_equal(np.asarray(local.store.counter),
                                  np.asarray(meshed.store.counter))
    s_l, s_m = local.select(K), meshed.select(K)
    np.testing.assert_array_equal(np.asarray(s_l.seeds),
                                  np.asarray(s_m.seeds))
    assert float(s_l.covered_frac) == float(s_m.covered_frac)


@needs_mesh
@pytest.mark.parametrize("method", ["rebuild", "decrement"])
def test_fused_selection_sharded(method):
    from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
    g = _graph()
    mk = mesh_engine_kwargs(make_im_mesh("2x2"))
    e = InfluenceEngine(g, IMMConfig(k=K, batch=BATCH, max_theta=1024,
                                     seed=3, partition="balanced"), **mk)
    e.extend(THETA)
    base = e.select(K, method=method)
    fused = e.select(K, method=f"fused-{method}")
    np.testing.assert_array_equal(np.asarray(base.seeds),
                                  np.asarray(fused.seeds))
    assert float(base.covered_frac) == float(fused.covered_frac)
