"""Unit wall for `select_vertex_partitioned` — the Ripples-faithful
vertex-partitioned binary-search baseline (`repro.core.selection`).

It must agree seed-for-seed with both production representations
(`select_dense` on bitmaps, `select_sparse` on index lists) on the same
row data, including the shapes the padding contract makes awkward:
uneven final blocks (rows whose live index count varies, up to the full
list width) and all-padding tiles (rows that are nothing but the
sentinel ``n``).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.selection import (
    select_dense, select_sparse, select_vertex_partitioned,
)


def _random_sets(rng, theta, n, L, *, empty_rows=(), full_rows=()):
    """(R_idx, R, valid): ascending sentinel-padded index lists, the
    matching bitmap, and an all-true valid mask.  Rows in ``empty_rows``
    get no vertices (all-padding tiles); rows in ``full_rows`` get
    exactly L (no padding at all)."""
    R_idx = np.full((theta, L), n, dtype=np.int32)
    R = np.zeros((theta, n), dtype=np.uint8)
    for t in range(theta):
        if t in empty_rows:
            continue
        size = L if t in full_rows else int(rng.integers(1, L + 1))
        vs = np.sort(rng.choice(n, size=size, replace=False))
        R_idx[t, :size] = vs
        R[t, vs] = 1
    return jnp.asarray(R_idx), jnp.asarray(R), jnp.ones(theta, bool)


def _assert_matches(R_idx, R, valid, n, k):
    seeds, frac, gains = select_vertex_partitioned(R_idx, valid, n, k)
    for ref in (select_dense(R, valid, k, "decrement"),
                select_sparse(R_idx, valid, n, k, "decrement")):
        np.testing.assert_array_equal(np.asarray(seeds),
                                      np.asarray(ref[0]))
        np.testing.assert_allclose(float(frac), float(ref[1]), atol=1e-6)
        np.testing.assert_allclose(np.asarray(gains, np.float32),
                                   np.asarray(ref[2], np.float32))


@pytest.mark.parametrize("seed", range(4))
def test_matches_dense_and_sparse_on_random_sets(seed):
    rng = np.random.default_rng(seed)
    n, theta, L, k = 24, 40, 6, 5
    R_idx, R, valid = _random_sets(rng, theta, n, L)
    _assert_matches(R_idx, R, valid, n, k)


def test_uneven_final_blocks(rng):
    """Rows spanning every fill level — empty, partial, and exactly-L
    (no sentinel at all) — in one store."""
    n, theta, L, k = 16, 12, 5, 4
    R_idx, R, valid = _random_sets(
        rng, theta, n, L, empty_rows=(3,), full_rows=(0, 7, 11))
    assert int((R_idx[0] < n).sum()) == L          # truly unpadded row
    assert int((R_idx[3] < n).sum()) == 0          # truly empty row
    _assert_matches(R_idx, R, valid, n, k)


def test_all_padding_tiles_contribute_nothing(rng):
    """Rows that are pure sentinel padding must act exactly like rows an
    invalid mask removed: same seeds, same gains, and a covered_frac
    normalized over the larger valid count."""
    n, theta, L, k = 20, 10, 4, 3
    R_idx, R, valid = _random_sets(rng, theta, n, L)
    pad = jnp.full((3, L), n, dtype=jnp.int32)
    R_idx_pad = jnp.concatenate([R_idx, pad])
    R_pad = jnp.concatenate([R, jnp.zeros((3, n), jnp.uint8)])
    valid_pad = jnp.concatenate([valid, jnp.ones(3, bool)])
    _assert_matches(R_idx_pad, R_pad, valid_pad, n, k)

    base = select_vertex_partitioned(R_idx, valid, n, k)
    padded = select_vertex_partitioned(R_idx_pad, valid_pad, n, k)
    np.testing.assert_array_equal(np.asarray(base[0]),
                                  np.asarray(padded[0]))
    np.testing.assert_array_equal(np.asarray(base[2]),
                                  np.asarray(padded[2]))
    # only the normalization sees the extra (empty but valid) rows
    assert float(padded[1]) == pytest.approx(
        float(base[1]) * theta / (theta + 3))


def test_valid_mask_is_arbitrary_not_a_prefix(rng):
    """Invalidated rows drop out of counters and coverage entirely."""
    n, theta, L, k = 18, 16, 5, 4
    R_idx, R, _ = _random_sets(rng, theta, n, L)
    valid = jnp.asarray(rng.random(theta) < 0.6)
    _assert_matches(R_idx, R, valid, n, k)
    # equivalence with physically deleting the invalid rows
    keep = np.flatnonzero(np.asarray(valid))
    sub = select_vertex_partitioned(
        jnp.asarray(np.asarray(R_idx)[keep]),
        jnp.ones(keep.size, bool), n, k)
    full = select_vertex_partitioned(R_idx, valid, n, k)
    np.testing.assert_array_equal(np.asarray(full[0]), np.asarray(sub[0]))
    np.testing.assert_allclose(float(full[1]), float(sub[1]), atol=1e-6)


def test_no_valid_rows_gives_zero_coverage():
    n, theta, L, k = 8, 5, 3, 2
    R_idx = jnp.full((theta, L), n, dtype=jnp.int32)
    seeds, frac, gains = select_vertex_partitioned(
        R_idx, jnp.zeros(theta, bool), n, k)
    assert float(frac) == 0.0
    assert np.all(np.asarray(gains) == 0)
    assert np.asarray(seeds).shape == (k,)


def test_k_exceeding_distinct_coverage_pads_with_zero_gain(rng):
    """Once every set is covered the remaining rounds add zero gain and
    the covered fraction saturates (== dense behavior)."""
    n, theta, L = 10, 6, 3
    R_idx, R, valid = _random_sets(rng, theta, n, L)
    k = n  # far more rounds than useful seeds
    seeds, frac, gains = select_vertex_partitioned(R_idx, valid, n, k)
    d_seeds, d_frac, d_gains = select_dense(R, valid, k, "decrement")
    np.testing.assert_allclose(float(frac), float(d_frac), atol=1e-6)
    assert float(frac) == pytest.approx(1.0)
    g = np.asarray(gains)
    assert g.sum() == theta and np.all(g[np.argmin(g):] >= 0)
