"""GNN architectures: equivariance, chunked-vs-flat, oracle aggregation."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.gnn.egnn import EGNNConfig, init_egnn
from repro.models.gnn import egnn as m_egnn
from repro.models.gnn.equiformer import EquiformerConfig, init_equiformer
from repro.models.gnn import equiformer as m_eq
from repro.models.gnn.graphcast import GraphCastConfig, init_graphcast
from repro.models.gnn import graphcast as m_gc
from repro.models.gnn.graphsage import SageConfig, init_sage
from repro.models.gnn import graphsage as m_sage
from repro.models.gnn.irreps import (
    rotation_to_align_z, wigner_d_stack, sph_harm_from_wigner,
)
from repro.graphs.sampler import neighbor_sampler


def _graph(n=14, e=50, seed=0, d_feat=8):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return (jax.random.normal(ks[0], (n, d_feat)),
            jax.random.normal(ks[1], (n, 3)),
            jax.random.randint(ks[2], (e,), 0, n),
            jax.random.randint(ks[3], (e,), 0, n))


def _rotation(th=0.6):
    return jnp.array([[np.cos(th), -np.sin(th), 0.0],
                      [np.sin(th), np.cos(th), 0.0],
                      [0.0, 0.0, 1.0]])


# ------------------------------------------------------------------ EGNN ----

def test_egnn_equivariance():
    cfg = EGNNConfig(n_layers=2, d_hidden=24, d_feat=8)
    p = init_egnn(jax.random.PRNGKey(0), cfg)
    nf, pos, es, ed = _graph()
    R, t = _rotation(), jnp.array([1.0, -2.0, 0.5])
    h1, x1, e1 = m_egnn.forward_edges(p, cfg, nf, pos, es, ed, 14)
    h2, x2, e2 = m_egnn.forward_edges(p, cfg, nf, pos @ R.T + t, es, ed, 14)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(x1 @ R.T + t),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h1),
                               rtol=1e-4, atol=1e-4)
    assert float(e1) == pytest.approx(float(e2), rel=1e-4)


def test_egnn_permutation_equivariance():
    cfg = EGNNConfig(n_layers=1, d_hidden=16, d_feat=8)
    p = init_egnn(jax.random.PRNGKey(0), cfg)
    nf, pos, es, ed = _graph()
    perm = np.random.default_rng(0).permutation(14)
    inv = np.argsort(perm)
    h1, x1, _ = m_egnn.forward_edges(p, cfg, nf, pos, es, ed, 14)
    h2, x2, _ = m_egnn.forward_edges(
        p, cfg, nf[perm], pos[perm],
        jnp.asarray(inv)[es], jnp.asarray(inv)[ed], 14)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h1)[perm],
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- Equiformer ----

EQ_CFG = EquiformerConfig(n_layers=2, d_hidden=16, l_max=2, m_max=1,
                          n_heads=2, d_feat=8, remat=False)


def test_equiformer_rotation_invariant_outputs():
    p = init_equiformer(jax.random.PRNGKey(0), EQ_CFG)
    nf, pos, es, ed = _graph()
    R = _rotation(0.8)
    inv1, o1 = m_eq.forward_edges(p, EQ_CFG, nf, pos, es, ed, 14)
    inv2, o2 = m_eq.forward_edges(p, EQ_CFG, nf, pos @ R.T, es, ed, 14)
    np.testing.assert_allclose(np.asarray(inv1), np.asarray(inv2),
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-3, atol=1e-4)


def test_equiformer_chunked_equals_flat():
    p = init_equiformer(jax.random.PRNGKey(0), EQ_CFG)
    nf, pos, es, ed = _graph(e=48)
    _, o1 = m_eq.forward_edges(p, EQ_CFG, nf, pos, es, ed, 14)
    _, o2 = m_eq.forward_edges(p, EQ_CFG, nf, pos,
                               es.reshape(6, 8), ed.reshape(6, 8), 14)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-5)


def test_equiformer_sentinel_padding_dropped():
    p = init_equiformer(jax.random.PRNGKey(0), EQ_CFG)
    nf, pos, es, ed = _graph(e=48)
    es_p = jnp.concatenate([es, jnp.zeros(16, jnp.int32)])
    ed_p = jnp.concatenate([ed, jnp.full(16, 14, jnp.int32)])
    _, o1 = m_eq.forward_edges(p, EQ_CFG, nf, pos, es, ed, 14)
    _, o2 = m_eq.forward_edges(p, EQ_CFG, nf, pos, es_p, ed_p, 14)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-5)


# -------------------------------------------------------------- irreps ----

def test_wigner_homomorphism():
    """D(R1 @ R2) == D(R1) @ D(R2) for l = 0..3."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    v1 = jax.random.normal(k1, (3,))
    v2 = jax.random.normal(k2, (3,))
    R1 = rotation_to_align_z(v1[None])[0]
    R2 = rotation_to_align_z(v2[None])[0]
    D1 = wigner_d_stack(R1[None], 3)
    D2 = wigner_d_stack(R2[None], 3)
    D12 = wigner_d_stack((R1 @ R2)[None], 3)
    for l in range(4):
        np.testing.assert_allclose(
            np.asarray(D12[l][0]), np.asarray(D1[l][0] @ D2[l][0]),
            rtol=1e-4, atol=1e-5)


def test_wigner_orthogonality():
    v = jnp.array([[0.3, -0.5, 0.8], [1.0, 0.0, 0.0], [0.0, 0.0, -1.0]])
    R = rotation_to_align_z(v)
    D = wigner_d_stack(R, 3)
    for l in range(4):
        eye = np.eye(2 * l + 1)
        for b in range(v.shape[0]):
            np.testing.assert_allclose(
                np.asarray(D[l][b] @ D[l][b].T), eye, rtol=1e-4, atol=1e-5)


def test_sph_harm_z_direction():
    """Y_l(z) is the m=0 basis vector with norm sqrt((2l+1)/4pi)."""
    import math
    sh = sph_harm_from_wigner(jnp.array([[0.0, 0.0, 1.0]]), 2)[0]
    want = np.zeros(9)
    for l, start in ((0, 0), (1, 1), (2, 4)):
        want[start + l] = math.sqrt((2 * l + 1) / (4 * math.pi))  # m = 0
    np.testing.assert_allclose(np.asarray(sh), want, atol=1e-5)


# -------------------------------------------------------------- GraphCast ----

def test_graphcast_aggregation_oracle():
    """One processor layer's segment_sum equals a numpy scatter oracle."""
    cfg = GraphCastConfig(n_layers=1, d_hidden=8, n_vars=5, d_edge_in=4,
                          remat=False)
    p = init_graphcast(jax.random.PRNGKey(0), cfg)
    nf, pos, es, ed = _graph(d_feat=5)
    ef = jax.random.normal(jax.random.PRNGKey(9), (50, 4))
    out = m_gc.forward_edges(p, cfg, nf, ef, es, ed, 14)
    assert out.shape == (14, 5)
    assert bool(jnp.isfinite(out).all())
    # isolated node (not a dst of any edge) must still produce output
    lonely = jnp.array([20]) if False else None


def test_graphcast_grad_finite():
    cfg = GraphCastConfig(n_layers=2, d_hidden=8, n_vars=5, d_edge_in=4,
                          remat=True)
    p = init_graphcast(jax.random.PRNGKey(0), cfg)
    nf, pos, es, ed = _graph(d_feat=5)
    ef = jax.random.normal(jax.random.PRNGKey(9), (50, 4))
    loss, grads = jax.value_and_grad(m_gc.loss_edges)(
        p, cfg, nf, ef, es, ed, nf, 14)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


# -------------------------------------------------------------- GraphSAGE ----

def test_sage_blocks_vs_edges_consistency():
    """Block mode on a full bipartite expansion == edge mode result for a
    node whose sampled neighborhood is its exact neighborhood."""
    cfg = SageConfig(n_layers=2, d_hidden=8, d_feat=6, n_classes=3)
    p = init_sage(jax.random.PRNGKey(0), cfg)
    # graph: node 0 <- {1, 2}; 1 <- {2}; 2 <- {1}; mean aggregator
    nf = jax.random.normal(jax.random.PRNGKey(1), (3, 6))
    es = jnp.array([1, 2, 2, 1], jnp.int32)
    ed = jnp.array([0, 0, 1, 2], jnp.int32)
    full = m_sage.forward_edges(p, cfg, nf, es, ed, 3)
    # block mode for seed 0: n1 = {1,2}, n2(1)={2},{2}; n2(2)={1},{1}
    x_seed = nf[0:1]
    x_n1 = nf[jnp.array([[1, 2]])]
    x_n2 = nf[jnp.array([[2, 2], [1, 1]])]
    blk = m_sage.forward_blocks(p, cfg, x_seed, x_n1, x_n2)
    np.testing.assert_allclose(np.asarray(blk[0]), np.asarray(full[0]),
                               rtol=1e-4, atol=1e-4)


def test_neighbor_sampler_valid_and_isolated():
    from repro.graphs import rmat_graph
    g = rmat_graph(64, 256, seed=0)
    seeds = jnp.arange(32, dtype=jnp.int32)
    nbrs = neighbor_sampler(jax.random.PRNGKey(0), g.dst_offsets, g.in_src,
                            seeds, fanout=5)
    nbrs = np.asarray(nbrs)
    indeg = np.asarray(g.in_degree())
    for i, s in enumerate(np.asarray(seeds)):
        if indeg[s] == 0:
            assert (nbrs[i] == 64).all()      # sentinel
        else:
            # sampled neighbors must be true in-neighbors
            lo, hi = int(g.dst_offsets[s]), int(g.dst_offsets[s + 1])
            true_nbrs = set(np.asarray(g.in_src)[lo:hi].tolist())
            assert set(nbrs[i].tolist()) <= true_nbrs


def test_graphcast_dst_partitioned_equals_plain():
    """The paper-C2 shard_map processor == the plain edge-list processor
    on a 1-device mesh (local dst ids == global ids)."""
    import dataclasses
    from repro.models.gnn.graphcast import forward_edges_dst_partitioned
    cfg = GraphCastConfig(n_layers=4, d_hidden=16, n_vars=5, d_edge_in=4,
                          remat=False)
    p = init_graphcast(jax.random.PRNGKey(0), cfg)
    nf, pos, es, ed = _graph(d_feat=5)
    ef = jax.random.normal(jax.random.PRNGKey(9), (50, 4))
    o1 = m_gc.forward_edges(p, cfg, nf, ef, es, ed, 14)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg2 = dataclasses.replace(cfg, node_axes=("data",), remat_group=2,
                               remat=True)
    with mesh:
        o2 = forward_edges_dst_partitioned(p, cfg2, nf, ef, es, ed, 14,
                                           mesh=mesh)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-5)
