"""Registry sanity (10 archs x 4 shapes = 40 cells), smoke steps, data
pipelines, graph generators."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_archs, all_cells, get_arch, IMM_EXPERIMENTS
from repro.data.tokens import TokenPipeline
from repro.data.clicks import synthetic_click_batches
from repro.graphs import rmat_graph, scaled_snap
from repro.graphs.partition import partition_edges_by_dst, balance_report


ASSIGNED = [
    "moonshot-v1-16b-a3b", "grok-1-314b", "h2o-danube-3-4b", "minicpm-2b",
    "qwen1.5-0.5b", "graphcast", "equiformer-v2", "egnn",
    "graphsage-reddit", "fm",
]


def test_registry_has_all_10_archs_and_40_cells():
    archs = all_archs()
    assert sorted(archs) == sorted(ASSIGNED)
    assert len(all_cells(include_skipped=True)) == 40
    skipped = set(all_cells(include_skipped=True)) - set(all_cells())
    # long_500k skipped exactly for the pure full-attention LMs
    assert skipped == {(a, "long_500k") for a in
                       ("moonshot-v1-16b-a3b", "grok-1-314b",
                        "minicpm-2b", "qwen1.5-0.5b")}


def test_assigned_dims_match_spec():
    """The exact published configs from the assignment block."""
    c = get_arch("moonshot-v1-16b-a3b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.n_experts, c.top_k) == \
        (48, 2048, 16, 16, 1408, 163840, 64, 6)
    c = get_arch("grok-1-314b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.n_experts, c.top_k) == \
        (64, 6144, 48, 8, 32768, 131072, 8, 2)
    c = get_arch("h2o-danube-3-4b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (24, 3840, 32, 8, 10240, 32000)
    assert c.window > 0
    c = get_arch("minicpm-2b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (40, 2304, 36, 36, 5760, 122753)
    c = get_arch("qwen1.5-0.5b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.qkv_bias) == (24, 1024, 16, 16, 2816, 151936, True)
    c = get_arch("graphcast").config
    assert (c.n_layers, c.d_hidden, c.mesh_refinement, c.n_vars) == \
        (16, 512, 6, 227)
    c = get_arch("equiformer-v2").config
    assert (c.n_layers, c.d_hidden, c.l_max, c.m_max, c.n_heads) == \
        (12, 128, 6, 2, 8)
    c = get_arch("egnn").config
    assert (c.n_layers, c.d_hidden) == (4, 64)
    c = get_arch("graphsage-reddit").config
    assert (c.n_layers, c.d_hidden, c.aggregator, c.sample_sizes) == \
        (2, 128, "mean", (25, 10))
    c = get_arch("fm").config
    assert (c.n_sparse, c.embed_dim, c.interaction) == (39, 10, "fm-2way")


def test_grok_param_count_near_314b():
    c = get_arch("grok-1-314b").config
    assert c.param_count() == pytest.approx(314e9, rel=0.05)


def test_moonshot_active_params_near_3b():
    c = get_arch("moonshot-v1-16b-a3b").config
    assert c.active_param_count() == pytest.approx(3.3e9, rel=0.25)


@pytest.mark.parametrize("arch_id", ASSIGNED)
def test_smoke_step_every_arch(arch_id):
    arch = get_arch(arch_id)
    params = arch.init_fn(jax.random.PRNGKey(0), arch.smoke_config)
    out = arch.smoke_step(params, arch.smoke_config, jax.random.PRNGKey(1))
    assert out, arch_id
    for k, v in out.items():
        arr = jnp.asarray(v, jnp.float32)
        assert bool(jnp.isfinite(arr).all()), (arch_id, k)


def test_imm_experiments_cover_paper_table1():
    assert sorted(IMM_EXPERIMENTS) == sorted(
        ["com-Amazon", "com-YouTube", "com-DBLP", "com-LJ", "soc-Pokec",
         "as-Skitter", "web-Google", "Twitter7"])


def test_imm_experiment_model_configs_resolve_to_registered_samplers():
    """Every per-experiment model config (IC/LT plus the WC/GT scenario
    models) composes to a registered sampler on both sides of the
    dense/sparse size threshold."""
    import dataclasses

    from repro.core.sampler import default_sampler_name, get_sampler
    from repro.graphs import rmat_graph
    small = rmat_graph(64, 256, seed=0)
    exp = IMM_EXPERIMENTS["com-Amazon"]
    for cfg in (exp.cfg_ic, exp.cfg_lt, exp.cfg_wc, exp.cfg_gt):
        name = default_sampler_name(small, cfg)
        assert name.startswith(f"{cfg.model}/")
        assert callable(get_sampler(name))
        sparse_cfg = dataclasses.replace(cfg, dense_sampler_max_n=8)
        assert callable(get_sampler(default_sampler_name(small, sparse_cfg)))


# ------------------------------------------------------------------ data ----

def test_token_pipeline_deterministic_and_sharded():
    p0 = TokenPipeline(vocab=64, batch=4, seq_len=16, seed=1, shard=0)
    p1 = TokenPipeline(vocab=64, batch=4, seq_len=16, seed=1, shard=1)
    t0a, l0a = p0.batch_at(5)
    t0b, _ = p0.batch_at(5)
    t1, _ = p1.batch_at(5)
    np.testing.assert_array_equal(t0a, t0b)        # deterministic
    assert (t0a != t1).any()                       # shards disjoint
    assert (l0a[:, :-1] == t0a[:, 1:]).all()       # labels shifted
    assert (l0a[:, -1] == -1).all()


def test_click_stream_learnable_signal():
    labels_all = []
    for idx, labels in synthetic_click_batches(4, 32, 512, 4, seed=0):
        assert idx.shape == (512, 4) and labels.shape == (512,)
        labels_all.append(labels)
    rate = np.concatenate(labels_all).mean()
    assert 0.2 < rate < 0.8                         # non-degenerate


# ---------------------------------------------------------------- graphs ----

def test_rmat_power_law_and_table1_style_stats():
    g = rmat_graph(1024, 8192, seed=0)
    deg = np.asarray(g.out_degree())
    assert deg.max() > 10 * max(np.median(deg), 1)  # skewed degrees
    assert g.dst_offsets.shape == (g.n + 1,)
    assert int(g.dst_offsets[-1]) == g.m


def test_lt_weights_sum_below_one():
    g = rmat_graph(256, 2048, seed=1)
    total = np.asarray(g.in_lt_total)
    assert (total <= 1.0 + 1e-5).all()
    # cumulative weights are within-segment increasing
    cum = np.asarray(g.in_lt_cum)
    off = np.asarray(g.dst_offsets)
    for v in range(0, 256, 37):
        seg = cum[off[v]:off[v + 1]]
        assert (np.diff(seg) >= -1e-6).all()


def test_scaled_snap_preserves_density():
    g = scaled_snap("com-Amazon", 0.01, seed=0)
    from repro.graphs.datasets import SNAP_STATS
    n, m, _ = SNAP_STATS["com-Amazon"]
    assert g.n == pytest.approx(n * 0.01, rel=0.3)


def test_edge_partitioner_local_dst_and_balance():
    g = rmat_graph(128, 1024, seed=2)
    src, dst = np.asarray(g.edge_src), np.asarray(g.edge_dst)
    slabs_s, slabs_d, block = partition_edges_by_dst(src, dst, 128, 4)
    assert slabs_s.shape == slabs_d.shape
    # every non-pad local dst is inside the block
    assert (slabs_d <= block).all()
    rep = balance_report(dst, 128, 4)
    assert rep["imbalance"] >= 1.0
