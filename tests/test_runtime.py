"""Runtime layer: fault tolerance, checkpointing, straggler, compression,
elastic resharding."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip on clean machines
from hypothesis import given, settings, strategies as st

from repro.checkpoint.store import (
    save_checkpoint, load_checkpoint, latest_step, CheckpointManager,
)
from repro.runtime.loop import TrainLoop, LoopConfig, RemeshRequested
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.compression import (
    compress_int8, decompress_int8, init_error_feedback,
    compress_with_feedback,
)
from repro.runtime.elastic import reshard_tree, replicated_plan

settings.register_profile("ci3", deadline=None, max_examples=20)
settings.load_profile("ci3")


# ------------------------------------------------------------- checkpoint ----

def test_checkpoint_roundtrip_nested():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(5), "b": [jnp.ones((2, 2)),
                                          {"c": jnp.float32(3.0)}],
                "t": (jnp.zeros(3), jnp.int32(7))}
        save_checkpoint(d, 3, tree)
        step, got = load_checkpoint(d)
        assert step == 3
        np.testing.assert_array_equal(got["a"], np.arange(5))
        assert isinstance(got["b"], list) and isinstance(got["t"], tuple)
        assert float(got["b"][1]["c"]) == 3.0


def test_checkpoint_rolling_retention_and_latest():
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(d, s, {"x": jnp.int32(s)}, keep=2)
        assert latest_step(d) == 5
        files = [f for f in os.listdir(d) if f.endswith(".npz")]
        assert len(files) == 2


def test_checkpoint_latest_pointer_fallback():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"x": jnp.int32(1)})
        save_checkpoint(d, 2, {"x": jnp.int32(2)})
        with open(os.path.join(d, "latest"), "w") as f:
            f.write("999")                         # stale pointer
        step, tree = load_checkpoint(d)
        assert step == 2 and int(tree["x"]) == 2


def test_checkpoint_no_partial_files_visible():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"x": jnp.zeros(10)})
        leftovers = [f for f in os.listdir(d) if ".tmp" in f]
        assert leftovers == []


def test_manager_restore_or_init():
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, save_every=1)
        step, tree = m.restore_or_init(lambda: {"x": jnp.int32(42)})
        assert step == 0 and int(tree["x"]) == 42
        m.save(7, {"x": jnp.int32(7)})
        step, tree = m.restore_or_init(lambda: {"x": jnp.int32(42)})
        assert step == 7 and int(tree["x"]) == 7


# ------------------------------------------------------------ fault loop ----

def test_loop_retries_transient_fault():
    with tempfile.TemporaryDirectory() as d:
        faults = {"n": 1}

        def inject(step, retries):
            if step == 3 and faults["n"] > 0:
                faults["n"] -= 1
                return True
            return False

        loop = TrainLoop(
            LoopConfig(total_steps=6, checkpoint_dir=d, save_every=2,
                       max_retries=2),
            lambda s, b: (s + b, {"v": s}), lambda step: jnp.float32(1.0),
            lambda: jnp.float32(0.0), inject_fault=inject)
        final = loop.run()
        assert float(final) == 6.0
        assert loop.recoveries == 0          # retry succeeded, no restore


def test_loop_restores_from_checkpoint_and_replays():
    with tempfile.TemporaryDirectory() as d:
        faults = {"n": 3}

        def inject(step, retries):
            if step == 4 and faults["n"] > 0:
                faults["n"] -= 1
                return True
            return False

        loop = TrainLoop(
            LoopConfig(total_steps=8, checkpoint_dir=d, save_every=2,
                       max_retries=2),
            lambda s, b: (s + b, {"v": s}), lambda step: jnp.float32(1.0),
            lambda: jnp.float32(0.0), inject_fault=inject)
        final = loop.run()
        assert float(final) == 8.0           # deterministic replay
        assert loop.recoveries == 1


def test_loop_requests_remesh_on_persistent_straggle():
    with tempfile.TemporaryDirectory() as d:
        import time as _t

        def slow_step(s, b):
            if float(s) >= 6.0:
                _t.sleep(0.05)
            return s + b, {"v": s}

        loop = TrainLoop(
            LoopConfig(total_steps=30, checkpoint_dir=d, save_every=100,
                       straggler_threshold=1.5),
            slow_step, lambda step: jnp.float32(1.0),
            lambda: jnp.float32(0.0))
        with pytest.raises(RemeshRequested):
            loop.run()
        # checkpoint must have been written before raising
        assert latest_step(d) is not None


# -------------------------------------------------------------- straggler ----

def test_straggler_monitor_flags_outlier():
    m = StragglerMonitor(threshold=2.0, warmup_steps=2)
    for i in range(5):
        assert not m.observe(i, 0.1)
    assert m.observe(5, 0.5)
    assert not m.unhealthy
    assert m.observe(6, 0.5) and m.observe(7, 0.5)
    assert m.unhealthy


def test_straggler_ewma_excludes_outliers():
    m = StragglerMonitor(threshold=2.0, warmup_steps=1)
    m.observe(0, 0.1)
    m.observe(1, 10.0)   # flagged; must not poison the EWMA
    assert m.ewma == pytest.approx(0.1)


# ------------------------------------------------------------ compression ----

@given(st.integers(0, 1000))
def test_compress_roundtrip_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0
    q, s = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6    # half-ulp of the quantizer


def test_error_feedback_reduces_bias():
    """With error feedback, the accumulated quantized stream converges to
    the accumulated true stream (bounded residual)."""
    g = jnp.full((8,), 0.01)                   # tiny constant gradient
    ef = init_error_feedback({"g": g})
    acc = np.zeros(8)
    for _ in range(100):
        qt, ef = compress_with_feedback({"g": g}, ef)
        q, s = qt["g"]
        acc += np.asarray(decompress_int8(q, s))
    np.testing.assert_allclose(acc, np.full(8, 1.0), rtol=0.05)


# ---------------------------------------------------------------- elastic ----

def test_reshard_tree_roundtrip():
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": np.arange(8.0), "b": [np.ones((2, 2))]}
    out = reshard_tree(tree, replicated_plan(mesh))
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
    assert out["w"].sharding.mesh.shape["data"] == 1


def test_checkpoint_then_reshard_elasticity():
    """Save under one 'mesh', restore into another (CPU: 1-device meshes
    with different axis layouts — exercises the full path)."""
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"w": jnp.arange(16.0).reshape(4, 4)})
        _, host_tree = load_checkpoint(d)
        mesh2 = jax.make_mesh((1, 1), ("data", "model"))
        out = reshard_tree(host_tree, replicated_plan(mesh2))
        np.testing.assert_array_equal(
            np.asarray(out["w"]), np.arange(16.0).reshape(4, 4))
