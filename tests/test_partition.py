"""Property wall for `repro.graphs.partition` — the one `VertexPartition`
contract every 2D layer (store columns, sampler tables, sharded
selection's id mapping, streaming reverse-touch) builds on.

Hypothesis is not available in the image, so these are seeded-RNG
parameter sweeps: every invariant is checked over a grid of (n, shards)
shapes x weight distributions (uniform, rmat power-law, adversarial
point masses), equal and balanced layouts alike.
"""
import numpy as np
import pytest

from repro.graphs import (
    VertexPartition,
    balance_report,
    balanced_vertex_partition,
    partition_edges_by_dst,
    resolve_partition,
    rmat_graph,
    vertex_partition,
)

# (n, shards) shapes: degenerate, non-dividing, shards > n, big-ish
SHAPES = [(1, 1), (5, 2), (7, 3), (8, 4), (17, 16), (3, 8),
          (64, 8), (100, 7), (193, 4)]


def _partitions(n, shards, rng):
    """Equal + a spread of balanced layouts (uniform / skewed / point
    masses) for one shape."""
    parts = [vertex_partition(n, shards)]
    parts.append(balanced_vertex_partition(
        n, shards, dst=rng.integers(0, n, size=4 * n)))
    # power-law-ish weights: most mass on a few vertices
    w = (1.0 / (1.0 + np.arange(n, dtype=np.float64))) ** 2
    parts.append(balanced_vertex_partition(n, shards,
                                           weights=rng.permutation(w)))
    # adversarial: all weight on one vertex (blocks must stay valid)
    w = np.ones(n)
    w[int(rng.integers(0, n))] = 1e6
    parts.append(balanced_vertex_partition(n, shards, weights=w))
    # no dst at all: degree-0 everywhere -> uniform weights -> ~equal
    parts.append(balanced_vertex_partition(n, shards))
    return parts


# ----------------------------------------------------------- invariants ----

@pytest.mark.parametrize("n,shards", SHAPES)
def test_partition_covers_every_vertex_exactly_once(n, shards, rng):
    for part in _partitions(n, shards, rng):
        starts = part.starts
        assert starts[0] == 0 and starts[-1] == n
        assert np.all(np.diff(starts) >= 0)
        sizes = part.sizes
        assert sizes.sum() == n
        assert sizes.max(initial=0) <= part.block
        assert part.n_pad == part.shards * part.block
        # the live entries of source_cols are exactly 0..n-1, once each
        src = part.source_cols()
        live = src[src < n]
        assert np.array_equal(np.sort(live), np.arange(n))
        # pad columns carry the sentinel n and nothing else
        assert np.all(src[src >= n] == n)
        assert (src >= n).sum() == part.n_pad - n


@pytest.mark.parametrize("n,shards", SHAPES)
def test_local_id_block_of_round_trip(n, shards, rng):
    u = np.arange(n)
    for part in _partitions(n, shards, rng):
        b = np.asarray(part.block_of(u))
        loc = np.asarray(part.local_id(u))
        starts = part.starts
        # each vertex falls inside its block's global range
        assert np.all(starts[b] <= u) and np.all(u < starts[b + 1])
        assert np.all((0 <= loc) & (loc < part.block))
        assert np.array_equal(starts[b] + loc, u)
        # padded_col is the inverse of source_cols restricted to live ids
        pc = np.asarray(part.padded_col(u))
        assert np.array_equal(pc, part.padded_cols())
        assert np.array_equal(part.source_cols()[pc], u)
        # distinct vertices never share a padded column
        assert np.unique(pc).size == n


@pytest.mark.parametrize("n,shards", SHAPES)
def test_pad_columns_are_invisible(n, shards, rng):
    """A global-order payload gathered into the padded layout and back
    is the identity, and pad columns never receive live data."""
    for part in _partitions(n, shards, rng):
        payload = rng.integers(1, 1 << 30, size=n)
        layout = np.zeros(part.n_pad, dtype=payload.dtype)
        src = part.source_cols()
        live = src < n
        layout[live] = payload[src[live]]
        assert np.array_equal(layout[part.padded_cols()], payload)
        assert np.all(layout[~live] == 0)


@pytest.mark.parametrize("n,shards", [(8, 4), (100, 7), (64, 8), (193, 4)])
def test_partition_edges_by_dst_slabs_are_dst_local(n, shards, rng):
    m = 6 * n
    src = rng.integers(0, n, size=m).astype(np.int32)
    dst = rng.integers(0, n, size=m).astype(np.int32)
    for part in _partitions(n, shards, rng):
        src_slabs, dst_slabs, node_block = partition_edges_by_dst(
            src, dst, n, shards, partition=part)
        assert node_block == part.block
        assert src_slabs.shape == dst_slabs.shape == (shards, src_slabs.shape[1])
        starts = part.starts
        rebuilt = []
        for s in range(shards):
            real = dst_slabs[s] < node_block
            # padding edges carry the dropped sentinel local id
            assert np.all(dst_slabs[s][~real] == node_block)
            # real edges are dst-local to block s
            g = dst_slabs[s][real] + starts[s]
            assert np.all((starts[s] <= g) & (g < starts[s + 1]))
            rebuilt.extend(zip(src_slabs[s][real].tolist(), g.tolist()))
        # the slabs hold exactly the input edge multiset
        assert sorted(rebuilt) == sorted(zip(src.tolist(), dst.tolist()))


def test_partition_edges_default_layout_unchanged(rng):
    """partition=None must keep producing the historical equal-block
    slabs byte-for-byte (the GNN path depends on it)."""
    n, shards = 50, 4
    src = rng.integers(0, n, size=300).astype(np.int32)
    dst = rng.integers(0, n, size=300).astype(np.int32)
    a = partition_edges_by_dst(src, dst, n, shards)
    b = partition_edges_by_dst(src, dst, n, shards,
                               partition=vertex_partition(n, shards))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


# ------------------------------------------------------------- balancing ----

@pytest.mark.parametrize("shards", [2, 4, 8])
def test_balanced_beats_equal_on_rmat(shards):
    """On a power-law (rmat) degree distribution the balanced layout's
    per-shard edge imbalance is never worse than equal blocks, and
    strictly better whenever equal blocks are meaningfully skewed."""
    for seed in range(3):
        g = rmat_graph(256, 2048, seed=seed)
        eq = balance_report(g.edge_dst, g.n, shards)
        bal = balance_report(
            g.edge_dst, g.n, shards,
            partition=balanced_vertex_partition(g.n, shards, dst=g.edge_dst))
        assert bal["imbalance"] <= eq["imbalance"] + 1e-9
        if eq["imbalance"] > 1.1:
            assert bal["imbalance"] < eq["imbalance"]


def test_balanced_uniform_degrees_reduce_to_near_equal():
    """With uniform weights the quantile cuts land on (near-)equal
    blocks; the layout stays valid and fully covering."""
    part = balanced_vertex_partition(64, 4, weights=np.ones(64))
    assert np.array_equal(part.sizes, [16, 16, 16, 16])
    assert part.block == 16


def test_balanced_point_mass_keeps_blocks_contiguous():
    """A single huge-degree vertex cannot break contiguity or coverage —
    some blocks may be tiny (even empty), never out of order."""
    w = np.ones(32)
    w[5] = 1e9
    part = balanced_vertex_partition(32, 4, weights=w)
    starts = part.starts
    assert starts[0] == 0 and starts[-1] == 32
    assert np.all(np.diff(starts) >= 0)
    assert part.sizes.sum() == 32


# --------------------------------------------------------------- resolve ----

def test_resolve_partition_specs():
    eq = resolve_partition(None, 40, 4)
    assert eq.is_equal and eq == vertex_partition(40, 4)
    assert resolve_partition("equal", 40, 4) == eq
    g = rmat_graph(64, 512, seed=0)
    bal = resolve_partition("balanced", g.n, 4, dst=g.edge_dst)
    assert not bal.is_equal
    assert resolve_partition(bal, g.n, 4) is bal
    with pytest.raises(ValueError):
        resolve_partition(bal, g.n + 1, 4)
    with pytest.raises(ValueError):
        resolve_partition(bal, g.n, 8)
    with pytest.raises(ValueError):
        resolve_partition("zigzag", 40, 4)


def test_balanced_weights_shape_validated():
    with pytest.raises(ValueError):
        balanced_vertex_partition(10, 2, weights=np.ones(9))
