"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


# ------------------------------------------------------ coverage_matvec ----

@pytest.mark.parametrize("theta,n", [(64, 100), (300, 700), (1024, 512),
                                     (257, 1000), (1, 33)])
@pytest.mark.parametrize("dtype", [jnp.uint8, jnp.int8])
def test_coverage_matvec_sweep(theta, n, dtype):
    key = jax.random.PRNGKey(theta * 7 + n)
    R = (jax.random.uniform(key, (theta, n)) < 0.3).astype(dtype)
    alive = jax.random.uniform(jax.random.PRNGKey(1), (theta,)) < 0.7
    got = ops.coverage_matvec(alive, R, interpret=True)
    want = ref.coverage_matvec_ref(alive, R)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("tile_theta,tile_n", [(64, 128), (256, 512),
                                               (128, 256)])
def test_coverage_matvec_tilings(tile_theta, tile_n):
    key = jax.random.PRNGKey(0)
    R = (jax.random.uniform(key, (500, 900)) < 0.2).astype(jnp.uint8)
    alive = jax.random.uniform(jax.random.PRNGKey(1), (500,)) < 0.5
    got = ops.coverage_matvec(alive, R, interpret=True,
                              tile_theta=tile_theta, tile_n=tile_n)
    want = ref.coverage_matvec_ref(alive, R)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------- fused_select ----

@pytest.mark.parametrize("theta,n", [(64, 100), (513, 300), (256, 2000)])
def test_fused_select_sweep(theta, n):
    key = jax.random.PRNGKey(theta + n)
    R = (jax.random.uniform(key, (theta, n)) < 0.25).astype(jnp.uint8)
    alive = jax.random.uniform(jax.random.PRNGKey(2), (theta,)) < 0.8
    mx, idx = ops.fused_select(alive, R, interpret=True)
    mref, iref = ref.fused_select_ref(alive, R)
    assert float(mx) == float(mref)
    # argmax may differ only among ties
    counter = np.asarray(ref.coverage_matvec_ref(alive, R))
    assert counter[int(idx)] == float(mref)


def test_fused_select_empty_alive():
    R = jnp.ones((32, 64), jnp.uint8)
    alive = jnp.zeros((32,), bool)
    mx, idx = ops.fused_select(alive, R, interpret=True)
    assert float(mx) == 0.0
    assert 0 <= int(idx) < 64


# ------------------------------------------------------------ ic_frontier ----

@pytest.mark.parametrize("B,n", [(16, 64), (64, 200), (128, 513)])
def test_ic_frontier_sweep(B, n):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(B + n), 3)
    frontier = jax.random.uniform(k1, (B, n)) < 0.1
    visited = jnp.logical_or(frontier,
                             jax.random.uniform(k2, (B, n)) < 0.2)
    P = jnp.where(jax.random.uniform(k3, (n, n)) < 0.05,
                  jax.random.uniform(k1, (n, n)), 0.0)
    logq = jnp.maximum(jnp.log1p(-P), -30.0)
    rand = jax.random.uniform(k2, (B, n))
    got = ops.ic_frontier_step(frontier, visited, logq, rand,
                               interpret=True)
    want = ref.ic_frontier_ref(frontier, visited, logq, rand)
    np.testing.assert_array_equal(np.asarray(got).astype(bool),
                                  np.asarray(want))


# --------------------------------------------------------- fm_interaction ----

@pytest.mark.parametrize("B,F,K", [(32, 39, 10), (100, 8, 4), (1025, 16, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fm_interaction_sweep(B, F, K, dtype):
    v = (jax.random.normal(jax.random.PRNGKey(B), (B, F, K)) * 0.3
         ).astype(dtype)
    got = ops.fm_interaction(v, interpret=True)
    want = ref.fm_interaction_ref(v.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_fm_interaction_matches_explicit_pairwise():
    """Sum-square trick == explicit sum_{i<j} <v_i, v_j>."""
    v = jax.random.normal(jax.random.PRNGKey(0), (16, 6, 4))
    got = ops.fm_interaction(v, interpret=True)
    inner = jnp.einsum("bik,bjk->bij", v, v)
    iu = jnp.triu_indices(6, k=1)
    want = inner[:, iu[0], iu[1]].sum(-1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# -------------------------------------------------------- flash_attention ----

@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (2, 8, 8, 64, 64, 32),       # MHA
    (2, 8, 2, 64, 64, 32),       # GQA 4:1
    (1, 4, 1, 128, 128, 64),     # MQA
    (2, 4, 2, 1, 128, 64),       # decode shape
    (1, 4, 4, 100, 100, 32),     # non-tile-multiple
])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, D):
    keys = jax.random.split(jax.random.PRNGKey(Sq + Skv), 3)
    q = jax.random.normal(keys[0], (B, Hq, Sq, D))
    k = jax.random.normal(keys[1], (B, Hkv, Skv, D))
    v = jax.random.normal(keys[2], (B, Hkv, Skv, D))
    got = ops.flash_attention(q, k, v, causal=True, interpret=True,
                              tile_q=32, tile_k=32)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [8, 32])
def test_flash_attention_sliding_window(window):
    keys = jax.random.split(jax.random.PRNGKey(window), 3)
    q = jax.random.normal(keys[0], (1, 4, 96, 32))
    k = jax.random.normal(keys[1], (1, 2, 96, 32))
    v = jax.random.normal(keys[2], (1, 2, 96, 32))
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              interpret=True, tile_q=32, tile_k=32)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(keys[0], (1, 4, 64, 32)).astype(dtype)
    k = jax.random.normal(keys[1], (1, 4, 64, 32)).astype(dtype)
    v = jax.random.normal(keys[2], (1, 4, 64, 32)).astype(dtype)
    got = ops.flash_attention(q, k, v, interpret=True)
    want = ref.attention_ref(q, k, v)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


# ----------------------------------------------------------- arena_commit ----
#
# the commit tail of the fused sample->write->count chain: encode (bitmap
# passthrough or MXU bit-pack) + exact int32 column count in one pass.
# Equality is bitwise, not approximate — the engine's fused path commits
# these bytes and counts directly into the arena.

@pytest.mark.parametrize("B,n", [(64, 128), (33, 100), (128, 1000),
                                 (1, 7), (127, 513)])
@pytest.mark.parametrize("kind", ["bitmap", "packed"])
def test_arena_commit_bitwise(B, n, kind):
    key = jax.random.PRNGKey(B * 13 + n)
    rows = (jax.random.uniform(key, (B, n)) < 0.3).astype(jnp.uint8)
    stored, colsum = ops.arena_commit(rows, kind=kind, interpret=True)
    sref, cref = ref.arena_commit_ref(rows, kind=kind)
    np.testing.assert_array_equal(np.asarray(stored), np.asarray(sref))
    np.testing.assert_array_equal(np.asarray(colsum), np.asarray(cref))


@pytest.mark.parametrize("kind", ["bitmap", "packed"])
def test_arena_commit_tilings(kind):
    rows = (jax.random.uniform(jax.random.PRNGKey(3), (200, 300))
            < 0.5).astype(jnp.uint8)
    got_s, got_c = ops.arena_commit(rows, kind=kind, interpret=True,
                                    tile_rows=64, tile_n=128)
    ref_s, ref_c = ref.arena_commit_ref(rows, kind=kind)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(ref_s))
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(ref_c))
